"""Shared CSV-row + JSON-artifact reporting for the bench scripts.

``gas_microbench.py`` and ``train_serve_bench.py`` emit the same shape:
one ``name,value[,derived]`` CSV row per measurement on stdout plus a
record in a machine-readable artifact (``BENCH_gas.json`` /
``BENCH_serve.json``) that CI uploads and ``sched.load_costs`` & friends
consume.  Keeping the writer here keeps the two artifact schemas from
forking.
"""
import json


def new_result() -> dict:
    return {"schema": 1, "rows": []}


def make_report(result: dict):
    """Bind a ``report(name, value, derived="", unit="us", **extra)``
    function to ``result``.

    ``unit`` keys the JSON field ("us" for timings, "x" for ratios,
    "us_per_kib" for slopes, ...) so artifact consumers never mix units.
    """

    def report(name: str, value: float, derived: str = "", unit: str = "us",
               **extra) -> None:
        digits = 1 if unit == "us" else 3
        text = f"{name},{value:.{digits}f}"
        print(f"{text},{derived}" if derived else text)
        row = {"name": name, unit: round(float(value), digits)}
        if derived:
            row["derived"] = derived
        row.update(extra)
        result["rows"].append(row)

    return report


def write_artifact(result: dict, path: str) -> None:
    with open(path, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    print(f"wrote {path}")


def append_history(result: dict, path: str, run_id: str | None = None) -> None:
    """Append one line of headline numbers to the committed trajectory
    log (``BENCH_history.jsonl``): ``{"run", "date", "rows"}`` with
    ``rows`` mapping row name to its measured value.  A point-in-time
    artifact answers "is this run ok"; the history answers "is the trend
    ok" — ``check_serve_perf --history`` gates against the trajectory
    median so a slow drift (each step inside the single-run tolerance)
    still trips CI."""
    import datetime

    date = datetime.datetime.now(datetime.timezone.utc)
    rows = {}
    for r in result.get("rows", []):
        for unit in ("us", "x", "mb_s", "pct", "tokens", "us_per_kib"):
            if unit in r:
                rows[r["name"]] = r[unit]
                break
    line = {
        "run": run_id or date.strftime("%Y%m%dT%H%M%SZ"),
        "date": date.strftime("%Y-%m-%d"),
        "rows": rows,
    }
    with open(path, "a") as f:
        f.write(json.dumps(line, sort_keys=True) + "\n")
    print(f"appended {path} ({len(rows)} rows)")

"""Serving-perf guard over the ``BENCH_serve.json`` artifact.

Parses the serving bench rows and flags the two regressions the paged
decode rework is specifically not allowed to reintroduce:

- ``serve_paged_decode`` slower than ``serve_dense_decode`` (the paged
  pool must not tax the decode hot path), and
- ``paged_fetch_overlap`` gaining nothing over blocking gets
  (``overlap_gap <= 1.0``) — the split-phase prefetch would be dead
  weight.

Warnings go to stdout and, when ``$GITHUB_STEP_SUMMARY`` is set, to the
workflow run's summary page.  Exit code is 0 on warnings (perf noise on
shared CI runners must not gate merges) and 2 only when the artifact is
missing or malformed.

Usage: ``python benchmarks/check_serve_perf.py [BENCH_serve.json]``
"""
import json
import os
import sys


def check(rows):
    """Return a list of human-readable warning strings."""
    by_name = {r.get("name"): r for r in rows}
    warnings = []

    dense = by_name.get("serve_dense_decode")
    paged = by_name.get("serve_paged_decode")
    if dense and paged:
        d, p = dense.get("tok_per_s", 0.0), paged.get("tok_per_s", 0.0)
        if p < d:
            warnings.append(
                f"paged decode is SLOWER than dense decode: "
                f"{p:.1f} tok/s vs {d:.1f} tok/s "
                f"(the paged pool must not tax the decode hot path)"
            )
    else:
        warnings.append(
            "missing serve_dense_decode/serve_paged_decode rows "
            "(paged sections skipped?)"
        )

    overlap = by_name.get("paged_fetch_overlap")
    if overlap:
        gap = overlap.get("overlap_gap", 0.0)
        if gap <= 1.0:
            warnings.append(
                f"split-phase page prefetch gains nothing: overlap_gap "
                f"{gap:.3f}x <= 1.0x vs blocking gets"
            )
    else:
        warnings.append(
            "missing paged_fetch_overlap row (overlap bench skipped?)"
        )
    return warnings


def main(argv):
    path = argv[1] if len(argv) > 1 else "BENCH_serve.json"
    try:
        with open(path) as f:
            artifact = json.load(f)
        rows = artifact["rows"]
    except (OSError, KeyError, ValueError) as e:
        print(f"check_serve_perf: cannot read {path}: {e}", file=sys.stderr)
        return 2

    warnings = check(rows)
    summary_path = os.environ.get("GITHUB_STEP_SUMMARY")
    lines = []
    if warnings:
        lines.append("### :warning: serving perf warnings")
        lines += [f"- {w}" for w in warnings]
    else:
        lines.append(
            "### serving perf OK — paged decode >= dense, overlap gap > 1.0x"
        )
    for line in lines:
        print(line)
    if summary_path:
        with open(summary_path, "a") as f:
            f.write("\n".join(lines) + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))

"""Serving-perf gate over the ``BENCH_serve.json`` artifact.

Parses the serving bench rows and flags the regressions the paged
decode rework is specifically not allowed to reintroduce:

- ``serve_paged_decode`` slower than ``serve_dense_decode`` (the paged
  pool must not tax the decode hot path),
- ``paged_fetch_overlap`` gaining nothing over blocking gets
  (``overlap_gap <= 1.0``) — the split-phase prefetch would be dead
  weight, and
- the tensor-parallel decode group losing to a single rank at the same
  per-rank byte budget (``serve_tp_decode_tp2`` <= ``serve_tp_decode_tp1``
  rank-concurrent tok/s), when the TP section is present in the artifact.

With ``--baseline PREV.json`` (the previous main-branch artifact) the
gate additionally compares throughput row-by-row and flags any shared
row whose ``tok_per_s`` fell MORE than 15% below the baseline — the
cross-run regression net the within-run orderings cannot catch.  An
unreadable baseline is noted and skipped (first run, expired artifact),
never fatal: the gate must not brick CI on its own bootstrap.

With ``--history BENCH_history.jsonl`` (the committed trajectory log
``train_serve_bench --json`` appends to) the gate also compares each
throughput row's headline against the MEDIAN of its whole trajectory —
the slow-drift net a one-run baseline cannot provide, since each step
inside the single-run tolerance walks the baseline down with it.

Findings go to stdout and, when ``$GITHUB_STEP_SUMMARY`` is set, to the
workflow run's summary page.  By default any finding FAILS the check
(exit 1): the serving benches run single-process on a pinned smoke
config, so these orderings are stable, not noise.  Nightly/scheduled
runs on shared runners can pass ``--warn-only`` to keep the old
advisory behaviour (exit 0 on findings).  Exit 2 means the artifact is
missing or malformed either way.

Usage: ``python benchmarks/check_serve_perf.py [--warn-only]
[--baseline PREV.json] [BENCH_serve.json]``
"""
import argparse
import json
import os
import sys


def check(rows):
    """Return a list of human-readable warning strings."""
    by_name = {r.get("name"): r for r in rows}
    warnings = []

    dense = by_name.get("serve_dense_decode")
    paged = by_name.get("serve_paged_decode")
    if dense and paged:
        d, p = dense.get("tok_per_s", 0.0), paged.get("tok_per_s", 0.0)
        if p < d:
            warnings.append(
                f"paged decode is SLOWER than dense decode: "
                f"{p:.1f} tok/s vs {d:.1f} tok/s "
                f"(the paged pool must not tax the decode hot path)"
            )
    else:
        warnings.append(
            "missing serve_dense_decode/serve_paged_decode rows "
            "(paged sections skipped?)"
        )

    overlap = by_name.get("paged_fetch_overlap")
    if overlap:
        gap = overlap.get("overlap_gap", 0.0)
        if gap <= 1.0:
            warnings.append(
                f"split-phase page prefetch gains nothing: overlap_gap "
                f"{gap:.3f}x <= 1.0x vs blocking gets"
            )
    else:
        warnings.append(
            "missing paged_fetch_overlap row (overlap bench skipped?)"
        )

    # TP section rides the same artifact but is optional (older artifacts
    # predate it) — only gate the ordering when both rows are present.
    tp1 = by_name.get("serve_tp_decode_tp1")
    tp2 = by_name.get("serve_tp_decode_tp2")
    if tp1 and tp2:
        t1, t2 = tp1.get("tok_per_s", 0.0), tp2.get("tok_per_s", 0.0)
        if t2 <= t1:
            warnings.append(
                f"tp=2 decode group does not beat the tp=1 rank at the "
                f"same byte budget: {t2:.1f} tok/s vs {t1:.1f} tok/s "
                f"(head-sharded pages fit ~2x the pages, so the "
                f"weights-bound decode should run ~2x the batch)"
            )

    # observability tax: tracing must be effectively free on the decode
    # hot path.  The row measures the fully-ENABLED recorder (an upper
    # bound on the disabled guards), so <2% here bounds both.  Optional:
    # older artifacts predate the obs section.
    obs = by_name.get("obs_overhead")
    if obs:
        x = obs.get("overhead_x", 0.0)
        if x >= 1.02:
            warnings.append(
                f"tracing overhead on the paged decode path is "
                f"{x:.3f}x >= 1.02x (instrumentation must stay under "
                f"the 2% budget)"
            )
    return warnings


# any shared row losing more than this fraction of its baseline tok/s
# fails the gate (pinned smoke configs drift far less than 15%)
REGRESSION_TOLERANCE = 0.15


def check_baseline(rows, baseline_rows, tolerance=REGRESSION_TOLERANCE):
    """Warnings for rows whose tok/s regressed vs the previous artifact,
    plus baseline rows that VANISHED from the new snapshot — a silently
    skipped section would otherwise shrink the gate's coverage with
    every merge (the old loop iterated only the new rows, so a dropped
    row was indistinguishable from a new one and never reported)."""
    prev = {
        r.get("name"): r.get("tok_per_s")
        for r in baseline_rows
        if r.get("name") and r.get("tok_per_s")
    }
    warnings = []
    seen = set()
    for r in rows:
        name, now = r.get("name"), r.get("tok_per_s")
        if name:
            seen.add(name)
        was = prev.get(name)
        if not name or not now or not was:
            continue  # new row or no throughput to compare
        if now < (1.0 - tolerance) * was:
            warnings.append(
                f"{name} throughput regressed {(1.0 - now / was):.0%} vs "
                f"the previous main-branch artifact: {now:.1f} tok/s vs "
                f"{was:.1f} tok/s (tolerance {tolerance:.0%})"
            )
    # every name in {rows} with a throughput is also checked above; what
    # remains is coverage loss: measured before, missing now
    for name in sorted(set(prev) - seen):
        warnings.append(
            f"{name} vanished from the new snapshot (present in the "
            f"baseline at {prev[name]:.1f} tok/s) — a bench section "
            f"silently stopped running?"
        )
    return warnings


def check_history(rows, history_lines, tolerance=REGRESSION_TOLERANCE):
    """Warnings for rows whose tok/s fell below the TRAJECTORY median.

    ``--baseline`` compares against one previous run, so a slow drift —
    each step inside the single-run tolerance — walks the baseline down
    with it and never trips.  The committed ``BENCH_history.jsonl``
    keeps every headline number ever shipped; gating against the median
    of that trajectory anchors the comparison to where the repo has
    actually been.  Malformed lines are skipped (the log is
    append-only across schema tweaks), and rows with fewer than 3
    historical points are not gated (too few to call a median a
    trend)."""
    hist = {}
    for line in history_lines:
        line = line.strip()
        if not line:
            continue
        try:
            rec = json.loads(line)
            for name, val in rec.get("rows", {}).items():
                hist.setdefault(name, []).append(float(val))
        except (ValueError, TypeError, AttributeError):
            continue
    warnings = []
    for r in rows:
        name, now = r.get("name"), r.get("tok_per_s")
        vals = hist.get(name, [])
        if not name or not now or len(vals) < 3:
            continue
        s = sorted(vals)
        n = len(s)
        median = s[n // 2] if n % 2 else (s[n // 2 - 1] + s[n // 2]) / 2
        if not median:
            continue
        # the history stores the row's HEADLINE value (us for timings,
        # where lower is better); gate tok_per_s rows on the matching
        # headline only when the units line up — the headline of every
        # tok_per_s row in this artifact is us/token, so a regression
        # is the new headline rising above the median
        unit_val = None
        for unit in ("us", "x", "mb_s", "pct", "tokens", "us_per_kib"):
            if unit in r:
                unit_val = (unit, r[unit])
                break
        if not unit_val or unit_val[0] != "us":
            continue
        if unit_val[1] > (1.0 + tolerance) * median:
            warnings.append(
                f"{name} is {unit_val[1] / median - 1.0:.0%} above its "
                f"trajectory median ({unit_val[1]:.1f}us vs "
                f"{median:.1f}us over {n} runs, tolerance "
                f"{tolerance:.0%}) — a drift the one-run baseline "
                f"cannot catch"
            )
    return warnings


def main(argv):
    ap = argparse.ArgumentParser(
        prog="check_serve_perf",
        description="gate CI on the BENCH_serve.json serving-perf rows",
    )
    ap.add_argument(
        "--warn-only", action="store_true",
        help="report findings but exit 0 (nightly runs on shared runners)",
    )
    ap.add_argument(
        "--baseline", metavar="PREV.json", default=None,
        help="previous main-branch BENCH_serve.json: fail any shared row "
        "whose tok/s fell >15%% below it (unreadable baseline: skipped)",
    )
    ap.add_argument(
        "--history", metavar="BENCH_history.jsonl", default=None,
        help="committed trajectory log: fail any throughput row whose "
        "headline drifted >15%% above its all-time median (unreadable "
        "history: skipped)",
    )
    ap.add_argument(
        "path", nargs="?", default="BENCH_serve.json",
        help="bench artifact to check (default: BENCH_serve.json)",
    )
    args = ap.parse_args(argv[1:])
    try:
        with open(args.path) as f:
            artifact = json.load(f)
        rows = artifact["rows"]
    except (OSError, KeyError, ValueError) as e:
        print(
            f"check_serve_perf: cannot read {args.path}: {e}",
            file=sys.stderr,
        )
        return 2

    warnings = check(rows)
    baseline_note = None
    if args.baseline:
        try:
            with open(args.baseline) as f:
                baseline_rows = json.load(f)["rows"]
        except (OSError, KeyError, ValueError) as e:
            baseline_note = (
                f"baseline {args.baseline} unreadable ({e}) — cross-run "
                f"gate skipped (first run or expired artifact)"
            )
        else:
            warnings += check_baseline(rows, baseline_rows)
    history_note = None
    if args.history:
        try:
            with open(args.history) as f:
                history_lines = f.readlines()
        except OSError as e:
            history_note = (
                f"history {args.history} unreadable ({e}) — trajectory "
                f"gate skipped (first run?)"
            )
        else:
            warnings += check_history(rows, history_lines)
    summary_path = os.environ.get("GITHUB_STEP_SUMMARY")
    lines = []
    if warnings:
        head = "warning" if args.warn_only else "x"
        lines.append(f"### :{head}: serving perf regressions")
        lines += [f"- {w}" for w in warnings]
    else:
        lines.append(
            "### serving perf OK — paged decode >= dense, overlap gap "
            "> 1.0x, tp=2 > tp=1"
            + (
                ", throughput within 15% of the previous main artifact"
                if args.baseline and baseline_note is None
                else ""
            )
        )
    if baseline_note:
        lines.append(f"- note: {baseline_note}")
    if history_note:
        lines.append(f"- note: {history_note}")
    for line in lines:
        print(line)
    if summary_path:
        with open(summary_path, "a") as f:
            f.write("\n".join(lines) + "\n")
    if warnings and not args.warn_only:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))

"""GASNet-style microbenchmarks (the paper's evaluation lineage, cf. [4]):
AM round-trip latency, one-sided put bandwidth, collective comparison, and
blocking vs split-phase (Extended API) comm/compute overlap.

Run as __main__ in a subprocess with 8 host devices (benchmarks/run.py does
this).  Prints ``name,us_per_call,derived`` CSV rows.
"""
import os

if __name__ == "__main__":
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8"
    )

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map


def timeit(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def main() -> None:
    from repro.core import gasnet
    from repro.core.engine import make_engine
    from repro.core import collectives
    from repro.optim import compression

    N = 8
    mesh = jax.make_mesh((N,), ("node",))

    # ---- AM round trip latency vs payload -------------------------------- #
    for width in (8, 64, 512):
        ctx = gasnet.Context(mesh, node_axis="node", backend="xla",
                             am_payload_width=width, am_capacity=2)
        ctx.handlers.register(
            "store",
            lambda state, payload, args: {"buf": payload.astype(jnp.float32)},
        )

        def am_rt(seg):
            def prog(node, seg):
                state = {"buf": jnp.zeros((width,), jnp.float32)}
                node.am_medium(
                    jnp.asarray((node.my_id + 1) % N, jnp.int32), "store",
                    payload=node.local(seg)[:width],
                )
                state = node.am_flush(state)
                # reply leg: send it back
                node.am_medium(
                    jnp.asarray((node.my_id - 1) % N, jnp.int32), "store",
                    payload=state["buf"],
                )
                state = node.am_flush(state)
                return state["buf"][None]

            return ctx.spmd(prog, seg, out_specs=P("node"))

        aspace = ctx.address_space()
        aspace.register("b", (max(width, 8),), jnp.float32)
        seg = aspace.alloc("b", init_fn=jnp.ones)
        us = timeit(am_rt, seg)
        print(f"am_roundtrip_w{width},{us:.1f},payload={width * 4}B")

    # ---- one-sided put bandwidth vs size ---------------------------------- #
    ctx = gasnet.Context(mesh, node_axis="node", backend="xla")
    for size in (1 << 10, 1 << 14, 1 << 18, 1 << 20):
        n_el = size // 4
        aspace = ctx.address_space()
        name = f"bw{size}"
        aspace.register(name, (n_el,), jnp.float32)
        seg = aspace.alloc(name)

        def put_prog(node, seg):
            data = jnp.ones((n_el,), jnp.float32) * node.my_id
            return node.put(seg, data, to=gasnet.Shift(1), index=0)

        us = timeit(lambda s: ctx.spmd(put_prog, s), seg)
        gbps = size / (us * 1e-6) / 1e9
        print(f"put_{size}B,{us:.1f},{gbps:.3f}GB/s/node")

    # ---- collectives: GAS ring (xla engine) vs lax natives ---------------- #
    M = 1 << 16  # 64k f32 per node contribution
    x = jnp.ones((N, M), jnp.float32)

    def ring_ar(xl):
        eng = make_engine("xla", "node", N)
        return collectives.ring_all_reduce(eng, xl[0])[None]

    def native_ar(xl):
        return jax.lax.psum(xl[0], "node")[None]

    for nm, fn in (("ring_allreduce", ring_ar), ("xla_allreduce", native_ar)):
        f = jax.jit(shard_map(fn, mesh=mesh, in_specs=(P("node"),),
                                  out_specs=P("node"), check_vma=False))
        us = timeit(f, x)
        print(f"{nm}_{M * 4}B,{us:.1f},sum_ok="
              f"{bool(jnp.allclose(f(x)[0], N))}")

    # ---- int8 EF compressed ring vs f32 ring ------------------------------ #
    err = jnp.zeros((M,), jnp.float32)

    def comp_ar(xl):
        eng = make_engine("xla", "node", N)
        red, _ = compression.compressed_ring_all_reduce(
            eng, xl[0], jnp.zeros((M,), jnp.float32)
        )
        return red[None]

    f = jax.jit(shard_map(comp_ar, mesh=mesh, in_specs=(P("node"),),
                              out_specs=P("node"), check_vma=False))
    us = timeit(f, x)
    wire_f32 = 2 * (N - 1) / N * M * 4
    wire_int8 = 2 * (N - 1) / N * (M * 1 + 4)
    print(f"compressed_ring_{M * 4}B,{us:.1f},"
          f"wire_bytes {wire_int8 / wire_f32:.2f}x_of_f32")

    # ---- blocking vs split-phase: comm/compute overlap (Extended API) ----- #
    # Ring pipeline, one heavy transform per received chunk (the transform
    # feeds only the final accumulator, not the forwarded packet).
    #
    #   blocking:    each hop's transfer must COMPLETE before the next
    #                statement runs (gasnet_put semantics) — modeled with an
    #                explicit ordering fence, so per hop: T + C.
    #   split-phase: hop h+1's put is initiated before hop h's transform
    #                (put_nb ... sync), so the transform may overlap the
    #                wire — per hop: max(T, C).
    #
    # Two numbers are reported:
    #   overlap_gain_bound    — (T+C)/max(T,C) from individually measured
    #                           per-hop transfer (T) and transform (C)
    #                           costs: the gap a node with a dedicated
    #                           communication engine (the paper's GAScore /
    #                           TPU ICI DMA) realizes, since the transfer
    #                           burns no compute-core cycles there.
    #   overlap_gain_measured — interleaved-median wall clock of the two
    #                           schedules on THIS host.  CPU host devices
    #                           execute transfers with the same cores that
    #                           run the transform, so on an oversubscribed
    #                           machine this tends toward 1.0 — which is
    #                           precisely the software-node bottleneck the
    #                           paper builds hardware nodes to remove.
    from jax import lax

    B, D = 8192, 128  # 4 MiB chunk per hop; transform = chunk @ (D, D)
    w_ov = jnp.eye(D, dtype=jnp.float32) * 0.5

    def transform(c, w):
        return jnp.tanh(c @ w)

    def blocking_ring(xl, w):
        eng = make_engine("xla", "node", N)
        cur = xl
        acc = jnp.zeros_like(cur)
        for _ in range(1, N):
            cur = eng.shift(cur, 1)          # blocking put: completes here
            acc = acc + transform(cur, w)
            # a blocking runtime cannot initiate hop h+1 until hop h's
            # statement finished — make that ordering edge explicit
            cur, acc = lax.optimization_barrier((cur, acc))
        return acc

    def overlap_ring(xl, w):
        eng = make_engine("xla", "node", N)
        cur = xl
        acc = jnp.zeros_like(cur)
        pending = eng.shift_nb(cur, 1)       # initiate hop 1
        for h in range(1, N):
            cur = pending.wait()             # sync hop h
            if h < N - 1:
                pending = eng.shift_nb(cur, 1)  # initiate hop h+1 first...
            acc = acc + transform(cur, w)       # ...then compute (overlapped)
        return acc

    def wrap(fn):
        def g(xl, w):
            return fn(xl[0], w)[None]
        return jax.jit(shard_map(g, mesh=mesh, in_specs=(P("node"), P()),
                                 out_specs=P("node"), check_vma=False))

    xs = jnp.ones((N, B, D), jnp.float32) * 0.01
    f_blk, f_ovl = wrap(blocking_ring), wrap(overlap_ring)
    assert bool(jnp.allclose(f_blk(xs, w_ov), f_ovl(xs, w_ov), rtol=1e-5))

    # per-hop costs measured in isolation (stable even on loaded hosts)
    def one_hop(xl, w):
        eng = make_engine("xla", "node", N)
        return eng.shift(xl[0], 1)[None]

    def one_transform(xl, w):
        return transform(xl[0], w)[None]

    f_T = jax.jit(shard_map(one_hop, mesh=mesh, in_specs=(P("node"), P()),
                            out_specs=P("node"), check_vma=False))
    f_C = jax.jit(shard_map(one_transform, mesh=mesh,
                            in_specs=(P("node"), P()),
                            out_specs=P("node"), check_vma=False))
    us_T = timeit(f_T, xs, w_ov, iters=10)
    us_C = timeit(f_C, xs, w_ov, iters=10)
    bound = (us_T + us_C) / max(us_T, us_C)
    print(f"hop_transfer_{B * D * 4}B,{us_T:.1f},T")
    print(f"hop_transform_{B * D * 4}B,{us_C:.1f},C")
    print(f"overlap_gain_bound,{bound:.3f},x=(T+C)/max(T:C)_hw_comm_engine")

    # interleaved A/B rounds + medians: host-device timings drift, and a
    # sequential A-then-B comparison aliases that drift into the gap
    for f in (f_blk, f_ovl):
        for _ in range(3):
            jax.block_until_ready(f(xs, w_ov))
    t_blk, t_ovl = [], []
    for _ in range(9):
        t0 = time.perf_counter()
        jax.block_until_ready(f_blk(xs, w_ov))
        t_blk.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(f_ovl(xs, w_ov))
        t_ovl.append(time.perf_counter() - t0)
    us_blk = float(np.median(t_blk)) * 1e6
    us_ovl = float(np.median(t_ovl)) * 1e6
    print(f"blocking_ring_{B * D * 4}B,{us_blk:.1f},per_hop=T+C")
    print(f"splitphase_ring_{B * D * 4}B,{us_ovl:.1f},per_hop=max(T:C)")
    print(f"overlap_gain_measured,{us_blk / us_ovl:.3f},x_on_shared_cpu_cores")

    print("GAS_BENCH_DONE")


if __name__ == "__main__":
    main()

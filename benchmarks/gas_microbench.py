"""GASNet-style microbenchmarks (the paper's evaluation lineage, cf. [4]):
AM round-trip latency, one-sided put bandwidth, collective comparison.

Run as __main__ in a subprocess with 8 host devices (benchmarks/run.py does
this).  Prints ``name,us_per_call,derived`` CSV rows.
"""
import os

if __name__ == "__main__":
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8"
    )

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


def timeit(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def main() -> None:
    from repro.core import gasnet
    from repro.core.engine import make_engine
    from repro.core import collectives
    from repro.optim import compression

    N = 8
    mesh = jax.make_mesh((N,), ("node",))

    # ---- AM round trip latency vs payload -------------------------------- #
    for width in (8, 64, 512):
        ctx = gasnet.Context(mesh, node_axis="node", backend="xla",
                             am_payload_width=width, am_capacity=2)
        ctx.handlers.register(
            "store",
            lambda state, payload, args: {"buf": payload.astype(jnp.float32)},
        )

        def am_rt(seg):
            def prog(node, seg):
                state = {"buf": jnp.zeros((width,), jnp.float32)}
                node.am_medium(
                    jnp.asarray((node.my_id + 1) % N, jnp.int32), "store",
                    payload=node.local(seg)[:width],
                )
                state = node.am_flush(state)
                # reply leg: send it back
                node.am_medium(
                    jnp.asarray((node.my_id - 1) % N, jnp.int32), "store",
                    payload=state["buf"],
                )
                state = node.am_flush(state)
                return state["buf"][None]

            return ctx.spmd(prog, seg, out_specs=P("node"))

        aspace = ctx.address_space()
        aspace.register("b", (max(width, 8),), jnp.float32)
        seg = aspace.alloc("b", init_fn=jnp.ones)
        us = timeit(am_rt, seg)
        print(f"am_roundtrip_w{width},{us:.1f},payload={width * 4}B")

    # ---- one-sided put bandwidth vs size ---------------------------------- #
    ctx = gasnet.Context(mesh, node_axis="node", backend="xla")
    for size in (1 << 10, 1 << 14, 1 << 18, 1 << 20):
        n_el = size // 4
        aspace = ctx.address_space()
        name = f"bw{size}"
        aspace.register(name, (n_el,), jnp.float32)
        seg = aspace.alloc(name)

        def put_prog(node, seg):
            data = jnp.ones((n_el,), jnp.float32) * node.my_id
            return node.put(seg, data, to=gasnet.Shift(1), index=0)

        us = timeit(lambda s: ctx.spmd(put_prog, s), seg)
        gbps = size / (us * 1e-6) / 1e9
        print(f"put_{size}B,{us:.1f},{gbps:.3f}GB/s/node")

    # ---- collectives: GAS ring (xla engine) vs lax natives ---------------- #
    M = 1 << 16  # 64k f32 per node contribution
    x = jnp.ones((N, M), jnp.float32)

    def ring_ar(xl):
        eng = make_engine("xla", "node", N)
        return collectives.ring_all_reduce(eng, xl[0])[None]

    def native_ar(xl):
        return jax.lax.psum(xl[0], "node")[None]

    for nm, fn in (("ring_allreduce", ring_ar), ("xla_allreduce", native_ar)):
        f = jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=(P("node"),),
                                  out_specs=P("node"), check_vma=False))
        us = timeit(f, x)
        print(f"{nm}_{M * 4}B,{us:.1f},sum_ok="
              f"{bool(jnp.allclose(f(x)[0], N))}")

    # ---- int8 EF compressed ring vs f32 ring ------------------------------ #
    err = jnp.zeros((M,), jnp.float32)

    def comp_ar(xl):
        eng = make_engine("xla", "node", N)
        red, _ = compression.compressed_ring_all_reduce(
            eng, xl[0], jnp.zeros((M,), jnp.float32)
        )
        return red[None]

    f = jax.jit(jax.shard_map(comp_ar, mesh=mesh, in_specs=(P("node"),),
                              out_specs=P("node"), check_vma=False))
    us = timeit(f, x)
    wire_f32 = 2 * (N - 1) / N * M * 4
    wire_int8 = 2 * (N - 1) / N * (M * 1 + 4)
    print(f"compressed_ring_{M * 4}B,{us:.1f},"
          f"wire_bytes {wire_int8 / wire_f32:.2f}x_of_f32")

    print("GAS_BENCH_DONE")


if __name__ == "__main__":
    main()

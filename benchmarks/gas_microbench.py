"""GASNet-style microbenchmarks (the paper's evaluation lineage, cf. [4]):
AM round-trip latency, one-sided put bandwidth, collective comparison,
blocking vs split-phase (Extended API) comm/compute overlap, and the
scheduler's segmented-vs-monolithic ring pipeline.

Run as __main__ in a subprocess with 8 host devices (benchmarks/run.py does
this).  Prints ``name,us_per_call,derived`` CSV rows; with ``--json PATH``
(default ``BENCH_gas.json`` when the flag is given bare) it also writes a
machine-readable artifact: every row, per-op bytes/sec, the overlap gap,
the segmented-vs-monolithic speedups per payload tier, and the measured
per-engine cost constants (``engine_costs``) that ``repro.core.sched``
can load back as its planning model.
"""
import os

if __name__ == "__main__":
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8"
    )

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map

from _report import make_report, new_result, write_artifact

RESULT = new_result()
report = make_report(RESULT)


def timeit(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def interleaved_us(fns, iters=9, warmup=3):
    """Interleaved A/B/... medians: host-device timings drift, and a
    sequential comparison aliases that drift into the gap."""
    for f in fns:
        for _ in range(warmup):
            jax.block_until_ready(f())
    t = [[] for _ in fns]
    for _ in range(iters):
        for i, f in enumerate(fns):
            t0 = time.perf_counter()
            jax.block_until_ready(f())
            t[i].append(time.perf_counter() - t0)
    return [float(np.median(ti)) * 1e6 for ti in t]


def main(json_path: str | None = None) -> None:
    from repro.core import gasnet
    from repro.core.engine import make_engine
    from repro.core import collectives
    from repro.optim import compression

    N = 8
    mesh = jax.make_mesh((N,), ("node",))

    # ---- AM round trip latency vs payload -------------------------------- #
    for width in (8, 64, 512):
        ctx = gasnet.Context(mesh, node_axis="node", backend="xla",
                             am_payload_width=width, am_capacity=2)
        ctx.handlers.register(
            "store",
            lambda state, payload, args: {"buf": payload.astype(jnp.float32)},
        )

        def am_rt(seg):
            def prog(node, seg):
                state = {"buf": jnp.zeros((width,), jnp.float32)}
                node.am_medium(
                    jnp.asarray((node.my_id + 1) % N, jnp.int32), "store",
                    payload=node.local(seg)[:width],
                )
                state = node.am_flush(state)
                # reply leg: send it back
                node.am_medium(
                    jnp.asarray((node.my_id - 1) % N, jnp.int32), "store",
                    payload=state["buf"],
                )
                state = node.am_flush(state)
                return state["buf"][None]

            return ctx.spmd(prog, seg, out_specs=P("node"))

        aspace = ctx.address_space()
        aspace.register("b", (max(width, 8),), jnp.float32)
        seg = aspace.alloc("b", init_fn=jnp.ones)
        us = timeit(am_rt, seg)
        report(f"am_roundtrip_w{width}", us, f"payload={width * 4}B",
               op="am_roundtrip", payload_bytes=width * 4)

    # ---- one-sided put bandwidth vs size ---------------------------------- #
    ctx = gasnet.Context(mesh, node_axis="node", backend="xla")
    for size in (1 << 10, 1 << 14, 1 << 18, 1 << 20):
        n_el = size // 4
        aspace = ctx.address_space()
        name = f"bw{size}"
        aspace.register(name, (n_el,), jnp.float32)
        seg = aspace.alloc(name)

        def put_prog(node, seg):
            data = jnp.ones((n_el,), jnp.float32) * node.my_id
            return node.put(seg, data, to=gasnet.Shift(1), index=0)

        us = timeit(lambda s: ctx.spmd(put_prog, s), seg)
        gbps = size / (us * 1e-6) / 1e9
        report(f"put_{size}B", us, f"{gbps:.3f}GB/s/node",
               op="put", payload_bytes=size,
               bytes_per_sec=round(size / (us * 1e-6), 1))

    # ---- collectives: GAS ring (xla engine) vs lax natives ---------------- #
    M = 1 << 16  # 64k f32 per node contribution
    x = jnp.ones((N, M), jnp.float32)

    def ring_ar(xl):
        eng = make_engine("xla", "node", N)
        return collectives.ring_all_reduce(eng, xl[0])[None]

    def native_ar(xl):
        return jax.lax.psum(xl[0], "node")[None]

    for nm, fn in (("ring_allreduce", ring_ar), ("xla_allreduce", native_ar)):
        f = jax.jit(shard_map(fn, mesh=mesh, in_specs=(P("node"),),
                                  out_specs=P("node"), check_vma=False))
        us = timeit(f, x)
        report(f"{nm}_{M * 4}B", us,
               f"sum_ok={bool(jnp.allclose(f(x)[0], N))}",
               op=nm, payload_bytes=M * 4,
               bytes_per_sec=round(M * 4 / (us * 1e-6), 1))

    # ---- int8 EF compressed ring vs f32 ring ------------------------------ #
    def comp_ar(xl):
        eng = make_engine("xla", "node", N)
        red, _ = compression.compressed_ring_all_reduce(
            eng, xl[0], jnp.zeros((M,), jnp.float32)
        )
        return red[None]

    f = jax.jit(shard_map(comp_ar, mesh=mesh, in_specs=(P("node"),),
                              out_specs=P("node"), check_vma=False))
    us = timeit(f, x)
    wire_f32 = 2 * (N - 1) / N * M * 4
    wire_int8 = 2 * (N - 1) / N * (M * 1 + 4)
    report(f"compressed_ring_{M * 4}B", us,
           f"wire_bytes {wire_int8 / wire_f32:.2f}x_of_f32",
           op="compressed_ring", payload_bytes=M * 4)

    # ---- blocking vs split-phase: comm/compute overlap (Extended API) ----- #
    # Ring pipeline, one heavy transform per received chunk (the transform
    # feeds only the final accumulator, not the forwarded packet).
    #
    #   blocking:    each hop's transfer must COMPLETE before the next
    #                statement runs (gasnet_put semantics) — modeled with an
    #                explicit ordering fence, so per hop: T + C.
    #   split-phase: hop h+1's put is initiated before hop h's transform
    #                (put_nb ... sync), so the transform may overlap the
    #                wire — per hop: max(T, C).
    #
    # Two numbers are reported:
    #   overlap_gain_bound    — (T+C)/max(T,C) from individually measured
    #                           per-hop transfer (T) and transform (C)
    #                           costs: the gap a node with a dedicated
    #                           communication engine (the paper's GAScore /
    #                           TPU ICI DMA) realizes, since the transfer
    #                           burns no compute-core cycles there.
    #   overlap_gain_measured — interleaved-median wall clock of the two
    #                           schedules on THIS host.  CPU host devices
    #                           execute transfers with the same cores that
    #                           run the transform, so on an oversubscribed
    #                           machine this tends toward 1.0 — which is
    #                           precisely the software-node bottleneck the
    #                           paper builds hardware nodes to remove.
    from jax import lax

    B, D = 8192, 128  # 4 MiB chunk per hop; transform = chunk @ (D, D)
    w_ov = jnp.eye(D, dtype=jnp.float32) * 0.5

    def transform(c, w):
        return jnp.tanh(c @ w)

    def blocking_ring(xl, w):
        eng = make_engine("xla", "node", N)
        cur = xl
        acc = jnp.zeros_like(cur)
        for _ in range(1, N):
            cur = eng.shift(cur, 1)          # blocking put: completes here
            acc = acc + transform(cur, w)
            # a blocking runtime cannot initiate hop h+1 until hop h's
            # statement finished — make that ordering edge explicit
            cur, acc = lax.optimization_barrier((cur, acc))
        return acc

    def overlap_ring(xl, w):
        eng = make_engine("xla", "node", N)
        cur = xl
        acc = jnp.zeros_like(cur)
        pending = eng.shift_nb(cur, 1)       # initiate hop 1
        for h in range(1, N):
            cur = pending.wait()             # sync hop h
            if h < N - 1:
                pending = eng.shift_nb(cur, 1)  # initiate hop h+1 first...
            acc = acc + transform(cur, w)       # ...then compute (overlapped)
        return acc

    def wrap(fn):
        def g(xl, w):
            return fn(xl[0], w)[None]
        return jax.jit(shard_map(g, mesh=mesh, in_specs=(P("node"), P()),
                                 out_specs=P("node"), check_vma=False))

    xs = jnp.ones((N, B, D), jnp.float32) * 0.01
    f_blk, f_ovl = wrap(blocking_ring), wrap(overlap_ring)
    assert bool(jnp.allclose(f_blk(xs, w_ov), f_ovl(xs, w_ov), rtol=1e-5))

    # per-hop costs measured in isolation (stable even on loaded hosts)
    def one_hop(xl, w):
        eng = make_engine("xla", "node", N)
        return eng.shift(xl[0], 1)[None]

    def one_transform(xl, w):
        return transform(xl[0], w)[None]

    f_T = jax.jit(shard_map(one_hop, mesh=mesh, in_specs=(P("node"), P()),
                            out_specs=P("node"), check_vma=False))
    f_C = jax.jit(shard_map(one_transform, mesh=mesh,
                            in_specs=(P("node"), P()),
                            out_specs=P("node"), check_vma=False))
    us_T = timeit(f_T, xs, w_ov, iters=10)
    us_C = timeit(f_C, xs, w_ov, iters=10)
    bound = (us_T + us_C) / max(us_T, us_C)
    report(f"hop_transfer_{B * D * 4}B", us_T, "T")
    report(f"hop_transform_{B * D * 4}B", us_C, "C")
    report("overlap_gain_bound", bound, "x=(T+C)/max(T:C)_hw_comm_engine",
           unit="x")

    us_blk, us_ovl = interleaved_us(
        (lambda: f_blk(xs, w_ov), lambda: f_ovl(xs, w_ov))
    )
    report(f"blocking_ring_{B * D * 4}B", us_blk, "per_hop=T+C")
    report(f"splitphase_ring_{B * D * 4}B", us_ovl, "per_hop=max(T:C)")
    report("overlap_gain_measured", us_blk / us_ovl, "x_on_shared_cpu_cores",
           unit="x")
    RESULT["overlap"] = {
        "gain_bound": round(bound, 3),
        "gain_measured": round(us_blk / us_ovl, 3),
    }

    # ---- scheduler: segmented vs monolithic ring all-reduce per tier ------ #
    # The scheduler chunks each ring payload into n_segments slices with
    # ``depth`` puts in flight, so segment k+1's wire overlaps segment k's
    # accumulate epilogue (the GAScore command-FIFO drain).  Two numbers
    # per payload tier, mirroring the overlap section above:
    #
    #   speedup_comm_engine — per-hop pipeline model from individually
    #       measured wire (T) and epilogue (C) costs:
    #       (T+C) / (max(T,C) + min(T,C)/G) — what a node with a dedicated
    #       communication engine realizes, since its DMAs burn no compute
    #       cycles.  > 1 whenever the plan segments (G > 1).
    #   speedup_measured    — interleaved-median wall clock on THIS host,
    #       where "wire" is the same CPU cores as the epilogue — the
    #       software-node bottleneck the paper builds hardware nodes to
    #       remove (tends toward 1.0 on oversubscribed machines).
    from repro.core import collectives, sched

    RESULT["segmented_allreduce"] = {}
    for Mseg in (1 << 18, 1 << 20, 1 << 22):  # 1/4/16 MiB f32 per node
        xseg = jnp.ones((N, Mseg), jnp.float32)
        plan = sched.plan_collective(
            "all_reduce", nbytes=Mseg * 4, n_nodes=N,
            engine=make_engine("xla", "node", N),
        )
        G, D_ = plan.n_segments, plan.depth
        if G == 1:
            G, D_ = 4, 2  # pin segmentation on tiers the model leaves whole

        def mono_ar(xl):
            eng = make_engine("xla", "node", N)
            return collectives.ring_all_reduce(eng, xl[0])[None]

        def seg_ar(xl, G=G, D_=D_):
            eng = make_engine("xla", "node", N)
            return collectives.segmented_ring_all_reduce(
                eng, xl[0], n_segments=G, depth=D_
            )[None]

        f_mono = jax.jit(shard_map(mono_ar, mesh=mesh, in_specs=(P("node"),),
                                   out_specs=P("node"), check_vma=False))
        f_seg = jax.jit(shard_map(seg_ar, mesh=mesh, in_specs=(P("node"),),
                                  out_specs=P("node"), check_vma=False))
        assert bool(jnp.allclose(f_mono(xseg), f_seg(xseg)))

        # per-hop wire (T) and accumulate-epilogue (C) costs, in isolation
        chunk = Mseg // N

        def hop_wire(xl):
            eng = make_engine("xla", "node", N)
            return eng.shift(xl[0, :chunk], 1)[None]

        def hop_epilogue(xl):
            return (xl[0, :chunk] + xl[0, chunk : 2 * chunk])[None]

        f_T2 = jax.jit(shard_map(hop_wire, mesh=mesh, in_specs=(P("node"),),
                                 out_specs=P("node"), check_vma=False))
        f_C2 = jax.jit(shard_map(hop_epilogue, mesh=mesh,
                                 in_specs=(P("node"),),
                                 out_specs=P("node"), check_vma=False))
        t_wire = timeit(f_T2, xseg, iters=8)
        t_epi = timeit(f_C2, xseg, iters=8)
        pipe = max(t_wire, t_epi) + min(t_wire, t_epi) / G
        speedup_engine = (t_wire + t_epi) / pipe
        us_mono, us_seg = interleaved_us(
            (lambda: f_mono(xseg), lambda: f_seg(xseg)), iters=7
        )
        measured = us_mono / us_seg
        nb = Mseg * 4
        report(f"monolithic_allreduce_{nb}B", us_mono,
               f"ring_{2 * (N - 1)}hops", op="allreduce_monolithic",
               payload_bytes=nb, bytes_per_sec=round(nb / (us_mono * 1e-6), 1))
        report(f"segmented_allreduce_{nb}B", us_seg,
               f"speedup_vs_monolithic={speedup_engine:.3f}x_with_comm_engine"
               f"(measured={measured:.3f}x_shared_cores)_plan={G}x{D_}",
               op="allreduce_segmented", payload_bytes=nb,
               bytes_per_sec=round(nb / (us_seg * 1e-6), 1))
        RESULT["segmented_allreduce"][str(nb)] = {
            "monolithic_us": round(us_mono, 1),
            "segmented_us": round(us_seg, 1),
            "n_segments": G,
            "depth": D_,
            "hop_wire_us": round(t_wire, 1),
            "hop_epilogue_us": round(t_epi, 1),
            "speedup_comm_engine": round(speedup_engine, 3),
            "speedup_measured": round(measured, 3),
        }

    # ---- measured engine cost constants (the scheduler's planning model) -- #
    # Per engine: alpha from a tiny hop, beta from the large-hop slope;
    # gamma (the local accumulate epilogue) is engine-independent.
    # repro.core.sched.load_costs() reads these back, including for the
    # worst-member planning of heterogeneous EngineMaps.
    kib = (chunk * 4) / 1024.0
    gamma = max(0.0, t_epi / kib)
    RESULT["engine_costs"] = {}
    # gascore hops run in Pallas TPU-interpret mode: keep shapes modest
    # ((rows, 128) trailing-dim-128 f32) so the measurement stays cheap
    probe = {
        "xla": (256, 8192),     # rows of 128 f32: 128 KiB / 4 MiB hops
        "gascore": (2, 256),    # 1 KiB / 128 KiB hops
    }
    xprobe = jnp.ones((N, 8192, 128), jnp.float32)
    for backend, (rows_a, rows_b) in probe.items():
        def hop(xl, backend=backend, rows=None):
            eng = make_engine(backend, "node", N, interpret=True)
            return eng.shift(xl[0, :rows], 1)[None]

        def make_f(rows):
            return jax.jit(shard_map(
                functools.partial(hop, rows=rows), mesh=mesh,
                in_specs=(P("node"),), out_specs=P("node"), check_vma=False,
            ))

        iters = 10 if backend == "xla" else 3
        alpha = timeit(make_f(rows_a), xprobe, iters=iters)
        t_big = timeit(make_f(rows_b), xprobe, iters=iters)
        big_kib = rows_b * 128 * 4 / 1024.0
        beta = max(0.0, (t_big - alpha) / big_kib)
        RESULT["engine_costs"][backend] = {
            "alpha_us": round(alpha, 2),
            "beta_us_per_kib": round(beta, 4),
            "gamma_us_per_kib": round(gamma, 4),
        }
        report(f"engine_cost_alpha_{backend}", alpha, "us_per_hop")
        report(f"engine_cost_beta_{backend}", beta, "us_per_kib_wire",
               unit="us_per_kib")
    report("engine_cost_gamma", gamma, "us_per_kib_epilogue",
           unit="us_per_kib")

    if json_path:
        write_artifact(RESULT, json_path)

    print("GAS_BENCH_DONE")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--json", nargs="?", const="BENCH_gas.json", default=None,
        metavar="PATH",
        help="write the machine-readable artifact (default: BENCH_gas.json)",
    )
    main(json_path=ap.parse_args().json)

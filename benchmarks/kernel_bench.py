"""Compute-kernel benchmarks (single device).

The Pallas kernels run in TPU-interpret mode on CPU, which measures
*semantics*, not speed — wall numbers quantify the oracle (jnp) path and
report kernel parity + the analytic FLOP count per call (what the roofline
uses on target hardware).  CSV: name,us_per_call,derived.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def timeit(fn, *args, iters=10, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def main() -> None:
    rng = np.random.default_rng(0)

    # flash attention (prefill hot spot)
    B, Hq, Hkv, S, D = 1, 8, 2, 1024, 128
    q = jnp.asarray(rng.normal(size=(B, Hq, S, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, D)), jnp.float32)
    f_ref = jax.jit(lambda a, b, c: ref.attention(a, b, c, causal=True))
    us = timeit(f_ref, q, k, v)
    flops = 4 * B * Hq * S * S * D / 2  # causal
    print(f"attention_ref_S{S},{us:.0f},{flops / (us * 1e-6) / 1e9:.1f}GFLOP/s")
    got = ops.attention(q, k, v, causal=True, impl="pallas")
    ok = bool(jnp.allclose(got, f_ref(q, k, v), atol=2e-4))
    print(f"attention_pallas_parity,0,{ok}")

    # paged attention (decode hot spot) — oracle wall time, pallas parity,
    # and the DMA-blocking knobs (pages_per_block x block_b) of the
    # batch-blocked kernel, which must be bit-identical across settings
    B, Hq, Hkv, D, T, NP, P = 8, 8, 2, 64, 8, 8, 64
    pq = jnp.asarray(rng.normal(size=(B, Hq, D)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(P, T, Hkv, D)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(P, T, Hkv, D)), jnp.float32)
    tbl = jnp.asarray(rng.integers(0, P, (B, NP)), jnp.int32)
    lens = jnp.asarray(rng.integers(1, NP * T + 1, (B,)), jnp.int32)
    f_pref = jax.jit(lambda *t: ref.paged_attention(*t))
    us = timeit(f_pref, pq, kp, vp, tbl, lens)
    print(f"paged_attention_ref_B{B}_NP{NP},{us:.0f},oracle")
    want = f_pref(pq, kp, vp, tbl, lens)
    base = None
    for ppb, bb in ((1, 1), (4, 4), (8, 8)):
        f_pa = jax.jit(lambda *t, _p=ppb, _b=bb: ops.paged_attention(
            *t, impl="pallas", pages_per_block=_p, block_b=_b))
        us = timeit(f_pa, pq, kp, vp, tbl, lens, iters=5)
        got = np.asarray(f_pa(pq, kp, vp, tbl, lens))
        if base is None:
            base = got
            ok = bool(np.allclose(got, np.asarray(want), atol=2e-4))
            print(f"paged_attention_pallas_parity,0,{ok}")
        else:
            # perf knobs must not change a single bit of the output
            bit = bool((got == base).all())
            print(f"paged_attention_pallas_bitinv_p{ppb}b{bb},0,{bit}")
        print(f"paged_attention_pallas_p{ppb}b{bb},{us:.0f},interpret")

    # MoE router
    T, E, K = 4096, 64, 8
    logits = jnp.asarray(rng.normal(size=(T, E)), jnp.float32)
    f_route = jax.jit(
        lambda l: ref.route_topk(l, k=K, capacity=T // E * 2)
    )
    us = timeit(f_route, logits)
    print(f"moe_router_ref_T{T}_E{E},{us:.0f},{T / (us * 1e-6) / 1e6:.1f}Mtok/s")
    pe, ps, pw, pk = ops.moe_router(
        logits, k=K, capacity=T // E * 2, impl="pallas", block_t=512
    )
    re_, rs_, rw_, rk_ = f_route(logits)
    ok = bool(
        (np.asarray(pe) == np.asarray(re_)).all()
        and (np.asarray(ps) == np.asarray(rs_)).all()
    )
    print(f"moe_router_pallas_parity,0,{ok}")

    # selective scan
    B, S, Di, N = 1, 2048, 512, 16
    x = jnp.asarray(rng.normal(size=(B, S, Di)), jnp.float32)
    dt = jnp.asarray(rng.uniform(1e-3, 1e-1, size=(B, S, Di)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 2.0, size=(Di, N)), jnp.float32)
    bmat = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    cmat = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    d = jnp.asarray(rng.normal(size=(Di,)), jnp.float32)
    f_scan = jax.jit(lambda *t: ref.selective_scan(*t))
    us = timeit(f_scan, x, dt, a, bmat, cmat, d)
    el = B * S * Di * N
    print(f"selective_scan_ref_S{S},{us:.0f},{el / (us * 1e-6) / 1e9:.2f}Gstate/s")

    # gated linear scan (RG-LRU)
    av = jnp.asarray(rng.uniform(0.1, 0.99, size=(B, S, Di)), jnp.float32)
    bv = jnp.asarray(rng.normal(size=(B, S, Di)), jnp.float32)
    f_lru = jax.jit(ref.gated_linear_scan)
    us = timeit(f_lru, av, bv)
    print(f"rglru_ref_S{S},{us:.0f},"
          f"{B * S * Di / (us * 1e-6) / 1e9:.2f}Gel/s")

    print("KERNEL_BENCH_DONE")


if __name__ == "__main__":
    main()

"""Benchmark harness — one section per paper-evaluation axis.

The 2014 paper defers quantitative tables to its companion FPGA'13 paper
[4], whose evaluation axes are: (a) remote-access latency, (b) put
bandwidth vs message size, (c) collective performance, and (d) application
kernels.  Each axis maps to a section here; the dry-run/roofline tables in
EXPERIMENTS.md cover the at-scale story these CPU microbenches cannot.

Prints ``name,us_per_call,derived`` CSV.  Multi-device sections run as
subprocesses with their own forced host-device counts so this process
stays single-device (the smoke/bench rule).
"""
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")


def _sub(module: str, devices: int) -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "benchmarks", module)],
        capture_output=True, text=True, cwd=ROOT, timeout=3600, env=env,
    )
    ok = proc.returncode == 0
    for line in proc.stdout.splitlines():
        if "," in line and not line.startswith(("W", "I", "E")):
            print(line)
    if not ok:
        print(f"{module},ERROR,rc={proc.returncode}")
        sys.stderr.write(proc.stderr[-2000:] + "\n")


def main() -> None:
    print("name,us_per_call,derived")
    # (a)+(b)+(c): GASNet microbench lineage — AM latency, put bandwidth,
    # ring vs native collectives, compressed rings (8 nodes)
    _sub("gas_microbench.py", devices=8)
    # (d) compute kernels: oracle timings + Pallas parity (1 device)
    _sub("kernel_bench.py", devices=1)
    # end-to-end: train-step throughput + serving decode (1 device)
    _sub("train_serve_bench.py", devices=4)  # 4: disaggregated serve section


if __name__ == "__main__":
    main()

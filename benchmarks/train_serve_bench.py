"""End-to-end benchmarks: train-step throughput + decode tokens/s
(single device, smoke configs).  CSV: name,us_per_call,derived."""
import time

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    from repro.configs.registry import SMOKE
    from repro.data.synthetic import SyntheticLM
    from repro.models.build import build_model
    from repro.optim import adamw
    from repro.parallel.ctx import RunCtx
    from repro.runtime.trainer import Trainer, TrainerConfig

    ctx = RunCtx(mesh=None, remat="none")

    for arch in ("qwen3-4b", "falcon-mamba-7b", "arctic-480b"):
        cfg = SMOKE[arch]
        model = build_model(cfg)
        tr = Trainer(model, ctx, adamw.AdamWConfig(lr=1e-3),
                     TrainerConfig(steps=1, ckpt_every=0))
        params, st = tr.init(jax.random.PRNGKey(0))
        fn = tr.make_train_step()
        B, S = 8, 128
        src = SyntheticLM(cfg, batch=B, seq_len=S, seed=0)
        batch = {k: jnp.asarray(v) for k, v in src.batch_at(0).items()}
        params, st, _ = fn(params, st, batch)  # compile+warm
        iters = 5
        t0 = time.perf_counter()
        for _ in range(iters):
            params, st, m = fn(params, st, batch)
        jax.block_until_ready(m["loss"])
        us = (time.perf_counter() - t0) / iters * 1e6
        tok_s = B * S / (us * 1e-6)
        print(f"train_step_{arch},{us:.0f},{tok_s:.0f}tok/s")

    # decode throughput
    from repro.launch.serve import Request, Server

    cfg = SMOKE["qwen3-4b"]
    model = build_model(cfg)
    params, _ = model.init(ctx, jax.random.PRNGKey(0))
    server = Server(model, ctx, params, batch_size=8, cache_len=96)
    rng = np.random.default_rng(0)
    for rid in range(16):
        server.submit(Request(rid=rid,
                              prompt=rng.integers(0, cfg.vocab, 16).tolist(),
                              max_new=16))
    stats = server.run_until_drained()
    us = stats["wall_s"] / max(stats["decoded_tokens"], 1) * 1e6
    print(f"serve_decode_qwen3,{us:.0f},{stats['tok_per_s']:.1f}tok/s")
    print(f"serve_p50_ttft,{stats['p50_ttft_s'] * 1e6:.0f},"
          f"{stats['requests']}req")
    print("TRAIN_SERVE_BENCH_DONE")


if __name__ == "__main__":
    main()

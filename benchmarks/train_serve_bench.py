"""End-to-end benchmarks: train-step throughput + decode tokens/s +
disaggregated serving (smoke configs).

Prints ``name,us_per_call,derived`` CSV rows; with ``--json PATH``
(default ``BENCH_serve.json`` when the flag is given bare) it also writes
a machine-readable artifact: serve throughput, p50/p99 request latency,
TTFT, and the KV-transfer goodput (bytes/sec) of the disaggregated
cluster — the serving-side numbers CI tracks next to ``BENCH_gas``.

The disaggregated section needs several host devices, so the device count
is forced before the first JAX import (like gas_microbench).
"""
import os

if __name__ == "__main__":
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=4"
    )

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from _report import append_history, make_report, new_result, write_artifact

RESULT = new_result()
report = make_report(RESULT)

SECTIONS = ("train", "serve", "disagg", "paged", "oversub", "tp", "obs")


def merge_artifact(result: dict, path: str) -> None:
    """Write ``result``'s rows into an existing artifact, replacing rows
    of the same name and keeping the rest — how a single-section run
    (``--sections tp``) refreshes its slice of ``BENCH_serve.json``
    without discarding the other sections' measurements."""
    try:
        with open(path) as f:
            artifact = json.load(f)
    except (OSError, ValueError):
        artifact = new_result()
    fresh = {r["name"] for r in result["rows"]}
    artifact["rows"] = [
        r for r in artifact.get("rows", []) if r.get("name") not in fresh
    ] + result["rows"]
    write_artifact(artifact, path)


def main(json_path: str | None = None,
         sections: tuple | None = None) -> None:
    from repro.configs.registry import SMOKE
    from repro.data.synthetic import SyntheticLM
    from repro.models.build import build_model
    from repro.optim import adamw
    from repro.parallel.ctx import RunCtx
    from repro.runtime.trainer import Trainer, TrainerConfig

    ctx = RunCtx(mesh=None, remat="none")

    def want(s: str) -> bool:
        return sections is None or s in sections

    for arch in () if not want("train") else (
            "qwen3-4b", "falcon-mamba-7b", "arctic-480b"):
        cfg = SMOKE[arch]
        model = build_model(cfg)
        tr = Trainer(model, ctx, adamw.AdamWConfig(lr=1e-3),
                     TrainerConfig(steps=1, ckpt_every=0))
        params, st = tr.init(jax.random.PRNGKey(0))
        fn = tr.make_train_step()
        B, S = 8, 128
        src = SyntheticLM(cfg, batch=B, seq_len=S, seed=0)
        batch = {k: jnp.asarray(v) for k, v in src.batch_at(0).items()}
        params, st, _ = fn(params, st, batch)  # compile+warm
        iters = 5
        t0 = time.perf_counter()
        for _ in range(iters):
            params, st, m = fn(params, st, batch)
        jax.block_until_ready(m["loss"])
        us = (time.perf_counter() - t0) / iters * 1e6
        tok_s = B * S / (us * 1e-6)
        report(f"train_step_{arch}", us, f"{tok_s:.0f}tok/s",
               op="train_step", arch=arch, tok_per_s=round(tok_s, 1))

    # ---- colocated decode throughput (continuous batching) --------------- #
    from repro.launch.serve import Request, Server

    cfg = SMOKE["qwen3-4b"]
    model = build_model(cfg)
    params, _ = model.init(ctx, jax.random.PRNGKey(0))
    if want("serve"):
        server = Server(model, ctx, params, batch_size=8, cache_len=96)
        rng = np.random.default_rng(0)
        for rid in range(16):
            server.submit(Request(
                rid=rid, prompt=rng.integers(0, cfg.vocab, 16).tolist(),
                max_new=16))
        stats = server.run_until_drained()
        us = stats["wall_s"] / max(stats["decoded_tokens"], 1) * 1e6
        report("serve_decode_qwen3", us, f"{stats['tok_per_s']:.1f}tok/s",
               op="serve_decode", tok_per_s=round(stats["tok_per_s"], 1),
               p50_latency_s=round(stats["p50_latency_s"], 4))
        report("serve_p50_ttft", stats["p50_ttft_s"] * 1e6,
               f"{stats['requests']}req", op="serve_ttft",
               requests=stats["requests"])

    # ---- disaggregated serving: prefill pool -> KV put -> decode pool ----- #
    # (only when the forced host device count allows >= 2 ranks)
    if not want("disagg"):
        pass
    elif jax.device_count() >= 4:
        from repro.serving.disagg import DisaggCluster

        cluster = DisaggCluster(
            model, ctx, params, n_prefill=2, n_decode=2,
            decode_batch=4, cache_len=64,
        )
        rng = np.random.default_rng(1)
        for rid in range(12):
            cluster.submit(Request(
                rid=rid, prompt=rng.integers(0, cfg.vocab, 16).tolist(),
                max_new=12,
            ))
        d = cluster.run_until_drained()
        us = d["wall_s"] / max(d["decoded_tokens"], 1) * 1e6
        report("serve_disagg_decode", us, f"{d['tok_per_s']:.1f}tok/s",
               op="serve_disagg", tok_per_s=round(d["tok_per_s"], 1),
               requests=d["requests"],
               p50_latency_s=round(d["p50_latency_s"], 4),
               p99_latency_s=round(d["p99_latency_s"], 4),
               p50_ttft_s=round(d["p50_ttft_s"], 4))
        report("serve_disagg_kv_goodput", d["kv_bytes_per_s"] / 1e6,
               f"{d['kv_transfers']}x{d['kv_block_bytes']}B", unit="mb_s",
               op="serve_disagg_kv",
               kv_bytes_per_sec=round(d["kv_bytes_per_s"], 1),
               kv_transfers=d["kv_transfers"],
               kv_block_bytes=d["kv_block_bytes"],
               kv_plan=d["kv_plan"], acked=d["kv_acked"])
        assert d["kv_acked"] == d["kv_transfers"]
    else:
        print("serve_disagg skipped: needs >= 4 host devices")

    # ---- paged KV pool: paged vs dense decode, page traffic, overlap ------ #
    if want("paged"):
        paged_sections(report)

    # ---- tiered KV memory: oversubscription + swap/recompute crossover ---- #
    if want("oversub"):
        oversub_sections(report)

    # ---- tensor-parallel decode groups: memory aggregation win ------------ #
    if want("tp"):
        tp_sections(report)

    # ---- observability: tracing tax + cost-model feedback loop ------------ #
    if want("obs"):
        obs_sections(report)

    if json_path:
        if sections is None:
            write_artifact(RESULT, json_path)
        else:
            merge_artifact(RESULT, json_path)
        append_history(RESULT, "BENCH_history.jsonl")
    print("TRAIN_SERVE_BENCH_DONE")


def paged_sections(report) -> None:
    """The global-paged-KV-pool section of ``BENCH_serve.json``:

    - paged vs dense colocated decode throughput (same burst, token parity
      asserted — the pool must be free),
    - disaggregated page traffic: page-fetch bytes/sec + prefix-hit rate
      on a burst with shared prompt prefixes,
    - page-fetch/compute overlap: split-phase vectored page get
      (``get_nbv``) overlapped with the paged-attention decode step vs the
      same fetch done blocking.
    """
    from repro.configs.registry import SMOKE
    from repro.launch.serve import PagedServer, Request, Server
    from repro.models.build import build_model
    from repro.parallel.ctx import RunCtx

    ctx = RunCtx(mesh=None, remat="none")
    cfg = SMOKE["qwen3-4b"]
    model = build_model(cfg)
    params, _ = model.init(ctx, jax.random.PRNGKey(0))

    def burst(n=12, shared_prefix=16):
        rng = np.random.default_rng(5)
        shared = rng.integers(0, cfg.vocab, shared_prefix).tolist()
        reqs = []
        for rid in range(n):
            if rid % 3 == 0:  # every third request rides the warm prefix
                prompt = shared + rng.integers(0, cfg.vocab, 2).tolist()
            else:
                prompt = rng.integers(0, cfg.vocab, 16).tolist()
            reqs.append(Request(rid=rid, prompt=prompt, max_new=12))
        return reqs

    results = {}
    for kind in ("dense", "paged"):
        if kind == "dense":
            server = Server(model, ctx, params, batch_size=8, cache_len=96)
        else:
            server = PagedServer(model, ctx, params, batch_size=8,
                                 cache_len=96, page_tokens=8)
        # warm the prefill/decode programs so the throughput row measures
        # serving, not XLA compile time (both kinds get the same warmup);
        # max_new=12 crosses a page boundary while the pool is already
        # device-resident, compiling the page-patch program too
        server.submit(Request(rid=10_000, prompt=burst()[0].prompt[:16],
                              max_new=12))
        server.run_until_drained()
        server.finished.clear()
        for req in burst():
            server.submit(req)
        stats = server.run_until_drained()
        results[kind] = {r.rid: r.out for r in server.finished}
        us = stats["wall_s"] / max(stats["decoded_tokens"], 1) * 1e6
        extra = {}
        if kind == "paged":
            extra = {k: v for k, v in stats.items() if k.startswith("pool_")}
        report(f"serve_{kind}_decode", us,
               f"{stats['tok_per_s']:.1f}tok/s", op=f"serve_{kind}",
               tok_per_s=round(stats["tok_per_s"], 1), **extra)
    assert results["dense"] == results["paged"]  # token parity, always

    # disaggregated page traffic (prefix sharing across the handoff)
    if jax.device_count() >= 4:
        from repro.serving.disagg import DisaggCluster

        cluster = DisaggCluster(
            model, ctx, params, n_prefill=2, n_decode=2,
            decode_batch=4, cache_len=64, paged=True, page_tokens=8,
        )
        for req in burst():
            cluster.submit(req)
        d = cluster.run_until_drained()
        report("serve_disagg_paged_goodput", d["kv_bytes_per_s"] / 1e6,
               f"{d['kv_pages_sent']}x{d['page_bytes']}B", unit="mb_s",
               op="serve_disagg_paged",
               tok_per_s=round(d["tok_per_s"], 1),
               kv_pages_sent=d["kv_pages_sent"],
               kv_pages_shared=d["kv_pages_shared"],
               prefix_hit_rate=round(d["prefix_hit_rate"], 4),
               page_bytes=d["page_bytes"],
               kv_bytes_per_sec=round(d["kv_bytes_per_s"], 1))
        assert d["kv_acked"] == d["kv_transfers"]
        assert d["kv_pages_shared"] > 0
    else:
        print("serve_disagg_paged skipped: needs >= 4 host devices")

    # page-fetch/compute overlap (the reason decode wants get_nbv)
    if jax.device_count() >= 2:
        overlap_bench(report)
    else:
        print("paged_fetch_overlap skipped: needs >= 2 host devices")


def oversub_sections(report) -> None:
    """The tiered-KV-memory section of ``BENCH_serve.json``:

    - tok/s and p99 request latency at 1.0x / 1.5x / 2.0x pool pressure
      (peak concurrent page demand over physical pool pages) on the
      SLO-scheduled colocated :class:`PagedServer` — the pressured runs
      preempt (swap to the memory tier / recompute-replay) and must stay
      token-identical to the unpressured run,
    - the swap-vs-recompute crossover: the generated length at which two
      vectored transfers of the victim's pages become cheaper than
      replaying the decode (recompute cost grows per generated token;
      below the crossover recompute wins), under the measured
      ``BENCH_gas.json`` β model.
    """
    from repro.configs.registry import SMOKE
    from repro.core import sched as core_sched
    from repro.launch.serve import PagedServer, Request
    from repro.models.build import build_model
    from repro.parallel.ctx import RunCtx

    ctx = RunCtx(mesh=None, remat="none")
    cfg = SMOKE["qwen3-4b"]
    model = build_model(cfg)
    params, _ = model.init(ctx, jax.random.PRNGKey(0))
    batch, cache_len, page_tokens = 4, 64, 8
    n_pages = cache_len // page_tokens
    peak_demand = batch * n_pages  # every row at a full table

    def burst():
        # long generations so every concurrent row grows toward a full
        # page table: peak demand actually reaches batch * n_pages and
        # the pressured pools must preempt (swap or recompute)
        rng = np.random.default_rng(9)
        reqs = []
        for rid in range(10):
            prompt_len = int(rng.integers(10, 30))
            max_new = int(rng.integers(24, 34))
            max_new = min(max_new, cache_len - prompt_len)
            reqs.append(Request(
                rid=rid,
                prompt=rng.integers(0, cfg.vocab, prompt_len).tolist(),
                max_new=max_new,
            ))
        return reqs

    baseline = None
    for pressure in (1.0, 1.5, 2.0):
        pool_pages = max(n_pages + 1, int(round(peak_demand / pressure)))
        server = PagedServer(model, ctx, params, batch, cache_len,
                             page_tokens=page_tokens, n_pool_pages=pool_pages)
        for req in burst():
            server.submit(req)
        stats = server.run_until_drained(max_ticks=2000)
        toks = {r.rid: r.out for r in server.finished}
        if baseline is None:
            baseline = toks
        else:
            assert toks == baseline  # preemption is semantics-transparent
        if pressure >= 1.5:
            # the pressured pools are too small for peak demand: the run
            # is only meaningful if the scheduler actually preempted
            assert stats["sched_swaps"] + stats["sched_recomputes"] >= 1, (
                pressure, stats["sched_swaps"], stats["sched_recomputes"])
        lat = sorted(r.t_done - r.t_enqueue for r in server.finished)
        p99 = lat[min(len(lat) - 1, int(0.99 * len(lat)))] if lat else 0.0
        us = stats["wall_s"] / max(stats["decoded_tokens"], 1) * 1e6
        report(
            f"serve_oversub_{pressure:.1f}x", us,
            f"{stats['tok_per_s']:.1f}tok/s", op="serve_oversub",
            pressure=pressure, pool_pages=pool_pages,
            tok_per_s=round(stats["tok_per_s"], 1),
            p99_latency_s=round(p99, 4),
            evictions=stats["sched_evictions"],
            swaps=stats["sched_swaps"],
            recomputes=stats["sched_recomputes"],
            resumes=stats["sched_resumes"],
            swap_pages=stats["tier_swapped_out_pages"],
        )

    # swap-vs-recompute crossover under the measured beta model
    from repro.serving.scheduler import swap_or_recompute
    from repro.serving.pool import PagedLayout

    costs = core_sched.load_costs("BENCH_gas.json")
    cost = costs.get("xla") or next(iter(costs.values()))
    layout = PagedLayout.from_struct(
        model.kv_block_struct(ctx, prompt_len=4, cache_len=cache_len),
        cache_len=cache_len, page_tokens=page_tokens,
    )
    crossover = None
    for g in range(0, 4096):
        mode, swap_us, rec_us = swap_or_recompute(
            n_pages, layout.page_bytes, g, cost
        )
        if mode == "swap":
            crossover = g
            break
    report(
        "serve_swap_recompute_crossover",
        float(crossover if crossover is not None else -1),
        f"beta={cost.beta_us_per_kib}us/KiB", unit="tokens",
        op="serve_oversub", page_bytes=layout.page_bytes,
        pages_per_request=n_pages,
        crossover_generated_tokens=crossover,
        alpha_us=cost.alpha_us, beta_us_per_kib=cost.beta_us_per_kib,
    )

    # ---- admission backpressure A/B: SLO health closing the loop ---------- #
    # Two identical oversubscribed runs — a bulk class (priority 0, long
    # decodes, arriving continuously: more demand than the pool fits)
    # against a latency class (priority 2, short decodes, finite
    # deadlines) streaming in AFTER the bulk has saturated the pool.
    # The only difference between the arms is whether the
    # HealthMonitor's backpressure floor is honoured.  Priority-major
    # admission already gives a QUEUED latency request first claim on a
    # freed slot in both arms — what it cannot do is keep capacity free
    # between latency arrivals: without the floor, every slot freed
    # while the latency queue is momentarily empty is recaptured by a
    # ~30-tick bulk decode, and the next latency arrival pays a
    # growth-eviction wait all over again.  With the floor (resident
    # latency requests hover at-risk on their tight TPOT deadline, so
    # it stays up through the stream), evicted bulk cannot resume into
    # freed capacity and the stream's TTFT collapses to ~1 tick.
    # Preemption is semantics-transparent, so both arms must produce
    # identical tokens (equal throughput) — the arms differ only in
    # WHEN work ran, i.e. in the latency class's TTFT/TPOT.
    from repro.obs.health import HealthMonitor
    from repro.serving.scheduler import SLO

    bulk_len, lat_len = 16, 8
    bulk_new, lat_new = 32, 8
    pool_bp = max(n_pages + 1, int(round(peak_demand / 1.5)))

    def bp_traffic(ttft_dl, tpot_dl):
        rng = np.random.default_rng(13)
        bulk = [
            Request(rid=100 + i,
                    prompt=rng.integers(0, cfg.vocab, bulk_len).tolist(),
                    max_new=bulk_new, slo=SLO(priority=0))
            for i in range(14)
        ]
        lat = [
            Request(rid=200 + i,
                    prompt=rng.integers(0, cfg.vocab, lat_len).tolist(),
                    max_new=lat_new,
                    slo=SLO(priority=2, ttft_deadline_s=ttft_dl,
                            tpot_deadline_s=tpot_dl))
            for i in range(16)
        ]
        # bulk saturates the pool first and keeps dripping so its queue
        # never empties; the latency stream starts after saturation
        plan = {0: bulk[:3], 2: bulk[3:5], 6: bulk[5:8]}
        for i, r in enumerate(bulk[8:]):
            plan.setdefault(30 + 8 * i, []).append(r)
        for i, r in enumerate(lat):
            plan.setdefault(24 + 4 * i, []).append(r)
        return plan, lat

    def bp_arm(backpressure, ttft_dl, tpot_dl):
        # risk_frac 0.5 with tpot_dl ~1.6 ticks keeps a healthily-decoding
        # latency request at-risk (steady risk ~0.6) without violating —
        # the floor holds through the stream instead of flapping
        mon = HealthMonitor(backpressure=backpressure, risk_frac=0.5)
        server = PagedServer(model, ctx, params, batch, cache_len,
                             page_tokens=page_tokens, n_pool_pages=pool_bp,
                             health=mon)
        # warm both prompt-length prefills and the full-width decode so
        # deadlines measure scheduling, not XLA compilation
        rng = np.random.default_rng(11)
        for rid, plen, mnew in ((90_000, bulk_len, bulk_new),
                                (90_001, lat_len, lat_new)):
            server.submit(Request(
                rid=rid, prompt=rng.integers(0, cfg.vocab, plen).tolist(),
                max_new=mnew))
        server.run_until_drained(max_ticks=2000)
        server.finished.clear()

        plan, lat = bp_traffic(ttft_dl, tpot_dl)
        t0 = time.perf_counter()
        for tick in range(max(plan) + 1):
            for r in plan.get(tick, ()):
                server.submit(r)
            server.step()
        stats = server.run_until_drained(max_ticks=4000)
        wall = time.perf_counter() - t0
        fin = {r.rid: r for r in server.finished}
        lat_fin = [fin[r.rid] for r in lat]
        ttfts = sorted(r.t_first - r.t_enqueue for r in lat_fin)
        return {
            "outs": {rid: r.out for rid, r in fin.items()},
            "toks": sum(len(r.out) for r in fin.values()),
            "wall_s": wall,
            "p99_ttft_s": ttfts[min(len(ttfts) - 1,
                                    int(0.99 * len(ttfts)))],
            "ttft_violations": sum(
                1 for r in lat_fin if r.t_first - r.t_enqueue > ttft_dl),
            "slo_violations": int(
                mon.registry.counter("slo_violations").value),
            "deferrals": stats["sched_deferrals"],
            "swaps": stats["sched_swaps"],
            "recomputes": stats["sched_recomputes"],
        }

    # calibrate deadlines from a healthy warm tick so they track this
    # machine, not a hardcoded wall; both arms share the same numbers.
    # The warm-up must drain a full-length request first: the decode jit
    # recompiles as the page-table width crosses its 4-page buckets, and
    # a compile landing inside the timed window would inflate per_tick
    # ~25x (and with it every deadline, leaving nothing ever at risk)
    cal = PagedServer(model, ctx, params, batch, cache_len,
                      page_tokens=page_tokens, n_pool_pages=pool_bp)
    rng = np.random.default_rng(11)
    cal.submit(Request(rid=94_999,
                       prompt=rng.integers(0, cfg.vocab, bulk_len).tolist(),
                       max_new=bulk_new))
    cal.run_until_drained(max_ticks=2000)
    cal.finished.clear()
    for i in range(batch):
        cal.submit(Request(
            rid=95_000 + i,
            prompt=rng.integers(0, cfg.vocab, bulk_len).tolist(),
            max_new=bulk_new))
    for _ in range(3):
        cal.step()  # settle admissions; jits are already warm
    t0 = time.perf_counter()
    for _ in range(8):
        cal.step()
    per_tick = (time.perf_counter() - t0) / 8
    cal.run_until_drained(max_ticks=2000)
    ttft_dl = 6.0 * per_tick
    tpot_dl = 1.6 * per_tick

    arms = {bp: bp_arm(bp, ttft_dl, tpot_dl) for bp in (False, True)}
    # equal throughput: scheduling may move work in time, never change it
    assert arms[True]["outs"] == arms[False]["outs"]
    assert arms[True]["deferrals"] >= 1, "backpressure arm never deferred"
    assert (arms[True]["ttft_violations"]
            <= arms[False]["ttft_violations"]), (
        arms[True]["ttft_violations"], arms[False]["ttft_violations"])
    for bp in (False, True):
        a = arms[bp]
        name = ("serve_oversub_backpressure" if bp
                else "serve_oversub_no_backpressure")
        report(
            name, a["p99_ttft_s"] * 1e6,
            f"{a['ttft_violations']} TTFT violations, "
            f"{a['toks']} toks", op="serve_oversub_bp",
            backpressure=bp, pool_pages=pool_bp,
            ttft_deadline_s=round(ttft_dl, 4),
            p99_ttft_s=round(a["p99_ttft_s"], 4),
            ttft_violations=a["ttft_violations"],
            slo_violations=a["slo_violations"],
            deferrals=a["deferrals"], swaps=a["swaps"],
            recomputes=a["recomputes"], tokens=a["toks"],
            wall_s=round(a["wall_s"], 4),
        )


def tp_sections(report) -> None:
    """The tensor-parallel-decode section of ``BENCH_serve.json``.

    Run on a scaled-up smoke config (~8M params) where the decode step
    is weights-bound, the regime the >= 8B configs live in: step cost is
    nearly batch-independent, so decode throughput is set by how many
    requests run CONCURRENTLY.  That is what the TP group buys on equal
    hardware — not FLOPs (every member computes 1/tp of each step, so
    aggregate compute is unchanged) but AGGREGATE MEMORY: at a fixed
    per-rank pool byte budget, head-sharded pages are ~1/tp the bytes,
    the group fits ~tp x the pages, and the decode batch scales with
    them.  Here tp=1's budget caps the batch at 4 while the tp=2
    group's aggregated pool runs batch 8.

    Every ``serve_tp_decode_tp{1,2,4}`` row carries two timings:

    - ``us_serialized``: the raw wall of the real shard_map step on this
      host — every rank's shard compute AND every all-reduce hop
      serialized back-to-back onto the local cores (this host simulates
      the group's devices on shared cores, so what it clocks is the
      group's total WORK, not its latency).
    - ``us`` (headline, feeds ``tok_per_s``): the group's RANK-CONCURRENT
      decode-step latency, ``us_serialized / tp`` — un-serializing what
      the ranks run simultaneously.  Cross-checked against
      ``us_rank_compute``, one rank's OWN step program (its head shard of
      the weights and pool, collectives elided) clocked alone: a hard
      lower bound on any rank's concurrent step, asserted to stay below
      the headline so the division never claims time the measured
      single-rank program disproves.

    Token parity vs an unsharded reference server is asserted from REAL
    ``TPPagedServer`` runs (actual planned all-reduces on the wire, all
    preemption machinery live) for every tp before any timing is
    reported.  tp=4 runs a ``n_kv_heads=4`` variant (4 does not divide
    the base config's 2 KV heads) against its own reference — its row
    shows the trend; only tp2/tp1 (same config) is the gated
    ``serve_tp_speedup`` ratio.
    """
    import dataclasses

    from jax.sharding import Mesh, NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.configs.registry import SMOKE
    from repro.core import sched as core_sched
    from repro.launch.serve import (PagedServer, Request, TPPagedServer,
                                    _paged_decode_views_fn,
                                    _tp_paged_decode_fn)
    from repro.models.build import build_model
    from repro.parallel import tp as tp_lib
    from repro.parallel.ctx import RunCtx
    from repro.serving.pool import PagedLayout

    ctx = RunCtx(mesh=None, remat="none")
    # weights-bound decode: scale the 405B smoke shape up until weight
    # streaming dominates per-step dispatch (~8M params, 31MB f32)
    base = SMOKE["llama3-405b"]
    base = dataclasses.replace(base, n_layers=8, d_model=256, d_ff=1024,
                               head_dim=32)
    cache_len, pt, max_batch = 64, 8, 8
    n_pages = cache_len // pt
    costs = core_sched.load_costs("BENCH_gas.json")

    def burst(cfg):
        rng = np.random.default_rng(17)
        reqs = []
        for rid in range(12):
            plen = int(rng.integers(10, 24))
            reqs.append(Request(
                rid=rid, prompt=rng.integers(0, cfg.vocab, plen).tolist(),
                max_new=min(int(rng.integers(28, 38)), cache_len - plen),
            ))
        return reqs

    def run(server, cfg):
        for req in burst(cfg):
            server.submit(req)
        stats = server.run_until_drained(max_ticks=4000)
        return {r.rid: list(r.out) for r in server.finished}, stats

    def timed_step(call, state, iters=12):
        """Per-step wall of ``call(state) -> (logits, state)``; the state
        (the pool views — donated by the real step programs) is threaded
        through so every iteration runs on a live buffer."""
        logits, state = call(state)  # compile + warm
        jax.block_until_ready(logits)
        t0 = time.perf_counter()
        for _ in range(iters):
            logits, state = call(state)
        jax.block_until_ready(logits)
        return (time.perf_counter() - t0) / iters * 1e6

    # the per-rank byte budget: one rank's pool barely fits batch 4 of
    # full pages; the tp=2 group's half-size pages fit batch 8
    base_layout = PagedLayout.from_struct(
        build_model(base).kv_block_struct(ctx, prompt_len=4,
                                          cache_len=cache_len),
        cache_len=cache_len, page_tokens=pt,
    )
    budget_bytes = (4 * n_pages + 1) * base_layout.page_bytes

    models = {}

    def get_model(cfg):
        if cfg not in models:
            model = build_model(cfg)
            params, _ = model.init(ctx, jax.random.PRNGKey(0))
            models[cfg] = (model, params, None)
        return models[cfg]

    # pass 1 — step timing at the budget-planned batch, in a clean
    # process state: the serving runs below allocate large pools and
    # churn donated buffers, which perturbs step walls clocked after
    setups = {}
    timing = {}
    for tp in (1, 2, 4):
        if jax.device_count() < tp:
            print(f"serve_tp_decode_tp{tp} skipped: needs >= {tp} devices")
            continue
        cfg = base if tp <= 2 else dataclasses.replace(base, n_kv_heads=4)
        model, params, _ = get_model(cfg)
        layout = PagedLayout.from_struct(
            model.kv_block_struct(ctx, prompt_len=4, cache_len=cache_len),
            cache_len=cache_len, page_tokens=pt,
        )
        shard_layout, _cols = layout.shard_heads(tp, cfg.n_kv_heads)
        n_pool = max(n_pages + 1, budget_bytes // shard_layout.page_bytes)
        batch = max(1, min(max_batch, n_pool // n_pages))
        setups[tp] = (cfg, layout, shard_layout, n_pool, batch)
        tok = jnp.zeros((batch, 1), jnp.int32)
        pos = jnp.full((batch,), 8, jnp.int32)
        tab = jnp.zeros((batch, n_pages), jnp.int32)
        if tp == 1:
            fn = _paged_decode_views_fn(model, ctx, layout)
            views0 = layout.decode_views(
                jnp.zeros((n_pool + 1, layout.page_elems), jnp.float32))
            serialized = compute = timed_step(
                lambda v: fn(params, tok, pos, v, tab), views0)
        else:
            # the REAL planned-collective shard_map program the TP
            # server decodes with, clocked on this host
            mesh = Mesh(np.array(jax.devices()[:tp]), ("tp",))
            sharding = NamedSharding(mesh, P("tp"))
            sparams = jax.device_put(
                tp_lib.stack_shards(params, tp), sharding)
            fn = _tp_paged_decode_fn(model, ctx, shard_layout, tp, "xla",
                                     mesh)
            dev0 = jax.device_put(
                jnp.zeros((tp, n_pool + 1, shard_layout.page_elems),
                          jnp.float32), sharding)
            serialized = timed_step(
                lambda v: fn(sparams, tok, pos, v, tab), dev0)
            # one rank's OWN step program (its head shard of the weights
            # and pool, collectives elided), clocked alone
            group = tp_lib.TPGroup(tp, lambda x: x)
            p0 = jax.device_put(tp_lib.shard_decode_params(params, tp, 0))

            @jax.jit
            def rank_step(p, token, position, views, tables=tab):
                return model.decode_step_paged(
                    p, ctx, token, position, views, tables, tp=group)

            sv0 = shard_layout.decode_views(
                jnp.zeros((n_pool + 1, shard_layout.page_elems),
                          jnp.float32))
            compute = timed_step(
                lambda v: rank_step(p0, tok, pos, v), sv0)
        timing[tp] = (serialized, compute)

    # pass 2 — real serving runs: actual planned all-reduces on the
    # wire, all preemption machinery live, token parity asserted
    tok_s = {}
    for tp, (cfg, layout, shard_layout, n_pool, batch) in setups.items():
        model, params, ref_toks = get_model(cfg)
        if ref_toks is None:
            ref = PagedServer(model, ctx, params, batch, cache_len,
                              page_tokens=pt)
            ref_toks, _ = run(ref, cfg)
            models[cfg] = (model, params, ref_toks)
        kw = dict(page_tokens=pt, n_pool_pages=n_pool)
        if tp == 1:
            server = PagedServer(model, ctx, params, batch, cache_len, **kw)
        else:
            server = TPPagedServer(model, ctx, params, batch, cache_len,
                                   tp=tp, tp_backend="xla", **kw)
        toks, stats = run(server, cfg)
        assert toks == ref_toks, f"tp={tp} token parity failed"

        serialized, compute = timing[tp]
        concurrent = serialized / tp
        if tp == 1:
            ar_us = 0.0
            ar_note = "none (single rank)"
        else:
            assert compute <= concurrent * 1.05, (
                f"tp={tp}: one rank's measured step ({compute:.0f}us) "
                f"exceeds the un-serialized group step ({concurrent:.0f}us)"
            )
            # 2 partial-sum all-reduces per layer (attention wo + mlp
            # w2), (batch, 1, d_model) f32 payloads; their serialized
            # in-program cost is the wall the shard compute can't explain
            ar_us = max(0.0, serialized - tp * compute)
            plan = core_sched.plan_collective(
                "all_reduce", nbytes=batch * cfg.d_model * 4,
                n_nodes=tp, costs=costs)
            n_ar = 2 * cfg.n_layers
            ar_note = (f"{n_ar} x {plan.algorithm}, "
                       f"~{ar_us / n_ar:.0f}us each serialized in-program")
        tps = batch / concurrent * 1e6
        tok_s[tp] = tps
        report(f"serve_tp_decode_tp{tp}", concurrent,
               f"{tps:.0f}tok/s @batch{batch}", op="serve_tp",
               tp=tp, tok_per_s=round(tps, 1), batch=batch,
               us_serialized=round(serialized, 1),
               us_rank_compute=round(compute, 1),
               tok_per_s_serialized=round(batch / serialized * 1e6, 1),
               serve_tok_per_s=round(stats["tok_per_s"], 1),
               allreduce_us=round(ar_us, 1), allreduce_plan=ar_note,
               pool_pages=n_pool,
               shard_page_bytes=shard_layout.page_bytes,
               budget_bytes=budget_bytes,
               n_kv_heads=cfg.n_kv_heads)
    if 1 in tok_s and 2 in tok_s:
        speedup = tok_s[2] / max(tok_s[1], 1e-9)
        report("serve_tp_speedup", speedup,
               f"tp2 {tok_s[2]:.0f} vs tp1 {tok_s[1]:.0f} tok/s", unit="x",
               op="serve_tp", tp_from=1, tp_to=2,
               tok_per_s_tp1=round(tok_s[1], 1),
               tok_per_s_tp2=round(tok_s[2], 1))


def overlap_bench(report) -> None:
    """Measure the split-phase win of nonblocking page prefetch.

    A decode tick that needs K remote page batches can either issue
    *blocking* gets — each get's sync completes before the next statement
    runs, serialising host dispatch with the wire — or initiate all K
    vectored gets nonblocking, run the paged-attention kernel over local
    pages, and sync the handles afterwards.  The overlap variant pipelines
    initiation with execution (the GASNet split-phase idiom); the gap is
    the dispatch+sync latency the blocking semantics cannot hide.
    """
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from repro.core import gasnet
    from repro.kernels import ops
    from repro.serving import pool as pool_lib

    n = 2
    B, Hq, Hkv, D, T, NP = 4, 8, 2, 64, 8, 8
    pages_per_rank = 64
    page_elems = T * Hkv * D * 2  # K and V halves of one page
    n_batches, pages_per_batch = 8, 4
    pmap = pool_lib.PoolMap(n, pages_per_rank, page_elems)
    mesh = jax.make_mesh((n,), ("node",))
    ctx_gas = gasnet.Context(mesh, node_axis="node", backend="xla")

    rng = np.random.default_rng(0)
    seg = jax.device_put(
        jnp.asarray(
            rng.normal(size=(n, pages_per_rank * page_elems)), jnp.float32
        ),
        jax.sharding.NamedSharding(mesh, P("node")),
    )
    q = jnp.asarray(rng.normal(size=(B, Hq, D)), jnp.float32)
    kv_pages = jnp.asarray(
        rng.normal(size=(pages_per_rank, T, Hkv, D)), jnp.float32
    )
    v_pages = jnp.asarray(
        rng.normal(size=(pages_per_rank, T, Hkv, D)), jnp.float32
    )
    table = jnp.asarray(
        rng.integers(0, pages_per_rank, (B, NP)), jnp.int32
    )
    lengths = jnp.full((B,), NP * T, jnp.int32)

    def make_fetch(offsets):
        def prog(node, s):
            handles, _ = pool_lib.fetch_pages(
                node, s, offsets, frm=gasnet.Shift(1),
                page_elems=page_elems,
            )
            return pool_lib.sync_fetch(node, handles)[None]

        return jax.jit(shard_map(
            lambda s: prog(ctx_gas.make_node(), s),
            mesh=mesh, in_specs=(P("node"),), out_specs=P("node"),
            check_vma=False,
        ))

    fetch_fns = []
    fetch_ids = []
    for _ in range(n_batches):
        ids = [int(x) for x in rng.integers(0, pages_per_rank, pages_per_batch)]
        fetch_ids.extend(ids)
        fetch_fns.append(make_fetch(
            jnp.asarray([pmap.offset(g) for g in ids], jnp.int32)
        ))
    attn_fn = jax.jit(lambda q, kp, vp, t, l: ops.paged_attention(
        q, kp, vp, t, l, impl="pallas"))

    for fn in fetch_fns:
        jax.block_until_ready(fn(seg))
    jax.block_until_ready(attn_fn(q, kv_pages, v_pages, table, lengths))

    def run_blocking():
        fetched = [None] * n_batches
        for i, fn in enumerate(fetch_fns):
            fetched[i] = fn(seg)
            jax.block_until_ready(fetched[i])  # blocking-get semantics
        out = attn_fn(q, kv_pages, v_pages, table, lengths)
        jax.block_until_ready(out)
        return out, fetched

    def run_overlap():
        # initiate every get nonblocking, decode, then sync the handles
        fetched = [fn(seg) for fn in fetch_fns]
        out = attn_fn(q, kv_pages, v_pages, table, lengths)
        jax.block_until_ready(out)
        for f in fetched:
            jax.block_until_ready(f)
        return out, fetched

    samples = {"blocking": [], "overlap": []}
    outs = {}
    iters = 30
    run_blocking()  # warm both programs
    run_overlap()
    for _ in range(iters):
        # interleave the variants so machine-load drift during the run
        # lands on both equally instead of biasing whichever ran last
        for kind, run in (("blocking", run_blocking), ("overlap", run_overlap)):
            t0 = time.perf_counter()
            o = run()
            samples[kind].append(time.perf_counter() - t0)
            out, fetched = o
            outs[kind] = [np.asarray(out)] + [np.asarray(f) for f in fetched]
    # best-of-N: the structural cost each variant cannot avoid, with
    # scheduler noise (which only ever adds time) stripped out
    times = {k: float(np.min(v)) * 1e6 for k, v in samples.items()}
    for a, b in zip(outs["blocking"], outs["overlap"]):
        np.testing.assert_allclose(a, b, rtol=1e-6)
    gap = times["blocking"] / max(times["overlap"], 1e-9)
    fetch_bytes = len(fetch_ids) * page_elems * 4
    report("paged_fetch_blocking", times["blocking"],
           f"{fetch_bytes}B fetched in {n_batches} blocking gets",
           op="paged_overlap", fetch_bytes=fetch_bytes,
           n_batches=n_batches)
    report("paged_fetch_overlap", times["overlap"],
           f"{gap:.2f}x vs blocking", op="paged_overlap",
           fetch_bytes=fetch_bytes, n_batches=n_batches,
           overlap_gap=round(gap, 3))


def obs_sections(report) -> None:
    """The observability section of ``BENCH_serve.json``:

    - ``obs_overhead``: paged-decode wall time with tracing fully ENABLED
      over the same burst with the no-op recorder installed (the
      production default).  The enabled run bounds the instrumentation
      tax from above — the disabled path runs only the ``active()`` +
      ``.enabled`` guards, which are strictly cheaper — so gating the
      ratio (``check_serve_perf``: < 1.02x) keeps tracing-off overhead
      under the 2% budget by construction.
    - device-timed kernel profiles (``DeviceProfiler``): the paged
      attention hot kernel vs its oracle, and the server's fused decode
      step, timed by interleaved re-execution (labelled
      ``measured="wall"`` on the forced-host backend),
    - the cost-model feedback loop: real executed transfers (warmed,
      blocking segmented puts at three payload sizes) recorded as
      ``cat="transfer"`` spans, PLUS the receiver epilogue (the
      install/store a landed segment pays) timed alone at the same
      sizes — so ``EngineCost.fit_from_trace`` refits (α, β) AND
      decomposes the measured per-KiB slope into wire β vs epilogue γ.
      Rows report the shipped DEFAULT model's predicted-vs-measured
      error and the refit's residual — the measurement closing the loop
      back into ``plan_p2p``/``plan_collective``.  Thin traces degrade
      to a reported ``fit: insufficient-data`` note, never a crash.
    """
    from repro.configs.registry import SMOKE
    from repro.core import gasnet
    from repro.core import sched as core_sched
    from repro.kernels import ops as kernel_ops
    from repro.launch.serve import PagedServer, Request
    from repro.models.build import build_model
    from repro.obs import trace as obs_trace
    from repro.obs.profile import DeviceProfiler
    from repro.parallel.ctx import RunCtx

    ctx = RunCtx(mesh=None, remat="none")
    cfg = SMOKE["qwen3-4b"]
    model = build_model(cfg)
    params, _ = model.init(ctx, jax.random.PRNGKey(0))

    def burst(base_rid, n=12):
        rng = np.random.default_rng(7)
        return [
            Request(rid=base_rid + rid,
                    prompt=rng.integers(0, cfg.vocab, 16).tolist(),
                    max_new=12)
            for rid in range(n)
        ]

    def make_server():
        server = PagedServer(model, ctx, params, batch_size=8,
                             cache_len=96, page_tokens=8)
        server.submit(Request(rid=10_000,
                              prompt=burst(0)[0].prompt, max_new=12))
        server.run_until_drained()  # warm prefill/decode/page-patch jits
        server.finished.clear()
        return server

    servers = {False: make_server(), True: make_server()}
    walls = {False: [], True: []}
    for rep in range(6):
        # interleave the variants so machine-load drift lands on both
        for enabled in (False, True):
            server = servers[enabled]
            reqs = burst((1 + rep) * 100)
            if enabled:
                obs_trace.enable(capacity=1 << 16)
            try:
                for req in reqs:
                    server.submit(req)
                t0 = time.perf_counter()
                server.run_until_drained()
                walls[enabled].append(time.perf_counter() - t0)
            finally:
                obs_trace.disable()
            server.finished.clear()
    # best-of-N: scheduler noise only ever adds time
    t_off = min(walls[False])
    t_on = min(walls[True])
    overhead = t_on / max(t_off, 1e-9)
    report("obs_overhead", t_on * 1e6, f"{overhead:.3f}x vs tracing off",
           unit="x", op="obs_overhead", overhead_x=round(overhead, 4),
           wall_on_s=round(t_on, 4), wall_off_s=round(t_off, 4))

    # ---- device-timed kernel profiles ------------------------------------- #
    # The decode hot kernel vs its oracle, interleaved so load drift
    # lands on both, then the server's fused decode step over live rows
    # (offline timed re-execution — decode from fixed tables is
    # idempotent, so re-running it never perturbs served state).
    prof = DeviceProfiler()
    kernel_best = prof.profile_many(
        kernel_ops.profiling_targets(interpret=True), rounds=4, warmup=1)
    for kname in sorted(kernel_best):
        rec = next(r for r in prof.records if r["name"] == kname)
        report(f"obs_profile_{kname}", kernel_best[kname],
               f"best-of-4 interleaved, measured={rec['measured']}",
               op="obs_profile", measured=rec["measured"])

    server = servers[False]
    for req in burst(5000, n=4):
        server.submit(req)
    for _ in range(3):
        server.step()
    dec_us = server.profile_decode(prof, iters=4, warmup=1)
    server.run_until_drained()
    server.finished.clear()
    if dec_us is not None:
        rec = next(r for r in prof.records
                   if r["name"] == "paged_decode_step")
        report("obs_profile_decode_step", dec_us,
               f"fused tick, live={rec.get('live')}, "
               f"measured={rec['measured']}",
               op="obs_profile", measured=rec["measured"],
               live=rec.get("live"), table_width=rec.get("table_width"))

    # ---- cost-model feedback: measured transfer spans -> refit ------------ #
    if jax.device_count() < 2:
        print("obs cost-model rows skipped: needs >= 2 host devices")
        return
    n = 2
    mesh = jax.make_mesh((n,), ("node",))
    gctx = gasnet.Context(mesh, node_axis="node", backend="xla")
    tr = obs_trace.enable(capacity=4096)
    sizes = (1 << 16, 1 << 18, 1 << 20)  # 64 KiB, 256 KiB, 1 MiB
    try:
        for size in sizes:
            n_el = size // 4
            aspace = gctx.address_space()
            aspace.register(f"obs{size}", (n_el,), jnp.float32)
            seg = aspace.alloc(f"obs{size}")

            def put_prog(node, seg, n_el=n_el):
                data = jnp.ones((n_el,), jnp.float32) * node.my_id
                return node.put(seg, data, to=gasnet.Shift(1), index=0)

            # jit ONCE per size (spmd builds a fresh closure per call, so
            # its internal jit cache never hits): the measured spans must
            # time executed wire work, not retracing
            fn = jax.jit(
                lambda s, prog=put_prog: gctx.spmd(prog, s, jit=False)
            )

            def run(s, fn=fn):
                return jax.block_until_ready(fn(s))

            for _ in range(3):
                run(seg)  # warm: the spans must time execution, not XLA
            for _ in range(4):
                with tr.span(f"put_{size}", cat="transfer", bytes=size):
                    run(seg)
        spans = list(tr.spans(cat="transfer"))
    finally:
        obs_trace.disable()
    cost0 = core_sched.DEFAULT_COSTS["xla"]
    err0 = cost0.model_error(spans)
    report("obs_cost_model_err", err0 * 100, "DEFAULT α/β vs measured",
           unit="pct", op="obs_cost", model_error=round(err0, 4),
           alpha_us=cost0.alpha_us, beta_us_per_kib=cost0.beta_us_per_kib)

    # γ measurement: time the receiver epilogue (installing a landed
    # segment into its resident buffer) ALONE at the same payload
    # sizes.  On the live path that store overlaps the wire, so its
    # cost hides inside the fitted end-to-end slope; measured
    # standalone, its per-KiB slope lets fit_from_trace split the
    # slope into wire β + epilogue γ without moving hop_us — the
    # software stand-in for ACCL+'s per-engine hardware counters.
    def make_install(nbytes):
        n_el = nbytes // 4
        dst = jnp.zeros((n_el,), jnp.float32)
        src = jnp.ones((n_el,), jnp.float32)
        install = jax.jit(
            lambda d, s: jax.lax.dynamic_update_slice(d, s, (0,)))
        return lambda: install(dst, src)

    epi = prof.profile_epilogue(make_install, sizes,
                                name="epilogue_install", iters=6, warmup=2)
    fit, note = core_sched.try_fit_from_trace(spans, epilogue_spans=epi)
    if fit is None:
        print(f"obs_cost_refit_err skipped: {note}")
        return
    err1 = fit.model_error(spans)
    gamma_meas = core_sched.EngineCost.fit_gamma_from_trace(epi)
    report("obs_cost_refit_err", err1 * 100,
           f"fit α={fit.alpha_us:.1f}us β={fit.beta_us_per_kib:.3f} "
           f"γ={fit.gamma_us_per_kib:.3f}us/KiB",
           unit="pct", op="obs_cost", model_error=round(err1, 4),
           alpha_us=round(fit.alpha_us, 2),
           beta_us_per_kib=round(fit.beta_us_per_kib, 4),
           gamma_us_per_kib=round(fit.gamma_us_per_kib, 4),
           n_spans=len(spans), note=note)
    report("obs_cost_gamma", fit.gamma_us_per_kib,
           f"epilogue slope {gamma_meas:.3f}us/KiB "
           f"(measured={epi[0]['measured']}, capped at wire β)",
           unit="us_per_kib", op="obs_cost", measured=epi[0]["measured"],
           epilogue_slope_us_per_kib=round(gamma_meas, 4))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--json", nargs="?", const="BENCH_serve.json", default=None,
        metavar="PATH",
        help="write the machine-readable artifact (default: BENCH_serve.json)",
    )
    ap.add_argument(
        "--sections", default=None, metavar="A,B,...",
        help=f"run only these sections (of {','.join(SECTIONS)}) and MERGE "
             "their rows into the --json artifact instead of rewriting it",
    )
    args = ap.parse_args()
    picked = None
    if args.sections is not None:
        picked = tuple(s.strip() for s in args.sections.split(",") if s.strip())
        unknown = [s for s in picked if s not in SECTIONS]
        if unknown:
            ap.error(f"unknown sections {unknown}; choose from {SECTIONS}")
    main(json_path=args.json, sections=picked)

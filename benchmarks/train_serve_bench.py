"""End-to-end benchmarks: train-step throughput + decode tokens/s +
disaggregated serving (smoke configs).

Prints ``name,us_per_call,derived`` CSV rows; with ``--json PATH``
(default ``BENCH_serve.json`` when the flag is given bare) it also writes
a machine-readable artifact: serve throughput, p50/p99 request latency,
TTFT, and the KV-transfer goodput (bytes/sec) of the disaggregated
cluster — the serving-side numbers CI tracks next to ``BENCH_gas``.

The disaggregated section needs several host devices, so the device count
is forced before the first JAX import (like gas_microbench).
"""
import os

if __name__ == "__main__":
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=4"
    )

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from _report import make_report, new_result, write_artifact

RESULT = new_result()
report = make_report(RESULT)


def main(json_path: str | None = None) -> None:
    from repro.configs.registry import SMOKE
    from repro.data.synthetic import SyntheticLM
    from repro.models.build import build_model
    from repro.optim import adamw
    from repro.parallel.ctx import RunCtx
    from repro.runtime.trainer import Trainer, TrainerConfig

    ctx = RunCtx(mesh=None, remat="none")

    for arch in ("qwen3-4b", "falcon-mamba-7b", "arctic-480b"):
        cfg = SMOKE[arch]
        model = build_model(cfg)
        tr = Trainer(model, ctx, adamw.AdamWConfig(lr=1e-3),
                     TrainerConfig(steps=1, ckpt_every=0))
        params, st = tr.init(jax.random.PRNGKey(0))
        fn = tr.make_train_step()
        B, S = 8, 128
        src = SyntheticLM(cfg, batch=B, seq_len=S, seed=0)
        batch = {k: jnp.asarray(v) for k, v in src.batch_at(0).items()}
        params, st, _ = fn(params, st, batch)  # compile+warm
        iters = 5
        t0 = time.perf_counter()
        for _ in range(iters):
            params, st, m = fn(params, st, batch)
        jax.block_until_ready(m["loss"])
        us = (time.perf_counter() - t0) / iters * 1e6
        tok_s = B * S / (us * 1e-6)
        report(f"train_step_{arch}", us, f"{tok_s:.0f}tok/s",
               op="train_step", arch=arch, tok_per_s=round(tok_s, 1))

    # ---- colocated decode throughput (continuous batching) --------------- #
    from repro.launch.serve import Request, Server

    cfg = SMOKE["qwen3-4b"]
    model = build_model(cfg)
    params, _ = model.init(ctx, jax.random.PRNGKey(0))
    server = Server(model, ctx, params, batch_size=8, cache_len=96)
    rng = np.random.default_rng(0)
    for rid in range(16):
        server.submit(Request(rid=rid,
                              prompt=rng.integers(0, cfg.vocab, 16).tolist(),
                              max_new=16))
    stats = server.run_until_drained()
    us = stats["wall_s"] / max(stats["decoded_tokens"], 1) * 1e6
    report("serve_decode_qwen3", us, f"{stats['tok_per_s']:.1f}tok/s",
           op="serve_decode", tok_per_s=round(stats["tok_per_s"], 1),
           p50_latency_s=round(stats["p50_latency_s"], 4))
    report("serve_p50_ttft", stats["p50_ttft_s"] * 1e6,
           f"{stats['requests']}req", op="serve_ttft",
           requests=stats["requests"])

    # ---- disaggregated serving: prefill pool -> KV put -> decode pool ----- #
    # (only when the forced host device count allows >= 2 ranks)
    if jax.device_count() >= 4:
        from repro.serving.disagg import DisaggCluster

        cluster = DisaggCluster(
            model, ctx, params, n_prefill=2, n_decode=2,
            decode_batch=4, cache_len=64,
        )
        rng = np.random.default_rng(1)
        for rid in range(12):
            cluster.submit(Request(
                rid=rid, prompt=rng.integers(0, cfg.vocab, 16).tolist(),
                max_new=12,
            ))
        d = cluster.run_until_drained()
        us = d["wall_s"] / max(d["decoded_tokens"], 1) * 1e6
        report("serve_disagg_decode", us, f"{d['tok_per_s']:.1f}tok/s",
               op="serve_disagg", tok_per_s=round(d["tok_per_s"], 1),
               requests=d["requests"],
               p50_latency_s=round(d["p50_latency_s"], 4),
               p99_latency_s=round(d["p99_latency_s"], 4),
               p50_ttft_s=round(d["p50_ttft_s"], 4))
        report("serve_disagg_kv_goodput", d["kv_bytes_per_s"] / 1e6,
               f"{d['kv_transfers']}x{d['kv_block_bytes']}B", unit="mb_s",
               op="serve_disagg_kv",
               kv_bytes_per_sec=round(d["kv_bytes_per_s"], 1),
               kv_transfers=d["kv_transfers"],
               kv_block_bytes=d["kv_block_bytes"],
               kv_plan=d["kv_plan"], acked=d["kv_acked"])
        assert d["kv_acked"] == d["kv_transfers"]
    else:
        print("serve_disagg skipped: needs >= 4 host devices")

    if json_path:
        write_artifact(RESULT, json_path)
    print("TRAIN_SERVE_BENCH_DONE")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--json", nargs="?", const="BENCH_serve.json", default=None,
        metavar="PATH",
        help="write the machine-readable artifact (default: BENCH_serve.json)",
    )
    main(json_path=ap.parse_args().json)

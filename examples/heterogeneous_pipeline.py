"""The paper's signature demo (§II/§IV): software and hardware nodes
cooperating transparently through one API.

Act 1 — *develop in software*: a producer/consumer pipeline where every
stage communicates with one-sided puts through the XLA ("software GASNet")
engine.  Act 2 — *migrate to hardware*: the identical program runs with the
GAScore engine (Pallas remote-DMA kernels, TPU-interpret on CPU), and the
results match bit-for-bit semantics.  Act 3 — a serving-shaped use: a
"prefill node" hands a KV cache to a "decode node" with a single one-sided
put (disaggregated inference transfer).

Run:  PYTHONPATH=src python examples/heterogeneous_pipeline.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import gasnet

N = 4
mesh = jax.make_mesh((N,), ("node",))


# A 3-stage pipeline over the ring: each node transforms what the previous
# node put into its inbox, then puts the result onward.
def pipeline_program(node, inbox, x):
    # stage 0: produce
    work = node.local(x)
    for _hop in range(N - 1):
        # transform then one-sided put to the right neighbor's inbox
        work = jnp.tanh(work) * 1.1
        inbox = node.put(inbox, work, to=gasnet.Shift(1), index=0)
        node.barrier()
        work = node.local(inbox).reshape(-1)[: work.shape[0]]
    return work[None]


def run(backend: str) -> np.ndarray:
    ctx = gasnet.Context(mesh, node_axis="node", backend=backend,
                         interpret=True)
    aspace = ctx.address_space()
    aspace.register("inbox", (128,), jnp.float32)
    inbox = aspace.alloc("inbox")
    x = jnp.tile(jnp.linspace(-1, 1, 128)[None], (N, 1)).astype(jnp.float32)
    out = ctx.spmd(
        functools.partial(pipeline_program),
        inbox, x, out_specs=P("node"),
    )
    return np.asarray(out)


print("Act 1: run the pipeline on the SOFTWARE engine (XLA collectives)")
sw = run("xla")
print("  node 0 out[:4] =", sw[0, :4])

print("Act 2: migrate to the HARDWARE engine (GAScore Pallas remote-DMA)")
hw = run("gascore")
print("  node 0 out[:4] =", hw[0, :4])
np.testing.assert_allclose(sw, hw, rtol=1e-6)
print("  identical results — zero application changes.")

# --------------------------------------------------------------------------- #
print("Act 2b: MIX them — a heterogeneous node map, software and hardware")
print("nodes cooperating in ONE job (the paper's cluster, §II)")

from repro.launch.mesh import node_backends

backends = node_backends(N, pattern="alternating")  # sw, hw, sw, hw
print("  node map:", dict(enumerate(backends)))
mixed = run(",".join(backends))
np.testing.assert_allclose(sw, mixed, rtol=1e-6)
print("  identical results again — each rank on its own engine, one API.")

# --------------------------------------------------------------------------- #
print("Act 3: disaggregated serving — prefill node puts a KV cache into the")
print("decode node's memory with ONE one-sided GAScore transfer")

from repro.kernels import gascore
from repro.compat import shard_map

S, KH, Dh = 32, 2, 16
kv = jnp.asarray(
    np.random.default_rng(0).normal(size=(N, S * KH * Dh)), jnp.float32
)
empty = jnp.zeros((N, 2 * S * KH * Dh), jnp.float32)


def handoff(seg, kv_l):
    # prefill node (every node plays both roles on the ring) writes its
    # computed KV block at offset S*KH*Dh of the decode node's cache segment
    return gascore.offset_put(
        seg[0], kv_l[0], jnp.int32(S * KH * Dh), k=1, axis="node", n_nodes=N
    )[None]


seg = jax.jit(
    shard_map(handoff, mesh=mesh, in_specs=(P("node"), P("node")),
                  out_specs=P("node"), check_vma=False)
)(empty, kv)
got = np.asarray(seg)
for d in range(N):
    np.testing.assert_allclose(
        got[d, S * KH * Dh :], np.asarray(kv)[(d - 1) % N]
    )
print("  KV cache landed at the receiver-side offset chosen by the sender —")
print("  the GAScore command format (local addr, node, remote addr, len).")

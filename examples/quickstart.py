"""Quickstart: the GASNet-style PGAS API in five minutes.

Eight "nodes" (CPU host devices standing in for TPU chips), one partitioned
global address space, one-sided puts/gets — blocking (Core API) and
split-phase non-blocking (Extended API) with comm/compute overlap — Active
Messages with handlers, and a ring all-reduce built from neighbor puts —
the paper's programming model end to end.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import collectives, gasnet

N = 8
mesh = jax.make_mesh((N,), ("node",))
ctx = gasnet.Context(mesh, node_axis="node", backend="xla",
                     am_payload_width=4)

# --- 1. attach a segment: every node owns a 64-float partition -----------
aspace = ctx.address_space()
aspace.register("scratch", (64,), jnp.float32)
seg = aspace.alloc("scratch")
print(f"segment 'scratch': {N} nodes x {aspace.spec('scratch').local_shape}")

# --- 2. one-sided put: write into your right neighbor's memory -----------
def put_demo(node, seg):
    payload = jnp.full((4,), 10.0 + node.my_id, jnp.float32)
    seg = node.put(seg, payload, to=gasnet.Shift(1), index=8)
    node.barrier()
    return seg

seg = ctx.spmd(put_demo, seg)
print("after put, node 3 holds (from node 2):",
      np.asarray(seg)[3, 8:12])

# --- 3. one-sided get: read 4 floats from node (me+2) --------------------
def get_demo(node, seg):
    return node.get(seg, frm=gasnet.Shift(2), index=8, size=4)[None]

got = ctx.spmd(get_demo, seg, out_specs=P("node"))
print("node 0 got (from node 2):", np.asarray(got)[0])

# --- 4. Extended API: split-phase non-blocking put/get with overlap -------
# put_nb initiates the transfer and returns a handle; independent compute
# issued before node.sync(h) overlaps the wire (gasnet_put_nb semantics).
def overlap_demo(node, seg):
    h = node.put_nb(seg, jnp.full((4,), 70.0 + node.my_id, jnp.float32),
                    to=gasnet.Shift(1), index=20)
    local = node.local(seg)[:16]
    stat = jnp.tanh(local) @ jnp.ones((16,))     # overlaps the transfer
    seg = node.sync(h)                           # split-phase completion
    g = node.get_nb(seg, frm=gasnet.Shift(1), index=20, size=4)
    fetched = node.sync(g)                       # completes the get
    return seg, (fetched + 0.0 * stat)[None]

seg, fetched = ctx.spmd(overlap_demo, seg, out_specs=(P("node"), P("node")))
print("node 0 put_nb'd to node 1, then get_nb'd it back:",
      np.asarray(fetched)[0])

# --- 5. Active Messages: handler runs at the receiver ---------------------
@ctx.handlers.handler("accumulate")
def h_acc(state, payload, args):
    out = dict(state)
    out["acc"] = state["acc"] + payload.sum() * args[0]
    return out

def am_demo(node, seg):
    state = {"acc": jnp.zeros((), jnp.float32)}
    dest = jnp.asarray((node.my_id + 3) % N, jnp.int32)
    node.am_medium(dest, "accumulate",
                   payload=jnp.ones((4,), jnp.float32), args=(2,))
    state = node.am_flush(state)  # route + run handlers
    return state["acc"][None]

acc = ctx.spmd(am_demo, seg, out_specs=P("node"))
print("AM handler results (each node got one message, 4*1*2):",
      np.asarray(acc))

# --- 6. collectives from one-sided puts (incl. broadcast + exchange) -------
# All rings are built on the split-phase primitives internally: each hop's
# put is initiated before the previous hop's local work.
def coll_demo(node, x):
    e = node.engine
    ar = collectives.ring_all_reduce(e, node.local(x))
    bc = collectives.broadcast(e, node.local(x), root=2)
    ex = collectives.exchange(e, node.local(x))  # all-to-all, fully in flight
    return ar[None], bc[None], ex[None]

x = jnp.arange(float(N * 16)).reshape(N, 16)
ar, bc, ex = ctx.spmd(coll_demo, x, out_specs=(P("node"),) * 3)
assert np.allclose(np.asarray(ar)[0], np.asarray(x).sum(0))
assert np.allclose(np.asarray(bc)[5], np.asarray(x)[2])
print("ring all-reduce / broadcast / exchange over one-sided puts: OK")

# --- 7. the collective scheduler: size-aware plans + segmented rings -------
# sched picks the algorithm (tree / recursive doubling / segmented ring)
# from payload bytes, node count, and the engine cost model, then runs it.
from repro.core import sched

for size in (1 << 10, 1 << 20, 1 << 24):  # 1 KiB, 1 MiB, 16 MiB
    print(" ", sched.plan_collective("all_reduce", nbytes=size,
                                     n_nodes=N).describe())

def seg_demo(node, x):  # segmented ring all-reduce, 4 slices x depth 2
    return collectives.segmented_ring_all_reduce(
        node.engine, node.local(x), n_segments=4, depth=2)[None]

seg_ar = ctx.spmd(seg_demo, x, out_specs=P("node"))
assert np.array_equal(np.asarray(seg_ar), np.asarray(ar))  # bit-identical
print("segmented ring all-reduce == monolithic (pipelined wire): OK")

print("\nSwap backend='gascore' (or a mixed map like 'xla,gascore') in the")
print("Context to run the same program on the Pallas remote-DMA engine")
print("(see examples/heterogeneous_pipeline.py).")

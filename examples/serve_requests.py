"""Disaggregated serving demo: prefill pool -> KV put -> decode pool.

Four GASNet ranks in one job (``launch.mesh.serve_roles``): ranks 0-1 are
the prefill pool, ranks 2-3 the decode pool running continuous batching
unchanged.  Each finished prefill's KV cache crosses the GAS layer as a
``sched.plan_p2p``-planned segmented split-phase put into a staging slot
of the decode node's segment; a ``kv_ready`` Active-Message *request*
rides along and the decode node's handler *replies* an installation ack
that resolves the prefill side's AckHandle.  Completions flow back the
same AM plane.

The demo then replays the identical request burst through the colocated
``Server`` and asserts the disaggregated cluster produced token-identical
outputs — the KV block handoff is bit-transparent.

Run:    PYTHONPATH=src python examples/serve_requests.py
Smoke:  PYTHONPATH=src python examples/serve_requests.py --smoke
"""
import argparse
import os
import sys

sys.path.insert(0, "src")

N_PREFILL, N_DECODE = 2, 2
os.environ.setdefault(
    "XLA_FLAGS",
    f"--xla_force_host_platform_device_count={N_PREFILL + N_DECODE}",
)

import jax  # noqa: E402  (device count must be forced first)
import numpy as np  # noqa: E402

from repro.configs.registry import SMOKE  # noqa: E402
from repro.launch.serve import Request, Server  # noqa: E402
from repro.models.build import build_model  # noqa: E402
from repro.parallel.ctx import RunCtx  # noqa: E402
from repro.serving.disagg import DisaggCluster  # noqa: E402


def make_requests(cfg, n, rng):
    reqs = []
    for rid in range(n):
        plen = int(rng.integers(4, 20))
        reqs.append(
            Request(
                rid=rid,
                prompt=rng.integers(0, cfg.vocab, size=plen).tolist(),
                max_new=int(rng.integers(4, 10)),
            )
        )
    return reqs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small burst + strict round-trip asserts")
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--cache-len", type=int, default=48)
    ap.add_argument("--decode-batch", type=int, default=2)
    ap.add_argument("--decode-backend", default="xla",
                    help="decode pool engine (try gascore: the paper's "
                         "hardware nodes serving the KV-install side)")
    args = ap.parse_args()
    n_requests = 6 if args.smoke else args.requests

    cfg = SMOKE[args.arch]
    model = build_model(cfg)
    ctx = RunCtx(mesh=None, remat="none")
    params, _ = model.init(ctx, jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    reqs = make_requests(cfg, n_requests, rng)

    print(f"cluster: {N_PREFILL} prefill + {N_DECODE} decode ranks "
          f"(roles over one GASNet job)")
    cluster = DisaggCluster(
        model, ctx, params,
        n_prefill=N_PREFILL, n_decode=N_DECODE,
        decode_batch=args.decode_batch, cache_len=args.cache_len,
        decode_backend=args.decode_backend,
    )
    print("kv plan:", cluster.plan.describe())
    for r in reqs:
        cluster.submit(r)
    stats = cluster.run_until_drained()

    print(f"served {stats['requests']} requests, "
          f"{stats['decoded_tokens']} tokens in {stats['ticks']} ticks")
    print(f"throughput: {stats['tok_per_s']:.1f} tok/s  "
          f"p50 latency: {stats['p50_latency_s'] * 1e3:.0f}ms  "
          f"p99: {stats['p99_latency_s'] * 1e3:.0f}ms")
    print(f"kv transfers: {stats['kv_transfers']} x "
          f"{stats['kv_block_bytes']}B "
          f"({stats['kv_bytes_per_s'] / 1e6:.2f} MB/s), "
          f"acked via AM reply: {stats['kv_acked']}")
    print(f"completions notified to prefill ranks (AM): "
          f"{stats['completions_notified']}")

    # ---- round-trip asserts: the handoff must be bit-transparent --------
    assert stats["requests"] == n_requests, stats
    assert stats["kv_transfers"] == n_requests, stats
    assert stats["kv_acked"] == stats["kv_transfers"], stats
    assert stats["completions_notified"] == n_requests, stats
    assert stats["am_dropped"] == 0, stats
    assert "p2p" in stats["kv_plan"], stats["kv_plan"]

    # identical burst through the colocated Server: greedy decode is
    # row-independent, so tokens must match exactly if the KV block
    # crossed the GAS layer bit-transparently
    server = Server(model, ctx, params, args.decode_batch, args.cache_len)
    rng = np.random.default_rng(7)
    for r in make_requests(cfg, n_requests, rng):
        server.submit(r)
    server.run_until_drained()
    base = {r.rid: r.out for r in server.finished}
    disg = {r.rid: r.out for r in cluster.finished}
    assert base.keys() == disg.keys()
    for rid in base:
        assert base[rid] == disg[rid], (rid, base[rid], disg[rid])
    print("parity: disaggregated tokens == colocated tokens (bit-exact "
          "KV handoff)")
    print("DISAGG_SERVE_PASS")


if __name__ == "__main__":
    main()

"""Batched serving demo: continuous batching over prefill/decode.

Submits a burst of requests with mixed prompt lengths to the Server (fixed
decode batch, slot recycling) and prints per-request latency stats.

Run:  PYTHONPATH=src python examples/serve_requests.py
"""
import sys

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.configs.registry import SMOKE
from repro.launch.serve import Request, Server
from repro.models.build import build_model
from repro.parallel.ctx import RunCtx


def main() -> None:
    cfg = SMOKE["gemma3-27b"]  # local:global pattern exercises ring caches
    model = build_model(cfg)
    ctx = RunCtx(mesh=None, remat="none")
    params, _ = model.init(ctx, jax.random.PRNGKey(0))
    server = Server(model, ctx, params, batch_size=4, cache_len=64)

    rng = np.random.default_rng(7)
    for rid in range(10):
        plen = int(rng.integers(4, 24))
        server.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab, size=plen).tolist(),
            max_new=int(rng.integers(4, 12)),
        ))
    stats = server.run_until_drained()
    print("served", stats["requests"], "requests,",
          stats["decoded_tokens"], "tokens")
    print(f"throughput: {stats['tok_per_s']:.1f} tok/s  "
          f"p50 latency: {stats['p50_latency_s']*1e3:.0f}ms  "
          f"p50 ttft: {stats['p50_ttft_s']*1e3:.0f}ms")
    for r in server.finished[:3]:
        print(f"  req {r.rid}: prompt {len(r.prompt)} -> {len(r.out)} new tokens")


if __name__ == "__main__":
    main()

"""Disaggregated serving demo: prefill pool -> KV put -> decode pool.

Four GASNet ranks in one job (``launch.mesh.serve_roles``): ranks 0-1 are
the prefill pool, ranks 2-3 the decode pool running continuous batching
unchanged.  Each finished prefill's KV cache crosses the GAS layer as a
``sched.plan_p2p``-planned segmented split-phase put into a staging slot
of the decode node's segment; a ``kv_ready`` Active-Message *request*
rides along and the decode node's handler *replies* an installation ack
that resolves the prefill side's AckHandle.  Completions flow back the
same AM plane.

Act 2 replays the identical burst through the **global paged KV pool**
(``DisaggCluster(paged=True)``): the decode segments hold fixed-size
token pages, the prefill rank puts each page straight into its
allocator-assigned pool slot (pred-gated, no dense staging copy), and
the two requests sharing a prompt prefix resolve to the *same physical
pages* — those pages are mapped, not moved.

Act 3 adds the **tiered KV memory**: a memory-only GAS rank (segment
capacity, no model compute — the paper's FPGA memory-node archetype)
joins a deliberately undersized pool.  Low-priority requests fill it;
high-priority latecomers force the SLO scheduler to preempt — victim
pages swap OUT to the memory rank as one vectored put (payloads + tier
slot offsets in one command block) and back IN at resume as one vectored
get, and every resumed request's tokens match the unpressured run
exactly.

The demo asserts all clusters produce token-identical outputs to the
colocated ``Server`` — the KV handoff, dense, paged, or swapped through
the memory tier, is bit-transparent.

Run:    PYTHONPATH=src python examples/serve_requests.py
Smoke:  PYTHONPATH=src python examples/serve_requests.py --smoke
"""
import argparse
import os
import sys

sys.path.insert(0, "src")

N_PREFILL, N_DECODE, N_MEMORY = 2, 2, 1
os.environ.setdefault(
    "XLA_FLAGS",
    f"--xla_force_host_platform_device_count={N_PREFILL + N_DECODE + N_MEMORY}",
)

import jax  # noqa: E402  (device count must be forced first)
import numpy as np  # noqa: E402

from repro.configs.registry import SMOKE  # noqa: E402
from repro.launch.serve import Request, Server  # noqa: E402
from repro.models.build import build_model  # noqa: E402
from repro.parallel.ctx import RunCtx  # noqa: E402
from repro.obs import export as obs_export  # noqa: E402
from repro.obs import trace as obs_trace  # noqa: E402
from repro.serving.disagg import DisaggCluster  # noqa: E402

PAGE_TOKENS = 8
SHARED_PREFIX = 2 * PAGE_TOKENS  # rid 0/1 share two full prompt pages


def make_requests(cfg, n, rng):
    shared = rng.integers(0, cfg.vocab, size=SHARED_PREFIX).tolist()
    reqs = []
    for rid in range(n):
        if rid < 2:
            # common prompt prefix: the paged cluster must map (not move)
            # the shared pages
            tail = rng.integers(0, cfg.vocab, size=rid + 1).tolist()
            plen = len(shared) + len(tail)
            prompt = shared + tail
        else:
            plen = int(rng.integers(4, 20))
            prompt = rng.integers(0, cfg.vocab, size=plen).tolist()
        reqs.append(
            Request(rid=rid, prompt=prompt, max_new=int(rng.integers(4, 10)))
        )
    return reqs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small burst + strict round-trip asserts")
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--cache-len", type=int, default=48)
    ap.add_argument("--decode-batch", type=int, default=2)
    ap.add_argument("--decode-backend", default="xla",
                    help="decode pool engine (try gascore: the paper's "
                         "hardware nodes serving the KV-install side)")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="record the Act-3 tiered run and write the merged "
                         "Chrome trace (chrome://tracing / Perfetto) here")
    args = ap.parse_args()
    n_requests = 6 if args.smoke else args.requests

    cfg = SMOKE[args.arch]
    model = build_model(cfg)
    ctx = RunCtx(mesh=None, remat="none")
    params, _ = model.init(ctx, jax.random.PRNGKey(0))
    reqs = make_requests(cfg, n_requests, np.random.default_rng(7))

    print(f"cluster: {N_PREFILL} prefill + {N_DECODE} decode ranks "
          f"(roles over one GASNet job)")
    cluster = DisaggCluster(
        model, ctx, params,
        n_prefill=N_PREFILL, n_decode=N_DECODE,
        decode_batch=args.decode_batch, cache_len=args.cache_len,
        decode_backend=args.decode_backend,
    )
    print("kv plan:", cluster.plan.describe())
    for r in reqs:
        cluster.submit(r)
    stats = cluster.run_until_drained()

    print(f"served {stats['requests']} requests, "
          f"{stats['decoded_tokens']} tokens in {stats['ticks']} ticks")
    print(f"throughput: {stats['tok_per_s']:.1f} tok/s  "
          f"p50 latency: {stats['p50_latency_s'] * 1e3:.0f}ms  "
          f"p99: {stats['p99_latency_s'] * 1e3:.0f}ms")
    print(f"kv transfers: {stats['kv_transfers']} x "
          f"{stats['kv_block_bytes']}B "
          f"({stats['kv_bytes_per_s'] / 1e6:.2f} MB/s), "
          f"acked via AM reply: {stats['kv_acked']}")
    print(f"completions notified to prefill ranks (AM): "
          f"{stats['completions_notified']}")

    # ---- round-trip asserts: the handoff must be bit-transparent --------
    assert stats["requests"] == n_requests, stats
    assert stats["kv_transfers"] == n_requests, stats
    assert stats["kv_acked"] == stats["kv_transfers"], stats
    assert stats["completions_notified"] == n_requests, stats
    assert stats["am_dropped"] == 0, stats
    assert "p2p" in stats["kv_plan"], stats["kv_plan"]

    # identical burst through the colocated Server: greedy decode is
    # row-independent, so tokens must match exactly if the KV block
    # crossed the GAS layer bit-transparently
    server = Server(model, ctx, params, args.decode_batch, args.cache_len)
    for r in make_requests(cfg, n_requests, np.random.default_rng(7)):
        server.submit(r)
    server.run_until_drained()
    base = {r.rid: r.out for r in server.finished}
    disg = {r.rid: r.out for r in cluster.finished}
    assert base.keys() == disg.keys()
    for rid in base:
        assert base[rid] == disg[rid], (rid, base[rid], disg[rid])
    print("parity: disaggregated tokens == colocated tokens (bit-exact "
          "KV handoff)")

    # ---- Act 2: the global paged KV pool --------------------------------
    paged = DisaggCluster(
        model, ctx, params,
        n_prefill=N_PREFILL, n_decode=N_DECODE,
        decode_batch=args.decode_batch, cache_len=args.cache_len,
        decode_backend=args.decode_backend,
        paged=True, page_tokens=PAGE_TOKENS,
    )
    print(f"paged pool: {paged.pages_per_rank} pages/rank x "
          f"{paged.playout.page_bytes}B pages "
          f"({PAGE_TOKENS} tokens/page), per-page plan: "
          f"{paged.plan.describe()}")
    for r in make_requests(cfg, n_requests, np.random.default_rng(7)):
        paged.submit(r)
    pstats = paged.run_until_drained()
    print(f"paged: {pstats['kv_pages_sent']} pages shipped, "
          f"{pstats['kv_pages_shared']} prefix-shared pages mapped not "
          f"moved (hit rate {pstats['prefix_hit_rate']:.1%}), "
          f"{pstats['kv_bytes_per_s'] / 1e6:.2f} MB/s page traffic")

    assert pstats["requests"] == n_requests, pstats
    assert pstats["kv_acked"] == pstats["kv_transfers"], pstats
    assert pstats["am_dropped"] == 0, pstats
    # the two prefix-sharing requests resolved to shared physical pages:
    # their common prompt pages were never re-shipped
    assert pstats["kv_pages_shared"] >= SHARED_PREFIX // PAGE_TOKENS, pstats
    # every page reference was dropped when its request finished
    assert pstats["pool_free_pages"] == (
        N_DECODE * paged.pages_per_rank
    ), pstats
    pg = {r.rid: r.out for r in paged.finished}
    assert base.keys() == pg.keys()
    for rid in base:
        assert base[rid] == pg[rid], (rid, base[rid], pg[rid])
    print("parity: paged tokens == dense tokens == colocated tokens "
          "(bit-exact page handoff, prefix pages shared)")

    # ---- Act 3: tiered KV memory — oversubscription + memory rank -------
    # A memory-only GAS rank (segment capacity, no model compute) joins a
    # deliberately undersized pool.  Low-priority requests fill it; then
    # high-priority latecomers arrive and the SLO scheduler preempts:
    # victim pages swap OUT to the memory rank (one vectored put: payloads
    # + tier-slot offsets in one command block) and back IN at resume.
    from repro.serving.scheduler import SLO

    def pressure_burst():
        rng = np.random.default_rng(11)
        reqs = []
        for rid in range(5):
            plen = int(rng.integers(18, 28))
            reqs.append(Request(
                rid=rid,
                prompt=rng.integers(0, cfg.vocab, size=plen).tolist(),
                max_new=14 if rid < 3 else 8,
            ))
        return reqs

    ref = Server(model, ctx, params, args.decode_batch, args.cache_len)
    for r in pressure_burst():
        ref.submit(r)
    ref.run_until_drained()
    unpressured = {r.rid: r.out for r in ref.finished}

    tracer = None
    if args.trace:
        # one registry for tracer + cluster: the exported span byte
        # totals and the cluster's RMA counters must agree bit-for-bit
        tracer = obs_trace.enable()
    tiered = DisaggCluster(
        model, ctx, params,
        n_prefill=1, n_decode=1, n_memory=N_MEMORY,
        decode_batch=args.decode_batch, cache_len=args.cache_len,
        decode_backend=args.decode_backend,
        paged=True, page_tokens=PAGE_TOKENS,
        pages_per_rank=8,  # aggregate demand >= 1.5x this pool
        metrics=tracer.registry if tracer else None,
    )
    reqs3 = pressure_burst()
    for r in reqs3[:3]:
        r.slo = SLO(priority=0)
        tiered.submit(r)
    for _ in range(8):
        tiered.tick()  # the low-priority bulk occupies the pool
    for r in reqs3[3:]:
        r.slo = SLO(priority=2)
        tiered.submit(r)
    tstats = tiered.run_until_drained()
    if tracer is not None:
        obs_trace.disable()
        trace = obs_export.chrome_trace(tracer)
        problems = obs_export.validate(trace, tracer.registry)
        assert not problems, problems
        obs_export.write_trace(trace, args.trace)
        n_events = len(trace["traceEvents"])
        print(f"trace: {n_events} events -> {args.trace} (validated: "
              f"spans nest, every RMA synced, span bytes == counters)")
        # close the postmortem loop: fold the same trace's lifecycle
        # events into a per-request critical path and name what the
        # preempted request actually spent its wall on (GASNET_TRACE
        # postmortems, one function call instead of an evening)
        from repro.obs import attrib as obs_attrib
        preempted = sorted({
            e.args.get("rid") for e in tracer.events
            if e.name == "req_preempt" and e.args.get("rid") is not None
        })
        if preempted:
            print(obs_attrib.why_slow(tracer, preempted[0]))
    print(f"tiered KV memory: {tstats['n_memory_ranks']} memory rank(s), "
          f"{tstats['sched_evictions']} preemption(s) "
          f"({tstats['sched_swaps']} swap / "
          f"{tstats['sched_recomputes']} recompute), "
          f"{tstats['swap_out_bytes']}B out / {tstats['swap_in_bytes']}B "
          f"back over the vectored put/get, swap plan: "
          f"{tstats['swap_plan']}")
    if tiered.health is not None:
        # the live SLO monitor ran on every tick of the run above; its
        # final summary is the health line an operator would watch
        print(f"health: {tiered.health.render()}")

    assert tstats["requests"] == len(reqs3), tstats
    assert tstats["sched_evictions"] >= 1, "expected >= 1 preemption"
    assert tstats["sched_swaps"] >= 1, "expected >= 1 swap to the memory rank"
    assert tstats["sched_resumes"] == tstats["sched_evictions"], tstats
    tg = {r.rid: r.out for r in tiered.finished}
    assert unpressured.keys() == tg.keys()
    for rid in unpressured:
        assert unpressured[rid] == tg[rid], (rid, unpressured[rid], tg[rid])
    print("parity: preempted+resumed tokens == unpressured tokens "
          "(bit-identical resume after swap to the memory rank)")
    # the hierarchy fully drains: no page leaked in either tier
    assert tstats["pool_free_pages"] == tiered.pages_per_rank, tstats
    assert tstats["tier_free_slots"] == tstats["tier_slots"], tstats
    print("pool + memory tier fully drained at shutdown")
    print("DISAGG_SERVE_PASS")


if __name__ == "__main__":
    main()

"""PGAS scientific kernel: 1-D Jacobi heat diffusion with halo exchange.

The paper closes by planning "higher-level communication abstractions
... for parallel scientific computations"; the canonical PGAS citizen is a
stencil whose halo exchange is a pair of one-sided puts per step.  Each
node owns an interior strip of the rod plus two ghost cells; every
iteration puts its boundary values into the neighbors' ghost cells through
the GAS API and relaxes locally.  Verified against the single-device dense
reference.

Run:  PYTHONPATH=src python examples/stencil_halo.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import gasnet

N_NODES = 8
LOCAL = 64  # interior cells per node
STEPS = 400
ALPHA = 0.25

mesh = jax.make_mesh((N_NODES,), ("node",))
ctx = gasnet.Context(mesh, node_axis="node", backend="xla")

# segment layout per node: [ghost_left | interior(LOCAL) | ghost_right]
aspace = ctx.address_space()
aspace.register("rod", (LOCAL + 2,), jnp.float32)

# initial condition: a hot spike in node 0's strip, fixed 0 boundaries
init = np.zeros((N_NODES, LOCAL + 2), np.float32)
init[0, 1 + LOCAL // 2] = 100.0
seg = aspace.alloc_from("rod", jnp.asarray(init))


def jacobi(node, seg):
    def step(seg, _):
        u = node.local(seg)
        # halo exchange: one-sided puts of boundary cells into neighbors
        seg = node.put(seg, u[1:2], to=gasnet.Shift(-1), index=LOCAL + 1)
        seg = node.put(seg, u[LOCAL : LOCAL + 1], to=gasnet.Shift(1), index=0)
        u = node.local(seg)
        # physical boundary: the rod ends see zero ghosts (the ring wraps,
        # so the end nodes must overwrite the wrapped-around halo)
        is_first = node.my_id == 0
        is_last = node.my_id == node.n_nodes - 1
        u = u.at[0].set(jnp.where(is_first, 0.0, u[0]))
        u = u.at[-1].set(jnp.where(is_last, 0.0, u[-1]))
        interior = u[1:-1]
        new = interior + ALPHA * (u[:-2] - 2 * interior + u[2:])
        seg = gasnet.Node._restore(seg, u.at[1:-1].set(new))
        return seg, new.sum()

    seg, heat = jax.lax.scan(step, seg, None, length=STEPS)
    return seg, heat[None]


seg_out, heat = ctx.spmd(jacobi, seg, out_specs=(P("node"), P("node")))

# ---- dense single-device reference ---------------------------------------- #
rod = np.zeros(N_NODES * LOCAL, np.float32)
rod[LOCAL // 2] = 100.0
for _ in range(STEPS):
    padded = np.pad(rod, 1)  # zero ends
    rod = rod + ALPHA * (padded[:-2] - 2 * rod + padded[2:])

got = np.asarray(seg_out)[:, 1:-1].reshape(-1)
np.testing.assert_allclose(got, rod, atol=1e-4)
print(f"Jacobi rod after {STEPS} steps: max={got.max():.4f}, "
      f"total heat={got.sum():.4f}")
print("distributed PGAS result matches the dense reference — OK")

"""End-to-end driver: data-parallel LM training ON the GAS layer, with
int8 error-feedback gradient rings, async checkpoints, an injected node
failure, and an elastic restart on the surviving nodes.

This is the explicit-DP path of the framework: 8 host devices act as 8
GASNet nodes; every node computes grads on its microbatch and the gradient
reduction is the paper's communication substrate — a ring of one-sided
puts (``--reduce gas_ring``), optionally int8-compressed with error
feedback (``--reduce gas_ring_int8``), or XLA's fused ``psum`` for
reference (``--reduce psum``).  At --fail-at the process loses two nodes;
``elastic_plan`` proposes the 6-node mesh, the latest snapshot restores
onto it, and the deterministic data stream resumes where it left off.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
      PYTHONPATH=src python examples/train_lm.py --reduce gas_ring_int8
"""
import argparse
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.checkpoint import ckpt
from repro.configs.registry import SMOKE
from repro.core import sched
from repro.core.engine import make_engine
from repro.data.synthetic import SyntheticLM
from repro.models.build import build_model
from repro.optim import adamw, compression
from repro.parallel.ctx import RunCtx
from repro.runtime.ft import elastic_plan
from repro.compat import shard_map


def make_step(model, opt_cfg, mesh, n_nodes, reduce_mode):
    """Explicit-DP train step: local grads -> GAS ring reduction -> AdamW."""
    local_ctx = RunCtx(mesh=None, remat="none")

    def node_program(params, opt_state, err, batch):
        loss, grads = jax.value_and_grad(
            lambda p: model.train_loss(p, local_ctx, batch)
        )(params)
        eng = make_engine("xla", "node", n_nodes)
        if reduce_mode == "psum":
            grads = jax.tree.map(lambda g: jax.lax.pmean(g, "node"), grads)
        elif reduce_mode == "gas_ring":
            leaves, treedef = jax.tree.flatten(grads)
            flat = jnp.concatenate(
                [x.reshape(-1).astype(jnp.float32) for x in leaves]
            )
            pad = (-flat.shape[0]) % n_nodes
            flat = jnp.pad(flat, (0, pad))
            # plan-driven: size-aware algorithm selection + segmentation
            # (ring for these payload sizes, recursive doubling for tiny)
            red = sched.all_reduce(eng, flat) / n_nodes
            out, off = [], 0
            for x in leaves:
                out.append(red[off : off + x.size].reshape(x.shape).astype(x.dtype))
                off += x.size
            grads = treedef.unflatten(out)
        elif reduce_mode == "gas_ring_int8":
            grads, err = compression.compressed_all_reduce_tree(eng, grads, err)
        loss = jax.lax.pmean(loss, "node")
        params, opt_state, metrics = adamw.apply_updates(
            params, grads, opt_state, opt_cfg
        )
        metrics["loss"] = loss
        return params, opt_state, err, metrics

    rep = P()  # replicated over nodes

    def batch_specs(b):
        return jax.tree.map(lambda _: P("node"), b)

    def step(params, opt_state, err, batch):
        return shard_map(
            node_program,
            mesh=mesh,
            in_specs=(rep, rep, rep, batch_specs(batch)),
            out_specs=(rep, rep, rep, rep),
            check_vma=False,
        )(params, opt_state, err, batch)

    return jax.jit(step, donate_argnums=(0, 1, 2))


def place_batch(batch, mesh):
    return {
        k: jax.device_put(
            v, NamedSharding(mesh, P("node", *([None] * (v.ndim - 1))))
        )
        for k, v in batch.items()
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--reduce", default="gas_ring_int8",
                    choices=["psum", "gas_ring", "gas_ring_int8"])
    ap.add_argument("--batch", type=int, default=24)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--fail-at", type=int, default=120)
    ap.add_argument("--ckpt-every", type=int, default=40)
    ap.add_argument("--ckpt-dir", default="/tmp/gaspax_train_lm")
    args = ap.parse_args()

    cfg = SMOKE["qwen3-4b"]
    model = build_model(cfg)
    opt_cfg = adamw.AdamWConfig(
        lr=3e-3, weight_decay=0.0,
        schedule=adamw.warmup_cosine(3e-3, 10, args.steps),
    )
    src = SyntheticLM(cfg, batch=args.batch, seq_len=args.seq, seed=1)
    os.makedirs(args.ckpt_dir, exist_ok=True)

    def build(n_nodes):
        mesh = Mesh(np.array(jax.devices()[:n_nodes]), ("node",))
        step = make_step(model, opt_cfg, mesh, n_nodes, args.reduce)
        return mesh, step

    n_nodes = 8
    mesh, step_fn = build(n_nodes)
    params, _ = model.init(RunCtx(mesh=None), jax.random.PRNGKey(0))
    opt_state = adamw.init_state(params, opt_cfg)
    err = compression.init_error_state(params, n_nodes)

    print(f"training {cfg.name}-smoke on {n_nodes} GASNet nodes, "
          f"reduce={args.reduce}")
    t0 = time.time()
    data_step = 0
    handle = None
    step = 0
    while step < args.steps:
        try:
            batch = place_batch(src.batch_at(data_step), mesh)
            if step == args.fail_at and n_nodes == 8:
                raise RuntimeError("NODE FAILURE: nodes {6,7} lost")
            params, opt_state, err, m = step_fn(params, opt_state, err, batch)
            data_step += 1
            if step % 20 == 0 or step == args.steps - 1:
                print(f"  step {step:4d} loss {float(m['loss']):.4f} "
                      f"lr {float(m['lr']):.2e}")
            if (step + 1) % args.ckpt_every == 0:
                if handle:
                    handle.wait()
                handle = ckpt.save(
                    args.ckpt_dir, step + 1,
                    {"params": params, "opt": opt_state},
                    extra={"data_step": data_step},
                )
            step += 1
        except RuntimeError as e:
            print(f"  !! {e}")
            plan = elastic_plan(6, 1)
            n_nodes = plan[0] * plan[1] * plan[2]
            # keep global batch divisible by the new node count
            n_nodes = 6
            print(f"  elastic plan -> continue on {n_nodes} nodes")
            if handle:
                handle.wait()
            last = ckpt.latest_step(args.ckpt_dir)
            mesh, step_fn = build(n_nodes)
            tree, extra = ckpt.restore(
                args.ckpt_dir, last, {"params": params, "opt": opt_state}
            )
            params, opt_state = tree["params"], tree["opt"]
            err = compression.init_error_state(params, n_nodes)
            step = last
            data_step = int(extra["data_step"])
            print(f"  restored step {last} (data cursor {data_step}) — "
                  f"resuming")
    if handle:
        handle.wait()
    print(f"done in {time.time() - t0:.1f}s — final loss "
          f"{float(m['loss']):.4f} (started ~{np.log(cfg.vocab):.2f})")


if __name__ == "__main__":
    main()

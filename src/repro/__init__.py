"""GASPAX — a GASNet-style PGAS communication substrate + training/serving
framework for JAX on TPU.

Reproduction of Willenberg & Chow, "A software parallel programming approach
to FPGA-accelerated computing" (2014), adapted from FPGA/GASNet to TPU/JAX:

- ``repro.core``      — the paper's contribution: partitioned global address
                        space segments, Active Messages, a GASNet-style API,
                        and ring/hierarchical collectives built on one-sided
                        puts, with two interchangeable engines ("xla" software
                        node vs "gascore" Pallas hardware node).
- ``repro.kernels``   — the GAScore remote-DMA engine as Pallas TPU kernels,
                        plus perf-critical compute kernels (flash attention,
                        MoE dispatch, SSM scans) with pure-jnp oracles.
- ``repro.models``    — composable model zoo covering the 10 assigned
                        architectures (dense / MoE / SSM / hybrid / VLM /
                        enc-dec).
- ``repro.parallel``  — DP/FSDP/TP/EP/SP sharding rules + pipeline stage
                        partitioning over the pod axis.
- ``repro.optim``     — sharded AdamW, int8 error-feedback grad compression.
- ``repro.data``      — deterministic synthetic data pipeline.
- ``repro.checkpoint``— sharded, async, elastically-restorable checkpoints.
- ``repro.runtime``   — training loop, fault tolerance, straggler mitigation.
- ``repro.launch``    — production mesh, multi-pod dry-run, roofline, train,
                        serve entry points.

Importing ``repro`` performs no JAX device initialization.
"""

__version__ = "1.0.0"

"""Sharded, async, elastically-restorable checkpoints.

Format: one directory per step with one ``.npy`` per pytree leaf plus a
JSON manifest (treedef paths, shapes, dtypes, step, data-stream cursor).
Arrays are gathered to host and written whole, so a restore can re-shard
onto a *different* mesh — the elastic-restart path: lose a pod, rebuild a
smaller mesh, ``restore(..., sharding_tree=new_shardings)`` and continue.
(At real 405B scale the writer would emit per-shard files via a
process-local io pool; the manifest layout already carries everything
needed — noted in DESIGN.md.)

Writes are atomic (tmp dir + rename) and asynchronous: ``save`` snapshots
to host memory synchronously (consistent cut), then writes on a background
thread while training continues — ``AsyncHandle.wait`` joins before the
next save or at shutdown.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "AsyncHandle", "cleanup"]

_MANIFEST = "manifest.json"


def _leaf_paths(tree: Any) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        out.append((key, leaf))
    return out


class AsyncHandle:
    def __init__(self, thread: Optional[threading.Thread], path: str):
        self._thread = thread
        self.path = path

    def wait(self):
        if self._thread is not None:
            self._thread.join()

    @property
    def done(self) -> bool:
        return self._thread is None or not self._thread.is_alive()


def save(
    root: str,
    step: int,
    tree: Any,
    *,
    extra: Optional[Dict[str, Any]] = None,
    async_: bool = True,
) -> AsyncHandle:
    """Snapshot ``tree`` at ``step``.  Synchronous host gather, async write."""
    os.makedirs(root, exist_ok=True)
    final = os.path.join(root, f"step_{step:010d}")
    tmp = final + ".tmp"
    # consistent cut: gather to host NOW
    host = [(k, np.asarray(jax.device_get(v))) for k, v in _leaf_paths(tree)]
    manifest = {
        "step": step,
        "leaves": [
            {"key": k, "shape": list(v.shape), "dtype": str(v.dtype)}
            for k, v in host
        ],
        "extra": extra or {},
    }

    def write():
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for k, v in host:
            fn = os.path.join(tmp, k.replace("/", "__") + ".npy")
            np.save(fn, v)
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if async_:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return AsyncHandle(t, final)
    write()
    return AsyncHandle(None, final)


def latest_step(root: str) -> Optional[int]:
    if not os.path.isdir(root):
        return None
    steps = []
    for d in os.listdir(root):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(root, d, _MANIFEST)):
                steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(
    root: str,
    step: int,
    target: Any,
    sharding_tree: Any = None,
) -> Tuple[Any, Dict[str, Any]]:
    """Restore into the structure of ``target`` (pytree of arrays or
    ShapeDtypeStructs).  ``sharding_tree`` (same structure, NamedSharding
    leaves) re-shards onto the *current* mesh — elastic restart."""
    d = os.path.join(root, f"step_{step:010d}")
    with open(os.path.join(d, _MANIFEST)) as f:
        manifest = json.load(f)
    keys = [k for k, _ in _leaf_paths(target)]
    shardings = (
        [s for _, s in _leaf_paths(sharding_tree)]
        if sharding_tree is not None
        else [None] * len(keys)
    )
    leaves = []
    for k, sh in zip(keys, shardings):
        fn = os.path.join(d, k.replace("/", "__") + ".npy")
        arr = np.load(fn)
        leaves.append(
            jax.device_put(arr, sh) if sh is not None else jax.device_put(arr)
        )
    treedef = jax.tree_util.tree_structure(target)
    return treedef.unflatten(leaves), manifest.get("extra", {})


def cleanup(root: str, keep_last: int = 2) -> None:
    if not os.path.isdir(root):
        return
    steps = sorted(
        int(d.split("_")[1])
        for d in os.listdir(root)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for s in steps[:-keep_last] if keep_last else steps:
        shutil.rmtree(os.path.join(root, f"step_{s:010d}"), ignore_errors=True)

"""Version compatibility shims for the jax API surface we depend on.

The repo targets the modern jax API; older releases spell several entry
points differently.  Route the affected calls through this module so both
work unchanged:

- :func:`shard_map` — ``jax.shard_map(..., check_vma=...)`` vs
  ``jax.experimental.shard_map.shard_map(..., check_rep=...)``.
- :func:`tpu_compiler_params` — ``pltpu.CompilerParams`` vs the older
  ``pltpu.TPUCompilerParams``.
- :func:`tpu_interpret` — ``pltpu.InterpretParams()`` (the richer
  TPU-interpret mode with DMA/semaphore emulation) vs the plain boolean
  ``interpret=True`` accepted everywhere.
"""
from __future__ import annotations

from typing import Any, Callable

import jax

__all__ = ["shard_map", "tpu_compiler_params", "tpu_interpret"]


def shard_map(
    f: Callable,
    *,
    mesh: jax.sharding.Mesh,
    in_specs: Any,
    out_specs: Any,
    check_vma: bool = True,
) -> Callable:
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )


def tpu_compiler_params(**kwargs) -> Any:
    """Construct Pallas TPU compiler params under either API name."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)


def tpu_interpret(interpret: bool) -> Any:
    """Value for ``pallas_call(..., interpret=...)`` selecting TPU interpret
    mode when available (CPU emulation of DMAs + semaphores) and falling
    back to plain interpret mode otherwise."""
    if not interpret:
        return False
    from jax.experimental.pallas import tpu as pltpu

    params = getattr(pltpu, "InterpretParams", None)
    return params() if params is not None else True


def dma_device_id(idx: Any) -> Any:
    """``device_id`` operand for ``pltpu.make_async_remote_copy`` with
    ``DeviceIdType.MESH``.  Modern jax takes a tuple of per-mesh-axis
    coordinates; the older interpret-mode discharge rule only handles a
    bare scalar (it all-gathers the operand directly).  All our kernels
    run on a 1-D node axis, so the two are interchangeable."""
    from jax.experimental.pallas import tpu as pltpu

    return (idx,) if hasattr(pltpu, "InterpretParams") else idx

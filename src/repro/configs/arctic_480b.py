"""arctic-480b — dense-MoE hybrid: every layer has a parallel dense
residual FFN plus 128-expert top-2 MoE [hf:Snowflake/snowflake-arctic-base]."""
import dataclasses
import jax.numpy as jnp
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab=32000,
    pattern=("moe",),
    n_experts=128,
    top_k=2,
    capacity_factor=1.25,
    moe_dense_residual=True,
    rope_theta=10000.0,
    dtype=jnp.bfloat16,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=128, vocab=512, n_experts=8, top_k=2, dtype=jnp.float32,
)

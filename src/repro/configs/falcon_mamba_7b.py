"""falcon-mamba-7b — attention-free mamba1 [arXiv:2410.05355]."""
import dataclasses
import jax.numpy as jnp
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,      # unused (attention-free)
    n_kv_heads=1,   # unused
    d_ff=0,
    vocab=65024,
    pattern=("mamba",),
    ssm_state=16,
    d_inner=8192,
    conv_width=4,
    dt_rank=256,
    sub_quadratic=True,
    dtype=jnp.bfloat16,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=4, d_model=128, d_inner=256, dt_rank=8, vocab=512,
    dtype=jnp.float32,
)

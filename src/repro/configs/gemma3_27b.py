"""gemma3-27b — 5:1 local:global attention, 256k vocab, 128k ctx
[hf:google/gemma-3 family].  Local window 1024; the local-dominated
pattern makes long_500k decode sub-quadratic-eligible."""
import dataclasses
import jax.numpy as jnp
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab=262144,
    pattern=("local", "local", "local", "local", "local", "global"),
    local_window=1024,
    qk_norm=True,
    rope_theta=1000000.0,
    tie_embeddings=True,
    sub_quadratic=True,
    dtype=jnp.bfloat16,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=8, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=256, vocab=512, local_window=16, dtype=jnp.float32,
)

"""granite-34b — llama-arch code model, MQA (kv=1) [arXiv:2405.04324]."""
import dataclasses
import jax.numpy as jnp
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab=49152,
    pattern=("global",),
    rope_theta=10000.0,
    mlp_gated=False,  # gpt-bigcode-style 2-matrix FFN
    act="gelu",
    dtype=jnp.bfloat16,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=4, d_model=128, n_heads=8, n_kv_heads=1, head_dim=16,
    d_ff=256, vocab=512, dtype=jnp.float32,
)

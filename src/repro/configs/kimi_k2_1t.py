"""kimi-k2-1t-a32b — trillion-param MoE: 384 experts top-8, one shared
expert, first layer dense [Kimi K2 paper table].  The dense first layer's
d_ff is set active-parameter-matched (top_k * expert d_ff) since the
assignment table specifies only the expert width."""
import dataclasses
import jax.numpy as jnp
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=2048,
    vocab=163840,
    pattern=("moe",),
    n_experts=384,
    top_k=8,
    capacity_factor=1.25,
    n_shared_experts=1,
    first_dense_layers=1,
    d_ff_dense=16384,
    rope_theta=50000.0,
    dtype=jnp.bfloat16,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=3, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=64, vocab=512, n_experts=8, top_k=2, d_ff_dense=256,
    dtype=jnp.float32,
)

"""llama-3.2-vision-11b — text backbone with gated cross-attention image
layers every 5th layer [hf:meta-llama/Llama-3.2-11B-Vision].  The vision
tower is a STUB per the assignment: input_specs provides precomputed patch
embeddings (B, 1601, d_model)."""
import dataclasses
import jax.numpy as jnp
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=128256,
    pattern=("global", "global", "global", "global", "cross"),
    cross_kv_len=1601,
    rope_theta=500000.0,
    dtype=jnp.bfloat16,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=5, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=256, vocab=512, cross_kv_len=17, dtype=jnp.float32,
)

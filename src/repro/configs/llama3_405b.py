"""llama3-405b — dense GQA transformer, 128k vocab [arXiv:2407.21783]."""
import dataclasses
import jax.numpy as jnp
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    head_dim=128,
    d_ff=53248,
    vocab=128256,
    pattern=("global",),
    rope_theta=500000.0,
    dtype=jnp.bfloat16,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=4, d_model=128, n_heads=8, n_kv_heads=2, head_dim=16,
    d_ff=256, vocab=512, dtype=jnp.float32,
)

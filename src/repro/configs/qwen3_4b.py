"""qwen3-4b — dense GQA with qk-norm [hf:Qwen/Qwen3-8B family]."""
import dataclasses
import jax.numpy as jnp
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-4b",
    family="dense",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab=151936,
    pattern=("global",),
    qk_norm=True,
    rope_theta=1000000.0,
    tie_embeddings=True,
    dtype=jnp.bfloat16,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=4, d_model=128, n_heads=8, n_kv_heads=2, head_dim=16,
    d_ff=256, vocab=512, dtype=jnp.float32,
)

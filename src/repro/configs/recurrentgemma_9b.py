"""recurrentgemma-9b — griffin: RG-LRU + local attention 1:2
[arXiv:2402.19427].  38 layers = (rec, rec, local) x 12 + (rec, rec)."""
import dataclasses
import jax.numpy as jnp
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab=256000,
    pattern=("rec", "rec", "local"),
    local_window=2048,
    lru_width=4096,
    conv_width=4,
    tie_embeddings=True,
    sub_quadratic=True,
    dtype=jnp.bfloat16,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=5, d_model=128, n_heads=4, n_kv_heads=1, head_dim=32,
    d_ff=256, vocab=512, lru_width=128, local_window=16, dtype=jnp.float32,
)

"""Architecture + shape registry: the 40 assigned (arch × shape) cells."""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.models.build import ShapeConfig
from repro.models.common import ArchConfig

from repro.configs import (
    arctic_480b,
    falcon_mamba_7b,
    gemma3_27b,
    granite_34b,
    kimi_k2_1t,
    llama3_405b,
    llama32_vision_11b,
    qwen3_4b,
    recurrentgemma_9b,
    seamless_m4t_medium,
)

_MODULES = {
    "llama3-405b": llama3_405b,
    "granite-34b": granite_34b,
    "qwen3-4b": qwen3_4b,
    "gemma3-27b": gemma3_27b,
    "arctic-480b": arctic_480b,
    "kimi-k2-1t-a32b": kimi_k2_1t,
    "falcon-mamba-7b": falcon_mamba_7b,
    "recurrentgemma-9b": recurrentgemma_9b,
    "llama-3.2-vision-11b": llama32_vision_11b,
    "seamless-m4t-medium": seamless_m4t_medium,
}

ARCHS: Dict[str, ArchConfig] = {k: m.CONFIG for k, m in _MODULES.items()}
SMOKE: Dict[str, ArchConfig] = {k: m.SMOKE for k, m in _MODULES.items()}

SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def cell_runnable(arch: str, shape: str) -> Tuple[bool, str]:
    """Whether an (arch, shape) dry-run cell runs, and why not if skipped.

    ``long_500k`` requires sub-quadratic attention (SSM / hybrid /
    local-attention-dominated archs); pure full-attention archs skip it per
    the assignment and DESIGN.md §Arch-applicability.
    """
    cfg = ARCHS[arch]
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: long_500k needs sub-quadratic attention"
    return True, ""


def all_cells() -> List[Tuple[str, str]]:
    return [(a, s) for a in ARCHS for s in SHAPES]


def runnable_cells() -> List[Tuple[str, str]]:
    return [(a, s) for a, s in all_cells() if cell_runnable(a, s)[0]]

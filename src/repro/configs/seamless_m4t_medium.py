"""seamless-m4t-medium — encoder-decoder, multimodal [arXiv:2308.11596].
The speech/text frontend is a STUB: input_specs provides precomputed frame
embeddings (B, S, d_model) for the encoder."""
import dataclasses
import jax.numpy as jnp
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,        # decoder
    n_enc_layers=12,    # encoder
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=256206,
    pattern=("xdec",),
    norm="layernorm",
    act="gelu",
    mlp_gated=False,  # classic transformer FFN
    dtype=jnp.bfloat16,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, n_enc_layers=2, d_model=128, n_heads=4,
    n_kv_heads=4, head_dim=32, d_ff=256, vocab=512, dtype=jnp.float32,
)

"""The paper's primary contribution: a GASNet-style PGAS substrate for JAX.

- ``addrspace``   — partitioned global address space segments.
- ``am``          — Active Messages (short/medium/long + handler dispatch).
- ``engine``      — interchangeable transports: XLA software node vs
                    GAScore Pallas hardware node (blocking + split-phase),
                    plus heterogeneous per-rank ``EngineMap`` node maps.
- ``extended``    — GASNet Extended API: non-blocking put/get handles.
- ``collectives`` — ring/hierarchical/segmented collectives over one-sided
                    puts, plus latency-optimal tree/recursive-doubling.
- ``sched``       — the collective scheduler: size-aware algorithm
                    selection + segmentation plans over the engine map.
- ``gasnet``      — the GASNet-like user API (Context / Node / put / get /
                    put_nb / get_nb / sync).
"""
from repro.core.addrspace import AddressSpace, GlobalAddress, SegmentSpec
from repro.core.engine import (
    AlreadyWaitedError,
    CommEngine,
    EngineMap,
    GascoreEngine,
    Pending,
    XlaEngine,
    make_engine,
    parse_backend_spec,
    wait_all,
)
from repro.core.extended import GetHandle, Handle, PutHandle
from repro.core.gasnet import Context, Node, Perm, Shift
from repro.core.sched import CollectivePlan, EngineCost, plan_collective

__all__ = [
    "AddressSpace",
    "GlobalAddress",
    "SegmentSpec",
    "AlreadyWaitedError",
    "CommEngine",
    "Pending",
    "XlaEngine",
    "GascoreEngine",
    "EngineMap",
    "make_engine",
    "parse_backend_spec",
    "wait_all",
    "Handle",
    "PutHandle",
    "GetHandle",
    "Context",
    "Node",
    "Shift",
    "Perm",
    "CollectivePlan",
    "EngineCost",
    "plan_collective",
]

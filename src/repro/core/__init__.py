"""The paper's primary contribution: a GASNet-style PGAS substrate for JAX.

- ``addrspace``   — partitioned global address space segments.
- ``am``          — Active Messages (short/medium/long + handler dispatch).
- ``engine``      — interchangeable transports: XLA software node vs
                    GAScore Pallas hardware node.
- ``collectives`` — ring/hierarchical collectives over one-sided puts.
- ``gasnet``      — the GASNet-like user API (Context / Node / put / get).
"""
from repro.core.addrspace import AddressSpace, GlobalAddress, SegmentSpec
from repro.core.engine import CommEngine, GascoreEngine, XlaEngine, make_engine
from repro.core.gasnet import Context, Node, Perm, Shift

__all__ = [
    "AddressSpace",
    "GlobalAddress",
    "SegmentSpec",
    "CommEngine",
    "XlaEngine",
    "GascoreEngine",
    "make_engine",
    "Context",
    "Node",
    "Shift",
    "Perm",
]

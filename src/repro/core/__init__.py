"""The paper's primary contribution: a GASNet-style PGAS substrate for JAX.

- ``addrspace``   — partitioned global address space segments.
- ``am``          — Active Messages (short/medium/long + handler dispatch).
- ``engine``      — interchangeable transports: XLA software node vs
                    GAScore Pallas hardware node (blocking + split-phase).
- ``extended``    — GASNet Extended API: non-blocking put/get handles.
- ``collectives`` — ring/hierarchical collectives over one-sided puts.
- ``gasnet``      — the GASNet-like user API (Context / Node / put / get /
                    put_nb / get_nb / sync).
"""
from repro.core.addrspace import AddressSpace, GlobalAddress, SegmentSpec
from repro.core.engine import (
    CommEngine,
    GascoreEngine,
    Pending,
    XlaEngine,
    make_engine,
)
from repro.core.extended import GetHandle, Handle, PutHandle
from repro.core.gasnet import Context, Node, Perm, Shift

__all__ = [
    "AddressSpace",
    "GlobalAddress",
    "SegmentSpec",
    "CommEngine",
    "Pending",
    "XlaEngine",
    "GascoreEngine",
    "make_engine",
    "Handle",
    "PutHandle",
    "GetHandle",
    "Context",
    "Node",
    "Shift",
    "Perm",
]

"""Partitioned Global Address Space (PGAS) segments.

The paper's GASNet nodes each expose a *segment* of memory that remote nodes
may read/write with one-sided operations.  On TPU, a node is a mesh device
along a designated ``node_axis``; a segment is a single global array with a
leading node dimension sharded over that axis:

    segment array shape = (n_nodes, *local_shape), sharding = P(node_axis)

Inside a ``shard_map`` over ``node_axis`` every node sees its own
``(1, *local_shape)`` partition — the "local memory" the GAScore engine of
that node reads and writes.  A global address is ``(node_id, local_index)``,
exactly the paper's addressing model.

Segments are *values* (functional): one-sided writes return an updated
segment array.  The :class:`AddressSpace` holds only metadata, so it can be
constructed at trace time and never touches device state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = ["SegmentSpec", "AddressSpace", "GlobalAddress"]


@dataclasses.dataclass(frozen=True)
class GlobalAddress:
    """A (node, index) pair addressing one element range of a segment."""

    node: int
    index: int

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"gaddr(node={self.node}, index={self.index})"


@dataclasses.dataclass(frozen=True)
class SegmentSpec:
    """Static description of one PGAS segment.

    Attributes:
      name:        registry key.
      local_shape: per-node shape (the partition owned by one node).
      dtype:       element type.
    """

    name: str
    local_shape: Tuple[int, ...]
    dtype: Any

    @property
    def local_size(self) -> int:
        size = 1
        for d in self.local_shape:
            size *= d
        return size

    def global_shape(self, n_nodes: int) -> Tuple[int, ...]:
        return (n_nodes,) + tuple(self.local_shape)


class AddressSpace:
    """Registry of named PGAS segments over one mesh axis.

    This mirrors ``gasnet_attach()``: every node contributes an equally sized
    partition per segment.  The registry is pure metadata; ``alloc`` produces
    the actual sharded array.
    """

    def __init__(self, mesh: jax.sharding.Mesh, node_axis: str = "node"):
        if node_axis not in mesh.axis_names:
            raise ValueError(
                f"node_axis {node_axis!r} not in mesh axes {mesh.axis_names}"
            )
        self.mesh = mesh
        self.node_axis = node_axis
        self._specs: Dict[str, SegmentSpec] = {}

    # ------------------------------------------------------------------ #
    # registry
    # ------------------------------------------------------------------ #
    @property
    def n_nodes(self) -> int:
        return self.mesh.shape[self.node_axis]

    @property
    def specs(self) -> Dict[str, SegmentSpec]:
        return dict(self._specs)

    def register(
        self,
        name: str,
        local_shape: Tuple[int, ...],
        dtype: Any = jnp.float32,
    ) -> SegmentSpec:
        if name in self._specs:
            raise ValueError(f"segment {name!r} already registered")
        spec = SegmentSpec(name=name, local_shape=tuple(local_shape), dtype=dtype)
        self._specs[name] = spec
        return spec

    def spec(self, name: str) -> SegmentSpec:
        return self._specs[name]

    # ------------------------------------------------------------------ #
    # allocation & addressing
    # ------------------------------------------------------------------ #
    def sharding(self, name: str) -> NamedSharding:
        del name  # every segment uses the same layout
        return NamedSharding(self.mesh, P(self.node_axis))

    def alloc(
        self,
        name: str,
        init_fn: Callable[..., jax.Array] = jnp.zeros,
    ) -> jax.Array:
        """Materialize a segment as a sharded device array."""
        spec = self._specs[name]
        shape = spec.global_shape(self.n_nodes)
        arr = init_fn(shape, dtype=spec.dtype)
        return jax.device_put(arr, self.sharding(name))

    def alloc_from(self, name: str, value: jax.Array) -> jax.Array:
        """Place an existing (n_nodes, *local_shape) array into the segment."""
        spec = self._specs[name]
        expect = spec.global_shape(self.n_nodes)
        if tuple(value.shape) != expect:
            raise ValueError(
                f"segment {name!r} expects shape {expect}, got {value.shape}"
            )
        return jax.device_put(value.astype(spec.dtype), self.sharding(name))

    # ------------------------------------------------------------------ #
    # host-side (test/debug) accessors
    # ------------------------------------------------------------------ #
    def read(self, seg: jax.Array, addr: GlobalAddress, length: int) -> jax.Array:
        """Host-side read of ``length`` flat elements at a global address."""
        local = seg[addr.node].reshape(-1)
        return local[addr.index : addr.index + length]

    def in_specs(self) -> P:
        """PartitionSpec of any segment for use in shard_map in/out specs."""
        return P(self.node_axis)

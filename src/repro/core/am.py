"""Active Messages (GASNet Core API), in bulk-synchronous SPMD form.

A GASNet *Active Message* is a packet carrying a data payload, a destination
node, and the ID of a *handler function* that runs at the receiver when the
packet lands.  The paper's GAScore engine generates and consumes exactly
these packets in hardware.

TPUs have no receiver-side interrupts, so the handler-on-arrival semantics
are reproduced in the TPU-idiomatic way:

1. every node accumulates outgoing messages into a fixed-capacity
   :class:`AMBatch` (the "FIFO command queue" in front of the GAScore);
2. :func:`route` moves all batches simultaneously with a capacity-bounded
   all-to-all (the on-chip packet network) — this is a *static* SPMD
   schedule, the Pallas/XLA analogue of dynamic packet routing;
3. :func:`deliver` runs the registered handler of each landed message
   against the receiver's local state (the asynchronous handler call,
   now a fused receiver-side epilogue).

Message categories follow GASNet:

- **AMShort**  — handler args only, no payload.
- **AMMedium** — payload delivered to a bounded temporary buffer, handler
  decides placement.
- **AMLong**   — payload written at a caller-specified segment offset
  (``args[0]``); the built-in :func:`long_write_handler` reproduces the
  GAScore remote-DMA write.

Everything here is pure-functional and shape-static, so it traces/lowers
under ``jit`` + ``shard_map`` and is property-testable with hypothesis.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

__all__ = [
    "AMBatch",
    "HandlerTable",
    "empty_batch",
    "push",
    "build_send_buffer",
    "route",
    "deliver",
    "long_write_handler",
]

MAX_ARGS = 4  # GASNet Core allows up to 16 handler args; 4 suffice here.


# --------------------------------------------------------------------------- #
# Handler registry
# --------------------------------------------------------------------------- #
class HandlerTable:
    """Ordered registry name -> (id, fn).

    Handler signature: ``fn(state, payload, args) -> state`` where ``state``
    is an arbitrary pytree (typically the node's local segment views),
    ``payload`` is a flat ``(payload_size,)`` vector and ``args`` a
    ``(MAX_ARGS,)`` int32 vector.  Handlers must be pure and return a pytree
    of identical structure (they are branches of one ``lax.switch``).
    """

    def __init__(self) -> None:
        self._names: List[str] = []
        self._fns: List[Callable] = []

    def register(self, name: str, fn: Callable) -> int:
        if name in self._names:
            raise ValueError(f"handler {name!r} already registered")
        self._names.append(name)
        self._fns.append(fn)
        return len(self._names) - 1

    def handler(self, name: str) -> Callable:
        """Decorator form of :meth:`register`."""

        def deco(fn: Callable) -> Callable:
            self.register(name, fn)
            return fn

        return deco

    def id_of(self, name: str) -> int:
        return self._names.index(name)

    @property
    def fns(self) -> Tuple[Callable, ...]:
        return tuple(self._fns)

    def __len__(self) -> int:
        return len(self._names)


# --------------------------------------------------------------------------- #
# Message batches
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class AMBatch:
    """Fixed-capacity outgoing message queue of ONE node (local view).

    Attributes (capacity C, payload width Pw):
      dest:    (C,)  int32   destination node id per slot.
      handler: (C,)  int32   handler id per slot.
      args:    (C, MAX_ARGS) int32 handler arguments.
      payload: (C, Pw) payload rows (zero width for AMShort-only batches).
      valid:   (C,)  bool    slot occupancy.
      count:   ()    int32   number of occupied slots.
    """

    dest: jax.Array
    handler: jax.Array
    args: jax.Array
    payload: jax.Array
    valid: jax.Array
    count: jax.Array

    @property
    def capacity(self) -> int:
        return self.dest.shape[0]

    @property
    def payload_width(self) -> int:
        return self.payload.shape[1]


jax.tree_util.register_pytree_node(
    AMBatch,
    lambda b: ((b.dest, b.handler, b.args, b.payload, b.valid, b.count), None),
    lambda _, xs: AMBatch(*xs),
)


def empty_batch(capacity: int, payload_width: int, dtype: Any = jnp.float32) -> AMBatch:
    return AMBatch(
        dest=jnp.zeros((capacity,), jnp.int32),
        handler=jnp.zeros((capacity,), jnp.int32),
        args=jnp.zeros((capacity, MAX_ARGS), jnp.int32),
        payload=jnp.zeros((capacity, payload_width), dtype),
        valid=jnp.zeros((capacity,), bool),
        count=jnp.zeros((), jnp.int32),
    )


def push(
    batch: AMBatch,
    dest: jax.Array,
    handler: int,
    args: Sequence[Any] = (),
    payload: jax.Array | None = None,
) -> AMBatch:
    """Enqueue one message (functional).  Overflow beyond capacity is dropped
    silently here and surfaced by :func:`build_send_buffer` as a count —
    matching GASNet back-pressure semantics in a shape-static world."""
    i = jnp.minimum(batch.count, batch.capacity - 1)
    in_range = batch.count < batch.capacity
    arg_vec = jnp.zeros((MAX_ARGS,), jnp.int32)
    for k, a in enumerate(args):
        arg_vec = arg_vec.at[k].set(jnp.asarray(a, jnp.int32))
    if payload is None:
        payload = jnp.zeros((batch.payload_width,), batch.payload.dtype)
    payload = payload.astype(batch.payload.dtype).reshape(-1)
    if payload.shape[0] != batch.payload_width:
        raise ValueError(
            f"payload width {payload.shape[0]} != batch width {batch.payload_width}"
        )

    def write(b: AMBatch) -> AMBatch:
        return AMBatch(
            dest=b.dest.at[i].set(jnp.asarray(dest, jnp.int32)),
            handler=b.handler.at[i].set(jnp.asarray(handler, jnp.int32)),
            args=b.args.at[i].set(arg_vec),
            payload=b.payload.at[i].set(payload),
            valid=b.valid.at[i].set(True),
            count=b.count + 1,
        )

    return lax.cond(in_range, write, lambda b: b, batch)


# --------------------------------------------------------------------------- #
# Routing (the on-chip packet network)
# --------------------------------------------------------------------------- #
def build_send_buffer(
    batch: AMBatch, n_nodes: int, per_peer_capacity: int
) -> Tuple[AMBatch, jax.Array]:
    """Pack a node's outgoing queue into a dense (n_nodes * K)-slot buffer,
    slot ``d*K + r`` holding the r-th message addressed to node d.

    Returns the packed batch (capacity n_nodes*K, same widths) plus the
    number of messages dropped because more than K were addressed to one
    peer (the static-capacity analogue of network back-pressure).
    """
    K = per_peer_capacity
    dest = jnp.where(batch.valid, batch.dest, n_nodes)  # park invalid
    # rank of each message within its destination group (stable order)
    one_hot = (dest[:, None] == jnp.arange(n_nodes + 1)[None, :]).astype(jnp.int32)
    rank = jnp.cumsum(one_hot, axis=0) - one_hot  # exclusive prefix count
    rank = jnp.take_along_axis(rank, dest[:, None], axis=1)[:, 0]
    ok = batch.valid & (rank < K) & (dest < n_nodes)
    slot = jnp.where(ok, dest * K + rank, n_nodes * K)  # park dropped
    dropped = jnp.sum(batch.valid & ~ok)

    C = n_nodes * K

    def scatter(x: jax.Array, fill: Any) -> jax.Array:
        out = jnp.full((C + 1,) + x.shape[1:], fill, x.dtype)
        return out.at[slot].set(x)[:C]

    packed = AMBatch(
        dest=scatter(batch.dest, 0),
        handler=scatter(batch.handler, 0),
        args=scatter(batch.args, 0),
        payload=scatter(batch.payload, 0),
        valid=scatter(ok, False),
        count=jnp.sum(ok).astype(jnp.int32),
    )
    return packed, dropped


def route(
    batch: AMBatch,
    *,
    axis: str,
    n_nodes: int,
    per_peer_capacity: int,
    all_to_all_fn: Callable[[jax.Array], jax.Array] | None = None,
    engine=None,
) -> Tuple[AMBatch, jax.Array]:
    """Exchange all nodes' batches; returns each node's *incoming* messages.

    Must be called inside ``shard_map`` over ``axis``.  The transport is,
    in order of preference: ``engine`` (a CommEngine — the exchange is then
    *plan-driven*: ``repro.core.sched`` picks native vs direct-put
    all-to-all from the buffer size and the engine's cost model, so a
    GAScore or mixed node map routes AMs over its own puts), then
    ``all_to_all_fn`` (an explicit callable), then ``lax.all_to_all``.

    The incoming batch has capacity ``n_nodes * K``; slot ``s*K + r`` holds
    the r-th message from source node s.  ``dest`` of received messages is
    rewritten to the *source* node id (GASNet handlers receive the sender's
    identity as the ``token``).
    """
    K = per_peer_capacity
    packed, dropped = build_send_buffer(batch, n_nodes, K)

    def a2a(x: jax.Array) -> jax.Array:
        if engine is not None:
            from repro.core import sched

            return sched.all_to_all(engine, x)
        if all_to_all_fn is not None:
            return all_to_all_fn(x)
        return lax.all_to_all(x, axis, split_axis=0, concat_axis=0, tiled=True)

    def exchange(x: jax.Array) -> jax.Array:
        # (n_nodes*K, ...) -> regroup so dim0 blocks are per-destination
        return a2a(x)

    recv = AMBatch(
        dest=exchange(packed.dest),
        handler=exchange(packed.handler),
        args=exchange(packed.args),
        payload=exchange(packed.payload),
        valid=exchange(packed.valid),
        count=jnp.zeros((), jnp.int32),
    )
    # annotate source node per received slot
    src = jnp.repeat(jnp.arange(n_nodes, dtype=jnp.int32), K)
    recv = AMBatch(
        dest=src,  # now: sender token
        handler=recv.handler,
        args=recv.args,
        payload=recv.payload,
        valid=recv.valid,
        count=jnp.sum(recv.valid).astype(jnp.int32),
    )
    return recv, dropped


# --------------------------------------------------------------------------- #
# Delivery (asynchronous handler invocation, fused)
# --------------------------------------------------------------------------- #
def deliver(state: Any, recv: AMBatch, handlers: HandlerTable) -> Any:
    """Apply each landed message's handler to the local state, in slot order.

    Exactly-once: every valid slot fires its handler exactly once; invalid
    slots are skipped.  Implemented as a ``lax.scan`` over slots with a
    ``lax.switch`` over handler ids — sequential like the paper's handler
    queue, which also serializes handler execution per node.
    """
    fns = handlers.fns
    if not fns:
        raise ValueError("no handlers registered")

    def body(st, slot):
        valid, hid, args, payload, token = slot

        def fire(s):
            branches = [
                (lambda f: (lambda ss: f(ss, payload, args)))(f) for f in fns
            ]
            return lax.switch(jnp.clip(hid, 0, len(fns) - 1), branches, s)

        st = lax.cond(valid, fire, lambda s: s, st)
        return st, None

    slots = (recv.valid, recv.handler, recv.args, recv.payload, recv.dest)
    state, _ = lax.scan(body, state, slots)
    return state


def long_write_handler(seg_key: str) -> Callable:
    """Built-in AMLong handler: GAScore-style remote write of the payload at
    flat offset ``args[0]`` (element count ``args[1]``, 0 = whole payload)
    into ``state[seg_key]`` (any-shaped local segment view)."""

    def fn(state: Any, payload: jax.Array, args: jax.Array) -> Any:
        seg = state[seg_key]
        flat = seg.reshape(-1)
        width = payload.shape[0]
        nelem = jnp.where(args[1] > 0, args[1], width)
        off = args[0]
        cur = lax.dynamic_slice(flat, (off,), (width,))
        mask = jnp.arange(width) < nelem
        new = jnp.where(mask, payload.astype(flat.dtype), cur)
        flat = lax.dynamic_update_slice(flat, new, (off,))
        out = dict(state)
        out[seg_key] = flat.reshape(seg.shape)
        return out

    return fn

"""Active Messages (GASNet Core API), in bulk-synchronous SPMD form.

A GASNet *Active Message* is a packet carrying a data payload, a destination
node, and the ID of a *handler function* that runs at the receiver when the
packet lands.  The paper's GAScore engine generates and consumes exactly
these packets in hardware.

TPUs have no receiver-side interrupts, so the handler-on-arrival semantics
are reproduced in the TPU-idiomatic way:

1. every node accumulates outgoing messages into a fixed-capacity
   :class:`AMBatch` (the "FIFO command queue" in front of the GAScore);
2. :func:`route` moves all batches simultaneously with a capacity-bounded
   all-to-all (the on-chip packet network) — this is a *static* SPMD
   schedule, the Pallas/XLA analogue of dynamic packet routing;
3. :func:`deliver` runs the registered handler of each landed message
   against the receiver's local state (the asynchronous handler call,
   now a fused receiver-side epilogue).

Message categories follow GASNet:

- **AMShort**  — handler args only, no payload.
- **AMMedium** — payload delivered to a bounded temporary buffer, handler
  decides placement.
- **AMLong**   — payload written at a caller-specified segment offset
  (``args[0]``); the built-in :func:`long_write_handler` reproduces the
  GAScore remote-DMA write.

**Request/reply** (GASNet Core rule: every AM is a *request* whose handler
may issue exactly one *reply* back to the requester, and reply handlers may
not reply again).  A handler registered with ``replies=True`` returns
``(state, AMReply)``; :func:`deliver_with_replies` collects the replies of
all landed requests into a second :class:`AMBatch` addressed at the sender
tokens, and :func:`request_reply` routes that batch in a second
:func:`route` hop and delivers it — the two-hop schedule is static, so the
whole round trip traces under ``jit`` + ``shard_map``.  Build replies with
:func:`reply_short` / :func:`reply_medium` (or :func:`no_reply` to decline).

Everything here is pure-functional and shape-static, so it traces/lowers
under ``jit`` + ``shard_map`` and is property-testable with hypothesis.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.obs import trace as obs_trace

__all__ = [
    "AMBatch",
    "AMReply",
    "HandlerTable",
    "empty_batch",
    "push",
    "build_send_buffer",
    "route",
    "deliver",
    "deliver_with_replies",
    "request_reply",
    "no_reply",
    "reply_short",
    "reply_medium",
    "long_write_handler",
]

MAX_ARGS = 4  # GASNet Core allows up to 16 handler args; 4 suffice here.


# --------------------------------------------------------------------------- #
# Handler registry
# --------------------------------------------------------------------------- #
class HandlerTable:
    """Ordered registry name -> (id, fn).

    Handler signature: ``fn(state, payload, args) -> state`` where ``state``
    is an arbitrary pytree (typically the node's local segment views),
    ``payload`` is a flat ``(payload_size,)`` vector and ``args`` a
    ``(MAX_ARGS,)`` int32 vector.  Handlers must be pure and return a pytree
    of identical structure (they are branches of one ``lax.switch``).

    A handler registered with ``replies=True`` is a GASNet *request*
    handler: it returns ``(state, AMReply)`` and its reply is routed back
    to the requester by :func:`request_reply`.  Reply handlers themselves
    must be plain (``replies=False``) — GASNet forbids replying to a reply,
    and :func:`request_reply` enforces this by discarding nested replies.
    """

    def __init__(self) -> None:
        self._names: List[str] = []
        self._fns: List[Callable] = []
        self._replies: List[bool] = []

    def register(self, name: str, fn: Callable, replies: bool = False) -> int:
        if name in self._names:
            raise ValueError(f"handler {name!r} already registered")
        self._names.append(name)
        self._fns.append(fn)
        self._replies.append(bool(replies))
        return len(self._names) - 1

    def handler(self, name: str, replies: bool = False) -> Callable:
        """Decorator form of :meth:`register`."""

        def deco(fn: Callable) -> Callable:
            self.register(name, fn, replies=replies)
            return fn

        return deco

    def id_of(self, name: str) -> int:
        return self._names.index(name)

    def replies_of(self, name: str) -> bool:
        return self._replies[self.id_of(name)]

    @property
    def fns(self) -> Tuple[Callable, ...]:
        return tuple(self._fns)

    @property
    def reply_flags(self) -> Tuple[bool, ...]:
        return tuple(self._replies)

    @property
    def has_replies(self) -> bool:
        return any(self._replies)

    def __len__(self) -> int:
        return len(self._names)


# --------------------------------------------------------------------------- #
# Message batches
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class AMBatch:
    """Fixed-capacity outgoing message queue of ONE node (local view).

    Attributes (capacity C, payload width Pw):
      dest:    (C,)  int32   destination node id per slot.
      handler: (C,)  int32   handler id per slot.
      args:    (C, MAX_ARGS) int32 handler arguments.
      payload: (C, Pw) payload rows (zero width for AMShort-only batches).
      valid:   (C,)  bool    slot occupancy.
      count:   ()    int32   number of occupied slots.
    """

    dest: jax.Array
    handler: jax.Array
    args: jax.Array
    payload: jax.Array
    valid: jax.Array
    count: jax.Array

    @property
    def capacity(self) -> int:
        return self.dest.shape[0]

    @property
    def payload_width(self) -> int:
        return self.payload.shape[1]


jax.tree_util.register_pytree_node(
    AMBatch,
    lambda b: ((b.dest, b.handler, b.args, b.payload, b.valid, b.count), None),
    lambda _, xs: AMBatch(*xs),
)


@dataclasses.dataclass
class AMReply:
    """Shape-static reply descriptor returned by a request handler.

    Attributes (payload width Pw of the delivering batch):
      send:    ()  bool    whether a reply is actually issued.
      handler: ()  int32   reply handler id (must be ``replies=False``).
      args:    (MAX_ARGS,) int32 reply handler arguments (AMReplyShort).
      payload: (Pw,)       reply payload (AMReplyMedium; zeros for Short).
    """

    send: jax.Array
    handler: jax.Array
    args: jax.Array
    payload: jax.Array


jax.tree_util.register_pytree_node(
    AMReply,
    lambda r: ((r.send, r.handler, r.args, r.payload), None),
    lambda _, xs: AMReply(*xs),
)


def _arg_vec(args: Sequence[Any]) -> jax.Array:
    vec = jnp.zeros((MAX_ARGS,), jnp.int32)
    for k, a in enumerate(args):
        vec = vec.at[k].set(jnp.asarray(a, jnp.int32))
    return vec


def no_reply(payload_width: int, dtype: Any = jnp.float32) -> AMReply:
    """The declined reply (the request handler stays one-way)."""
    return AMReply(
        send=jnp.zeros((), bool),
        handler=jnp.zeros((), jnp.int32),
        args=jnp.zeros((MAX_ARGS,), jnp.int32),
        payload=jnp.zeros((payload_width,), dtype),
    )


def reply_short(
    handler: int, args: Sequence[Any] = (), *, like: jax.Array
) -> AMReply:
    """AMReplyShort: handler id + args, no payload.  ``like`` is the request
    payload (or any ``(Pw,)`` vector of the batch dtype) fixing the reply
    payload shape — all ``lax.switch`` branches must agree on it."""
    return AMReply(
        send=jnp.ones((), bool),
        handler=jnp.asarray(handler, jnp.int32),
        args=_arg_vec(args),
        payload=jnp.zeros_like(like),
    )


def reply_medium(
    handler: int, payload: jax.Array, args: Sequence[Any] = ()
) -> AMReply:
    """AMReplyMedium: payload travels back to the requester."""
    return AMReply(
        send=jnp.ones((), bool),
        handler=jnp.asarray(handler, jnp.int32),
        args=_arg_vec(args),
        payload=payload,
    )


def empty_batch(capacity: int, payload_width: int, dtype: Any = jnp.float32) -> AMBatch:
    return AMBatch(
        dest=jnp.zeros((capacity,), jnp.int32),
        handler=jnp.zeros((capacity,), jnp.int32),
        args=jnp.zeros((capacity, MAX_ARGS), jnp.int32),
        payload=jnp.zeros((capacity, payload_width), dtype),
        valid=jnp.zeros((capacity,), bool),
        count=jnp.zeros((), jnp.int32),
    )


def push(
    batch: AMBatch,
    dest: jax.Array,
    handler: int,
    args: Sequence[Any] = (),
    payload: jax.Array | None = None,
    pred: jax.Array | bool | None = None,
) -> AMBatch:
    """Enqueue one message (functional).  Overflow beyond capacity is dropped
    silently here and surfaced by :func:`build_send_buffer` as a count —
    matching GASNet back-pressure semantics in a shape-static world.

    ``pred`` gates the enqueue (shape-static conditional send): under SPMD
    every rank traces the same ``push``, and a rank with nothing to say
    passes ``pred=False`` — the slot is simply not occupied."""
    i = jnp.minimum(batch.count, batch.capacity - 1)
    in_range = batch.count < batch.capacity
    if pred is not None:
        in_range = in_range & jnp.asarray(pred, bool)
    arg_vec = _arg_vec(args)
    if payload is None:
        payload = jnp.zeros((batch.payload_width,), batch.payload.dtype)
    payload = payload.astype(batch.payload.dtype).reshape(-1)
    if payload.shape[0] != batch.payload_width:
        raise ValueError(
            f"payload width {payload.shape[0]} != batch width {batch.payload_width}"
        )

    def write(b: AMBatch) -> AMBatch:
        return AMBatch(
            dest=b.dest.at[i].set(jnp.asarray(dest, jnp.int32)),
            handler=b.handler.at[i].set(jnp.asarray(handler, jnp.int32)),
            args=b.args.at[i].set(arg_vec),
            payload=b.payload.at[i].set(payload),
            valid=b.valid.at[i].set(True),
            count=b.count + 1,
        )

    return lax.cond(in_range, write, lambda b: b, batch)


# --------------------------------------------------------------------------- #
# Routing (the on-chip packet network)
# --------------------------------------------------------------------------- #
def build_send_buffer(
    batch: AMBatch, n_nodes: int, per_peer_capacity: int
) -> Tuple[AMBatch, jax.Array]:
    """Pack a node's outgoing queue into a dense (n_nodes * K)-slot buffer,
    slot ``d*K + r`` holding the r-th message addressed to node d.

    Returns the packed batch (capacity n_nodes*K, same widths) plus the
    number of messages dropped because more than K were addressed to one
    peer (the static-capacity analogue of network back-pressure).
    """
    K = per_peer_capacity
    dest = jnp.where(batch.valid, batch.dest, n_nodes)  # park invalid
    # rank of each message within its destination group (stable order)
    one_hot = (dest[:, None] == jnp.arange(n_nodes + 1)[None, :]).astype(jnp.int32)
    rank = jnp.cumsum(one_hot, axis=0) - one_hot  # exclusive prefix count
    rank = jnp.take_along_axis(rank, dest[:, None], axis=1)[:, 0]
    ok = batch.valid & (rank < K) & (dest < n_nodes)
    slot = jnp.where(ok, dest * K + rank, n_nodes * K)  # park dropped
    dropped = jnp.sum(batch.valid & ~ok)

    C = n_nodes * K

    def scatter(x: jax.Array, fill: Any) -> jax.Array:
        out = jnp.full((C + 1,) + x.shape[1:], fill, x.dtype)
        return out.at[slot].set(x)[:C]

    packed = AMBatch(
        dest=scatter(batch.dest, 0),
        handler=scatter(batch.handler, 0),
        args=scatter(batch.args, 0),
        payload=scatter(batch.payload, 0),
        valid=scatter(ok, False),
        count=jnp.sum(ok).astype(jnp.int32),
    )
    return packed, dropped


def route(
    batch: AMBatch,
    *,
    axis: str,
    n_nodes: int,
    per_peer_capacity: int,
    all_to_all_fn: Callable[[jax.Array], jax.Array] | None = None,
    engine=None,
) -> Tuple[AMBatch, jax.Array]:
    """Exchange all nodes' batches; returns each node's *incoming* messages.

    Must be called inside ``shard_map`` over ``axis``.  The transport is,
    in order of preference: ``engine`` (a CommEngine — the exchange is then
    *plan-driven*: ``repro.core.sched`` picks native vs direct-put
    all-to-all from the buffer size and the engine's cost model, so a
    GAScore or mixed node map routes AMs over its own puts), then
    ``all_to_all_fn`` (an explicit callable), then ``lax.all_to_all``.

    The incoming batch has capacity ``n_nodes * K``; slot ``s*K + r`` holds
    the r-th message from source node s.  ``dest`` of received messages is
    rewritten to the *source* node id (GASNet handlers receive the sender's
    identity as the ``token``).
    """
    K = per_peer_capacity
    tr = obs_trace.active()
    if tr.enabled:
        w = batch.payload_width
        tr.instant(
            "am_route", cat="am", n_nodes=n_nodes, capacity=K,
            payload_width=w,
            engine=getattr(engine, "name", None) if engine is not None
            else ("custom" if all_to_all_fn is not None else "lax"),
        )
    packed, dropped = build_send_buffer(batch, n_nodes, K)

    def a2a(x: jax.Array) -> jax.Array:
        if engine is not None:
            from repro.core import sched

            return sched.all_to_all(engine, x)
        if all_to_all_fn is not None:
            return all_to_all_fn(x)
        return lax.all_to_all(x, axis, split_axis=0, concat_axis=0, tiled=True)

    def exchange(x: jax.Array) -> jax.Array:
        # (n_nodes*K, ...) -> regroup so dim0 blocks are per-destination
        return a2a(x)

    recv = AMBatch(
        dest=exchange(packed.dest),
        handler=exchange(packed.handler),
        args=exchange(packed.args),
        payload=exchange(packed.payload),
        valid=exchange(packed.valid),
        count=jnp.zeros((), jnp.int32),
    )
    # annotate source node per received slot
    src = jnp.repeat(jnp.arange(n_nodes, dtype=jnp.int32), K)
    recv = AMBatch(
        dest=src,  # now: sender token
        handler=recv.handler,
        args=recv.args,
        payload=recv.payload,
        valid=recv.valid,
        count=jnp.sum(recv.valid).astype(jnp.int32),
    )
    return recv, dropped


# --------------------------------------------------------------------------- #
# Delivery (asynchronous handler invocation, fused)
# --------------------------------------------------------------------------- #
def deliver_with_replies(
    state: Any, recv: AMBatch, handlers: HandlerTable
) -> Tuple[Any, AMBatch]:
    """Apply each landed message's handler to the local state, in slot order,
    and collect the replies of ``replies=True`` handlers.

    Exactly-once: every valid slot fires its handler exactly once; invalid
    slots are skipped.  Implemented as a ``lax.scan`` over slots with a
    ``lax.switch`` over handler ids — sequential like the paper's handler
    queue, which also serializes handler execution per node.

    Returns ``(state, reply_batch)``: slot s of the reply batch is the
    reply (if any) of the request in slot s of ``recv``, addressed at that
    request's sender token — ready for a second :func:`route` hop.
    """
    fns = handlers.fns
    flags = handlers.reply_flags
    if not fns:
        raise ValueError("no handlers registered")
    pw = recv.payload_width
    pdtype = recv.payload.dtype

    def body(st, slot):
        valid, hid, args, payload, token = slot
        del token  # reply routing uses recv.dest directly

        def fire(s):
            branches = []
            for f, rep in zip(fns, flags):
                if rep:
                    branches.append(
                        (lambda f: (lambda ss: f(ss, payload, args)))(f)
                    )
                else:
                    branches.append(
                        (lambda f: (
                            lambda ss: (f(ss, payload, args),
                                        no_reply(pw, pdtype))
                        ))(f)
                    )
            return lax.switch(jnp.clip(hid, 0, len(fns) - 1), branches, s)

        st, rep = lax.cond(
            valid, fire, lambda s: (s, no_reply(pw, pdtype)), st
        )
        return st, rep

    slots = (recv.valid, recv.handler, recv.args, recv.payload, recv.dest)
    state, reps = lax.scan(body, state, slots)
    valid = recv.valid & reps.send
    reply = AMBatch(
        dest=recv.dest,  # the sender token of each request
        handler=reps.handler,
        args=reps.args,
        payload=reps.payload,
        valid=valid,
        count=jnp.sum(valid).astype(jnp.int32),
    )
    return state, reply


def deliver(state: Any, recv: AMBatch, handlers: HandlerTable) -> Any:
    """One-way delivery (see :func:`deliver_with_replies`); replies of any
    ``replies=True`` handlers are discarded — this is the reply-hop rule
    (a reply handler may not reply again)."""
    state, _ = deliver_with_replies(state, recv, handlers)
    return state


def request_reply(
    state: Any,
    batch: AMBatch,
    handlers: HandlerTable,
    *,
    axis: str,
    n_nodes: int,
    per_peer_capacity: int,
    all_to_all_fn: Callable[[jax.Array], jax.Array] | None = None,
    engine=None,
) -> Tuple[Any, jax.Array]:
    """The full GASNet request/reply cycle, statically scheduled:

    1. :func:`route` the request batch (hop 1) and deliver it, collecting
       the ``AMReply`` each request handler returns;
    2. :func:`route` the reply batch back (hop 2) and deliver it one-way
       (nested replies are discarded — GASNet's no-reply-to-a-reply rule).

    The reply hop reuses the same per-peer capacity: a node holds at most
    ``per_peer_capacity`` requests per source, hence at most that many
    replies per destination, so hop 2 can never drop for capacity.
    Returns ``(state, dropped)`` with the hop-1 + hop-2 drop count.
    """
    tr = obs_trace.active()
    with tr.span("am_request_hop", cat="am", n_nodes=n_nodes):
        recv, dropped = route(
            batch, axis=axis, n_nodes=n_nodes,
            per_peer_capacity=per_peer_capacity,
            all_to_all_fn=all_to_all_fn, engine=engine,
        )
        state, replies = deliver_with_replies(state, recv, handlers)
    with tr.span("am_reply_hop", cat="am", n_nodes=n_nodes):
        recv2, dropped2 = route(
            replies, axis=axis, n_nodes=n_nodes,
            per_peer_capacity=per_peer_capacity,
            all_to_all_fn=all_to_all_fn, engine=engine,
        )
        state = deliver(state, recv2, handlers)
    return state, dropped + dropped2


def long_write_handler(seg_key: str) -> Callable:
    """Built-in AMLong handler: GAScore-style remote write of the payload at
    flat offset ``args[0]`` (element count ``args[1]``, 0 = whole payload)
    into ``state[seg_key]`` (any-shaped local segment view)."""

    def fn(state: Any, payload: jax.Array, args: jax.Array) -> Any:
        seg = state[seg_key]
        flat = seg.reshape(-1)
        width = payload.shape[0]
        nelem = jnp.where(args[1] > 0, args[1], width)
        off = args[0]
        cur = lax.dynamic_slice(flat, (off,), (width,))
        mask = jnp.arange(width) < nelem
        new = jnp.where(mask, payload.astype(flat.dtype), cur)
        flat = lax.dynamic_update_slice(flat, new, (off,))
        out = dict(state)
        out[seg_key] = flat.reshape(seg.shape)
        return out

    return fn

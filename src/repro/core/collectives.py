"""Ring & hierarchical collectives built from one-sided puts.

The paper's GAScore gives nodes one-sided remote writes; classic PGAS
collectives are then *algorithms over puts*.  These implementations take a
:class:`~repro.core.engine.CommEngine`, so the same algorithm runs on the
software node (XLA ppermute transport) or the hardware node (Pallas
remote-DMA transport) — engine parity is tested.

All rings are built on the **split-phase** primitives
(``engine.shift_nb`` → ``Pending.wait``, the Extended-API transport): each
hop's put is initiated *before* the local work of the previous hop
(slice/accumulate/store), so per-hop compute overlaps the wire — the
double-buffered schedule a GAScore drains from its command FIFO.

All functions must be called inside ``shard_map`` over ``engine.axis``.

Ring algorithms (bandwidth-optimal, n-1 hops of 1/n of the data):

- :func:`ring_all_gather`     local (m, ...)        -> (n*m, ...)
- :func:`ring_reduce_scatter` (n*m, ...)            -> summed (m, ...)
- :func:`ring_all_reduce`     (n*m, ...)            -> summed (n*m, ...)
- :func:`broadcast`           root's (m, ...)       -> same on every node
- :func:`exchange`            (n*m, ...)            -> all-to-all, all n-1
  puts in flight simultaneously (fully overlapped personalized exchange)

Segmented/pipelined rings (the scheduler's bulk tier — see
``repro.core.sched``): the payload is chunked into ``n_segments`` slices
with up to ``depth`` puts in flight, so segment k+1's wire time overlaps
segment k's slice/accumulate/store epilogue — the GAScore command-FIFO
drain made software-visible:

- :func:`segmented_ring_all_gather`
- :func:`segmented_ring_reduce_scatter`
- :func:`segmented_ring_all_reduce`

Segmentation is bit-transparent: every segment follows the exact hop and
accumulate order of the monolithic ring, so results match the monolithic
call bit for bit (property-tested for int dtypes over arbitrary
``n_segments``/``depth``).

Latency-optimal algorithms (the scheduler's small-payload tier):

- :func:`recursive_doubling_all_reduce` — log2(n) exchange rounds carrying
  the full payload (n must be a power of two); beats the ring when the
  per-hop latency α dominates the wire term.
- :func:`tree_broadcast` — binomial tree, ceil(log2 n) rounds (requires an
  engine with partial-permute support, i.e. software nodes).

Hierarchical (pod-aware — the paper's on-chip network vs OCCC split):

- :func:`hierarchical_all_reduce` — reduce-scatter on the cheap inner axis,
  all-reduce the 1/n_inner shard across the expensive outer axis, then
  all-gather on the inner axis.  Cross-pod wire bytes drop from
  2·(n_out-1)/n_out · S to 2·(n_out-1)/n_out · S/n_inner.
"""
from __future__ import annotations

import math
from collections import deque
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.engine import CommEngine

__all__ = [
    "ring_all_gather",
    "ring_reduce_scatter",
    "ring_all_reduce",
    "segmented_ring_all_gather",
    "segmented_ring_reduce_scatter",
    "segmented_ring_all_reduce",
    "recursive_doubling_all_reduce",
    "tree_broadcast",
    "broadcast",
    "exchange",
    "hierarchical_all_reduce",
    "ring_all_to_all",
    "segment_bounds",
]


def ring_all_gather(engine: CommEngine, x: jax.Array) -> jax.Array:
    """All-gather via n-1 neighbor puts.

    Round k: every node puts the chunk it received in round k-1 to its right
    neighbor.  Split-phase schedule: the hop-(k+1) put of a received chunk
    is initiated *before* that chunk is stored into the local output slot,
    so the store overlaps the next transfer (the chunk itself is forwarded
    untouched — the store is off the forwarding path).
    """
    n = engine.n_nodes
    me = engine.my_id()
    out = jnp.zeros((n,) + x.shape, x.dtype)
    out = lax.dynamic_update_slice_in_dim(out, x[None], me, axis=0)
    pending = engine.shift_nb(x, 1)  # hop 1 in flight before any local work
    for k in range(1, n):
        cur = pending.wait()
        if k < n - 1:
            pending = engine.shift_nb(cur, 1)  # forward before storing
        src = lax.rem(me - k + n, n)
        out = lax.dynamic_update_slice_in_dim(out, cur[None], src, axis=0)
    return out.reshape((n * x.shape[0],) + x.shape[1:]) if x.ndim else out


def ring_reduce_scatter(engine: CommEngine, x: jax.Array) -> jax.Array:
    """Reduce-scatter via n-1 put+accumulate hops.

    Input is each node's full (n*m, ...) contribution viewed as n chunks;
    node i ends with ``sum_j x_j[chunk i]``.

    Schedule: the packet for chunk ``c`` starts at node ``c+1`` (with that
    node's contribution to chunk c), travels the ring by one-sided puts to
    the right neighbor, and each visited node accumulates its own
    contribution.  After ``h`` hops, the packet held by node ``me`` started
    at node ``me-h`` and is therefore for chunk ``c = me-h-1 (mod n)``.
    After ``n-1`` hops node ``me`` holds the packet for chunk
    ``me-(n-1)-1 ≡ me (mod n)`` — its own — having just added its own
    contribution on the final accumulate.  Verified against
    ``lax.psum_scatter`` in tests.

    Split-phase: each hop's put is initiated first; the slice of the local
    contribution for the incoming chunk is computed while the packet is on
    the wire, and only the final add waits on delivery.
    """
    n = engine.n_nodes
    if x.shape[0] % n != 0:
        raise ValueError(f"reduce_scatter dim0 {x.shape[0]} not divisible by {n}")
    m = x.shape[0] // n
    blocks = x.reshape((n, m) + x.shape[1:])
    me = engine.my_id()
    # packet leaving me is for chunk (me - 1) mod n; seed with my contribution
    cur = lax.dynamic_slice_in_dim(blocks, lax.rem(me - 1 + n, n), 1, axis=0)[0]
    for h in range(1, n):
        pending = engine.shift_nb(cur, 1)  # put partial sum to right neighbor
        c = lax.rem(me - h - 1 + 2 * n, n)  # chunk id of the incoming packet
        mine = lax.dynamic_slice_in_dim(blocks, c, 1, axis=0)[0]  # overlapped
        cur = pending.wait() + mine
    return cur


def ring_all_reduce(engine: CommEngine, x: jax.Array) -> jax.Array:
    """All-reduce = reduce-scatter + all-gather (2·(n-1) hops of size S/n)."""
    n = engine.n_nodes
    if x.ndim and x.shape[0] % n == 0 and x.shape[0] > 0:
        return ring_all_gather(engine, ring_reduce_scatter(engine, x))
    # fallback: shift-accumulate ring with full payload per hop
    acc = x
    cur = x
    for _ in range(n - 1):
        cur = engine.shift(cur, 1)
        acc = acc + cur
    return acc


def broadcast(engine: CommEngine, x: jax.Array, *, root: int = 0) -> jax.Array:
    """Broadcast the root node's ``x`` to every node (ring pipeline).

    Every node forwards what it received on the previous hop; node
    ``(root + k) % n`` receives the root's value at hop ``k`` and selects
    it into its output.  Split-phase: hop k+1 is initiated before the
    hop-k select, so the select overlaps the wire.
    """
    n = engine.n_nodes
    if n == 1:
        return x
    me = engine.my_id()
    out = x  # root already holds its own value; others get overwritten
    cur = x
    pending = engine.shift_nb(cur, 1)
    for k in range(1, n):
        cur = pending.wait()
        if k < n - 1:
            pending = engine.shift_nb(cur, 1)  # forward before selecting
        out = jnp.where(me == (root + k) % n, cur, out)
    return out


def exchange(engine: CommEngine, x: jax.Array) -> jax.Array:
    """All-to-all personalized exchange built from non-blocking puts.

    Viewing (n*m, ...) as n blocks, block ``d`` of node ``s`` lands as
    block ``s`` of node ``d``.  All n-1 one-sided puts (block for node
    ``me+k`` travels as one distance-k put) are *initiated before any
    completion is consumed* — the maximally overlapped schedule: with a
    GAScore transport every DMA is in flight simultaneously, with the XLA
    transport the async collective-permutes pipeline back-to-back.
    """
    n = engine.n_nodes
    if x.shape[0] % n != 0:
        raise ValueError(f"exchange dim0 {x.shape[0]} not divisible by {n}")
    m = x.shape[0] // n
    blocks = x.reshape((n, m) + x.shape[1:])
    me = engine.my_id()
    out = jnp.zeros_like(blocks)
    own = lax.dynamic_slice_in_dim(blocks, me, 1, axis=0)
    out = lax.dynamic_update_slice_in_dim(out, own, me, axis=0)
    pendings = []
    for k in range(1, n):
        send = lax.dynamic_slice_in_dim(blocks, lax.rem(me + k, n), 1, axis=0)
        pendings.append((k, engine.shift_nb(send, k)))  # initiate all
    for k, p in pendings:  # then drain completions
        recv = p.wait()
        src = lax.rem(me - k + n, n)
        out = lax.dynamic_update_slice_in_dim(out, recv, src, axis=0)
    return out.reshape(x.shape)


def ring_all_to_all(engine: CommEngine, x: jax.Array) -> jax.Array:
    """All-to-all over the engine's transport (see CommEngine.all_to_all)."""
    return engine.all_to_all(x)


# --------------------------------------------------------------------------- #
# Segmented / pipelined rings
# --------------------------------------------------------------------------- #
def segment_bounds(m: int, n_segments: int) -> List[Tuple[int, int]]:
    """Contiguous ``[lo, hi)`` slices splitting ``m`` rows into at most
    ``n_segments`` near-equal segments (first remainder segments one larger,
    like ``np.array_split``)."""
    g = max(1, min(int(n_segments), m))
    base, rem = divmod(m, g)
    bounds = []
    lo = 0
    for i in range(g):
        hi = lo + base + (1 if i < rem else 0)
        bounds.append((lo, hi))
        lo = hi
    return bounds


def _drain_pipeline(states: List[dict], depth: int, step: Callable) -> None:
    """Software pipeline over per-segment ring state machines.

    At most ``depth`` segments have a put in flight at any point; segments
    are serviced round-robin FIFO, so the wait of the oldest in-flight
    segment is followed by (a) initiating its next hop and (b) its local
    epilogue — the epilogue of segment k overlapping the wire of the other
    in-flight segments.  ``step(st)`` waits st's pending, runs the epilogue,
    initiates the next hop, and returns False once the segment retired.
    """
    depth = max(1, int(depth))
    inflight: deque = deque()
    pending_start = deque(states)
    while pending_start and len(inflight) < depth:
        st = pending_start.popleft()
        st["start"](st)
        inflight.append(st)
    while inflight:
        st = inflight.popleft()
        if step(st):
            inflight.append(st)
        elif pending_start:
            nxt = pending_start.popleft()
            nxt["start"](nxt)
            inflight.append(nxt)


def segmented_ring_all_gather(
    engine: CommEngine, x: jax.Array, *, n_segments: int = 1, depth: int = 2
) -> jax.Array:
    """:func:`ring_all_gather`, payload chunked into ``n_segments`` slices
    with up to ``depth`` puts in flight.

    Each segment runs the exact monolithic hop schedule over its slice, so
    the result is bit-identical to the monolithic call; segmentation only
    changes *when* wire time happens relative to the store epilogues (the
    pipelining a GAScore realizes by draining its command FIFO while the
    receiver lands earlier packets).
    """
    n = engine.n_nodes
    if x.ndim == 0 or n_segments <= 1 or x.shape[0] < 2 or n == 1:
        return ring_all_gather(engine, x)
    m = x.shape[0]
    me = engine.my_id()
    bounds = segment_bounds(m, n_segments)
    if len(bounds) == 1:
        return ring_all_gather(engine, x)

    def start(st):
        seg = lax.slice_in_dim(x, st["lo"], st["hi"], axis=0)
        out = jnp.zeros((n,) + seg.shape, seg.dtype)
        st["out"] = lax.dynamic_update_slice_in_dim(out, seg[None], me, axis=0)
        st["pending"] = engine.shift_nb(seg, 1)
        st["k"] = 1

    def step(st):
        cur = st["pending"].wait()
        k = st["k"]
        alive = k < n - 1
        if alive:
            st["pending"] = engine.shift_nb(cur, 1)  # forward before storing
        src = lax.rem(me - k + n, n)
        st["out"] = lax.dynamic_update_slice_in_dim(
            st["out"], cur[None], src, axis=0
        )
        st["k"] = k + 1
        return alive

    states = [dict(lo=lo, hi=hi, start=start) for lo, hi in bounds]
    _drain_pipeline(states, depth, step)
    # stitch segments back: (n, m_g, ...) concat over the row axis
    full = jnp.concatenate([st["out"] for st in states], axis=1)
    return full.reshape((n * m,) + x.shape[1:])


def segmented_ring_reduce_scatter(
    engine: CommEngine, x: jax.Array, *, n_segments: int = 1, depth: int = 2
) -> jax.Array:
    """:func:`ring_reduce_scatter`, payload chunked into ``n_segments``
    slices with up to ``depth`` put+accumulate pipelines in flight.

    Per segment the hop order and accumulation order are exactly the
    monolithic ring's, so results are bit-identical (for floats too: the
    same additions happen in the same order on the same values).
    """
    n = engine.n_nodes
    if x.shape[0] % n != 0:
        raise ValueError(f"reduce_scatter dim0 {x.shape[0]} not divisible by {n}")
    m = x.shape[0] // n
    if n_segments <= 1 or m < 2 or n == 1:
        return ring_reduce_scatter(engine, x)
    bounds = segment_bounds(m, n_segments)
    if len(bounds) == 1:
        return ring_reduce_scatter(engine, x)
    blocks = x.reshape((n, m) + x.shape[1:])
    me = engine.my_id()

    def start(st):
        seg_blocks = lax.slice_in_dim(blocks, st["lo"], st["hi"], axis=1)
        st["blocks"] = seg_blocks  # (n, m_g, ...)
        cur = lax.dynamic_slice_in_dim(
            seg_blocks, lax.rem(me - 1 + n, n), 1, axis=0
        )[0]
        st["pending"] = engine.shift_nb(cur, 1)
        st["h"] = 1

    def step(st):
        h = st["h"]
        c = lax.rem(me - h - 1 + 2 * n, n)
        mine = lax.dynamic_slice_in_dim(st["blocks"], c, 1, axis=0)[0]
        cur = st["pending"].wait() + mine
        alive = h < n - 1
        if alive:
            st["pending"] = engine.shift_nb(cur, 1)
        else:
            st["cur"] = cur
        st["h"] = h + 1
        return alive

    states = [dict(lo=lo, hi=hi, start=start) for lo, hi in bounds]
    _drain_pipeline(states, depth, step)
    return jnp.concatenate([st["cur"] for st in states], axis=0)


def segmented_ring_all_reduce(
    engine: CommEngine, x: jax.Array, *, n_segments: int = 1, depth: int = 2
) -> jax.Array:
    """Segmented :func:`ring_all_reduce` (RS + AG, both pipelined).

    Bit-identical to the monolithic call for any ``n_segments``/``depth``
    (property-tested for int dtypes)."""
    n = engine.n_nodes
    if x.ndim and x.shape[0] % n == 0 and x.shape[0] > 0:
        shard = segmented_ring_reduce_scatter(
            engine, x, n_segments=n_segments, depth=depth
        )
        return segmented_ring_all_gather(
            engine, shard, n_segments=n_segments, depth=depth
        )
    return ring_all_reduce(engine, x)


# --------------------------------------------------------------------------- #
# Latency-optimal algorithms (the scheduler's small-payload tier)
# --------------------------------------------------------------------------- #
def recursive_doubling_all_reduce(engine: CommEngine, x: jax.Array) -> jax.Array:
    """All-reduce in log2(n) pairwise-exchange rounds (full payload each).

    Round r exchanges with the partner at XOR distance 2^r — a bijection,
    so it runs on every engine (including the GAScore transport).  Total
    cost log2(n)·(α + β·S): beats the ring's 2(n-1)·(α + β·S/n) when α
    dominates, i.e. for small payloads.  Requires power-of-two n.
    """
    n = engine.n_nodes
    if n & (n - 1):
        raise ValueError(f"recursive doubling needs power-of-two nodes, got {n}")
    cur = x
    d = 1
    while d < n:
        dst = [i ^ d for i in range(n)]
        pending = engine.permute_nb(cur, dst)
        cur = cur + pending.wait()
        d *= 2
    return cur


def tree_broadcast(
    engine: CommEngine, x: jax.Array, *, root: int = 0
) -> jax.Array:
    """Binomial-tree broadcast: ceil(log2 n) rounds instead of n-1 hops.

    Round r: ranks (relative to root) in [0, 2^r) send to rank+2^r.  The
    send set is partial, so this needs ``engine.can_permute_partial``
    (software nodes); the scheduler falls back to the ring pipeline
    otherwise."""
    n = engine.n_nodes
    if n == 1:
        return x
    if not engine.can_permute_partial:
        raise ValueError(
            f"tree_broadcast needs partial permute; engine {engine.name!r} "
            "only supports bijections (use broadcast())"
        )
    me = engine.my_id()
    rel = lax.rem(me - root + n, n)
    out = x
    rounds = max(1, math.ceil(math.log2(n)))
    for r in range(rounds):
        span = 1 << r
        dst = [None] * n
        for i in range(n):
            i_rel = (i - root) % n
            if i_rel < span and i_rel + span < n:
                dst[i] = (i + span) % n
        pending = engine.permute_nb(out, dst)
        recv = pending.wait()
        is_recv = (rel >= span) & (rel < 2 * span)
        out = jnp.where(is_recv, recv, out)
    return out


def hierarchical_all_reduce(
    inner: CommEngine,
    outer: CommEngine,
    x: jax.Array,
    all_reduce_outer: Callable[[CommEngine, jax.Array], jax.Array] | None = None,
) -> jax.Array:
    """Pod-aware all-reduce: RS(inner) -> AR(outer) -> AG(inner).

    ``inner`` enumerates nodes inside a pod (cheap on-chip-network links),
    ``outer`` enumerates pods (expensive OCCC links).  Only 1/n_inner of
    the data crosses the outer axis.
    """
    ar = all_reduce_outer or ring_all_reduce
    shard = ring_reduce_scatter(inner, x)
    shard = ar(outer, shard)
    return ring_all_gather(inner, shard)

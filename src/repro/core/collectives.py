"""Ring & hierarchical collectives built from one-sided puts.

The paper's GAScore gives nodes one-sided remote writes; classic PGAS
collectives are then *algorithms over puts*.  These implementations take a
:class:`~repro.core.engine.CommEngine`, so the same algorithm runs on the
software node (XLA ppermute transport) or the hardware node (Pallas
remote-DMA transport) — engine parity is tested.

All functions must be called inside ``shard_map`` over ``engine.axis``.

Ring algorithms (bandwidth-optimal, n-1 hops of 1/n of the data):

- :func:`ring_all_gather`     local (m, ...)        -> (n*m, ...)
- :func:`ring_reduce_scatter` (n*m, ...)            -> summed (m, ...)
- :func:`ring_all_reduce`     (n*m, ...)            -> summed (n*m, ...)

Hierarchical (pod-aware — the paper's on-chip network vs OCCC split):

- :func:`hierarchical_all_reduce` — reduce-scatter on the cheap inner axis,
  all-reduce the 1/n_inner shard across the expensive outer axis, then
  all-gather on the inner axis.  Cross-pod wire bytes drop from
  2·(n_out-1)/n_out · S to 2·(n_out-1)/n_out · S/n_inner.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.engine import CommEngine

__all__ = [
    "ring_all_gather",
    "ring_reduce_scatter",
    "ring_all_reduce",
    "hierarchical_all_reduce",
    "ring_all_to_all",
]


def ring_all_gather(engine: CommEngine, x: jax.Array) -> jax.Array:
    """All-gather via n-1 neighbor puts.

    Round k: every node puts the chunk it received in round k-1 to its right
    neighbor.  After n-1 rounds everyone holds all chunks, ordered by source
    node id.
    """
    n = engine.n_nodes
    me = engine.my_id()
    out = jnp.zeros((n,) + x.shape, x.dtype)
    out = lax.dynamic_update_slice_in_dim(out, x[None], me, axis=0)
    cur = x
    for k in range(1, n):
        cur = engine.shift(cur, 1)  # one-sided put to right neighbor
        src = lax.rem(me - k + n, n)
        out = lax.dynamic_update_slice_in_dim(out, cur[None], src, axis=0)
    return out.reshape((n * x.shape[0],) + x.shape[1:]) if x.ndim else out


def ring_reduce_scatter(engine: CommEngine, x: jax.Array) -> jax.Array:
    """Reduce-scatter via n-1 put+accumulate hops.

    Input is each node's full (n*m, ...) contribution viewed as n chunks;
    node i ends with ``sum_j x_j[chunk i]``.

    Schedule: the packet for chunk ``c`` starts at node ``c+1`` (with that
    node's contribution to chunk c), travels the ring by one-sided puts to
    the right neighbor, and each visited node accumulates its own
    contribution.  After ``h`` hops, the packet held by node ``me`` started
    at node ``me-h`` and is therefore for chunk ``c = me-h-1 (mod n)``.
    After ``n-1`` hops node ``me`` holds the packet for chunk
    ``me-(n-1)-1 ≡ me (mod n)`` — its own — having just added its own
    contribution on the final accumulate.  Verified against
    ``lax.psum_scatter`` in tests.
    """
    n = engine.n_nodes
    if x.shape[0] % n != 0:
        raise ValueError(f"reduce_scatter dim0 {x.shape[0]} not divisible by {n}")
    m = x.shape[0] // n
    blocks = x.reshape((n, m) + x.shape[1:])
    me = engine.my_id()
    # packet leaving me is for chunk (me - 1) mod n; seed with my contribution
    cur = lax.dynamic_slice_in_dim(blocks, lax.rem(me - 1 + n, n), 1, axis=0)[0]
    for h in range(1, n):
        cur = engine.shift(cur, 1)  # put partial sum to right neighbor
        c = lax.rem(me - h - 1 + 2 * n, n)  # chunk id of the packet now here
        mine = lax.dynamic_slice_in_dim(blocks, c, 1, axis=0)[0]
        cur = cur + mine
    return cur


def ring_all_reduce(engine: CommEngine, x: jax.Array) -> jax.Array:
    """All-reduce = reduce-scatter + all-gather (2·(n-1) hops of size S/n)."""
    n = engine.n_nodes
    if x.ndim and x.shape[0] % n == 0 and x.shape[0] > 0:
        return ring_all_gather(engine, ring_reduce_scatter(engine, x))
    # fallback: shift-accumulate ring with full payload per hop
    acc = x
    cur = x
    for _ in range(n - 1):
        cur = engine.shift(cur, 1)
        acc = acc + cur
    return acc


def ring_all_to_all(engine: CommEngine, x: jax.Array) -> jax.Array:
    """All-to-all over the engine's transport (see CommEngine.all_to_all)."""
    return engine.all_to_all(x)


def hierarchical_all_reduce(
    inner: CommEngine,
    outer: CommEngine,
    x: jax.Array,
    all_reduce_outer: Callable[[CommEngine, jax.Array], jax.Array] | None = None,
) -> jax.Array:
    """Pod-aware all-reduce: RS(inner) -> AR(outer) -> AG(inner).

    ``inner`` enumerates nodes inside a pod (cheap on-chip-network links),
    ``outer`` enumerates pods (expensive OCCC links).  Only 1/n_inner of
    the data crosses the outer axis.
    """
    ar = all_reduce_outer or ring_all_reduce
    shard = ring_reduce_scatter(inner, x)
    shard = ar(outer, shard)
    return ring_all_gather(inner, shard)

"""Communication engines: the "software GASNet node" vs the "GAScore".

The paper's central demonstration is that software nodes (x86/ARM GASNet)
and hardware nodes (the GAScore remote-DMA engine) interoperate through one
API.  We reproduce that split exactly:

- :class:`XlaEngine`     — the *software node*: transport primitives are
  ``jax.lax`` collectives, i.e. XLA's own (reference) implementation.
- :class:`GascoreEngine` — the *hardware node*: the same primitives are the
  hand-written Pallas remote-DMA kernels from ``repro.kernels.gascore``
  (``pltpu.make_async_remote_copy`` + DMA semaphores over ICI).

Both expose the identical :class:`CommEngine` interface, so any code built
on top (the ring collectives, the AM router, user programs) migrates from
software to hardware by swapping the engine — the paper's software→hardware
migration story with zero API change.

All methods must be called inside a ``shard_map`` over ``self.axis``.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["CommEngine", "Pending", "XlaEngine", "GascoreEngine", "make_engine"]


def ring_pairs(n: int, k: int) -> List[Tuple[int, int]]:
    """Permutation pairs for 'every node sends to (me + k) mod n'."""
    k = k % n
    return [(i, (i + k) % n) for i in range(n)]


class Pending:
    """An in-flight transport operation (the engine half of split-phase).

    ``shift_nb``/``permute_nb`` return a ``Pending`` at *initiation*;
    ``wait()`` is the *sync point* that yields the delivered value.  Any
    compute traced between initiation and ``wait()`` has no data dependence
    on the transfer, so the scheduler is free to overlap it:

    - ``XlaEngine``: the ppermute lowers to an async ``collective-permute``
      start/done pair; XLA's latency-hiding scheduler slides independent
      compute between them (double-buffered scheduling).
    - ``GascoreEngine``: the Pallas kernel's DMA *recv-semaphore wait* is
      the sync point; the DMA itself progresses in the background exactly
      like the paper's GAScore engine draining its command FIFO.
    """

    __slots__ = ("_value", "_waited")

    def __init__(self, value: jax.Array):
        self._value = value
        self._waited = False

    def wait(self) -> jax.Array:
        """Complete the transfer and return the delivered value (a
        transfer completes exactly once, like ``gasnet_wait_syncnb``)."""
        if self._waited:
            raise RuntimeError("Pending transfer already waited on")
        self._waited = True
        return self._value

    def ready(self) -> bool:
        """Poll (``gasnet_try_syncnb``).  The static SPMD schedule
        guarantees delivery of every initiated transfer, so this is
        constant-``True`` — kept for API fidelity."""
        return True


class CommEngine:
    """Transport primitives of one GASNet node.

    ``axis`` is the mesh axis enumerating the nodes; ``n_nodes`` its size.
    """

    name = "abstract"

    def __init__(self, axis: str, n_nodes: int):
        self.axis = axis
        self.n_nodes = n_nodes

    # -- point-to-point (one-sided put transport) ----------------------- #
    def shift(self, x: jax.Array, k: int = 1) -> jax.Array:
        """Every node's ``x`` lands on node ``(me + k) % n``."""
        raise NotImplementedError

    def permute(self, x: jax.Array, dst: Sequence[int]) -> jax.Array:
        """Static permutation: node i's ``x`` lands on node ``dst[i]``.
        Non-destinations receive zeros."""
        raise NotImplementedError

    # -- split-phase point-to-point (Extended API transport) ------------- #
    def shift_nb(self, x: jax.Array, k: int = 1) -> Pending:
        """Non-blocking :meth:`shift`: initiate the transfer of ``x`` to
        node ``(me + k) % n`` and return a :class:`Pending` whose
        ``wait()`` is the sync point.  Compute traced between the two
        overlaps with the transfer."""
        return Pending(self.shift(x, k))

    def permute_nb(self, x: jax.Array, dst: Sequence[int]) -> Pending:
        """Non-blocking :meth:`permute` (split-phase, see :meth:`shift_nb`)."""
        return Pending(self.permute(x, dst))

    # -- collectives ----------------------------------------------------- #
    def all_to_all(self, x: jax.Array) -> jax.Array:
        """x: (n_nodes * m, ...) tiled exchange along dim 0.

        Default implementation: the fully overlapped split-phase exchange
        (all n-1 one-sided puts initiated before any completion is
        consumed, see ``collectives.exchange``).  Engines with a native
        all-to-all (XLA) override this."""
        # lazy import, mirroring _k(): collectives imports this module.
        from repro.core import collectives

        return collectives.exchange(self, x)

    def all_gather(self, x: jax.Array) -> jax.Array:
        """x: local (m, ...) -> (n_nodes * m, ...)."""
        raise NotImplementedError

    def reduce_scatter(self, x: jax.Array) -> jax.Array:
        """x: (n_nodes * m, ...) -> summed local (m, ...)."""
        raise NotImplementedError

    def all_reduce(self, x: jax.Array) -> jax.Array:
        raise NotImplementedError

    # -- control ---------------------------------------------------------- #
    def my_id(self) -> jax.Array:
        return lax.axis_index(self.axis)

    def barrier(self, token: jax.Array | None = None) -> jax.Array:
        """GASNet barrier.  In bulk-synchronous SPMD a barrier is implied by
        any collective; we keep the call for API fidelity and as an
        explicit synchronization edge (psum of a unit token)."""
        t = jnp.ones((), jnp.int32) if token is None else token
        return lax.psum(t, self.axis)


class XlaEngine(CommEngine):
    """Software GASNet node: XLA collectives as the transport."""

    name = "xla"

    def shift(self, x: jax.Array, k: int = 1) -> jax.Array:
        if k % self.n_nodes == 0:
            return x
        return lax.ppermute(x, self.axis, ring_pairs(self.n_nodes, k))

    def permute(self, x: jax.Array, dst: Sequence[int]) -> jax.Array:
        pairs = [(i, int(d)) for i, d in enumerate(dst) if d is not None]
        return lax.ppermute(x, self.axis, pairs)

    def all_to_all(self, x: jax.Array) -> jax.Array:
        return lax.all_to_all(x, self.axis, split_axis=0, concat_axis=0, tiled=True)

    def all_gather(self, x: jax.Array) -> jax.Array:
        return lax.all_gather(x, self.axis, tiled=True)

    def reduce_scatter(self, x: jax.Array) -> jax.Array:
        return lax.psum_scatter(x, self.axis, scatter_dimension=0, tiled=True)

    def all_reduce(self, x: jax.Array) -> jax.Array:
        return lax.psum(x, self.axis)


class GascoreEngine(CommEngine):
    """Hardware GASNet node: Pallas remote-DMA (GAScore) as the transport.

    ``interpret=True`` runs the kernels in TPU-interpret mode (CPU
    emulation of DMAs + semaphores); on real TPUs pass ``interpret=False``
    to compile Mosaic kernels over ICI.
    """

    name = "gascore"

    def __init__(self, axis: str, n_nodes: int, interpret: bool = True):
        super().__init__(axis, n_nodes)
        self.interpret = interpret

    # kernels are imported lazily to keep `repro.core` import-light and to
    # avoid a core <-> kernels import cycle.
    def _k(self):
        from repro.kernels import gascore

        return gascore

    def shift(self, x: jax.Array, k: int = 1) -> jax.Array:
        if k % self.n_nodes == 0:
            return x
        return self._k().ring_shift(
            x, k=k, axis=self.axis, n_nodes=self.n_nodes, interpret=self.interpret
        )

    def permute(self, x: jax.Array, dst: Sequence[int]) -> jax.Array:
        return self._k().perm_put(
            x, dst=tuple(int(d) for d in dst), axis=self.axis,
            n_nodes=self.n_nodes, interpret=self.interpret,
        )

    def all_gather(self, x: jax.Array) -> jax.Array:
        return self._k().ring_all_gather(
            x, axis=self.axis, n_nodes=self.n_nodes, interpret=self.interpret
        )

    def reduce_scatter(self, x: jax.Array) -> jax.Array:
        return self._k().ring_reduce_scatter(
            x, axis=self.axis, n_nodes=self.n_nodes, interpret=self.interpret
        )

    def all_reduce(self, x: jax.Array) -> jax.Array:
        # RS + AG when the leading dim tiles evenly; otherwise a shift-and-
        # accumulate ring (n-1 hops carrying the full tensor).
        lead = x.shape[0] if x.ndim else 0
        if x.ndim and lead % self.n_nodes == 0 and lead > 0:
            return self.all_gather(self.reduce_scatter(x))
        acc = x
        cur = x
        for _ in range(self.n_nodes - 1):
            cur = self.shift(cur, 1)
            acc = acc + cur
        return acc

    # all_to_all: inherited split-phase exchange over shift_nb (each of the
    # n-1 remote DMAs is in flight before any recv-semaphore wait).


def make_engine(
    backend: str, axis: str, n_nodes: int, interpret: bool = True
) -> CommEngine:
    if backend == "xla":
        return XlaEngine(axis, n_nodes)
    if backend == "gascore":
        return GascoreEngine(axis, n_nodes, interpret=interpret)
    raise ValueError(f"unknown engine backend {backend!r}")

"""Communication engines: the "software GASNet node" vs the "GAScore".

The paper's central demonstration is that software nodes (x86/ARM GASNet)
and hardware nodes (the GAScore remote-DMA engine) interoperate through one
API.  We reproduce that split exactly:

- :class:`XlaEngine`     — the *software node*: transport primitives are
  ``jax.lax`` collectives, i.e. XLA's own (reference) implementation.
- :class:`GascoreEngine` — the *hardware node*: the same primitives are the
  hand-written Pallas remote-DMA kernels from ``repro.kernels.gascore``
  (``pltpu.make_async_remote_copy`` + DMA semaphores over ICI).

Both expose the identical :class:`CommEngine` interface, so any code built
on top (the ring collectives, the AM router, user programs) migrates from
software to hardware by swapping the engine — the paper's software→hardware
migration story with zero API change.

All methods must be called inside a ``shard_map`` over ``self.axis``.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["CommEngine", "XlaEngine", "GascoreEngine", "make_engine"]


def ring_pairs(n: int, k: int) -> List[Tuple[int, int]]:
    """Permutation pairs for 'every node sends to (me + k) mod n'."""
    k = k % n
    return [(i, (i + k) % n) for i in range(n)]


class CommEngine:
    """Transport primitives of one GASNet node.

    ``axis`` is the mesh axis enumerating the nodes; ``n_nodes`` its size.
    """

    name = "abstract"

    def __init__(self, axis: str, n_nodes: int):
        self.axis = axis
        self.n_nodes = n_nodes

    # -- point-to-point (one-sided put transport) ----------------------- #
    def shift(self, x: jax.Array, k: int = 1) -> jax.Array:
        """Every node's ``x`` lands on node ``(me + k) % n``."""
        raise NotImplementedError

    def permute(self, x: jax.Array, dst: Sequence[int]) -> jax.Array:
        """Static permutation: node i's ``x`` lands on node ``dst[i]``.
        Non-destinations receive zeros."""
        raise NotImplementedError

    # -- collectives ----------------------------------------------------- #
    def all_to_all(self, x: jax.Array) -> jax.Array:
        """x: (n_nodes * m, ...) tiled exchange along dim 0."""
        raise NotImplementedError

    def all_gather(self, x: jax.Array) -> jax.Array:
        """x: local (m, ...) -> (n_nodes * m, ...)."""
        raise NotImplementedError

    def reduce_scatter(self, x: jax.Array) -> jax.Array:
        """x: (n_nodes * m, ...) -> summed local (m, ...)."""
        raise NotImplementedError

    def all_reduce(self, x: jax.Array) -> jax.Array:
        raise NotImplementedError

    # -- control ---------------------------------------------------------- #
    def my_id(self) -> jax.Array:
        return lax.axis_index(self.axis)

    def barrier(self, token: jax.Array | None = None) -> jax.Array:
        """GASNet barrier.  In bulk-synchronous SPMD a barrier is implied by
        any collective; we keep the call for API fidelity and as an
        explicit synchronization edge (psum of a unit token)."""
        t = jnp.ones((), jnp.int32) if token is None else token
        return lax.psum(t, self.axis)


class XlaEngine(CommEngine):
    """Software GASNet node: XLA collectives as the transport."""

    name = "xla"

    def shift(self, x: jax.Array, k: int = 1) -> jax.Array:
        if k % self.n_nodes == 0:
            return x
        return lax.ppermute(x, self.axis, ring_pairs(self.n_nodes, k))

    def permute(self, x: jax.Array, dst: Sequence[int]) -> jax.Array:
        pairs = [(i, int(d)) for i, d in enumerate(dst) if d is not None]
        return lax.ppermute(x, self.axis, pairs)

    def all_to_all(self, x: jax.Array) -> jax.Array:
        return lax.all_to_all(x, self.axis, split_axis=0, concat_axis=0, tiled=True)

    def all_gather(self, x: jax.Array) -> jax.Array:
        return lax.all_gather(x, self.axis, tiled=True)

    def reduce_scatter(self, x: jax.Array) -> jax.Array:
        return lax.psum_scatter(x, self.axis, scatter_dimension=0, tiled=True)

    def all_reduce(self, x: jax.Array) -> jax.Array:
        return lax.psum(x, self.axis)


class GascoreEngine(CommEngine):
    """Hardware GASNet node: Pallas remote-DMA (GAScore) as the transport.

    ``interpret=True`` runs the kernels in TPU-interpret mode (CPU
    emulation of DMAs + semaphores); on real TPUs pass ``interpret=False``
    to compile Mosaic kernels over ICI.
    """

    name = "gascore"

    def __init__(self, axis: str, n_nodes: int, interpret: bool = True):
        super().__init__(axis, n_nodes)
        self.interpret = interpret

    # kernels are imported lazily to keep `repro.core` import-light and to
    # avoid a core <-> kernels import cycle.
    def _k(self):
        from repro.kernels import gascore

        return gascore

    def shift(self, x: jax.Array, k: int = 1) -> jax.Array:
        if k % self.n_nodes == 0:
            return x
        return self._k().ring_shift(
            x, k=k, axis=self.axis, n_nodes=self.n_nodes, interpret=self.interpret
        )

    def permute(self, x: jax.Array, dst: Sequence[int]) -> jax.Array:
        return self._k().perm_put(
            x, dst=tuple(int(d) for d in dst), axis=self.axis,
            n_nodes=self.n_nodes, interpret=self.interpret,
        )

    def all_gather(self, x: jax.Array) -> jax.Array:
        return self._k().ring_all_gather(
            x, axis=self.axis, n_nodes=self.n_nodes, interpret=self.interpret
        )

    def reduce_scatter(self, x: jax.Array) -> jax.Array:
        return self._k().ring_reduce_scatter(
            x, axis=self.axis, n_nodes=self.n_nodes, interpret=self.interpret
        )

    def all_reduce(self, x: jax.Array) -> jax.Array:
        # RS + AG when the leading dim tiles evenly; otherwise a shift-and-
        # accumulate ring (n-1 hops carrying the full tensor).
        lead = x.shape[0] if x.ndim else 0
        if x.ndim and lead % self.n_nodes == 0 and lead > 0:
            return self.all_gather(self.reduce_scatter(x))
        acc = x
        cur = x
        for _ in range(self.n_nodes - 1):
            cur = self.shift(cur, 1)
            acc = acc + cur
        return acc

    def all_to_all(self, x: jax.Array) -> jax.Array:
        # Ring a2a: block destined to (me + k) travels k hops; n-1 rounds of
        # one-sided puts.  Block b of the output comes from source node b.
        n = self.n_nodes
        if x.shape[0] % n != 0:
            raise ValueError(f"all_to_all dim0 {x.shape[0]} not divisible by {n}")
        m = x.shape[0] // n
        blocks = x.reshape((n, m) + x.shape[1:])
        me = self.my_id()
        out = jnp.zeros_like(blocks)
        # my own block to myself
        own = lax.dynamic_slice_in_dim(blocks, me, 1, axis=0)
        out = lax.dynamic_update_slice_in_dim(out, own, me, axis=0)
        for k in range(1, n):
            # send the block addressed to node (me + k); it arrives at that
            # node as the block from source (me), i.e. slot (me_recv - k).
            send = lax.dynamic_slice_in_dim(
                blocks, lax.rem(me + k, n), 1, axis=0
            )
            recv = self.shift(send, k)
            src = lax.rem(me - k + n, n)
            out = lax.dynamic_update_slice_in_dim(out, recv, src, axis=0)
        return out.reshape(x.shape)


def make_engine(
    backend: str, axis: str, n_nodes: int, interpret: bool = True
) -> CommEngine:
    if backend == "xla":
        return XlaEngine(axis, n_nodes)
    if backend == "gascore":
        return GascoreEngine(axis, n_nodes, interpret=interpret)
    raise ValueError(f"unknown engine backend {backend!r}")

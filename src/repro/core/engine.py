"""Communication engines: the "software GASNet node" vs the "GAScore".

The paper's central demonstration is that software nodes (x86/ARM GASNet)
and hardware nodes (the GAScore remote-DMA engine) interoperate through one
API.  We reproduce that split exactly:

- :class:`XlaEngine`     — the *software node*: transport primitives are
  ``jax.lax`` collectives, i.e. XLA's own (reference) implementation.
- :class:`GascoreEngine` — the *hardware node*: the same primitives are the
  hand-written Pallas remote-DMA kernels from ``repro.kernels.gascore``
  (``pltpu.make_async_remote_copy`` + DMA semaphores over ICI).

Both expose the identical :class:`CommEngine` interface, so any code built
on top (the ring collectives, the AM router, user programs) migrates from
software to hardware by swapping the engine — the paper's software→hardware
migration story with zero API change.

All methods must be called inside a ``shard_map`` over ``self.axis``.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

__all__ = [
    "CommEngine",
    "Pending",
    "AlreadyWaitedError",
    "wait_all",
    "XlaEngine",
    "GascoreEngine",
    "EngineMap",
    "make_engine",
    "parse_backend_spec",
]


def ring_pairs(n: int, k: int) -> List[Tuple[int, int]]:
    """Permutation pairs for 'every node sends to (me + k) mod n'."""
    k = k % n
    return [(i, (i + k) % n) for i in range(n)]


class AlreadyWaitedError(RuntimeError):
    """A split-phase handle was synced twice.

    A transfer completes exactly once (``gasnet_wait_syncnb`` semantics);
    the message always names the offending op so batch waits
    (:func:`wait_all`, ``node.sync_all``) are debuggable.
    """


class Pending:
    """An in-flight transport operation (the engine half of split-phase).

    ``shift_nb``/``permute_nb`` return a ``Pending`` at *initiation*;
    ``wait()`` is the *sync point* that yields the delivered value.  Any
    compute traced between initiation and ``wait()`` has no data dependence
    on the transfer, so the scheduler is free to overlap it:

    - ``XlaEngine``: the ppermute lowers to an async ``collective-permute``
      start/done pair; XLA's latency-hiding scheduler slides independent
      compute between them (double-buffered scheduling).
    - ``GascoreEngine``: the Pallas kernel's DMA *recv-semaphore wait* is
      the sync point; the DMA itself progresses in the background exactly
      like the paper's GAScore engine draining its command FIFO.

    ``op`` labels the operation for error messages (``shift(k=1)``,
    ``permute``, ...), so a double-wait — including one buried inside a
    :func:`wait_all` batch — names the op instead of raising bare.
    """

    __slots__ = ("_value", "_waited", "op")

    def __init__(self, value: jax.Array, op: str = "transfer"):
        self._value = value
        self._waited = False
        self.op = op

    @property
    def waited(self) -> bool:
        return self._waited

    def wait(self) -> jax.Array:
        """Complete the transfer and return the delivered value (a
        transfer completes exactly once, like ``gasnet_wait_syncnb``)."""
        if self._waited:
            raise AlreadyWaitedError(
                f"Pending {self.op} transfer already waited on"
            )
        self._waited = True
        return self._value

    def ready(self) -> bool:
        """Poll (``gasnet_try_syncnb``).  The static SPMD schedule
        guarantees delivery of every initiated transfer, so this is
        constant-``True`` — kept for API fidelity."""
        return True


def wait_all(pendings: Sequence["Pending"]) -> List[jax.Array]:
    """Complete a batch of pendings in issue order (``gasnet_wait_syncnb_all``).

    Idempotence is checked up front: if any entry was already waited on,
    raise one clear error naming the op and its position *before* consuming
    any of the others, so the batch is not left half-drained.
    """
    stale = [
        (i, p.op) for i, p in enumerate(pendings) if p.waited
    ]
    if stale:
        desc = ", ".join(f"#{i} ({op})" for i, op in stale)
        raise AlreadyWaitedError(
            f"wait_all: pending transfer(s) already waited on: {desc}"
        )
    return [p.wait() for p in pendings]


class CommEngine:
    """Transport primitives of one GASNet node.

    ``axis`` is the mesh axis enumerating the nodes; ``n_nodes`` its size.

    ``can_permute_partial`` advertises whether :meth:`permute` accepts
    ``None`` destinations (nodes that send nowhere).  The XLA transport
    can (a chain collective-permute); the GAScore transport cannot — every
    recv semaphore must be signalled exactly once, so only bijections are
    legal.  The scheduler consults this instead of engine ``isinstance``
    checks when choosing tree vs ring algorithms and chain vs ring
    pipeline boundaries.
    """

    name = "abstract"
    can_permute_partial = False

    def __init__(self, axis: str, n_nodes: int):
        self.axis = axis
        self.n_nodes = n_nodes

    def backend_of(self, rank: int) -> str:
        """Backend name serving ``rank`` (uniform for homogeneous engines;
        :class:`EngineMap` overrides per rank)."""
        return self.name

    # -- point-to-point (one-sided put transport) ----------------------- #
    def shift(self, x: jax.Array, k: int = 1) -> jax.Array:
        """Every node's ``x`` lands on node ``(me + k) % n``."""
        raise NotImplementedError

    def permute(self, x: jax.Array, dst: Sequence[int]) -> jax.Array:
        """Static permutation: node i's ``x`` lands on node ``dst[i]``.
        Non-destinations receive zeros."""
        raise NotImplementedError

    # -- split-phase point-to-point (Extended API transport) ------------- #
    def shift_nb(self, x: jax.Array, k: int = 1) -> Pending:
        """Non-blocking :meth:`shift`: initiate the transfer of ``x`` to
        node ``(me + k) % n`` and return a :class:`Pending` whose
        ``wait()`` is the sync point.  Compute traced between the two
        overlaps with the transfer."""
        return Pending(self.shift(x, k), op=f"shift(k={k})")

    def permute_nb(self, x: jax.Array, dst: Sequence[int]) -> Pending:
        """Non-blocking :meth:`permute` (split-phase, see :meth:`shift_nb`)."""
        return Pending(self.permute(x, dst), op="permute")

    # -- vectored split-phase transport (engine-level multi-get/multi-put) #
    def _pack_nbv(self, xs: Sequence[jax.Array]) -> jax.Array:
        flats = [x.reshape(-1) for x in xs]
        dtypes = {f.dtype for f in flats}
        if len(dtypes) > 1:
            raise TypeError(
                f"vectored transfer payloads must share one dtype, got "
                f"{sorted(str(d) for d in dtypes)}"
            )
        return flats[0] if len(flats) == 1 else jnp.concatenate(flats)

    def _unpack_nbv(
        self, moved: jax.Array, xs: Sequence[jax.Array], op: str
    ) -> List[Pending]:
        out: List[Pending] = []
        offset = 0
        for x in xs:
            piece = moved[offset : offset + x.size].reshape(x.shape)
            out.append(Pending(piece, op=op))
            offset += x.size
        return out

    def shift_nbv(self, xs: Sequence[jax.Array], k: int = 1) -> List[Pending]:
        """Vectored non-blocking shift: ONE transport initiation (a single
        command word / DMA descriptor) carries every payload in ``xs`` to
        node ``(me + k) % n``; returns one :class:`Pending` per payload.

        This is the engine half of a multi-get/multi-put: m transfers cost
        one initiation α instead of m — the GAScore draining a whole FIFO
        of commands as one wire message.  Payloads must share a dtype (the
        carrier); sizes are static so the receive split is free.
        """
        xs = list(xs)
        if not xs:
            return []
        moved = self.shift(self._pack_nbv(xs), k)
        return self._unpack_nbv(moved, xs, op=f"shiftv(k={k})")

    def permute_nbv(
        self, xs: Sequence[jax.Array], dst: Sequence[int]
    ) -> List[Pending]:
        """Vectored non-blocking :meth:`permute` (see :meth:`shift_nbv`)."""
        xs = list(xs)
        if not xs:
            return []
        moved = self.permute(self._pack_nbv(xs), dst)
        return self._unpack_nbv(moved, xs, op="permutev")

    # -- vectored put transport (payloads + command block in one message) - #
    def _nbv_put(
        self, mover, xs: Sequence[jax.Array], meta: jax.Array
    ) -> Tuple[List[Pending], Pending]:
        xs = list(xs)
        if not xs:
            raise ValueError("vectored put needs at least one payload")
        meta = jnp.asarray(meta, jnp.int32).reshape(-1)
        if jnp.dtype(xs[0].dtype).itemsize == 4:
            # the int32 command block bitcasts into the payload carrier, so
            # payloads AND their target offsets ride ONE transport
            # initiation — the GAScore draining a whole command FIFO as a
            # single wire message.
            mcarrier = lax.bitcast_convert_type(meta, xs[0].dtype)
            pendings = mover(xs + [mcarrier])
            return pendings[:-1], pendings[-1]
        # non-4-byte carriers: the command block rides its own initiation
        # (still 2 α for m puts instead of 3m).
        payload = mover(xs)
        (mp,) = mover([meta])
        return payload, mp

    def shift_nbv_put(
        self, xs: Sequence[jax.Array], meta: jax.Array, k: int = 1
    ) -> Tuple[List[Pending], Pending]:
        """Vectored put transport to node ``(me + k) % n``: the write-side
        mirror of :meth:`shift_nbv`.  ``xs`` are the m payload vectors and
        ``meta`` the int32 *command block* (target offsets + arrival
        flags) — shipped together in one initiation when the payload dtype
        is 4 bytes wide (the command words bitcast into the carrier).
        Returns ``(payload_pendings, meta_pending)``; the meta pending
        completes to the carrier dtype and the caller bitcasts it back.
        """
        return self._nbv_put(lambda v: self.shift_nbv(v, k), xs, meta)

    def permute_nbv_put(
        self, xs: Sequence[jax.Array], meta: jax.Array, dst: Sequence[int]
    ) -> Tuple[List[Pending], Pending]:
        """Vectored put transport along a permutation (see
        :meth:`shift_nbv_put`)."""
        return self._nbv_put(lambda v: self.permute_nbv(v, dst), xs, meta)

    # -- collectives ----------------------------------------------------- #
    def all_to_all(self, x: jax.Array) -> jax.Array:
        """x: (n_nodes * m, ...) tiled exchange along dim 0.

        Default implementation: the fully overlapped split-phase exchange
        (all n-1 one-sided puts initiated before any completion is
        consumed, see ``collectives.exchange``).  Engines with a native
        all-to-all (XLA) override this."""
        # lazy import, mirroring _k(): collectives imports this module.
        from repro.core import collectives

        return collectives.exchange(self, x)

    def all_gather(self, x: jax.Array) -> jax.Array:
        """x: local (m, ...) -> (n_nodes * m, ...)."""
        raise NotImplementedError

    def reduce_scatter(self, x: jax.Array) -> jax.Array:
        """x: (n_nodes * m, ...) -> summed local (m, ...)."""
        raise NotImplementedError

    def all_reduce(self, x: jax.Array) -> jax.Array:
        raise NotImplementedError

    # -- control ---------------------------------------------------------- #
    def my_id(self) -> jax.Array:
        return lax.axis_index(self.axis)

    def barrier(self, token: jax.Array | None = None) -> jax.Array:
        """GASNet barrier.  In bulk-synchronous SPMD a barrier is implied by
        any collective; we keep the call for API fidelity and as an
        explicit synchronization edge (psum of a unit token)."""
        t = jnp.ones((), jnp.int32) if token is None else token
        return lax.psum(t, self.axis)


class XlaEngine(CommEngine):
    """Software GASNet node: XLA collectives as the transport."""

    name = "xla"
    can_permute_partial = True

    def shift(self, x: jax.Array, k: int = 1) -> jax.Array:
        if k % self.n_nodes == 0:
            return x
        return lax.ppermute(x, self.axis, ring_pairs(self.n_nodes, k))

    def permute(self, x: jax.Array, dst: Sequence[int]) -> jax.Array:
        pairs = [(i, int(d)) for i, d in enumerate(dst) if d is not None]
        return lax.ppermute(x, self.axis, pairs)

    def all_to_all(self, x: jax.Array) -> jax.Array:
        return lax.all_to_all(x, self.axis, split_axis=0, concat_axis=0, tiled=True)

    def all_gather(self, x: jax.Array) -> jax.Array:
        return lax.all_gather(x, self.axis, tiled=True)

    def reduce_scatter(self, x: jax.Array) -> jax.Array:
        return lax.psum_scatter(x, self.axis, scatter_dimension=0, tiled=True)

    def all_reduce(self, x: jax.Array) -> jax.Array:
        return lax.psum(x, self.axis)


class GascoreEngine(CommEngine):
    """Hardware GASNet node: Pallas remote-DMA (GAScore) as the transport.

    ``interpret=True`` runs the kernels in TPU-interpret mode (CPU
    emulation of DMAs + semaphores); on real TPUs pass ``interpret=False``
    to compile Mosaic kernels over ICI.
    """

    name = "gascore"

    def __init__(self, axis: str, n_nodes: int, interpret: bool = True):
        super().__init__(axis, n_nodes)
        self.interpret = interpret

    # kernels are imported lazily to keep `repro.core` import-light and to
    # avoid a core <-> kernels import cycle.
    def _k(self):
        from repro.kernels import gascore

        return gascore

    def shift(self, x: jax.Array, k: int = 1) -> jax.Array:
        if k % self.n_nodes == 0:
            return x
        return self._k().ring_shift(
            x, k=k, axis=self.axis, n_nodes=self.n_nodes, interpret=self.interpret
        )

    def permute(self, x: jax.Array, dst: Sequence[int]) -> jax.Array:
        return self._k().perm_put(
            x, dst=tuple(int(d) for d in dst), axis=self.axis,
            n_nodes=self.n_nodes, interpret=self.interpret,
        )

    def all_gather(self, x: jax.Array) -> jax.Array:
        return self._k().ring_all_gather(
            x, axis=self.axis, n_nodes=self.n_nodes, interpret=self.interpret
        )

    def reduce_scatter(self, x: jax.Array) -> jax.Array:
        return self._k().ring_reduce_scatter(
            x, axis=self.axis, n_nodes=self.n_nodes, interpret=self.interpret
        )

    def all_reduce(self, x: jax.Array) -> jax.Array:
        # RS + AG when the leading dim tiles evenly; otherwise a shift-and-
        # accumulate ring (n-1 hops carrying the full tensor).
        lead = x.shape[0] if x.ndim else 0
        if x.ndim and lead % self.n_nodes == 0 and lead > 0:
            return self.all_gather(self.reduce_scatter(x))
        acc = x
        cur = x
        for _ in range(self.n_nodes - 1):
            cur = self.shift(cur, 1)
            acc = acc + cur
        return acc

    # all_to_all: inherited split-phase exchange over shift_nb (each of the
    # n-1 remote DMAs is in flight before any recv-semaphore wait).


class EngineMap(CommEngine):
    """Heterogeneous node map: each rank is backed by its own engine.

    The paper's cluster mixes software nodes (x86/ARM GASNet) and hardware
    nodes (GAScore) in one job; here ``backends[r]`` names the engine
    serving rank ``r`` (``"xla"`` = software node, ``"gascore"`` = hardware
    node).  Point-to-point transport is carried *per edge* by the sender's
    engine: every member engine moves the payload (all ranks participate in
    both transports — the SPMD analogue of a packet crossing engine
    domains), and each receiver keeps the copy delivered by its sender's
    backend.  Collectives are the ring/put algorithms from
    ``repro.core.collectives`` running over that mixed edge transport, so
    mixed jobs run unmodified — and match both homogeneous engines bit for
    bit (parity is asserted in the testing suites).

    A partial permute (``None`` destinations) is only legal when every
    member engine supports it.
    """

    name = "map"

    def __init__(
        self,
        axis: str,
        backends: Sequence[str],
        interpret: bool = True,
        engines: dict | None = None,
    ):
        super().__init__(axis, len(backends))
        self.backends = tuple(backends)
        uniq: List[str] = []
        for b in self.backends:
            if b not in uniq:
                uniq.append(b)
        if engines is None:
            engines = {
                b: _make_single_engine(b, axis, self.n_nodes, interpret)
                for b in uniq
            }
        self._engines = engines
        self._uniq = tuple(uniq)
        # bool mask per backend: which ranks it serves (host constants)
        self._masks = {
            b: jnp.asarray([be == b for be in self.backends])
            for b in self._uniq
        }
        self.can_permute_partial = all(
            self._engines[b].can_permute_partial for b in self._uniq
        )

    def backend_of(self, rank: int) -> str:
        return self.backends[rank % self.n_nodes]

    def member(self, backend: str) -> CommEngine:
        return self._engines[backend]

    @property
    def is_heterogeneous(self) -> bool:
        return len(self._uniq) > 1

    # -- per-edge transport selection ----------------------------------- #
    def _select_by_src(self, outs: dict, src: jax.Array) -> jax.Array:
        """Each receiver keeps the copy carried by its *sender's* engine."""
        acc = outs[self._uniq[0]]
        for b in self._uniq[1:]:
            acc = jnp.where(self._masks[b][src], outs[b], acc)
        return acc

    def shift(self, x: jax.Array, k: int = 1) -> jax.Array:
        if k % self.n_nodes == 0:
            return x
        if not self.is_heterogeneous:
            return self._engines[self._uniq[0]].shift(x, k)
        outs = {b: self._engines[b].shift(x, k) for b in self._uniq}
        src = lax.rem(self.my_id() - k + 2 * self.n_nodes, self.n_nodes)
        return self._select_by_src(outs, src)

    def permute(self, x: jax.Array, dst: Sequence[int]) -> jax.Array:
        if not self.is_heterogeneous:
            return self._engines[self._uniq[0]].permute(x, dst)
        has_none = any(d is None for d in dst)
        if has_none and not self.can_permute_partial:
            raise ValueError(
                "partial permute (None destinations) unsupported by "
                f"engine map {self.backends}"
            )
        outs = {b: self._engines[b].permute(x, dst) for b in self._uniq}
        # receiver j's sender is inv[j]; non-destinations receive zeros
        # from every member engine, so any branch is correct for them.
        inv = [0] * self.n_nodes
        for s, d in enumerate(dst):
            if d is not None:
                inv[int(d)] = s
        src = jnp.asarray(inv, jnp.int32)[self.my_id()]
        return self._select_by_src(outs, src)

    # -- collectives: the put algorithms over the mixed edge transport --- #
    def all_gather(self, x: jax.Array) -> jax.Array:
        from repro.core import collectives

        return collectives.ring_all_gather(self, x)

    def reduce_scatter(self, x: jax.Array) -> jax.Array:
        from repro.core import collectives

        return collectives.ring_reduce_scatter(self, x)

    def all_reduce(self, x: jax.Array) -> jax.Array:
        from repro.core import collectives

        return collectives.ring_all_reduce(self, x)

    # all_to_all: inherited split-phase exchange over shift_nb.


def _make_single_engine(
    backend: str, axis: str, n_nodes: int, interpret: bool
) -> CommEngine:
    if backend == "xla":
        return XlaEngine(axis, n_nodes)
    if backend == "gascore":
        return GascoreEngine(axis, n_nodes, interpret=interpret)
    raise ValueError(f"unknown engine backend {backend!r}")


def parse_backend_spec(backend, n_nodes: int) -> Tuple[str, ...]:
    """Normalize a backend spec to one name per rank.

    Accepts a single name (``"xla"``), a comma-separated per-rank pattern
    (``"xla,gascore"`` — tiled around the ring when shorter than
    ``n_nodes``), or a sequence of names.
    """
    if isinstance(backend, str):
        names = [b.strip() for b in backend.split(",") if b.strip()]
    else:
        names = [str(b) for b in backend]
    if not names:
        raise ValueError("empty engine backend spec")
    if n_nodes % len(names):
        raise ValueError(
            f"backend pattern {names} (len {len(names)}) does not tile "
            f"{n_nodes} nodes"
        )
    return tuple(names[i % len(names)] for i in range(n_nodes))


def make_engine(
    backend, axis: str, n_nodes: int, interpret: bool = True
) -> CommEngine:
    """Build the engine (or heterogeneous :class:`EngineMap`) for a mesh axis.

    ``backend`` is a single engine name, a comma-separated per-rank pattern,
    or a sequence of per-rank names — ``make_engine("xla,gascore", ...)``
    gives alternating software/hardware nodes, the paper's mixed cluster.
    """
    ranks = parse_backend_spec(backend, n_nodes)
    uniq = set(ranks)
    if len(uniq) == 1:
        return _make_single_engine(ranks[0], axis, n_nodes, interpret)
    return EngineMap(axis, ranks, interpret=interpret)

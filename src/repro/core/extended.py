"""GASNet Extended API: split-phase non-blocking one-sided operations.

The Core API's ``put``/``get`` are *blocking*: the call returns the fully
transferred value, so every subsequent statement is ordered after the wire.
Real GASNet applications (and the paper's GAScore clients) instead use the
Extended API — explicit-handle non-blocking ops — so the runtime can overlap
communication with independent compute.  This module reproduces that layer:

======================  ====================================================
GASNet Extended          here
======================  ====================================================
gasnet_put_nb            ``node.put_nb(seg, data, to=..., index=...)``
gasnet_get_nb            ``node.get_nb(seg, frm=..., index=..., size=...)``
gasnet_handle_t          :class:`PutHandle` / :class:`GetHandle`
gasnet_wait_syncnb       ``node.sync(handle)``
gasnet_try_syncnb        ``node.try_sync(handle)``
gasnet_wait_syncnb_all   ``node.sync_all()``
======================  ====================================================

Split-phase semantics under XLA/Pallas: *initiation* (``put_nb``/``get_nb``)
traces the transport — the ``XlaEngine``'s async ``collective-permute``
start, or the ``GascoreEngine``'s remote DMA whose recv-semaphore wait is
the completion event.  The *sync* (``node.sync``) traces the landing of the
data into its destination (segment update for puts, reply value for gets).
Any compute traced between the two has no data dependence on the transfer
and therefore overlaps with it — the comm/compute overlap the paper's
hardware nodes exploit to hide OCCC latency.

Example (overlap a matmul with a neighbor put)::

    def program(node, seg, w):
        h = node.put_nb(seg, node.local(seg)[:16], to=gasnet.Shift(1))
        acc = w @ w.T          # independent compute: overlaps the wire
        seg = node.sync(h)     # split-phase completion
        return seg, acc

Handles are trace-time Python objects (like the engines themselves); they
never cross a ``jit`` boundary.  Completion order for ``sync_all`` is FIFO
(issue order), matching the deterministic static schedule.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.engine import AlreadyWaitedError

__all__ = [
    "Handle",
    "PutHandle",
    "PutvHandle",
    "GetHandle",
    "GetvHandle",
    "AckHandle",
    "AlreadyWaitedError",
]


class Handle:
    """Base explicit handle (``gasnet_handle_t``) of one non-blocking op.

    Subclasses carry the in-flight values captured at initiation; the
    owning :class:`~repro.core.gasnet.Node` completes them via
    ``node.sync(handle)``.
    """

    op: str = "nop"
    # open trace span riding the handle from initiation to sync (set by
    # the Node when tracing is enabled; None otherwise — the tracer is
    # host-side only, so the span never crosses a jit boundary either)
    span = None

    def __init__(self) -> None:
        self.done = False

    def _complete(self) -> Any:  # pragma: no cover - abstract
        raise NotImplementedError

    def complete(self) -> Any:
        """Finish the op (idempotent error: a handle syncs exactly once).

        Raises :class:`AlreadyWaitedError` naming the op, so batch waits
        (``node.sync_all``) over a list containing an already-synced
        handle fail with a debuggable message."""
        if self.done:
            raise AlreadyWaitedError(f"{self.op} handle already synced")
        self.done = True
        return self._complete()


class PutHandle(Handle):
    """In-flight ``put_nb``: the payload, target offset and arrival flag
    have been shipped (transport initiated); :meth:`complete` lands them in
    the receiver's partition of the segment and returns the updated
    segment.

    ``key`` identifies the segment object the put was issued against, so
    the owning Node can chain several outstanding puts on the same segment
    (each sync applies onto the latest synced version, not the stale
    snapshot taken at initiation — GASNet permits multiple outstanding
    puts)."""

    op = "put"

    def __init__(
        self,
        local: jax.Array,
        moved: jax.Array,
        midx: jax.Array,
        received: jax.Array,
        restore,
        key: int = 0,
    ):
        super().__init__()
        self._local = local
        self._moved = moved
        self._midx = midx
        self._received = received
        self._restore = restore
        self.key = key

    def apply(self, local: jax.Array) -> jax.Array:
        """Land the in-flight data into ``local`` (a segment partition of
        the same shape as the one snapshotted at initiation)."""
        flat = local.reshape(-1)
        cur = lax.dynamic_slice(flat, (self._midx,), (self._moved.shape[0],))
        new = lax.dynamic_update_slice(
            flat,
            jnp.where(self._received, self._moved, cur),
            (self._midx,),
        )
        return new.reshape(local.shape)

    def restore(self, local: jax.Array) -> jax.Array:
        return self._restore(local)

    def _complete(self) -> jax.Array:
        return self._restore(self.apply(self._local))


class PutvHandle(PutHandle):
    """In-flight vectored ``put_nbv`` (engine multi-put): m payloads plus
    the int32 *command block* (their m target offsets + m arrival flags)
    travelled as one vectored transport — the write half of the GAScore
    draining a command FIFO in a single wire message.  :meth:`complete`
    waits the payload/meta :class:`~repro.core.engine.Pending`\\ s and
    lands every flagged payload at its offset in the receiver's partition.

    Per-payload flags make the put SPMD-conditional at page granularity: a
    sender clearing flag j ships payload j anyway (the static schedule)
    but the receiver keeps its current bytes at offset j.  Chains with
    other outstanding puts on the same segment via the inherited ``key``
    (see ``Node.sync``)."""

    op = "putv"

    def __init__(self, local, payloads, meta, restore, key: int = 0):
        Handle.__init__(self)
        self._local = local
        self._payloads = payloads  # list[Pending | jax.Array]
        self._meta = meta  # Pending | jax.Array; int32 or bitcast carrier
        self._restore = restore
        self.key = key
        self._landed = None

    def _land(self):
        if self._landed is None:
            vals = [
                p.wait() if hasattr(p, "wait") else p for p in self._payloads
            ]
            m = (
                self._meta.wait()
                if hasattr(self._meta, "wait")
                else self._meta
            )
            if m.dtype != jnp.int32:
                m = lax.bitcast_convert_type(m, jnp.int32)
            n = len(vals)
            self._landed = (vals, m[:n], m[n:] != 0)
        return self._landed

    def apply(self, local: jax.Array) -> jax.Array:
        vals, offs, flags = self._land()
        flat = local.reshape(-1)
        for j, v in enumerate(vals):
            cur = lax.dynamic_slice(flat, (offs[j],), (v.shape[0],))
            flat = lax.dynamic_update_slice(
                flat, jnp.where(flags[j], v, cur), (offs[j],)
            )
        return flat.reshape(local.shape)


class GetHandle(Handle):
    """In-flight ``get_nb``: the request (offset) has travelled to the
    source and the reply is on the wire; :meth:`complete` returns the
    fetched data."""

    op = "get"

    def __init__(self, reply: jax.Array):
        super().__init__()
        self._reply = reply

    def _complete(self) -> jax.Array:
        return self._reply


class GetvHandle(Handle):
    """In-flight vectored ``get_nbv`` (engine multi-get): the request leg
    shipped every offset in one vectored transport and the reply leg —
    all fetched slices packed into one wire message — is in flight;
    :meth:`complete` waits the reply :class:`~repro.core.engine.Pending`
    and returns the ``(m, size)`` stack of fetched vectors.

    ``pred`` gates the fetch SPMD-conditionally: every rank traces both
    legs (the static schedule), but a rank that initiated with
    ``pred=False`` completes to zeros — the vector analogue of the
    cleared arrival flag of a pred-gated put."""

    op = "getv"

    def __init__(self, reply, m: int, size: int, pred: jax.Array):
        super().__init__()
        self._reply = reply  # Pending | jax.Array
        self._m = m
        self._size = size
        self._pred = pred

    def _complete(self) -> jax.Array:
        data = (
            self._reply.wait()
            if hasattr(self._reply, "wait")
            else self._reply
        )
        out = data.reshape(self._m, self._size)
        return jnp.where(self._pred, out, jnp.zeros_like(out))


class AckHandle(Handle):
    """A pending remote acknowledgment (the handle half of an AM
    request/reply round trip — ``Node.am_call``).

    At initiation the request is only *queued*; the acknowledgment value
    does not exist until ``node.am_flush`` has routed the request, run the
    remote handler, and routed its ``AMReply`` back.  The flush resolves
    the handle by applying ``fetch`` to the post-reply handler state;
    ``node.sync(handle)`` then returns that value.  Syncing before the
    flush is an ordering error and raises."""

    op = "ack"

    def __init__(self, fetch: Callable[[Any], Any]):
        super().__init__()
        self._fetch = fetch
        self._value: Any = None
        self._resolved = False

    @property
    def resolved(self) -> bool:
        return self._resolved

    def resolve(self, state: Any) -> None:
        self._value = self._fetch(state)
        self._resolved = True

    def _complete(self) -> Any:
        if not self._resolved:
            raise RuntimeError(
                "ack handle synced before am_flush delivered the reply"
            )
        return self._value

"""GASNet-style API surface: contexts, nodes, one-sided put/get, AMs.

This is the unified API the paper argues for: the *same* calls are made by
"software nodes" and "hardware nodes"; only the engine differs.  Mapping to
GASNet Core and Extended:

======================  ===================================================
GASNet Core              here
======================  ===================================================
gasnet_init/attach       ``Context(mesh, node_axis, backend)`` + AddressSpace
gasnet_mynode            ``node.my_id``
gasnet_nodes             ``node.n_nodes``
gasnet_put               ``node.put(seg, data, to=..., index=...)``
gasnet_get               ``node.get(seg, frm=..., index=..., size=...)``
gasnet_AMRequestShort    ``node.am_short(dest, handler, args)``
gasnet_AMRequestMedium   ``node.am_medium(dest, handler, payload, args)``
gasnet_AMRequestLong     ``node.am_long(dest, handler, payload, dst_index)``
gasnet_AMReplyShort      handler returns ``am.reply_short(...)`` (see below)
gasnet_AMReplyMedium     handler returns ``am.reply_medium(...)``
(request expecting ack)  ``node.am_call(dest, handler, ..., ack=fetch)``
(poll + handler run)     ``node.am_flush(state)`` — two hops when the
                         table has ``replies=True`` handlers
gasnet_barrier           ``node.barrier()``
======================  ===================================================

======================  ===================================================
GASNet Extended          here (split-phase, see ``repro.core.extended``)
======================  ===================================================
gasnet_put_nb            ``node.put_nb(seg, data, to=..., index=...)``
gasnet_get_nb            ``node.get_nb(seg, frm=..., index=..., size=...)``
(vector get, one α)      ``node.get_nbv(seg, frm=..., indices=[...],
                         size=...)`` — m fetches per request/reply pair
(vector put, one α)      ``node.put_nbv(seg, datas, to=...,
                         indices=[...])`` — m writes + their target
                         offsets in one command block
gasnet_wait_syncnb       ``node.sync(handle)``
gasnet_try_syncnb        ``node.try_sync(handle)``
gasnet_wait_syncnb_all   ``node.sync_all()``
======================  ===================================================

One-sided semantics under SPMD: every node executes the same program, so a
"one-sided put" is a *pattern* of puts — :class:`Shift` (every node targets
``me+k``) or :class:`Perm` (arbitrary static permutation).  Data-dependent
destinations go through the Active Message router (capacity-bounded
all-to-all), the static-schedule analogue of the paper's packet network.

Example::

    ctx = gasnet.Context(mesh, node_axis="node", backend="gascore")
    aspace = ctx.address_space()
    aspace.register("buf", (128,), jnp.float32)
    seg = aspace.alloc("buf")

    def program(node, seg):
        seg = node.put(seg, node.local(seg)[:16], to=gasnet.Shift(1), index=0)
        node.barrier()
        return seg

    seg = ctx.spmd(program, seg)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import am as am_lib
from repro.core import extended
from repro.core.addrspace import AddressSpace
from repro.core.engine import CommEngine, make_engine
from repro.compat import shard_map
from repro.obs import trace as obs_trace

__all__ = ["Shift", "Perm", "Context", "Node"]


@dataclasses.dataclass(frozen=True)
class Shift:
    """Every node targets node ``(me + k) % n``."""

    k: int = 1


@dataclasses.dataclass(frozen=True)
class Perm:
    """Node ``i`` targets node ``dst[i]`` (a static permutation)."""

    dst: Tuple[int, ...]


Pattern = Any  # Shift | Perm


def _inverse(pattern: Pattern, n: int) -> Pattern:
    if isinstance(pattern, Shift):
        return Shift(-pattern.k)
    inv = [0] * n
    for s, d in enumerate(pattern.dst):
        inv[int(d)] = s
    return Perm(tuple(inv))


class Node:
    """Handle passed to SPMD node programs; wraps one CommEngine.

    All methods are trace-time; segments appear as their local
    ``(1, *local_shape)`` partitions inside ``shard_map``.
    """

    def __init__(self, engine: CommEngine, handlers: am_lib.HandlerTable,
                 am_capacity: int, am_payload_width: int,
                 am_per_peer_capacity: int):
        self.engine = engine
        self.handlers = handlers
        self._am_capacity = am_capacity
        self._am_payload_width = am_payload_width
        self._am_per_peer = am_per_peer_capacity
        self._batch: Optional[am_lib.AMBatch] = None
        self._outstanding: list[extended.Handle] = []
        self._pending_acks: list[extended.AckHandle] = []
        # id(seg) -> latest synced local partition, so several outstanding
        # puts against the same segment object chain instead of each
        # applying to the stale snapshot taken at initiation.  Pinning the
        # seg objects keeps the ids stable for the node's lifetime.
        self._seg_latest: dict[int, jax.Array] = {}
        self._seg_pins: list[jax.Array] = []
        self.dropped = jnp.zeros((), jnp.int32)

    # ----------------------------------------------------------------- #
    # identity & sync
    # ----------------------------------------------------------------- #
    @property
    def my_id(self) -> jax.Array:
        return self.engine.my_id()

    @property
    def n_nodes(self) -> int:
        return self.engine.n_nodes

    def barrier(self) -> None:
        self.engine.barrier()

    # ----------------------------------------------------------------- #
    # segments: local views
    # ----------------------------------------------------------------- #
    @staticmethod
    def local(seg: jax.Array) -> jax.Array:
        """Local partition of a segment inside shard_map: drop the leading
        per-node axis of size 1."""
        return seg[0]

    @staticmethod
    def _restore(seg_like: jax.Array, local: jax.Array) -> jax.Array:
        del seg_like
        return local[None]

    # ----------------------------------------------------------------- #
    # one-sided remote memory access
    # ----------------------------------------------------------------- #
    def _move(self, x: jax.Array, to: Pattern) -> jax.Array:
        if isinstance(to, Shift):
            return self.engine.shift(x, to.k)
        if isinstance(to, Perm):
            return self.engine.permute(x, to.dst)
        raise TypeError(f"bad pattern {to!r}")

    def _move_nbv(self, xs: Sequence[jax.Array], to: Pattern) -> list:
        """Vectored split-phase move: one transport initiation for all of
        ``xs`` (see ``CommEngine.shift_nbv``); returns the Pendings."""
        if isinstance(to, Shift):
            return self.engine.shift_nbv(xs, to.k)
        if isinstance(to, Perm):
            return self.engine.permute_nbv(xs, to.dst)
        raise TypeError(f"bad pattern {to!r}")

    def put(
        self,
        seg: jax.Array,
        data: jax.Array,
        *,
        to: Pattern = Shift(1),
        index: jax.Array | int = 0,
        pred: jax.Array | bool | None = None,
    ) -> jax.Array:
        """One-sided remote write: ``data`` lands in the target node's
        partition of ``seg`` at flat offset ``index`` (sender-specified,
        shipped with the payload, exactly like a GAScore AMLong header).

        Returns the updated segment.  ``data`` is flattened; the write is
        contiguous in the flattened local partition.

        Blocking = ``put_nb`` + immediate ``sync`` (GASNet defines
        ``gasnet_put`` exactly this way).
        """
        return self.sync(self.put_nb(seg, data, to=to, index=index, pred=pred))

    def get(
        self,
        seg: jax.Array,
        *,
        frm: Pattern = Shift(1),
        index: jax.Array | int = 0,
        size: int = 1,
    ) -> jax.Array:
        """One-sided remote read of ``size`` flat elements at offset
        ``index`` in node ``pattern(me)``'s partition.

        GASNet gets are request/reply; so is this: the offset travels to the
        source (inverse pattern), the source slices, the reply travels back.
        Blocking = ``get_nb`` + immediate ``sync``.
        """
        return self.sync(self.get_nb(seg, frm=frm, index=index, size=size))

    # ----------------------------------------------------------------- #
    # Extended API: split-phase non-blocking RMA (see repro.core.extended)
    # ----------------------------------------------------------------- #
    def put_nb(
        self,
        seg: jax.Array,
        data: jax.Array,
        *,
        to: Pattern = Shift(1),
        index: jax.Array | int = 0,
        pred: jax.Array | bool | None = None,
    ) -> extended.PutHandle:
        """Initiate a non-blocking one-sided put (``gasnet_put_nb``).

        The payload, target offset and arrival flag are shipped at the call
        (transport initiation); the returned handle lands them in the
        segment when synced: ``seg = node.sync(h)``.  Compute issued
        between the two overlaps with the transfer.

        ``pred`` gates the write (SPMD conditional put): every rank traces
        the same transfer, but a rank passing ``pred=False`` ships a
        cleared arrival flag, so the receiver keeps its current contents —
        the static-schedule analogue of simply not issuing the put.
        """
        local = self.local(seg)
        payload = data.reshape(-1).astype(local.dtype)
        idx = jnp.asarray(index, jnp.int32)
        flag = (
            jnp.ones((), bool) if pred is None else jnp.asarray(pred, bool)
        )
        moved = self._move(payload, to)
        midx = self._move(idx, to)
        received = self._move(flag, to)
        self._seg_pins.append(seg)
        h = extended.PutHandle(
            local, moved, midx, received,
            functools.partial(self._restore, seg),
            key=id(seg),
        )
        tr = obs_trace.active()
        if tr.enabled:
            h.span = tr.begin_async(
                "put_nb", cat="rma",
                bytes=int(payload.size) * payload.dtype.itemsize,
                engine=self.engine.name, seg=id(seg),
                pred=pred is not None,
            )
        self._outstanding.append(h)
        return h

    def get_nb(
        self,
        seg: jax.Array,
        *,
        frm: Pattern = Shift(1),
        index: jax.Array | int = 0,
        size: int = 1,
    ) -> extended.GetHandle:
        """Initiate a non-blocking one-sided get (``gasnet_get_nb``).

        Request and reply legs are both initiated here; ``node.sync(h)``
        returns the fetched ``(size,)`` vector.
        """
        n = self.n_nodes
        inv = _inverse(frm, n)
        local = self.local(seg).reshape(-1)
        idx = jnp.asarray(index, jnp.int32)
        # request: the source node pattern(me) learns the offset I want
        req = self._move(idx, frm)
        data = lax.dynamic_slice(local, (req,), (size,))
        # reply: data travels back from the source to me
        h = extended.GetHandle(self._move(data, inv))
        tr = obs_trace.active()
        if tr.enabled:
            h.span = tr.begin_async(
                "get_nb", cat="rma",
                bytes=size * local.dtype.itemsize,
                engine=self.engine.name, seg=id(seg), pred=False,
            )
        self._outstanding.append(h)
        return h

    def put_nbv(
        self,
        seg: jax.Array,
        datas: Any,
        *,
        to: Pattern = Shift(1),
        indices: jax.Array | Sequence[int],
        pred: jax.Array | bool | Sequence[Any] | None = None,
    ) -> extended.PutvHandle:
        """Initiate a vectored non-blocking put (``gasnet_put_nbv``): land
        ``m = len(indices)`` equally-sized payloads at flat offsets
        ``indices`` of node ``pattern(me)``'s partition, as ONE vectored
        transport — the write-side mirror of :meth:`get_nbv`.

        ``datas`` is an ``(m, size)`` stack or a sequence of m equal-length
        vectors.  Payloads and the int32 *command block* (offsets + arrival
        flags) ride the engine's vectored put transport
        (``shift_nbv_put``/``permute_nbv_put``): m writes cost one
        initiation α instead of 3m — a GAScore command FIFO drained as a
        single wire message.  Callers batching many page writes (e.g. KV
        swap-out to a memory rank) pick the batch size with
        ``sched.plan_p2p`` on the total byte count.

        ``pred`` gates the writes SPMD-conditionally: a scalar gates the
        whole batch, a length-m vector gates per payload — a cleared flag
        ships its payload anyway (static schedule) but the receiver keeps
        its current bytes at that offset.  ``seg = node.sync(h)`` lands the
        flagged payloads; outstanding puts on the same segment compose.
        """
        local = self.local(seg)
        if isinstance(datas, (list, tuple)):
            payloads = [jnp.asarray(d).reshape(-1) for d in datas]
        else:
            datas = jnp.asarray(datas)
            payloads = [datas[j].reshape(-1) for j in range(datas.shape[0])]
        m = len(payloads)
        if m == 0:
            raise ValueError("put_nbv needs at least one payload")
        sizes = {int(p.shape[0]) for p in payloads}
        if len(sizes) != 1:
            raise ValueError(
                f"put_nbv payloads must share one size, got {sorted(sizes)}"
            )
        payloads = [p.astype(local.dtype) for p in payloads]
        idxs = jnp.asarray(indices, jnp.int32).reshape(-1)
        if int(idxs.shape[0]) != m:
            raise ValueError(
                f"put_nbv got {m} payloads but {int(idxs.shape[0])} indices"
            )
        if pred is None:
            flags = jnp.ones((m,), jnp.int32)
        else:
            flags = jnp.asarray(pred)
            if flags.ndim == 0:
                flags = jnp.broadcast_to(flags, (m,))
            flags = flags.astype(jnp.int32).reshape(-1)
            if int(flags.shape[0]) != m:
                raise ValueError(
                    f"put_nbv pred must be scalar or length {m}"
                )
        meta = jnp.concatenate([idxs, flags])
        if isinstance(to, Shift):
            pp, mp = self.engine.shift_nbv_put(payloads, meta, to.k)
        elif isinstance(to, Perm):
            pp, mp = self.engine.permute_nbv_put(payloads, meta, to.dst)
        else:
            raise TypeError(f"bad pattern {to!r}")
        self._seg_pins.append(seg)
        h = extended.PutvHandle(
            local, pp, mp,
            functools.partial(self._restore, seg),
            key=id(seg),
        )
        tr = obs_trace.active()
        if tr.enabled:
            size = payloads[0].shape[0]
            h.span = tr.begin_async(
                "put_nbv", cat="rma",
                bytes=m * size * local.dtype.itemsize,
                m=m, engine=self.engine.name, seg=id(seg),
                pred=pred is not None,
            )
        self._outstanding.append(h)
        return h

    def put_v(
        self,
        seg: jax.Array,
        datas: Any,
        *,
        to: Pattern = Shift(1),
        indices: jax.Array | Sequence[int],
        pred: jax.Array | bool | Sequence[Any] | None = None,
    ) -> jax.Array:
        """Blocking vectored put: ``put_nbv`` + immediate ``sync``."""
        return self.sync(
            self.put_nbv(seg, datas, to=to, indices=indices, pred=pred)
        )

    def get_nbv(
        self,
        seg: jax.Array,
        *,
        frm: Pattern = Shift(1),
        indices: jax.Array | Sequence[int],
        size: int = 1,
        pred: jax.Array | bool | None = None,
    ) -> extended.GetvHandle:
        """Initiate a vectored non-blocking get (``gasnet_get_nbv``): fetch
        ``m = len(indices)`` slices of ``size`` flat elements each from
        node ``pattern(me)``'s partition, as ONE request/reply pair.

        Both legs ride the engine's *vectored* transport
        (``shift_nbv``/``permute_nbv``): the request ships all m offsets
        in one message, the source slices every window, and the reply
        packs all m slices into one wire transfer — m gets for one
        initiation α per direction, instead of m.  Callers batching many
        fetches (e.g. KV page prefetch) pick the batch size with
        ``sched.plan_p2p`` on the total byte count.

        ``node.sync(h)`` returns the ``(m, size)`` stack.  ``pred`` gates
        the fetch SPMD-conditionally: a rank passing ``False`` traces the
        identical transfers but completes to zeros.
        """
        n = self.n_nodes
        inv = _inverse(frm, n)
        local = self.local(seg).reshape(-1)
        idxs = jnp.asarray(indices, jnp.int32).reshape(-1)
        m = int(idxs.shape[0])
        if m == 0:
            raise ValueError("get_nbv needs at least one index")
        flag = (
            jnp.ones((), bool) if pred is None else jnp.asarray(pred, bool)
        )
        # request leg: all m offsets travel to the source in one message
        (preq,) = self._move_nbv([idxs], frm)
        req = preq.wait()
        # source side: slice every window, pack into one reply payload
        data = jnp.concatenate(
            [lax.dynamic_slice(local, (req[j],), (size,)) for j in range(m)]
        )
        # reply leg: one vectored transfer back to the requester
        (prep,) = self._move_nbv([data], inv)
        h = extended.GetvHandle(prep, m, size, flag)
        tr = obs_trace.active()
        if tr.enabled:
            h.span = tr.begin_async(
                "get_nbv", cat="rma",
                bytes=m * size * local.dtype.itemsize,
                m=m, engine=self.engine.name, seg=id(seg),
                pred=pred is not None,
            )
        self._outstanding.append(h)
        return h

    def get_v(
        self,
        seg: jax.Array,
        *,
        frm: Pattern = Shift(1),
        indices: jax.Array | Sequence[int],
        size: int = 1,
        pred: jax.Array | bool | None = None,
    ) -> jax.Array:
        """Blocking vectored get: ``get_nbv`` + immediate ``sync``."""
        return self.sync(
            self.get_nbv(seg, frm=frm, indices=indices, size=size, pred=pred)
        )

    def sync(self, handle: extended.Handle) -> jax.Array:
        """Complete one handle (``gasnet_wait_syncnb``): returns the
        updated segment for puts, the fetched data for gets.

        Several *outstanding* puts against the same segment object compose:
        each sync applies onto the result of the previous one (FIFO), so no
        write is lost (GASNet permits multiple puts in flight).  Once the
        last outstanding put on a segment completes the chain is dropped,
        so a later independent ``put``/``put_nb`` of the same array starts
        from its own snapshot again.
        """
        if handle in self._outstanding:
            self._outstanding.remove(handle)
        if isinstance(handle, extended.PutHandle):
            if handle.done:
                raise extended.AlreadyWaitedError(
                    f"{handle.op} handle already synced"
                )
            handle.done = True
            base = self._seg_latest.get(handle.key, handle._local)
            new_local = handle.apply(base)
            still_open = any(
                isinstance(h, extended.PutHandle) and h.key == handle.key
                for h in self._outstanding
            )
            if still_open:
                self._seg_latest[handle.key] = new_local
            else:
                self._seg_latest.pop(handle.key, None)
            result = handle.restore(new_local)
        else:
            result = handle.complete()
        sp = handle.span
        if sp is not None:
            handle.span = None
            obs_trace.active().end_async(sp)
        return result

    def try_sync(
        self, handle: extended.Handle
    ) -> Tuple[bool, Optional[jax.Array]]:
        """Poll one handle (``gasnet_try_syncnb``): ``(done, value)``.

        Under the static SPMD schedule every initiated transfer is
        guaranteed to complete, so the poll always succeeds; the method is
        kept for GASNet API fidelity and returns ``(True, value)``.
        """
        return True, self.sync(handle)

    def sync_all(self) -> list:
        """Complete every outstanding handle in issue order
        (``gasnet_wait_syncnb_all``); returns their results FIFO.
        Outstanding puts on the same segment compose (see :meth:`sync`)."""
        results = []
        while self._outstanding:
            results.append(self.sync(self._outstanding[0]))
        return results

    # ----------------------------------------------------------------- #
    # Active Messages
    # ----------------------------------------------------------------- #
    def _ensure_batch(self) -> am_lib.AMBatch:
        if self._batch is None:
            self._batch = am_lib.empty_batch(
                self._am_capacity, self._am_payload_width
            )
        return self._batch

    def am_short(
        self,
        dest: jax.Array,
        handler: str,
        args: Sequence[Any] = (),
        pred: jax.Array | bool | None = None,
    ):
        b = self._ensure_batch()
        self._batch = am_lib.push(
            b, dest, self.handlers.id_of(handler), args=args, pred=pred
        )

    def am_medium(
        self,
        dest: jax.Array,
        handler: str,
        payload: jax.Array,
        args: Sequence[Any] = (),
        pred: jax.Array | bool | None = None,
    ):
        b = self._ensure_batch()
        self._batch = am_lib.push(
            b, dest, self.handlers.id_of(handler), args=args, payload=payload,
            pred=pred,
        )

    def am_long(
        self,
        dest: jax.Array,
        handler: str,
        payload: jax.Array,
        dst_index: jax.Array | int,
        nelem: jax.Array | int = 0,
        pred: jax.Array | bool | None = None,
    ):
        """AMLong: payload lands at ``dst_index`` (flat) of the handler's
        segment; handler convention is ``long_write_handler``-compatible
        (args[0]=offset, args[1]=element count)."""
        b = self._ensure_batch()
        self._batch = am_lib.push(
            b,
            dest,
            self.handlers.id_of(handler),
            args=(dst_index, nelem),
            payload=payload,
            pred=pred,
        )

    def am_call(
        self,
        dest: jax.Array,
        handler: str,
        payload: jax.Array | None = None,
        args: Sequence[Any] = (),
        pred: jax.Array | bool | None = None,
        ack: Callable[[Any], Any] | None = None,
    ) -> Optional[extended.AckHandle]:
        """Queue a *request* to a ``replies=True`` handler (the GASNet
        AMRequest whose handler will send an AMReply back here).

        With ``ack`` (a pure ``state -> value`` fetch), returns an
        :class:`~repro.core.extended.AckHandle` that the next
        :meth:`am_flush` resolves against the post-reply state —
        ``node.sync(h)`` then yields the acknowledgment value.
        """
        if not self.handlers.replies_of(handler):
            raise ValueError(
                f"am_call target {handler!r} is not a replying handler "
                "(register it with replies=True)"
            )
        if payload is None:
            self.am_short(dest, handler, args=args, pred=pred)
        else:
            self.am_medium(dest, handler, payload, args=args, pred=pred)
        if ack is None:
            return None
        h = extended.AckHandle(ack)
        self._pending_acks.append(h)
        self._outstanding.append(h)
        return h

    def am_flush(self, state: Any) -> Any:
        """Route all queued messages and run handlers at the receivers.
        Returns the updated receiver state.  (The poll loop of GASNet.)

        The router's all-to-all is plan-driven: ``repro.core.sched``
        chooses native vs direct-put exchange from the buffer size and
        this node's engine cost model (heterogeneous maps route over
        their mixed puts).

        When the handler table contains ``replies=True`` handlers the
        flush is the full request/reply cycle — a second ``route`` hop
        carries each handler's ``AMReply`` back to its requester and runs
        the reply handlers — and any :class:`AckHandle` from
        :meth:`am_call` is resolved against the post-reply state."""
        batch = self._ensure_batch()
        kw = dict(
            axis=self.engine.axis,
            n_nodes=self.n_nodes,
            per_peer_capacity=self._am_per_peer,
            engine=self.engine,
        )
        with obs_trace.active().span(
            "am_flush", cat="am", engine=self.engine.name,
            replies=self.handlers.has_replies,
            capacity=self._am_per_peer,
        ):
            if self.handlers.has_replies:
                state, dropped = am_lib.request_reply(
                    state, batch, self.handlers, **kw
                )
            else:
                recv, dropped = am_lib.route(batch, **kw)
                state = am_lib.deliver(state, recv, self.handlers)
        self.dropped = self.dropped + dropped
        self._batch = None
        for h in self._pending_acks:
            h.resolve(state)
        self._pending_acks = []
        return state


class Context:
    """Session object: mesh + node axis + engine backend + handler table.

    ``backend`` is a single engine name (``"xla"`` — software nodes,
    ``"gascore"`` — hardware nodes), a comma-separated per-rank pattern
    (``"xla,gascore"`` — the paper's heterogeneous cluster: alternating
    software/hardware nodes in one job), or a sequence of per-rank names;
    see :func:`repro.core.engine.make_engine`.
    """

    def __init__(
        self,
        mesh: jax.sharding.Mesh,
        node_axis: str = "node",
        backend: str = "xla",
        interpret: bool = True,
        am_capacity: int = 16,
        am_payload_width: int = 8,
        am_per_peer_capacity: int | None = None,
    ):
        self.mesh = mesh
        self.node_axis = node_axis
        self.backend = backend
        self.interpret = interpret
        self.handlers = am_lib.HandlerTable()
        self.am_capacity = am_capacity
        self.am_payload_width = am_payload_width
        self.am_per_peer_capacity = am_per_peer_capacity or am_capacity
        self.n_nodes = mesh.shape[node_axis]

    # ----------------------------------------------------------------- #
    def address_space(self) -> AddressSpace:
        return AddressSpace(self.mesh, self.node_axis)

    def register_handler(self, name: str, fn: Callable) -> int:
        return self.handlers.register(name, fn)

    def make_engine(self) -> CommEngine:
        return make_engine(
            self.backend, self.node_axis, self.n_nodes, interpret=self.interpret
        )

    def make_node(self) -> Node:
        return Node(
            self.make_engine(),
            self.handlers,
            self.am_capacity,
            self.am_payload_width,
            self.am_per_peer_capacity,
        )

    # ----------------------------------------------------------------- #
    def spmd(
        self,
        program: Callable,
        *args: Any,
        in_specs: Any = None,
        out_specs: Any = None,
        jit: bool = True,
    ) -> Any:
        """Run ``program(node, *local_args)`` as an SPMD node program.

        Default in/out specs treat every argument as a segment (sharded on
        the leading node axis).  Pass explicit specs for replicated or
        differently-sharded arguments.
        """
        seg_spec = P(self.node_axis)
        if in_specs is None:
            in_specs = jax.tree.map(lambda _: seg_spec, args)
        if out_specs is None:
            out_specs = seg_spec

        def body(*local_args):
            node = self.make_node()
            return program(node, *local_args)

        fn = shard_map(
            body,
            mesh=self.mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_vma=False,
        )
        if jit:
            fn = jax.jit(fn)
        return fn(*args)

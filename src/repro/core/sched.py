"""Collective scheduler: size-aware algorithm selection + segmentation.

The paper's GAScore earns its keep not just by moving bytes but by the
*schedule* it drains from its command FIFO: large transfers are cut into
segments so the wire time of segment k+1 overlaps the slice/accumulate
epilogue of segment k, and the collective algorithm itself is chosen by
message size (latency-bound payloads take log-depth trees, bandwidth-bound
payloads take segmented rings).  This module is that scheduler layer,
software-visible:

1. **Cost model** — per-engine (α latency, β wire, γ epilogue) constants,
   measured by ``benchmarks/gas_microbench.py`` and loadable from its
   ``BENCH_gas.json`` artifact; heterogeneous :class:`~repro.core.engine.
   EngineMap` jobs plan against the *worst* member engine (the ring is
   paced by its slowest edge).

2. **Planning** — :func:`plan_collective` turns (op, payload bytes, node
   count, engine) into a :class:`CollectivePlan`: the algorithm (ring vs
   recursive-doubling/tree vs direct exchange), the segment count and the
   pipeline depth, with an estimated cost and a human-readable reason.

3. **Execution** — :func:`all_reduce` / :func:`all_gather` /
   :func:`reduce_scatter` / :func:`broadcast` / :func:`all_to_all` plan
   and dispatch in one call; every call site that used to hard-code a
   ring (collectives users, the AM router, gpipe stage boundaries, the
   explicit-DP trainer) now routes through these.

All execution paths must run inside ``shard_map`` over ``engine.axis``.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Dict, Iterable, Optional

import jax

from repro.core import collectives
from repro.core.engine import CommEngine, EngineMap
from repro.obs import trace as obs_trace

__all__ = [
    "EngineCost",
    "try_fit_from_trace",
    "CollectivePlan",
    "DEFAULT_COSTS",
    "load_costs",
    "cost_of",
    "plan_collective",
    "plan_p2p",
    "all_reduce",
    "all_gather",
    "reduce_scatter",
    "broadcast",
    "all_to_all",
]


@dataclasses.dataclass(frozen=True)
class EngineCost:
    """Per-engine transport constants (microseconds).

    alpha_us          — per-hop initiation latency (the command-word issue:
                        ppermute setup for software nodes, DMA descriptor
                        push for the GAScore).
    beta_us_per_kib   — wire time per KiB on one hop.
    gamma_us_per_kib  — receiver-side epilogue per KiB (slice/accumulate/
                        store); this is what segmentation overlaps with
                        the wire.
    """

    alpha_us: float
    beta_us_per_kib: float
    gamma_us_per_kib: float

    def hop_us(self, nbytes: float) -> float:
        kib = nbytes / 1024.0
        return self.alpha_us + (self.beta_us_per_kib + self.gamma_us_per_kib) * kib

    def worst(self, other: "EngineCost") -> "EngineCost":
        return EngineCost(
            max(self.alpha_us, other.alpha_us),
            max(self.beta_us_per_kib, other.beta_us_per_kib),
            max(self.gamma_us_per_kib, other.gamma_us_per_kib),
        )

    @staticmethod
    def _points(spans: Iterable) -> list:
        """(KiB, measured us) pairs from recorded transfer spans — either
        :class:`repro.obs.trace.Span` objects (``bytes`` tag + wall
        ``dur_us``) or plain ``{"bytes", "dur_us"}`` dicts."""
        pts = []
        for s in spans:
            if isinstance(s, dict):
                b, d = s.get("bytes"), s.get("dur_us")
            else:
                b, d = s.args.get("bytes"), s.dur_us
            if not b or not d or d <= 0:
                continue
            pts.append((b / 1024.0, float(d)))
        return pts

    @staticmethod
    def _line_fit(pts: list, what: str) -> tuple:
        """Least-squares ``(intercept, slope)`` over (KiB, us) points;
        raises :class:`ValueError` on thin data (fewer than two points,
        or a single payload size — the constants are not separable)."""
        if len(pts) < 2:
            raise ValueError(
                f"{what} needs >= 2 measured transfer spans with "
                f"byte tags, got {len(pts)}"
            )
        n = float(len(pts))
        sx = sum(x for x, _ in pts)
        sy = sum(y for _, y in pts)
        sxx = sum(x * x for x, _ in pts)
        sxy = sum(x * y for x, y in pts)
        den = n * sxx - sx * sx
        if den <= 0:
            raise ValueError(
                f"{what} needs spans of at least two distinct "
                f"sizes to separate the intercept from the slope"
            )
        slope = (n * sxy - sx * sy) / den
        intercept = (sy - slope * sx) / n
        return intercept, slope

    @classmethod
    def fit_gamma_from_trace(cls, spans: Iterable) -> float:
        """Fit γ (receiver-epilogue us/KiB) from *measured epilogue*
        spans — the install/accumulate program timed alone, at several
        payload sizes (``obs.profile`` records these).  End-to-end
        transfer walls cannot separate γ from β (the epilogue overlaps
        the wire by design); a directly timed epilogue can: its per-KiB
        slope IS γ.  The per-call dispatch overhead lands in the
        intercept and is discarded."""
        pts = cls._points(spans)
        _, slope = cls._line_fit(pts, "fit_gamma_from_trace")
        return max(slope, 0.0)

    @classmethod
    def fit_from_trace(
        cls, spans: Iterable, *, gamma_us_per_kib: float = 0.0,
        epilogue_spans: Optional[Iterable] = None,
    ) -> "EngineCost":
        """Refit the model by least squares from *measured* transfer
        spans — the loop the paper's hardware counters close in ACCL+:
        plan with a model, measure what the transfers actually cost in
        situ, feed the measurements back.

        ``spans`` must cover at least two distinct sizes (α and β are
        not separable from a single point).  Without ``epilogue_spans``,
        γ is not observable from end-to-end transfer walls (it overlaps
        the wire by design) and passes through unchanged.  With
        ``epilogue_spans`` (the receiver install program timed alone —
        see :meth:`fit_gamma_from_trace`), the measured per-KiB slope of
        the end-to-end walls is *decomposed*: the epilogue's measured
        share becomes γ and the remainder stays β, so ``hop_us`` (and
        therefore :meth:`model_error`) is unchanged while segmentation
        planning gains a measured overlap opportunity (``min(β, γ)``).
        """
        pts = cls._points(spans)
        alpha, beta = cls._line_fit(pts, "fit_from_trace")
        alpha, beta = max(alpha, 0.0), max(beta, 0.0)
        gamma = gamma_us_per_kib
        if epilogue_spans is not None:
            measured = cls.fit_gamma_from_trace(epilogue_spans)
            # the epilogue cannot claim more than the measured end-to-end
            # per-KiB cost; the un-overlapped remainder is the wire
            gamma = min(measured, beta)
            beta = beta - gamma
        return cls(alpha, beta, gamma)

    def model_error(self, spans: Iterable) -> float:
        """Mean absolute relative error of this model's :meth:`hop_us`
        prediction against measured transfer spans (0.0 = perfect)."""
        pts = self._points(spans)
        if not pts:
            raise ValueError("model_error needs measured transfer spans")
        return sum(
            abs(self.hop_us(kib * 1024.0) - d) / d for kib, d in pts
        ) / len(pts)


def try_fit_from_trace(
    spans: Iterable,
    *,
    epilogue_spans: Optional[Iterable] = None,
    default: Optional[EngineCost] = None,
) -> tuple:
    """:meth:`EngineCost.fit_from_trace` that reports instead of dying.

    A thin trace (cold ring, filtered spans, a bench section that ran
    alone) raises :class:`ValueError` from the fitter; consumers that
    refit mid-run — the bench's obs section, anything folding measured
    spans back against :func:`_record_plan` estimates — should degrade
    to their prior model, not crash the run.  Returns ``(cost, note)``:
    ``note`` is ``"fit: ok"`` on success, else
    ``"fit: insufficient-data (<reason>)"`` with ``cost`` falling back
    to ``default`` (possibly None).
    """
    try:
        fit = EngineCost.fit_from_trace(spans, epilogue_spans=epilogue_spans)
        return fit, "fit: ok"
    except ValueError as e:
        return default, f"fit: insufficient-data ({e})"


# Defaults in the measured ballpark of host-device runs (gas_microbench
# writes the real ones into BENCH_gas.json -> load_costs); the hardware
# node pays less per hop (no software AM dispatch) but the same order of
# wire time.  With these, recursive doubling wins all-reduce below
# ~0.5 MiB on 8 nodes and the segmented ring takes over above it.
DEFAULT_COSTS: Dict[str, EngineCost] = {
    "xla": EngineCost(alpha_us=40.0, beta_us_per_kib=0.5, gamma_us_per_kib=0.2),
    "gascore": EngineCost(alpha_us=25.0, beta_us_per_kib=0.5, gamma_us_per_kib=0.2),
}

# Segmentation targets: chunk the per-hop payload so one segment's wire
# time is a few α (enough to hide the epilogue without drowning in
# initiation overhead), and bound the segment count.
SEGMENT_TARGET_BYTES = 256 * 1024
MAX_SEGMENTS = 16
DEFAULT_DEPTH = 2  # double-buffered command FIFO


def load_costs(path: str) -> Dict[str, EngineCost]:
    """Read per-engine constants from a ``BENCH_gas.json`` artifact
    (``engine_costs`` key); unknown engines fall back to defaults.

    When the artifact also carries measured *pair* costs (an
    ``engine_pair_costs`` key with ``"a->b"`` entries — the edge cost of
    a heterogeneous hop, e.g. an xla rank pushing into a gascore rank's
    FIFO), those land in the same table under their ``"a->b"`` keys and
    :func:`cost_of` prefers them for mixed :class:`~repro.core.engine.
    EngineMap` groups.  Pair entries are strictly optional: a missing or
    partial table degrades to the analytic worst-member α/β model, never
    to a lookup error.
    """
    costs = dict(DEFAULT_COSTS)
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, ValueError):
        return costs
    for section in ("engine_costs", "engine_pair_costs"):
        for name, c in (data.get(section) or {}).items():
            try:
                costs[name] = EngineCost(
                    float(c["alpha_us"]),
                    float(c["beta_us_per_kib"]),
                    float(c.get("gamma_us_per_kib", 0.05)),
                )
            except (KeyError, TypeError, ValueError):
                continue
    return costs


def cost_of(
    engine: Optional[CommEngine],
    costs: Optional[Dict[str, EngineCost]] = None,
) -> EngineCost:
    """Planning constants for an engine; a heterogeneous map plans against
    the worst member (the ring is paced by its slowest edge).

    If the cost table carries measured pair entries (``"a->b"`` keys from
    ``load_costs``), a mixed map plans against the worst measured *edge*
    between its member backends instead of the analytic per-engine worst.
    Missing pair entries fall back to the analytic model via ``.get`` —
    never a KeyError, so a partially-measured ``BENCH_gas.json`` still
    plans every group.
    """
    table = costs or DEFAULT_COSTS
    fallback = table.get("xla") or next(iter(table.values()))
    if engine is None:
        return fallback
    if isinstance(engine, EngineMap):
        members = sorted(set(engine.backends))
        acc = None
        for b in members:
            c = table.get(b, fallback)
            acc = c if acc is None else acc.worst(c)
        analytic = acc or fallback
        if len(members) > 1:
            pairs = [
                table.get(f"{a}->{b}")
                for a in members
                for b in members
                if a != b
            ]
            measured = [p for p in pairs if p is not None]
            if measured and len(measured) == len(pairs):
                worst = measured[0]
                for p in measured[1:]:
                    worst = worst.worst(p)
                return worst
        return analytic
    return table.get(engine.name, fallback)


@dataclasses.dataclass(frozen=True)
class CollectivePlan:
    """One planned collective: what to run and why.

    ``algorithm`` ∈ {"ring", "recursive_doubling", "tree", "direct",
    "native"}; ``n_segments``/``depth`` only apply to ring plans.
    """

    op: str
    algorithm: str
    n_segments: int
    depth: int
    payload_bytes: int
    n_nodes: int
    engine: str
    est_us: float
    reason: str

    def describe(self) -> str:
        seg = (
            f", {self.n_segments} segment(s) x depth {self.depth}"
            if self.algorithm == "ring"
            else ""
        )
        return (
            f"{self.op}[{self.payload_bytes}B, n={self.n_nodes}, "
            f"{self.engine}] -> {self.algorithm}{seg} "
            f"(~{self.est_us:.0f}us: {self.reason})"
        )


def _segments_for(per_hop_bytes: float, cost: EngineCost) -> int:
    """Segment count for a ring: target SEGMENT_TARGET_BYTES per segment
    hop, but never let added per-segment α exceed the epilogue time it
    buys back."""
    if per_hop_bytes <= SEGMENT_TARGET_BYTES:
        return 1
    g = min(MAX_SEGMENTS, int(math.ceil(per_hop_bytes / SEGMENT_TARGET_BYTES)))
    # overlap buys ~min(beta, gamma) * per_hop_kib; alpha costs (g-1)*alpha
    kib = per_hop_bytes / 1024.0
    gain = min(cost.beta_us_per_kib, cost.gamma_us_per_kib) * kib
    while g > 1 and (g - 1) * cost.alpha_us > gain:
        g -= 1
    return max(1, g)


def _ring_est(
    per_hop_bytes: float, cost: EngineCost, hops: int, g: int, depth: int
) -> float:
    """Pipelined ring estimate: per hop, G segment commands (α each) plus
    wire/epilogue overlapped across segments when depth > 1."""
    if g <= 1 or depth <= 1:
        return hops * cost.hop_us(per_hop_bytes)
    kib = per_hop_bytes / 1024.0
    return hops * (
        g * cost.alpha_us
        + max(cost.beta_us_per_kib, cost.gamma_us_per_kib) * kib
        + min(cost.beta_us_per_kib, cost.gamma_us_per_kib) * kib / g
    )


def _record_plan(plan: CollectivePlan) -> CollectivePlan:
    """Emit the chosen algorithm + *predicted* cost as a trace instant,
    so a measured transfer span sits next to the estimate that planned
    it — the cost-model error becomes a trace query."""
    tr = obs_trace.active()
    if tr.enabled:
        tr.instant(
            "plan", cat="plan", op=plan.op, algorithm=plan.algorithm,
            n_segments=plan.n_segments, depth=plan.depth,
            bytes=plan.payload_bytes, n_nodes=plan.n_nodes,
            engine=plan.engine, est_us=round(plan.est_us, 3),
        )
    return plan


def plan_collective(
    op: str,
    *,
    nbytes: int,
    n_nodes: int,
    engine: Optional[CommEngine] = None,
    costs: Optional[Dict[str, EngineCost]] = None,
    n_segments: Optional[int] = None,
    depth: Optional[int] = None,
) -> CollectivePlan:
    """Choose algorithm + segmentation for one collective.

    ``engine`` supplies the cost constants and capability flags (falls
    back to software-node defaults when None).  Explicit ``n_segments`` /
    ``depth`` pin the segmentation — and therefore the ring algorithm
    itself: a caller asking for segments is asking for the segmented
    ring, so the latency-tier overrides (recursive doubling, tree) are
    skipped.
    """
    return _record_plan(_plan_collective(
        op, nbytes=nbytes, n_nodes=n_nodes, engine=engine, costs=costs,
        n_segments=n_segments, depth=depth,
    ))


def _plan_collective(
    op: str,
    *,
    nbytes: int,
    n_nodes: int,
    engine: Optional[CommEngine] = None,
    costs: Optional[Dict[str, EngineCost]] = None,
    n_segments: Optional[int] = None,
    depth: Optional[int] = None,
) -> CollectivePlan:
    cost = cost_of(engine, costs)
    ename = engine.name if engine is not None else "xla"
    n = max(1, n_nodes)
    pow2 = n & (n - 1) == 0
    partial_ok = engine.can_permute_partial if engine is not None else True
    pinned = n_segments is not None or depth is not None
    kib = nbytes / 1024.0

    def ring_plan(hops: int, per_hop_bytes: float, chunk_desc: str) -> CollectivePlan:
        g = n_segments if n_segments is not None else _segments_for(
            per_hop_bytes, cost
        )
        d = depth if depth is not None else (DEFAULT_DEPTH if g > 1 else 1)
        est = _ring_est(per_hop_bytes, cost, hops, g, d)
        why = f"bandwidth-bound: ring moves {chunk_desc} per hop" + (
            f"; segmented x{g} to overlap wire with epilogue" if g > 1 else ""
        )
        return CollectivePlan(op, "ring", g, d, nbytes, n, ename, est, why)

    if n == 1:
        return CollectivePlan(
            op, "ring", 1, 1, nbytes, n, ename, 0.0, "single node: no wire"
        )

    if op == "all_reduce":
        # input is the full (n*m) buffer; each RS/AG hop carries one S/n chunk
        ring = ring_plan(2 * (n - 1), nbytes / n, "S/n")
        if pow2 and not pinned:
            rd_est = math.log2(n) * cost.hop_us(nbytes)
            if rd_est < ring.est_us:
                return CollectivePlan(
                    op, "recursive_doubling", 1, 1, nbytes, n, ename, rd_est,
                    "latency-bound: log2(n) exchange rounds beat 2(n-1) hops",
                )
        return ring

    if op == "all_gather":
        # nbytes is the LOCAL contribution; every hop forwards one full
        # local-sized chunk, so per-hop bytes = nbytes (not nbytes/n)
        return ring_plan(n - 1, float(nbytes), "the local chunk")

    if op == "reduce_scatter":
        # input is the full (n*m) buffer; each hop carries one S/n packet
        return ring_plan(n - 1, nbytes / n, "S/n")

    if op == "broadcast":
        # the ring broadcast forwards the FULL payload on each of its n-1
        # hops (no chunking), unlike the ring reductions' S/n chunks
        ring_est = (n - 1) * (cost.alpha_us + cost.beta_us_per_kib * kib)
        ring = CollectivePlan(
            op, "ring", 1, 1, nbytes, n, ename, ring_est,
            "ring pipeline: n-1 forward hops (bijection-only transport)",
        )
        if partial_ok and not pinned:
            tree_est = math.ceil(math.log2(n)) * (
                cost.alpha_us + cost.beta_us_per_kib * kib
            )
            if tree_est < ring.est_us:
                return CollectivePlan(
                    op, "tree", 1, 1, nbytes, n, ename, tree_est,
                    "binomial tree: ceil(log2 n) rounds beat n-1 hops",
                )
        return ring

    if op == "all_to_all":
        native = (
            engine is not None
            and type(engine).all_to_all is not CommEngine.all_to_all
        )
        est = cost.alpha_us + cost.beta_us_per_kib * kib * (n - 1) / n
        if native:
            return CollectivePlan(
                op, "native", 1, 1, nbytes, n, ename, est,
                "engine-native all-to-all (XLA transport)",
            )
        return CollectivePlan(
            op, "direct", 1, 1, nbytes, n, ename, est,
            "fully overlapped personalized exchange: all n-1 puts in flight",
        )

    raise ValueError(f"unknown collective op {op!r}")


def plan_p2p(
    *,
    nbytes: int,
    engine: Optional[CommEngine] = None,
    costs: Optional[Dict[str, EngineCost]] = None,
) -> CollectivePlan:
    """Plan one point-to-point put (a gpipe stage boundary): how many
    segments to keep in flight so wire overlaps the receiver epilogue."""
    cost = cost_of(engine, costs)
    g = _segments_for(float(nbytes), cost)
    d = DEFAULT_DEPTH if g > 1 else 1
    est = _ring_est(float(nbytes), cost, 1, g, d)
    return _record_plan(CollectivePlan(
        "p2p", "ring", g, d, nbytes, 2,
        engine.name if engine is not None else "xla", est,
        "stage-boundary put" + (f"; segmented x{g}" if g > 1 else ""),
    ))


# --------------------------------------------------------------------------- #
# Plan-driven execution: the one entry point call sites migrate to
# --------------------------------------------------------------------------- #
def _nbytes(x: jax.Array) -> int:
    return int(x.size) * x.dtype.itemsize


def _resolve(
    op: str, engine: CommEngine, x: jax.Array, plan: Optional[CollectivePlan],
    costs: Optional[Dict[str, EngineCost]],
) -> CollectivePlan:
    if plan is not None:
        return plan
    return plan_collective(
        op, nbytes=_nbytes(x), n_nodes=engine.n_nodes, engine=engine,
        costs=costs,
    )


def all_reduce(
    engine: CommEngine,
    x: jax.Array,
    *,
    plan: Optional[CollectivePlan] = None,
    costs: Optional[Dict[str, EngineCost]] = None,
) -> jax.Array:
    """Planned all-reduce: recursive doubling for latency-bound payloads,
    segmented ring for bandwidth-bound ones."""
    p = _resolve("all_reduce", engine, x, plan, costs)
    if p.algorithm == "recursive_doubling":
        return collectives.recursive_doubling_all_reduce(engine, x)
    return collectives.segmented_ring_all_reduce(
        engine, x, n_segments=p.n_segments, depth=p.depth
    )


def all_gather(
    engine: CommEngine,
    x: jax.Array,
    *,
    plan: Optional[CollectivePlan] = None,
    costs: Optional[Dict[str, EngineCost]] = None,
) -> jax.Array:
    p = _resolve("all_gather", engine, x, plan, costs)
    return collectives.segmented_ring_all_gather(
        engine, x, n_segments=p.n_segments, depth=p.depth
    )


def reduce_scatter(
    engine: CommEngine,
    x: jax.Array,
    *,
    plan: Optional[CollectivePlan] = None,
    costs: Optional[Dict[str, EngineCost]] = None,
) -> jax.Array:
    p = _resolve("reduce_scatter", engine, x, plan, costs)
    return collectives.segmented_ring_reduce_scatter(
        engine, x, n_segments=p.n_segments, depth=p.depth
    )


def broadcast(
    engine: CommEngine,
    x: jax.Array,
    *,
    root: int = 0,
    plan: Optional[CollectivePlan] = None,
    costs: Optional[Dict[str, EngineCost]] = None,
) -> jax.Array:
    p = _resolve("broadcast", engine, x, plan, costs)
    if p.algorithm == "tree":
        return collectives.tree_broadcast(engine, x, root=root)
    return collectives.broadcast(engine, x, root=root)


def all_to_all(
    engine: CommEngine,
    x: jax.Array,
    *,
    plan: Optional[CollectivePlan] = None,
    costs: Optional[Dict[str, EngineCost]] = None,
) -> jax.Array:
    p = _resolve("all_to_all", engine, x, plan, costs)
    if p.algorithm == "native":
        return engine.all_to_all(x)
    return collectives.exchange(engine, x)

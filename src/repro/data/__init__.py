"""Deterministic synthetic data pipeline with sharded, prefetched loading."""
from repro.data.synthetic import SyntheticLM, ShardedLoader

__all__ = ["SyntheticLM", "ShardedLoader"]

"""Synthetic LM data: deterministic, seekable, restart-safe.

Every batch is a pure function of (seed, step), so a training job restarted
from a checkpoint at step k consumes *exactly* the same stream it would
have seen uninterrupted — the property the fault-tolerance tests assert.

The token stream is Zipf-ish with a planted bigram structure
(``next = (5 * tok + 7) % vocab`` with noise) so that a real model exhibits
decreasing loss — pure-uniform tokens would give a flat loss and hide
integration bugs.

``ShardedLoader`` device_puts each batch with the mesh's batch sharding and
prefetches one batch ahead on a background thread (host-side pipelining,
the CPU analogue of an input pipeline overlapping the training step).
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.common import ArchConfig


class SyntheticLM:
    """Deterministic synthetic batches for an ArchConfig."""

    def __init__(
        self,
        cfg: ArchConfig,
        batch: int,
        seq_len: int,
        seed: int = 0,
        structure: float = 0.7,
    ):
        self.cfg = cfg
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        self.structure = structure

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        B, S, V = self.batch, self.seq_len, self.cfg.vocab
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.integers(0, V, size=B)
        noise = rng.random((B, S))
        rand_next = rng.integers(0, V, size=(B, S))
        for t in range(S):
            planted = (5 * toks[:, t] + 7) % V
            toks[:, t + 1] = np.where(
                noise[:, t] < self.structure, planted, rand_next[:, t]
            )
        out: Dict[str, np.ndarray] = {
            "inputs": toks[:, :-1],
            "targets": toks[:, 1:],
            "mask": np.ones((B, S), np.float32),
        }
        if self.cfg.n_enc_layers:
            out["frames"] = rng.standard_normal(
                (B, S, self.cfg.d_model), np.float32
            ).astype(np.float32)
        elif self.cfg.cross_kv_len:
            out["xkv"] = rng.standard_normal(
                (B, self.cfg.cross_kv_len, self.cfg.d_model), np.float32
            ).astype(np.float32)
        return out


class ShardedLoader:
    """Prefetching loader that places batches with the mesh batch sharding."""

    def __init__(
        self,
        source: SyntheticLM,
        mesh: Optional[jax.sharding.Mesh] = None,
        dp_axes=("data",),
        start_step: int = 0,
        prefetch: int = 1,
    ):
        self.source = source
        self.mesh = mesh
        self.dp_axes = tuple(dp_axes)
        self.step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=max(prefetch, 1))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _place(self, host_batch: Dict[str, np.ndarray]) -> Dict[str, jax.Array]:
        out = {}
        for k, v in host_batch.items():
            if self.mesh is not None:
                spec = P(self.dp_axes, *([None] * (v.ndim - 1)))
                out[k] = jax.device_put(v, NamedSharding(self.mesh, spec))
            else:
                out[k] = jnp.asarray(v)
        return out

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            try:
                self._q.put((step, batch), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        return self

    def __next__(self) -> Dict[str, jax.Array]:
        step, batch = self._q.get()
        self.step = step + 1
        return self._place(batch)

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass

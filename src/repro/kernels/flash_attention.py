"""Blockwise (flash) attention forward kernel for TPU.

The training/prefill compute hot spot.  Standard online-softmax blockwise
algorithm, adapted to the TPU memory hierarchy: Q/K/V tiles are staged
HBM->VMEM by the ``BlockSpec`` pipeline, the running (m, l, acc) state lives
in VMEM scratch and persists across the (sequential, innermost) KV-block
grid dimension, and the two matmuls per tile hit the MXU with
(block_q × head_dim) · (head_dim × block_k) shapes — keep ``block_q``,
``block_k`` multiples of 128 and ``head_dim`` ∈ {64, 128, 256}.

Supports causal masking, GQA (q heads grouped over fewer KV heads, resolved
in the K/V index_map so KV tiles are fetched once per group), and a sliding
local-attention window (gemma3 / recurrentgemma local layers).

Oracle: ``repro.kernels.ref.attention``.  Validated under interpret mode;
on real TPUs pass ``interpret=False``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

__all__ = ["flash_attention"]

NEG_INF = -1e30


def _fa_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    lse_ref,
    m_scr,
    l_scr,
    acc_scr,
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    block_q: int,
    block_k: int,
    kv_blocks: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale  # (BQ, D)
    k = k_ref[0, 0].astype(jnp.float32)  # (BK, D)
    v = v_ref[0, 0].astype(jnp.float32)  # (BK, D)

    s = lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (BQ, BK)

    qpos = qi * block_q + lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = ki * block_k + lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones_like(s, dtype=bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
        if not causal:
            mask &= (kpos - qpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[:, 0]
    l_prev = l_scr[:, 0]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    # rows with no visible keys keep m == NEG_INF; exp() there must be 0.
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + p.sum(axis=-1)
    acc = acc_scr[...] * alpha[:, None] + lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    m_scr[:, 0] = m_new
    l_scr[:, 0] = l_new
    acc_scr[...] = acc

    @pl.when(ki == kv_blocks - 1)
    def _finalize():
        l = l_scr[:, 0]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)
        # log-sum-exp for the backward pass: L = m + log(l)
        lse_ref[0, 0] = m_scr[:, 0] + jnp.log(denom)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal",
        "window",
        "scale",
        "block_q",
        "block_k",
        "interpret",
        "return_lse",
    ),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
    return_lse: bool = False,
) -> jax.Array:
    """Blockwise attention.

    Args:
      q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D) with Hq % Hkv == 0 (GQA).
      causal: causal mask (positions aligned to sequence end when Sq == Sk).
      window: sliding local-attention window size (None = global).
      scale: softmax scale, default 1/sqrt(D).
      block_q/block_k: VMEM tile sizes (multiples of 128 on target).
    Returns:
      (B, Hq, Sq, D) in q.dtype; with ``return_lse`` also the per-row
      log-sum-exp (B, Hq, Sq) f32 (consumed by the backward kernels).
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    if Hq % Hkv != 0:
        raise ValueError(f"Hq={Hq} not a multiple of Hkv={Hkv}")
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D**0.5)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    if Sq % block_q or Sk % block_k:
        raise ValueError(
            f"seq ({Sq},{Sk}) not divisible by blocks ({block_q},{block_k})"
        )
    nq, nk = Sq // block_q, Sk // block_k

    kernel = functools.partial(
        _fa_kernel,
        scale=scale,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        kv_blocks=nk,
    )

    out, lse = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec(
                (1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_k, D), lambda b, h, qi, ki: (b, h // group, ki, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_k, D), lambda b, h, qi, ki: (b, h // group, ki, 0)
            ),
        ],
        out_specs=[
            pl.BlockSpec(
                (1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)
            ),
            pl.BlockSpec((1, 1, block_q), lambda b, h, qi, ki: (b, h, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hq, Sq, D), q.dtype),
            jax.ShapeDtypeStruct((B, Hq, Sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=compat.tpu_interpret(interpret),
        name="flash_attention_fwd",
    )(q, k, v)
    if return_lse:
        return out, lse
    return out

"""Blockwise (flash) attention BACKWARD kernels + custom-VJP wrapper.

Standard two-kernel formulation (Dao et al., adapted to TPU tiling):

  forward (``flash_attention.py`` with ``return_lse=True``) additionally
  emits the per-row log-sum-exp L = m + log(l), so the backward pass can
  recompute the probability tiles p = exp(q·kᵀ·scale − L) exactly without
  storing the (S × S) matrix.

  delta   = rowsum(dO ⊙ O)                       (jnp; one fused pass)
  dKV     : grid (B, KH, kv-blocks); inner loop over the GQA group's query
            heads × q-blocks, accumulating
              dV += pᵀ · dO
              dK += (p ⊙ (dO·Vᵀ − delta))ᵀ · q · scale
  dQ      : grid (B, Hq, q-blocks); inner loop over kv-blocks accumulating
              dQ += (p ⊙ (dO·Vᵀ − delta)) · K · scale

Both kernels stage tiles through VMEM via BlockSpecs with f32 accumulators
in scratch; the MXU sees (block × head_dim)·(head_dim × block) shapes.
The public entry point is ``flash_attention_vjp`` — a ``jax.custom_vjp``
drop-in whose gradients are swept against ``jax.grad`` of the pure-jnp
oracle in tests/test_kernels.py.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

from repro.kernels.flash_attention import flash_attention

__all__ = ["flash_attention_vjp"]

NEG_INF = -1e30


def _mask(qpos, kpos, causal, window):
    m = jnp.ones(qpos.shape, bool)
    if causal:
        m &= qpos >= kpos
    if window is not None:
        m &= (qpos - kpos) < window
        if not causal:
            m &= (kpos - qpos) < window
    return m


# --------------------------------------------------------------------------- #
# dK/dV kernel: one (kv-block, kv-head) per grid step, loop q side inside
# --------------------------------------------------------------------------- #
def _dkv_kernel(
    q_ref,      # (1, 1, G, Sq, D)   all q rows of this kv head's group
    k_ref,      # (1, 1, BK, D)
    v_ref,      # (1, 1, BK, D)
    do_ref,     # (1, 1, G, Sq, D)
    lse_ref,    # (1, 1, G, Sq)
    delta_ref,  # (1, 1, G, Sq)
    dk_ref,     # (1, 1, BK, D)
    dv_ref,     # (1, 1, BK, D)
    dk_scr,
    dv_scr,
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    block_q: int,
    block_k: int,
    group: int,
):
    ki = pl.program_id(2)
    seq_q = q_ref.shape[3]
    nq = seq_q // block_q
    dk_scr[...] = jnp.zeros_like(dk_scr)
    dv_scr[...] = jnp.zeros_like(dv_scr)
    k = k_ref[0, 0].astype(jnp.float32)  # (BK, D)
    v = v_ref[0, 0].astype(jnp.float32)

    def q_block(idx, _):
        g = idx // nq
        qi = idx % nq
        qs = pl.ds(qi * block_q, block_q)
        q = q_ref[0, 0, g, qs, :].astype(jnp.float32)
        do = do_ref[0, 0, g, qs, :].astype(jnp.float32)
        lse = lse_ref[0, 0, g, qs]
        delta = delta_ref[0, 0, g, qs]
        s = lax.dot_general(
            q * scale, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (BQ, BK)
        qpos = qi * block_q + lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        kpos = ki * block_k + lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        msk = _mask(qpos, kpos, causal, window)
        p = jnp.where(msk, jnp.exp(s - lse[:, None]), 0.0)  # (BQ, BK)
        # dV += p^T @ dO
        dv_scr[...] += lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        # dS = p * (dO @ V^T - delta)
        dov = lax.dot_general(
            do, v, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        ds = p * (dov - delta[:, None])
        # dK += dS^T @ q * scale
        dk_scr[...] += lax.dot_general(
            ds, q * scale, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return 0

    lax.fori_loop(0, group * nq, q_block, 0)
    dk_ref[0, 0] = dk_scr[...].astype(dk_ref.dtype)
    dv_ref[0, 0] = dv_scr[...].astype(dv_ref.dtype)


# --------------------------------------------------------------------------- #
# dQ kernel: one (q-block, q-head) per grid step, loop kv side inside
# --------------------------------------------------------------------------- #
def _dq_kernel(
    q_ref,      # (1, 1, BQ, D)
    k_ref,      # (1, 1, Sk, D)
    v_ref,      # (1, 1, Sk, D)
    do_ref,     # (1, 1, BQ, D)
    lse_ref,    # (1, 1, BQ)
    delta_ref,  # (1, 1, BQ)
    dq_ref,     # (1, 1, BQ, D)
    dq_scr,
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    block_q: int,
    block_k: int,
):
    qi = pl.program_id(2)
    seq_k = k_ref.shape[2]
    nk = seq_k // block_k
    dq_scr[...] = jnp.zeros_like(dq_scr)
    q = q_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0]
    delta = delta_ref[0, 0]

    def kv_block(ki, _):
        ks_ = pl.ds(ki * block_k, block_k)
        k = k_ref[0, 0, ks_, :].astype(jnp.float32)
        v = v_ref[0, 0, ks_, :].astype(jnp.float32)
        s = lax.dot_general(
            q * scale, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        qpos = qi * block_q + lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        kpos = ki * block_k + lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        msk = _mask(qpos, kpos, causal, window)
        p = jnp.where(msk, jnp.exp(s - lse[:, None]), 0.0)
        dov = lax.dot_general(
            do, v, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        ds = p * (dov - delta[:, None])
        dq_scr[...] += lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale
        return 0

    lax.fori_loop(0, nk, kv_block, 0)
    dq_ref[0, 0] = dq_scr[...].astype(dq_ref.dtype)


# --------------------------------------------------------------------------- #
# custom VJP wrapper
# --------------------------------------------------------------------------- #
@functools.partial(
    jax.custom_vjp,
    nondiff_argnums=(3, 4, 5, 6, 7, 8),
)
def flash_attention_vjp(
    q, k, v,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
):
    out, _ = _fwd(q, k, v, causal, window, scale, block_q, block_k, interpret)
    return out


def _fwd(q, k, v, causal, window, scale, block_q, block_k, interpret):
    out, lse = flash_attention(
        q, k, v, causal=causal, window=window, scale=scale,
        block_q=block_q, block_k=block_k, interpret=interpret,
        return_lse=True,
    )
    return out, (q, k, v, out, lse)


def _bwd(causal, window, scale, block_q, block_k, interpret, res, dout):
    q, k, v, out, lse = res
    B, Hq, Sq, D = q.shape
    _, KH, Sk, _ = k.shape
    G = Hq // KH
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    nq, nk = Sq // bq, Sk // bk

    delta = jnp.sum(
        dout.astype(jnp.float32) * out.astype(jnp.float32), axis=-1
    )  # (B, Hq, Sq)

    # ---- dK/dV ---------------------------------------------------------- #
    qg = q.reshape(B, KH, G, Sq, D)
    dog = dout.reshape(B, KH, G, Sq, D)
    lseg = lse.reshape(B, KH, G, Sq)
    deltag = delta.reshape(B, KH, G, Sq)

    dkv = pl.pallas_call(
        functools.partial(
            _dkv_kernel, scale=scale, causal=causal, window=window,
            block_q=bq, block_k=bk, group=G,
        ),
        grid=(B, KH, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, Sq, D), lambda b, h, ki: (b, h, 0, 0, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, G, Sq, D), lambda b, h, ki: (b, h, 0, 0, 0)),
            pl.BlockSpec((1, 1, G, Sq), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, G, Sq), lambda b, h, ki: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bk, D), lambda b, h, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, ki: (b, h, ki, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, KH, Sk, D), k.dtype),
            jax.ShapeDtypeStruct((B, KH, Sk, D), v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((bk, D), jnp.float32),
            pltpu.VMEM((bk, D), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=compat.tpu_interpret(interpret),
        name="flash_attention_dkv",
    )(qg, k, v, dog, lseg, deltag)
    dk, dv = dkv

    # ---- dQ -------------------------------------------------------------- #
    kx = jnp.repeat(k, G, axis=1) if G > 1 else k
    vx = jnp.repeat(v, G, axis=1) if G > 1 else v
    dq = pl.pallas_call(
        functools.partial(
            _dq_kernel, scale=scale, causal=causal, window=window,
            block_q=bq, block_k=bk,
        ),
        grid=(B, Hq, nq),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, qi: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, Sk, D), lambda b, h, qi: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, Sk, D), lambda b, h, qi: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bq, D), lambda b, h, qi: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, qi: (b, h, qi)),
            pl.BlockSpec((1, 1, bq), lambda b, h, qi: (b, h, qi)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, qi: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, D), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=compat.tpu_interpret(interpret),
        name="flash_attention_dq",
    )(q, kx, vx, dout, lse, delta)

    return dq, dk, dv


flash_attention_vjp.defvjp(_fwd, _bwd)

"""GAScore: the paper's remote-DMA engine, as Pallas TPU kernels.

The paper's GAScore is a hardware block that executes one-sided remote
memory operations (Active-Message packets) between nodes, driven by
GASNet-argument command words.  The exact TPU analogue is an inter-chip
DMA over ICI: ``pltpu.make_async_remote_copy`` builds the DMA descriptor
(source ref, *remote* destination ref, destination chip) and a pair of DMA
semaphores provides the send-complete / receive-complete notifications that
the paper delivers via its handler mechanism.

Kernels:

- :func:`ring_shift`          — one put to node ``(me + k) % n``.
- :func:`perm_put`            — one put along an arbitrary static permutation.
- :func:`offset_put`          — put with *sender-chosen remote offset*
                                (AMLong: the sender addresses remote memory
                                directly; purest GAScore semantics).
- :func:`ring_all_gather`     — n-1 chained puts, each forwarding the chunk
                                received on the previous hop (single fused
                                kernel; compute proceeds between start/wait).
- :func:`ring_reduce_scatter` — n-1 chained put+accumulate hops.

All kernels run under TPU interpret mode on CPU (``interpret=True``, the
validation path in this repo) and compile to Mosaic for real ICI
(``interpret=False``).  They must be invoked inside a ``shard_map`` over
``axis``; the node axis must be the kernel's only mesh axis (1-D subgrid),
which is how the GAS layer always invokes them.

VMEM/alignment notes (target hardware): chunks are staged through VMEM
scratch; callers should keep the trailing dim a multiple of 128 and the
second-minor a multiple of 8 (f32) / 16 (bf16) for full-speed DMAs — the
``ops.aligned`` helper checks this.  The pure-jnp oracles live in
``repro.kernels.ref``.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

__all__ = [
    "ring_shift",
    "perm_put",
    "offset_put",
    "ring_all_gather",
    "ring_reduce_scatter",
]


def _interp(interpret: bool):
    return compat.tpu_interpret(interpret)


def _any_spec() -> pl.BlockSpec:
    return pl.BlockSpec(memory_space=pl.ANY)


# --------------------------------------------------------------------------- #
# point-to-point puts
# --------------------------------------------------------------------------- #
def ring_shift(
    x: jax.Array, *, k: int, axis: str, n_nodes: int, interpret: bool = True
) -> jax.Array:
    """Every node's ``x`` lands on node ``(me + k) % n`` (one remote DMA)."""
    k = k % n_nodes
    if k == 0:
        return x

    def kernel(x_ref, o_ref, send_sem, recv_sem):
        me = lax.axis_index(axis)
        dst = lax.rem(me + k, n_nodes)
        rdma = pltpu.make_async_remote_copy(
            src_ref=x_ref,
            dst_ref=o_ref,
            send_sem=send_sem,
            recv_sem=recv_sem,
            device_id=compat.dma_device_id(dst),
            device_id_type=pltpu.DeviceIdType.MESH,
        )
        rdma.start()
        rdma.wait()

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        in_specs=[_any_spec()],
        out_specs=_any_spec(),
        scratch_shapes=[pltpu.SemaphoreType.DMA, pltpu.SemaphoreType.DMA],
        interpret=_interp(interpret),
        name=f"gascore_ring_shift_{k}",
    )(x)


def perm_put(
    x: jax.Array,
    *,
    dst: Tuple[int, ...],
    axis: str,
    n_nodes: int,
    interpret: bool = True,
) -> jax.Array:
    """Put along a static permutation: node i's ``x`` lands on ``dst[i]``.

    ``dst`` must be a bijection of 0..n-1 (every node receives exactly one
    message, so its recv semaphore is signalled exactly once).  The XLA
    engine additionally supports non-bijective patterns; the GAScore engine
    mirrors hardware, where an unpaired wait would deadlock.
    """
    if sorted(dst) != list(range(n_nodes)):
        raise ValueError(f"perm_put requires a bijection, got {dst}")

    def kernel(dst_ref, x_ref, o_ref, send_sem, recv_sem):
        me = lax.axis_index(axis)
        target = dst_ref[me]
        rdma = pltpu.make_async_remote_copy(
            src_ref=x_ref,
            dst_ref=o_ref,
            send_sem=send_sem,
            recv_sem=recv_sem,
            device_id=compat.dma_device_id(target),
            device_id_type=pltpu.DeviceIdType.MESH,
        )
        rdma.start()
        rdma.wait()

    dst_arr = jnp.asarray(dst, jnp.int32)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            _any_spec(),
        ],
        out_specs=_any_spec(),
        scratch_shapes=[pltpu.SemaphoreType.DMA, pltpu.SemaphoreType.DMA],
        interpret=_interp(interpret),
        name="gascore_perm_put",
    )(dst_arr, x)


def offset_put(
    seg: jax.Array,
    data: jax.Array,
    offset: jax.Array,
    *,
    k: int,
    axis: str,
    n_nodes: int,
    interpret: bool = True,
) -> jax.Array:
    """AMLong via GAScore: write ``data`` into the partition of node
    ``(me + k) % n`` of segment ``seg`` at *sender-chosen* element offset
    ``offset`` along the leading axis.

    The sender constructs the full remote address (``dst_ref`` slice) in the
    DMA descriptor — this is precisely the GAScore command format (local
    address, remote node, remote address, length).  The updated segment is
    returned (aliased in-place on TPU).

    ``seg``: (S, ...) local partition; ``data``: (L, ...) with L <= S and
    matching trailing dims; ``offset``: scalar int32, 0 <= offset <= S - L.
    """
    k = k % n_nodes
    L = data.shape[0]

    def kernel(off_ref, data_ref, seg_in_ref, seg_ref, send_sem, recv_sem):
        del seg_in_ref  # aliased with seg_ref; content already in place
        me = lax.axis_index(axis)
        dst = lax.rem(me + k, n_nodes)
        rdma = pltpu.make_async_remote_copy(
            src_ref=data_ref,
            dst_ref=seg_ref.at[pl.ds(off_ref[0], L)],
            send_sem=send_sem,
            recv_sem=recv_sem,
            device_id=compat.dma_device_id(dst),
            device_id_type=pltpu.DeviceIdType.MESH,
        )
        rdma.start()
        rdma.wait()

    off_arr = jnp.asarray(offset, jnp.int32).reshape((1,))
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(seg.shape, seg.dtype),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            _any_spec(),
            _any_spec(),
        ],
        out_specs=_any_spec(),
        input_output_aliases={2: 0},
        scratch_shapes=[pltpu.SemaphoreType.DMA, pltpu.SemaphoreType.DMA],
        interpret=_interp(interpret),
        name=f"gascore_offset_put_{k}",
    )(off_arr, data, seg)


# --------------------------------------------------------------------------- #
# fused ring collectives
# --------------------------------------------------------------------------- #
def ring_all_gather(
    x: jax.Array, *, axis: str, n_nodes: int, interpret: bool = True
) -> jax.Array:
    """All-gather: local chunk (m, ...) -> tiled (n*m, ...).

    One fused kernel performs all n-1 hops: at hop h every node forwards
    the chunk it received at hop h-1 (chunk id ``me - h``) to its right
    neighbor, writing directly into the neighbor's output slot with a
    single remote DMA — no intermediate staging, which is the bandwidth
    advantage the paper claims for hardware-managed RDMA.
    """
    n = n_nodes
    chunk_shape = x.shape

    def kernel(x_ref, o_ref, local_sem, send_sems, recv_sems):
        me = lax.axis_index(axis)
        right = lax.rem(me + 1, n)
        # publish my own chunk into my slot (local DMA)
        lcopy = pltpu.make_async_copy(x_ref, o_ref.at[me], local_sem)
        lcopy.start()
        lcopy.wait()

        def hop(h, _):
            slot = lax.rem(me - h + n + n, n)  # chunk forwarded at hop h+1
            # Per-hop semaphores: a fast neighbor may start hop h+1 before
            # we finish hop h; sharing one DMA semaphore would let its
            # arrival satisfy our hop-h wait while hop-h bytes are still in
            # flight (observed as NaN slots in interpret mode).
            rdma = pltpu.make_async_remote_copy(
                src_ref=o_ref.at[slot],
                dst_ref=o_ref.at[slot],
                send_sem=send_sems.at[h],
                recv_sem=recv_sems.at[h],
                device_id=compat.dma_device_id(right),
                device_id_type=pltpu.DeviceIdType.MESH,
            )
            rdma.start()
            rdma.wait()
            return 0

        lax.fori_loop(0, n - 1, hop, 0)

    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n,) + chunk_shape, x.dtype),
        in_specs=[_any_spec()],
        out_specs=_any_spec(),
        scratch_shapes=[
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA((n - 1,)),
            pltpu.SemaphoreType.DMA((n - 1,)),
        ],
        interpret=_interp(interpret),
        name="gascore_ring_all_gather",
    )(x)
    return out.reshape((n * chunk_shape[0],) + chunk_shape[1:])


def ring_reduce_scatter(
    x: jax.Array, *, axis: str, n_nodes: int, interpret: bool = True
) -> jax.Array:
    """Reduce-scatter: (n*m, ...) -> summed local chunk (m, ...).

    The packet for chunk ``c`` starts at node ``c+1`` and travels the ring
    accumulating each visited node's contribution (see
    ``core.collectives.ring_reduce_scatter`` for the schedule proof).  The
    accumulation happens in VMEM between the recv-wait of hop h and the
    send of hop h+1 — GAScore's "handler runs on arrival" realized as a
    fused add.
    """
    n = n_nodes
    if x.shape[0] % n != 0:
        raise ValueError(f"dim0 {x.shape[0]} not divisible by {n}")
    m = x.shape[0] // n
    chunk_shape = (m,) + x.shape[1:]
    xb = x.reshape((n,) + chunk_shape)

    def kernel(x_ref, o_ref, acc, recv2, mine, csem, send_sems, recv_sems):
        me = lax.axis_index(axis)
        right = lax.rem(me + 1, n)
        # seed: my contribution to chunk (me - 1) mod n
        seed = pltpu.make_async_copy(
            x_ref.at[lax.rem(me - 1 + n, n)], acc, csem
        )
        seed.start()
        seed.wait()

        def hop(h, _):
            # Ship the partial sum to the right neighbor.  Per-hop
            # semaphores + ping-pong recv buffers: the neighbor's hop h+1
            # write may land while we still read hop h's packet; it goes to
            # the other recv slot.  It cannot run 2 hops ahead because its
            # hop h+2 send waits on our hop h+1 put, which we only issue
            # after consuming recv slot h%2.
            slot = lax.rem(h - 1, 2)
            rdma = pltpu.make_async_remote_copy(
                src_ref=acc,
                dst_ref=recv2.at[slot],
                send_sem=send_sems.at[h - 1],
                recv_sem=recv_sems.at[h - 1],
                device_id=compat.dma_device_id(right),
                device_id_type=pltpu.DeviceIdType.MESH,
            )
            rdma.start()
            rdma.wait()
            # the packet now here is for chunk c = me - h - 1 (h is 1-based)
            c = lax.rem(me - h - 1 + 2 * n, n)
            fetch = pltpu.make_async_copy(x_ref.at[c], mine, csem)
            fetch.start()
            fetch.wait()
            acc[...] = recv2[slot] + mine[...]
            return 0

        lax.fori_loop(1, n, hop, 0, unroll=False)
        out = pltpu.make_async_copy(acc, o_ref, csem)
        out.start()
        out.wait()

    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(chunk_shape, x.dtype),
        in_specs=[_any_spec()],
        out_specs=_any_spec(),
        scratch_shapes=[
            pltpu.VMEM(chunk_shape, x.dtype),
            pltpu.VMEM((2,) + chunk_shape, x.dtype),
            pltpu.VMEM(chunk_shape, x.dtype),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA((n - 1,)),
            pltpu.SemaphoreType.DMA((n - 1,)),
        ],
        interpret=_interp(interpret),
        name="gascore_ring_reduce_scatter",
    )(xb)
    return out

"""MoE routing engine: top-k gating + capacity slot assignment, fused.

This kernel is the Active-Message *schedule builder* of the paper realized
for MoE: every routed token is an AM (payload = hidden vector, destination =
expert, handler = expert FFN), and the router computes, per token, the
destination and the *capacity slot* (rank within the destination's buffer) —
exactly the computation ``repro.core.am.build_send_buffer`` performs for
general messages, here fused with softmax gating and iterative top-k on the
VPU so the (T, E) logits are read from HBM once.

The slot counters persist in VMEM scratch across the (sequential) token-
block grid dimension, making the rank assignment globally consistent in
token order — the property the combine step relies on and the hypothesis
tests check (slot uniqueness per expert, conservation of kept tokens).

Dispatch/combine themselves are dense one-hot einsums (``ops.moe_dispatch``/
``ops.moe_combine``) — the GSPMD-friendly form whose all-to-all over the
expert axis is scheduled by the partitioner; the router's slot map is what
makes them capacity-bounded.

Oracle: ``repro.kernels.ref.route_topk``.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

__all__ = ["moe_router"]

NEG_INF = -1e30


def _router_kernel(
    logits_ref,
    eidx_ref,
    slot_ref,
    w_ref,
    keep_ref,
    counts_scr,
    *,
    k: int,
    n_experts: int,
    capacity: int,
    renormalize: bool,
):
    ti = pl.program_id(0)

    @pl.when(ti == 0)
    def _init():
        counts_scr[...] = jnp.zeros_like(counts_scr)

    logits = logits_ref[...].astype(jnp.float32)  # (BT, E)
    bt = logits.shape[0]
    probs = jax.nn.softmax(logits, axis=-1)

    # iterative top-k (k is small: 1..8) — max+mask per step on the VPU
    masked = probs
    eye = lax.broadcasted_iota(jnp.int32, (bt, n_experts), 1)
    top_w = []
    top_e = []
    for _ in range(k):
        w = masked.max(axis=-1)
        e = jnp.argmax(masked, axis=-1).astype(jnp.int32)
        top_w.append(w)
        top_e.append(e)
        masked = jnp.where(eye == e[:, None], NEG_INF, masked)
    wmat = jnp.stack(top_w, axis=1)  # (BT, K)
    emat = jnp.stack(top_e, axis=1)  # (BT, K)
    if renormalize:
        wmat = wmat / jnp.maximum(wmat.sum(axis=1, keepdims=True), 1e-9)

    # capacity slots: rank of each (token, choice) within its expert, in
    # flat token-major order, offset by the running counters.
    flat_e = emat.reshape(-1)  # (BT*K,)
    oh = (flat_e[:, None] == lax.broadcasted_iota(
        jnp.int32, (bt * k, n_experts), 1)).astype(jnp.int32)
    excl = jnp.cumsum(oh, axis=0) - oh  # exclusive in-block rank
    rank_in_block = (excl * oh).sum(axis=-1)
    base = (counts_scr[0][None, :] * oh).sum(axis=-1)  # gather via one-hot dot
    slot = base + rank_in_block
    keep = slot < capacity

    counts_scr[0, :] = counts_scr[0, :] + oh.sum(axis=0)

    eidx_ref[...] = emat
    slot_ref[...] = slot.reshape(bt, k)
    w_ref[...] = wmat.astype(w_ref.dtype)
    keep_ref[...] = keep.reshape(bt, k)


@functools.partial(
    jax.jit,
    static_argnames=("k", "capacity", "renormalize", "block_t", "interpret"),
)
def moe_router(
    logits: jax.Array,
    *,
    k: int,
    capacity: int,
    renormalize: bool = True,
    block_t: int = 256,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Route tokens to experts with capacity-bounded slot assignment.

    Args:
      logits: (T, E) router logits.
      k: experts per token.
      capacity: per-expert buffer size C; choices ranked >= C are dropped.
      renormalize: renormalize the kept top-k weights to sum to 1.
      block_t: token block per grid step (sequential dimension).
    Returns:
      expert_idx (T, K) int32, slot (T, K) int32, weight (T, K) f32,
      keep (T, K) bool.
    """
    T, E = logits.shape
    block_t = min(block_t, T)
    if T % block_t:
        raise ValueError(f"T={T} not divisible by block_t={block_t}")
    nt = T // block_t

    kernel = functools.partial(
        _router_kernel,
        k=k,
        n_experts=E,
        capacity=capacity,
        renormalize=renormalize,
    )
    out_shapes = (
        jax.ShapeDtypeStruct((T, k), jnp.int32),
        jax.ShapeDtypeStruct((T, k), jnp.int32),
        jax.ShapeDtypeStruct((T, k), jnp.float32),
        jax.ShapeDtypeStruct((T, k), bool),
    )
    blk = lambda ti: (ti, 0)
    return pl.pallas_call(
        kernel,
        grid=(nt,),
        in_specs=[pl.BlockSpec((block_t, E), blk)],
        out_specs=tuple(pl.BlockSpec((block_t, k), blk) for _ in range(4)),
        out_shape=out_shapes,
        scratch_shapes=[pltpu.VMEM((1, E), jnp.int32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary",),
        ),
        interpret=compat.tpu_interpret(interpret),
        name="moe_router",
    )(logits)

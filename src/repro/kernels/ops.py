"""Public jit'd wrappers for all kernels, with software/hardware dispatch.

Every op takes ``impl`` ∈ {"pallas", "ref"}: "ref" is the pure-jnp oracle
(the verified *software node*), "pallas" the TPU kernel (the *hardware
node*).  Models call these wrappers, so migrating a hot spot between the
two is a config flag — the paper's development story.

On CPU the pallas path runs under TPU-interpret mode automatically; pass
``interpret=False`` on real TPUs.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import flash_attention_bwd as _fab
from repro.kernels import moe_dispatch as _moe
from repro.kernels import paged_attention as _pa
from repro.kernels import ref
from repro.kernels import rglru as _rglru
from repro.kernels import ssm_scan as _ssm

__all__ = [
    "attention",
    "paged_attention",
    "moe_router",
    "moe_dispatch",
    "moe_combine",
    "selective_scan",
    "gated_linear_scan",
    "aligned",
    "profiling_targets",
]


def aligned(dim: int, dtype=jnp.float32) -> bool:
    """True if ``dim`` is lane-aligned for full-speed TPU tiles."""
    del dtype
    return dim % 128 == 0


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    impl: str = "ref",
    interpret: bool = True,
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    if impl == "pallas":
        # differentiable: custom-VJP pairing the fwd kernel with the
        # blockwise dQ/dKV backward kernels (flash_attention_bwd.py)
        return _fab.flash_attention_vjp(
            q, k, v, causal, window, scale, block_q, block_k, interpret
        )
    if impl == "pallas_fwd":
        return _fa.flash_attention(
            q, k, v, causal=causal, window=window, scale=scale,
            block_q=block_q, block_k=block_k, interpret=interpret,
        )
    return ref.attention(q, k, v, causal=causal, window=window, scale=scale)


def paged_attention(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    page_table: jax.Array,
    lengths: jax.Array,
    *,
    scale: Optional[float] = None,
    impl: str = "ref",
    interpret: bool = True,
    pages_per_block: Optional[int] = None,
    block_b: Optional[int] = None,
) -> jax.Array:
    """Decode attention through a page table (the paged KV pool's compute
    side): q (B, Hq, D) against (P, T, Hkv, D) physical pages addressed by
    page_table (B, NP), masked at lengths (B,).

    ``pages_per_block``/``block_b`` tune the pallas kernel's DMA blocking
    (pages streamed per grid step / requests sharing a burst) — pure perf
    knobs, bit-identical output across settings; ignored by the oracle.
    """
    if impl == "pallas":
        return _pa.paged_attention(
            q, k_pages, v_pages, page_table, lengths,
            scale=scale, interpret=interpret,
            pages_per_block=pages_per_block, block_b=block_b,
        )
    return ref.paged_attention(
        q, k_pages, v_pages, page_table, lengths, scale=scale
    )


def moe_router(
    logits: jax.Array,
    *,
    k: int,
    capacity: int,
    renormalize: bool = True,
    impl: str = "ref",
    interpret: bool = True,
    block_t: int = 256,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    if impl == "pallas":
        return _moe.moe_router(
            logits, k=k, capacity=capacity, renormalize=renormalize,
            block_t=block_t, interpret=interpret,
        )
    return ref.route_topk(logits, k=k, capacity=capacity, renormalize=renormalize)


# dispatch/combine are dense scatters/gathers; XLA handles them well and the
# GSPMD partitioner schedules the EP all-to-all.  They are thin and shared.
moe_dispatch = ref.moe_dispatch
moe_combine = ref.moe_combine


def selective_scan(
    x, dt, a, b, c, d, *, impl: str = "ref", interpret: bool = True,
    block_d: int = 512, block_s: int = 128,
) -> jax.Array:
    if impl == "pallas":
        return _ssm.selective_scan(
            x, dt, a, b, c, d, block_d=block_d, block_s=block_s,
            interpret=interpret,
        )
    if impl == "chunked":
        return ref.selective_scan_chunked(x, dt, a, b, c, d, chunk=block_s)
    return ref.selective_scan(x, dt, a, b, c, d)


def gated_linear_scan(
    a, b, *, impl: str = "ref", interpret: bool = True,
    block_d: int = 512, block_s: int = 128,
) -> jax.Array:
    if impl == "pallas":
        return _rglru.gated_linear_scan(
            a, b, block_d=block_d, block_s=block_s, interpret=interpret
        )
    if impl == "chunked":
        return ref.gated_linear_scan_chunked(a, b, chunk=block_s * 2)
    return ref.gated_linear_scan(a, b)


def profiling_targets(
    *,
    batch: int = 4,
    heads: int = 4,
    kv_heads: int = 2,
    head_dim: int = 64,
    n_pages: int = 16,
    page_tokens: int = 8,
    interpret: bool = True,
    seed: int = 0,
):
    """Named, jitted paged-attention closures over one synthetic decode
    shape — the targets :meth:`repro.obs.profile.DeviceProfiler.profile_many`
    interleaves to time the serving hot kernel against its oracle.

    Inputs are built once (device-resident after the first call) so each
    closure times *only* the kernel dispatch + execution; shapes follow
    the paged pool layout: q ``(B, Hq, D)`` against ``(P, T, Hkv, D)``
    physical pages through a ``(B, NP)`` table.  Returns a list of
    ``(name, fn, tags)`` tuples.
    """
    rng = jax.random.PRNGKey(seed)
    kq, kk, kv_, kl = jax.random.split(rng, 4)
    q = jax.random.normal(kq, (batch, heads, head_dim), jnp.float32)
    k_pages = jax.random.normal(
        kk, (n_pages, page_tokens, kv_heads, head_dim), jnp.float32)
    v_pages = jax.random.normal(
        kv_, (n_pages, page_tokens, kv_heads, head_dim), jnp.float32)
    per_req = n_pages // batch
    table = jnp.arange(batch * per_req, dtype=jnp.int32).reshape(
        batch, per_req) % n_pages
    lengths = jax.random.randint(
        kl, (batch,), page_tokens, per_req * page_tokens + 1
    ).astype(jnp.int32)

    def make(impl):
        @jax.jit
        def run():
            return paged_attention(
                q, k_pages, v_pages, table, lengths,
                impl=impl, interpret=interpret,
            )
        return run

    tags = {
        "batch": batch, "heads": heads, "kv_heads": kv_heads,
        "head_dim": head_dim, "n_pages": n_pages,
        "page_tokens": page_tokens,
    }
    return [
        ("paged_attention_pallas", make("pallas"), {**tags, "impl": "pallas"}),
        ("paged_attention_ref", make("ref"), {**tags, "impl": "ref"}),
    ]

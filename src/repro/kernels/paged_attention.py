"""Paged decode-attention kernel for TPU: K/V read through a page table.

The serving hot spot once the KV cache is page-granular (the global KV
pool of ``repro.serving.pool``): each request's cache is a list of
fixed-size token pages scattered through one physical pool array, and the
decode step must attend over them *in place* — no dense gather, no
per-request contiguous copy.

The page table rides the scalar-prefetch lane
(``pltpu.PrefetchScalarGridSpec``): it is available before the kernel
body runs, so the K/V ``BlockSpec`` index maps resolve the *physical*
page for grid step (b, h, p) and the HBM->VMEM pipeline DMAs exactly the
pages the request owns — the hardware analogue of the pool's one-sided
``get_nbv`` page fetch, one level down the memory hierarchy.

Online-softmax accumulation over the (sequential, innermost) logical-page
grid dimension, exactly like ``flash_attention``; GQA is resolved in the
index maps (one KV head's pages serve its whole query group).  Positions
past ``lengths[b]`` are masked, so padded page-table entries may point at
any physical page.

Oracle: ``repro.kernels.ref.paged_attention``.  Validated under interpret
mode; on real TPUs pass ``interpret=False``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

__all__ = ["paged_attention"]

NEG_INF = -1e30


def _pa_kernel(
    table_ref,  # scalar prefetch: (B * NP,) physical page ids
    len_ref,  # scalar prefetch: (B,) live lengths
    q_ref,  # (1, group, D)
    k_ref,  # (1, T, 1, D) — the physical page picked by the index map
    v_ref,  # (1, T, 1, D)
    o_ref,  # (1, group, D)
    m_scr,
    l_scr,
    acc_scr,
    *,
    scale: float,
    page_tokens: int,
    n_pages: int,
):
    del table_ref  # consumed by the index maps
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale  # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)  # (T, D)
    v = v_ref[0, :, 0].astype(jnp.float32)  # (T, D)

    s = lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (G, T)
    kpos = p * page_tokens + lax.broadcasted_iota(
        jnp.int32, s.shape, dimension=1
    )
    mask = kpos < len_ref[b]
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[:, 0]
    l_prev = l_scr[:, 0]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    pexp = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + pexp.sum(axis=-1)
    acc = acc_scr[...] * alpha[:, None] + lax.dot_general(
        pexp, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    m_scr[:, 0] = m_new
    l_scr[:, 0] = l_new
    acc_scr[...] = acc

    @pl.when(p == n_pages - 1)
    def _finalize():
        l = l_scr[:, 0]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "interpret")
)
def paged_attention(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    page_table: jax.Array,
    lengths: jax.Array,
    *,
    scale: Optional[float] = None,
    interpret: bool = True,
) -> jax.Array:
    """Decode attention over a paged KV pool.

    Args:
      q: (B, Hq, D) — one query token per request (decode step).
      k_pages, v_pages: (P, T, Hkv, D) — the physical page pool.
      page_table: (B, NP) int32 — physical page id of request b's logical
        page p; entries at or past ``ceil(lengths[b] / T)`` are masked and
        may hold any valid physical id.
      lengths: (B,) int32 — live cache positions per request.
    Returns:
      (B, Hq, D) in q.dtype.
    """
    B, Hq, D = q.shape
    P, T, Hkv, Dk = k_pages.shape
    if Dk != D:
        raise ValueError(f"head_dim mismatch: q has {D}, pages have {Dk}")
    if k_pages.shape != v_pages.shape:
        raise ValueError(
            f"k_pages {k_pages.shape} != v_pages {v_pages.shape}"
        )
    if Hq % Hkv != 0:
        raise ValueError(f"Hq={Hq} not a multiple of Hkv={Hkv}")
    if page_table.shape[0] != B or lengths.shape != (B,):
        raise ValueError("page_table/lengths batch mismatch")
    group = Hq // Hkv
    NP = page_table.shape[1]
    if scale is None:
        scale = 1.0 / (D**0.5)

    kernel = functools.partial(
        _pa_kernel, scale=scale, page_tokens=T, n_pages=NP
    )

    def kv_map(b, h, p, table, lens):
        del lens
        return (table[b * NP + p], 0, h, 0)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, Hkv, NP),
            in_specs=[
                pl.BlockSpec(
                    (1, group, D), lambda b, h, p, table, lens: (b, h, 0)
                ),
                pl.BlockSpec((1, T, 1, D), kv_map),
                pl.BlockSpec((1, T, 1, D), kv_map),
            ],
            out_specs=pl.BlockSpec(
                (1, group, D), lambda b, h, p, table, lens: (b, h, 0)
            ),
            scratch_shapes=[
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hq, D), q.dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=compat.tpu_interpret(interpret),
        name="paged_attention_decode",
    )(page_table.reshape(-1).astype(jnp.int32), lengths.astype(jnp.int32),
      q, k_pages, v_pages)
    return out

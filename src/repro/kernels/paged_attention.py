"""Paged decode-attention kernel for TPU: K/V read through a page table.

The serving hot spot once the KV cache is page-granular (the global KV
pool of ``repro.serving.pool``): each request's cache is a list of
fixed-size token pages scattered through one physical pool array, and the
decode step must attend over them *in place* — no dense gather, no
per-request contiguous copy.

Serving-grade blocking (the SMI lesson: decouple message granularity
from transfer granularity — here, page granularity from kernel-grid
granularity):

- **Batch blocking** — the grid is ``(B/BLOCK_B, Hkv, NP/PAGES_PER_BLOCK)``,
  so one kernel program serves ``BLOCK_B`` requests at once and their
  page DMAs are issued as one burst per block instead of one grid step
  per (request, page).
- **Page-block streaming** — K/V stay in HBM (``memory_space=ANY``); the
  kernel resolves physical pages through the scalar-prefetched table and
  copies ``BLOCK_B x PAGES_PER_BLOCK`` pages per grid step into VMEM
  scratch with explicit ``make_async_copy`` DMAs — the hardware analogue
  of the pool's one-sided vectored ``get_nbv`` page fetch, one level down
  the memory hierarchy.
- **Double buffering** — two VMEM slots: the next page block's DMA burst
  is issued *before* the current block's compute, so the wire hides
  behind the online-softmax work exactly like the split-phase GASNet
  ops hide behind the decode step.

Blocking is a pure perf knob, never a semantics knob: the per-request
online-softmax update is computed page by page in logical order with
shapes independent of ``BLOCK_B``/``PAGES_PER_BLOCK``, so the output is
bit-identical across block settings (property-tested in
``tests/test_properties.py``).

Positions past ``lengths[b]`` are masked *before* the running max and V
is zeroed at masked positions, so padded page-table entries may point at
any physical page — even one holding NaN garbage.

Oracle: ``repro.kernels.ref.paged_attention``.  Validated under interpret
mode; on real TPUs pass ``interpret=False``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

__all__ = ["paged_attention", "DEFAULT_PAGES_PER_BLOCK", "DEFAULT_BLOCK_B"]

NEG_INF = -1e30

# Default blocking: 4 requests share each DMA burst, 4 pages stream per
# grid step (tuned for decode shapes where pages are small and the grid
# overhead of one-(request, page)-per-step dominates).
DEFAULT_BLOCK_B = 4
DEFAULT_PAGES_PER_BLOCK = 4


def _pa_kernel(
    table_ref,  # scalar prefetch: (B * NP,) physical page ids
    len_ref,  # scalar prefetch: (B,) live lengths
    q_ref,  # (BLOCK_B, group, D)
    k_hbm,  # (P, T, Hkv, D) — full pool, memory_space=ANY
    v_hbm,  # (P, T, Hkv, D)
    o_ref,  # (BLOCK_B, group, D)
    k_buf,  # VMEM (2, BLOCK_B, PPB, T, D) double-buffered page blocks
    v_buf,
    sems,  # DMA semaphores (2, 2, BLOCK_B, PPB)
    m_scr,  # (BLOCK_B, group)
    l_scr,
    acc_scr,  # (BLOCK_B, group, D)
    *,
    scale: float,
    page_tokens: int,
    n_pages: int,
    block_b: int,
    pages_per_block: int,
):
    bb = pl.program_id(0)
    h = pl.program_id(1)
    pb = pl.program_id(2)
    npb = pl.num_programs(2)
    T = page_tokens

    def issue(slot, blk):
        """One DMA burst: every (request, page) of one page block."""
        for i in range(block_b):
            gb = bb * block_b + i
            for j in range(pages_per_block):
                # clamp ragged tails: the copied page is fully masked
                gp = jnp.minimum(blk * pages_per_block + j, n_pages - 1)
                page = table_ref[gb * n_pages + gp]
                pltpu.make_async_copy(
                    k_hbm.at[page, :, h, :], k_buf.at[slot, i, j],
                    sems.at[0, slot, i, j],
                ).start()
                pltpu.make_async_copy(
                    v_hbm.at[page, :, h, :], v_buf.at[slot, i, j],
                    sems.at[1, slot, i, j],
                ).start()

    def wait(slot):
        for i in range(block_b):
            for j in range(pages_per_block):
                pltpu.make_async_copy(
                    k_hbm.at[0, :, h, :], k_buf.at[slot, i, j],
                    sems.at[0, slot, i, j],
                ).wait()
                pltpu.make_async_copy(
                    v_hbm.at[0, :, h, :], v_buf.at[slot, i, j],
                    sems.at[1, slot, i, j],
                ).wait()

    @pl.when(pb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)
        issue(0, 0)

    @pl.when(pb + 1 < npb)
    def _prefetch():
        # next block's wire time hides behind this block's compute
        issue((pb + 1) % 2, pb + 1)

    slot = pb % 2
    wait(slot)

    for i in range(block_b):
        gb = bb * block_b + i
        q = q_ref[i].astype(jnp.float32) * scale  # (G, D)
        m_prev = m_scr[i, :]
        l_prev = l_scr[i, :]
        acc = acc_scr[i]
        # pages combine in logical order with BLOCK-INDEPENDENT shapes:
        # bit-identical across (block_b, pages_per_block) settings
        for j in range(pages_per_block):
            gp = pb * pages_per_block + j
            k = k_buf[slot, i, j].astype(jnp.float32)  # (T, D)
            v = v_buf[slot, i, j].astype(jnp.float32)
            s = lax.dot_general(
                q, k, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            )  # (G, T)
            kpos = gp * T + lax.broadcasted_iota(
                jnp.int32, s.shape, dimension=1
            )
            mask = kpos < len_ref[gb]
            # mask BEFORE the running max and zero V at masked slots:
            # garbage (even NaN) in padded pages never reaches the output
            s = jnp.where(mask, s, NEG_INF)
            v = jnp.where(mask[0][:, None], v, 0.0)
            m_new = jnp.maximum(m_prev, s.max(axis=-1))
            pexp = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
            alpha = jnp.exp(m_prev - m_new)
            l_prev = alpha * l_prev + pexp.sum(axis=-1)
            acc = acc * alpha[:, None] + lax.dot_general(
                pexp, v, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            m_prev = m_new
        m_scr[i, :] = m_prev
        l_scr[i, :] = l_prev
        acc_scr[i] = acc

    @pl.when(pb == npb - 1)
    def _finalize():
        for i in range(block_b):
            l = l_scr[i, :]
            denom = jnp.where(l == 0.0, 1.0, l)
            o_ref[i] = (acc_scr[i] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "pages_per_block", "block_b", "interpret"),
)
def paged_attention(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    page_table: jax.Array,
    lengths: jax.Array,
    *,
    scale: Optional[float] = None,
    pages_per_block: Optional[int] = None,
    block_b: Optional[int] = None,
    interpret: bool = True,
) -> jax.Array:
    """Decode attention over a paged KV pool.

    Args:
      q: (B, Hq, D) — one query token per request (decode step).
      k_pages, v_pages: (P, T, Hkv, D) — the physical page pool.
      page_table: (B, NP) int32 — physical page id of request b's logical
        page p; entries at or past ``ceil(lengths[b] / T)`` are masked and
        may hold any valid physical id (even pages holding garbage).
      lengths: (B,) int32 — live cache positions per request.
      pages_per_block: physical pages streamed per grid step (default
        ``DEFAULT_PAGES_PER_BLOCK``, clamped to NP).  Perf knob only —
        the output is bit-identical across settings.
      block_b: requests sharing one DMA burst (default
        ``DEFAULT_BLOCK_B``, clamped to B).  Perf knob only.
    Returns:
      (B, Hq, D) in q.dtype.
    """
    B, Hq, D = q.shape
    P, T, Hkv, Dk = k_pages.shape
    if Dk != D:
        raise ValueError(f"head_dim mismatch: q has {D}, pages have {Dk}")
    if k_pages.shape != v_pages.shape:
        raise ValueError(
            f"k_pages {k_pages.shape} != v_pages {v_pages.shape}"
        )
    if Hq % Hkv != 0:
        raise ValueError(f"Hq={Hq} not a multiple of Hkv={Hkv}")
    if page_table.shape[0] != B or lengths.shape != (B,):
        raise ValueError("page_table/lengths batch mismatch")
    group = Hq // Hkv
    NP = page_table.shape[1]
    if scale is None:
        scale = 1.0 / (D**0.5)
    ppb = max(1, min(pages_per_block or DEFAULT_PAGES_PER_BLOCK, NP))
    blk_b = max(1, min(block_b or DEFAULT_BLOCK_B, B))
    npb = -(-NP // ppb)

    # pad the batch to a multiple of the block; padded rows have length 0
    # (their output is zeros and dropped below) and table entries 0
    Bp = -(-B // blk_b) * blk_b
    table = page_table.astype(jnp.int32)
    lens = lengths.astype(jnp.int32)
    qq = q
    if Bp != B:
        qq = jnp.pad(q, ((0, Bp - B), (0, 0), (0, 0)))
        table = jnp.pad(table, ((0, Bp - B), (0, 0)))
        lens = jnp.pad(lens, ((0, Bp - B),))

    kernel = functools.partial(
        _pa_kernel,
        scale=scale,
        page_tokens=T,
        n_pages=NP,
        block_b=blk_b,
        pages_per_block=ppb,
    )

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(Bp // blk_b, Hkv, npb),
            in_specs=[
                pl.BlockSpec(
                    (blk_b, group, D),
                    lambda b, h, p, table, lens: (b, h, 0),
                ),
                pl.BlockSpec(memory_space=pltpu.ANY),
                pl.BlockSpec(memory_space=pltpu.ANY),
            ],
            out_specs=pl.BlockSpec(
                (blk_b, group, D), lambda b, h, p, table, lens: (b, h, 0)
            ),
            scratch_shapes=[
                pltpu.VMEM((2, blk_b, ppb, T, D), k_pages.dtype),
                pltpu.VMEM((2, blk_b, ppb, T, D), v_pages.dtype),
                pltpu.SemaphoreType.DMA((2, 2, blk_b, ppb)),
                pltpu.VMEM((blk_b, group), jnp.float32),
                pltpu.VMEM((blk_b, group), jnp.float32),
                pltpu.VMEM((blk_b, group, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((Bp, Hq, D), q.dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=compat.tpu_interpret(interpret),
        name="paged_attention_decode",
    )(table.reshape(-1), lens, qq, k_pages, v_pages)
    return out[:B]

"""Pure-jnp oracles for every kernel in ``repro.kernels``.

These are the semantic ground truth: simple, obviously-correct, unfused
implementations that the Pallas kernels are swept against (shapes × dtypes)
in ``tests/test_kernels.py``.  They are also the "software node" compute
path in the paper's sense — the version you verify first, then migrate to
the hardware engine.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

__all__ = [
    "ring_shift",
    "perm_put",
    "all_gather",
    "reduce_scatter",
    "all_reduce",
    "all_to_all",
    "attention",
    "paged_attention",
    "route_topk",
    "selective_scan",
    "gated_linear_scan",
]


# --------------------------------------------------------------------------- #
# GAScore collectives: oracles act on the GLOBAL (n_nodes-leading) array
# --------------------------------------------------------------------------- #
def ring_shift(x_global: np.ndarray, k: int) -> np.ndarray:
    """Node (i+k) receives node i's data == roll by +k along axis 0."""
    return np.roll(x_global, k, axis=0)


def perm_put(x_global: np.ndarray, dst: Tuple[int, ...]) -> np.ndarray:
    out = np.zeros_like(x_global)
    for s, d in enumerate(dst):
        out[d] = x_global[s]
    return out


def all_gather(x_global: np.ndarray) -> np.ndarray:
    """(n, m, ...) locals -> every node holds the (n*m, ...) concatenation."""
    n = x_global.shape[0]
    full = x_global.reshape((n * x_global.shape[1],) + x_global.shape[2:])
    return np.stack([full] * n)


def reduce_scatter(x_global: np.ndarray) -> np.ndarray:
    """(n, n*m, ...) contributions -> node i holds sum over nodes of chunk i."""
    n = x_global.shape[0]
    m = x_global.shape[1] // n
    summed = x_global.sum(axis=0).reshape((n, m) + x_global.shape[2:])
    return summed


def all_reduce(x_global: np.ndarray) -> np.ndarray:
    s = x_global.sum(axis=0)
    return np.stack([s] * x_global.shape[0])


def all_to_all(x_global: np.ndarray) -> np.ndarray:
    """(n, n*m, ...) -> out[r, s*m:(s+1)*m] = x[s, r*m:(r+1)*m]."""
    n = x_global.shape[0]
    m = x_global.shape[1] // n
    blocks = x_global.reshape((n, n, m) + x_global.shape[2:])
    return np.swapaxes(blocks, 0, 1).reshape(x_global.shape)


# --------------------------------------------------------------------------- #
# attention
# --------------------------------------------------------------------------- #
def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    """Unfused softmax attention with GQA/causal/window; f32 internals."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D**0.5)
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk",
        q.astype(jnp.float32) * scale,
        kx.astype(jnp.float32),
    )
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
        if not causal:
            mask &= (kpos - qpos) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows give uniform p; zero them like the kernel does
    any_visible = mask.any(axis=-1)[None, None, :, None]
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vx.astype(jnp.float32))
    out = jnp.where(any_visible, out, 0.0)
    return out.astype(q.dtype)


def paged_attention(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    page_table: jax.Array,
    lengths: jax.Array,
    *,
    scale: Optional[float] = None,
) -> jax.Array:
    """Decode attention reading K/V through a page table (unfused oracle).

    q: (B, Hq, D) — one query token per request.
    k_pages / v_pages: (P, T, Hkv, D) — the physical page pool: P pages of
      T tokens each (the KV pool's carrier blocks, unflattened).
    page_table: (B, NP) int32 — request b's logical page p lives in
      physical page ``page_table[b, p]``; entries past the live length may
      point anywhere (they are masked).
    lengths: (B,) int32 — number of live cache positions per request.
    """
    B, Hq, D = q.shape
    _, T, Hkv, _ = k_pages.shape
    NP = page_table.shape[1]
    S = NP * T
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D**0.5)
    # gather: (B, NP, T, Hkv, D) -> (B, Hkv, S, D)
    kd = jnp.moveaxis(k_pages[page_table].reshape(B, S, Hkv, D), 1, 2)
    vd = jnp.moveaxis(v_pages[page_table].reshape(B, S, Hkv, D), 1, 2)
    kx = jnp.repeat(kd, group, axis=1)  # (B, Hq, S, D)
    vx = jnp.repeat(vd, group, axis=1)
    s = jnp.einsum(
        "bhd,bhsd->bhs",
        q.astype(jnp.float32) * scale,
        kx.astype(jnp.float32),
    )
    valid = jnp.arange(S)[None, :] < lengths[:, None]  # (B, S)
    s = jnp.where(valid[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # zero V at masked positions too: masked probabilities are ~0 but
    # 0 * NaN = NaN, and padded table slots may point at garbage pages
    vx = jnp.where(valid[:, None, :, None], vx.astype(jnp.float32), 0.0)
    out = jnp.einsum("bhs,bhsd->bhd", p, vx)
    any_visible = valid.any(axis=-1)[:, None, None]
    return jnp.where(any_visible, out, 0.0).astype(q.dtype)


# --------------------------------------------------------------------------- #
# MoE routing
# --------------------------------------------------------------------------- #
def route_topk(
    logits: jax.Array, *, k: int, capacity: int, renormalize: bool = True
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Sequential-oracle top-k routing with capacity slots (token order)."""
    T, E = logits.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, e = jax.lax.top_k(probs, k)  # (T, K)
    if renormalize:
        w = w / jnp.maximum(w.sum(axis=-1, keepdims=True), 1e-9)
    flat_e = e.reshape(-1)
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    excl = jnp.cumsum(oh, axis=0) - oh
    slot = (excl * oh).sum(-1)
    keep = slot < capacity
    return (
        e.astype(jnp.int32),
        slot.reshape(T, k).astype(jnp.int32),
        w.astype(jnp.float32),
        keep.reshape(T, k),
    )


def moe_dispatch(
    tokens: jax.Array,
    expert_idx: jax.Array,
    slot: jax.Array,
    keep: jax.Array,
    *,
    n_experts: int,
    capacity: int,
) -> jax.Array:
    """(T, D) tokens -> (E, C, D) expert buffers (dropped rows zero)."""
    T, D = tokens.shape
    K = expert_idx.shape[1]
    buf = jnp.zeros((n_experts, capacity, D), tokens.dtype)
    for j in range(K):
        e = expert_idx[:, j]
        s = jnp.where(keep[:, j], slot[:, j], 0)
        contrib = jnp.where(keep[:, j, None], tokens, 0)
        buf = buf.at[e, s].add(contrib, mode="drop")
    return buf


def moe_combine(
    expert_out: jax.Array,
    expert_idx: jax.Array,
    slot: jax.Array,
    weight: jax.Array,
    keep: jax.Array,
) -> jax.Array:
    """(E, C, D) expert outputs -> (T, D) weighted combination."""
    T, K = expert_idx.shape
    rows = expert_out[expert_idx, slot]  # (T, K, D)
    w = jnp.where(keep, weight, 0.0)
    return (rows * w[..., None]).sum(axis=1).astype(expert_out.dtype)


# --------------------------------------------------------------------------- #
# scans
# --------------------------------------------------------------------------- #
def selective_scan(
    x: jax.Array,
    dt: jax.Array,
    a: jax.Array,
    b: jax.Array,
    c: jax.Array,
    d: jax.Array,
) -> jax.Array:
    """lax.scan oracle of the mamba1 recurrence (f32 internals)."""
    B, S, Di = x.shape
    N = a.shape[1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    df = d.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp  # (B,Di) (B,Di) (B,N) (B,N)
        decay = jnp.exp(dtt[..., None] * af[None])  # (B, Di, N)
        drive = (dtt * xt)[..., None] * bt[:, None, :]
        h = decay * h + drive
        yt = (h * ct[:, None, :]).sum(-1) + df[None] * xt
        return h, yt

    h0 = jnp.zeros((B, Di, N), jnp.float32)
    xs = (
        jnp.moveaxis(xf, 1, 0),
        jnp.moveaxis(dtf, 1, 0),
        jnp.moveaxis(bf, 1, 0),
        jnp.moveaxis(cf, 1, 0),
    )
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)


def selective_scan_chunked(
    x: jax.Array,
    dt: jax.Array,
    a: jax.Array,
    b: jax.Array,
    c: jax.Array,
    d: jax.Array,
    chunk: int = 128,
) -> jax.Array:
    """Chunked associative-scan mamba1 (exact; no per-timestep ops).

    The per-timestep ``lax.scan`` form emits one tiny collective per step in
    the backward pass when d_inner is tensor-sharded (measured: ~5e5
    all-reduces for falcon train_4k).  This form runs
    ``lax.associative_scan`` inside fixed-size chunks — decay factors stay
    in (0, 1] so the product form is numerically safe — and carries the
    state across chunks, reducing the sequential depth from S to S/chunk
    and the backward collectives by the same factor.
    """
    B, S, Di = x.shape
    N = a.shape[1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    xf = jnp.pad(x.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
    dtf = jnp.pad(dt.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
    bf = jnp.pad(b.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
    cf = jnp.pad(c.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
    af = a.astype(jnp.float32)
    df = d.astype(jnp.float32)
    nc = xf.shape[1] // chunk

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, br + ar * bl

    def chunk_step(h0, inp):
        xt, dtt, bt, ct = inp  # (B,c,Di) (B,c,Di) (B,c,N) (B,c,N)
        decay = jnp.exp(dtt[..., None] * af[None, None])  # (B,c,Di,N)
        drive = (dtt * xt)[..., None] * bt[:, :, None, :]
        A, Bv = lax.associative_scan(combine, (decay, drive), axis=1)
        h = A * h0[:, None] + Bv  # (B,c,Di,N)
        y = (h * ct[:, :, None, :]).sum(-1) + df[None, None] * xt
        return h[:, -1], y

    xs = tuple(
        jnp.moveaxis(t.reshape(B, nc, chunk, -1), 1, 0)
        for t in (xf, dtf, bf, cf)
    )
    h0 = jnp.zeros((B, Di, N), jnp.float32)
    _, ys = lax.scan(chunk_step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, nc * chunk, Di)[:, :S]
    return y.astype(x.dtype)


def gated_linear_scan_chunked(a: jax.Array, b: jax.Array,
                              chunk: int = 256) -> jax.Array:
    """Chunked associative form of h_t = a_t h_{t-1} + b_t (see above)."""
    B, S, D = a.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    af = jnp.pad(a.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)),
                 constant_values=1.0)
    bf = jnp.pad(b.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
    nc = af.shape[1] // chunk

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, br + ar * bl

    def chunk_step(h0, inp):
        at, bt = inp
        A, Bv = lax.associative_scan(combine, (at, bt), axis=1)
        h = A * h0[:, None] + Bv
        return h[:, -1], h

    xs = tuple(
        jnp.moveaxis(t.reshape(B, nc, chunk, D), 1, 0) for t in (af, bf)
    )
    h0 = jnp.zeros((B, D), jnp.float32)
    _, ys = lax.scan(chunk_step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, nc * chunk, D)[:, :S]
    return y.astype(b.dtype)


def gated_linear_scan(a: jax.Array, b: jax.Array) -> jax.Array:
    """lax.scan oracle of h_t = a_t h_{t-1} + b_t (f32 internals)."""

    def step(h, inp):
        at, bt = inp
        h = at * h + bt
        return h, h

    af = jnp.moveaxis(a.astype(jnp.float32), 1, 0)
    bf = jnp.moveaxis(b.astype(jnp.float32), 1, 0)
    h0 = jnp.zeros(af.shape[1:], jnp.float32)
    _, ys = jax.lax.scan(step, h0, (af, bf))
    return jnp.moveaxis(ys, 0, 1).astype(b.dtype)

"""RG-LRU gated diagonal linear recurrence (recurrentgemma), fused for TPU.

  h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

with a_t already materialized by the block (a_t = exp(-c·softplus(Λ)·r_t)).
The kernel takes the generic form ``h_t = a_t ⊙ h_{t-1} + b_t`` so it
doubles as a fused scan for any diagonal gated recurrence; the RG-LRU
gating algebra lives in the model layer (it is elementwise and fuses there).

Grid: (batch, D/block_d, S/block_s), time sequential, state in VMEM scratch.
Oracle: ``repro.kernels.ref.gated_linear_scan`` (lax.scan / associative_scan).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

__all__ = ["gated_linear_scan"]


def _lru_kernel(a_ref, b_ref, y_ref, h_scr, *, block_s: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    def step(t, h):
        at = a_ref[0, t].astype(jnp.float32)
        bt = b_ref[0, t].astype(jnp.float32)
        h = at * h + bt
        y_ref[0, t] = h.astype(y_ref.dtype)
        return h

    h = lax.fori_loop(0, block_s, step, h_scr[0])
    h_scr[0, :] = h


@functools.partial(
    jax.jit, static_argnames=("block_d", "block_s", "interpret")
)
def gated_linear_scan(
    a: jax.Array,
    b: jax.Array,
    *,
    block_d: int = 512,
    block_s: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """y_t = a_t * y_{t-1} + b_t along axis 1.

    Args:
      a, b: (B, S, D); ``a`` in [0, 1).
    Returns:
      (B, S, D) scan output in b.dtype.
    """
    B, S, D = a.shape
    block_d = min(block_d, D)
    block_s = min(block_s, S)
    if D % block_d or S % block_s:
        raise ValueError(f"(S={S}, D={D}) not divisible by ({block_s},{block_d})")
    nd, ns = D // block_d, S // block_s

    kernel = functools.partial(_lru_kernel, block_s=block_s)
    spec = pl.BlockSpec((1, block_s, block_d), lambda bi, di, si: (bi, si, di))
    return pl.pallas_call(
        kernel,
        grid=(B, nd, ns),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, S, D), b.dtype),
        scratch_shapes=[pltpu.VMEM((1, block_d), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=compat.tpu_interpret(interpret),
        name="rglru_gated_linear_scan",
    )(a, b)

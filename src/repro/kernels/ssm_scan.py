"""Mamba-1 selective-state-space scan, fused for TPU.

The CUDA selective-scan kernel's reason to exist is avoiding the
(B, S, d_inner, N) materialization of the per-step transition/input terms;
we adapt that insight to TPU: the recurrence runs over time *inside* VMEM
with the state laid out as (N, block_d) — N=16 f32 sublanes × 128-lane
multiples of d_inner — so each step is a handful of full-width VPU ops and
nothing of size (S, d, N) ever touches HBM.

Grid: (batch, d_inner/block_d, S/block_s) with the time dimension
sequential; the state h persists in VMEM scratch across time blocks.

  h_t = exp(Δ_t ⊙ A) ⊙ h_{t-1} + (Δ_t ⊙ x_t) ⊗ B_t
  y_t = Cᵀ_t h_t + D ⊙ x_t

Oracle: ``repro.kernels.ref.selective_scan`` (lax.scan).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

__all__ = ["selective_scan"]


def _ssm_kernel(
    x_ref,  # (1, BS, BD)
    dt_ref,  # (1, BS, BD)
    a_ref,  # (N, BD)   A transposed
    b_ref,  # (1, BS, N)
    c_ref,  # (1, BS, N)
    d_ref,  # (1, BD)
    y_ref,  # (1, BS, BD)
    h_scr,  # (N, BD) f32
    *,
    block_s: int,
):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[...].astype(jnp.float32)  # (N, BD)
    dvec = d_ref[0].astype(jnp.float32)  # (BD,)

    def step(t, h):
        xt = x_ref[0, t].astype(jnp.float32)  # (BD,)
        dtt = dt_ref[0, t].astype(jnp.float32)  # (BD,)
        bt = b_ref[0, t].astype(jnp.float32)  # (N,)
        ct = c_ref[0, t].astype(jnp.float32)  # (N,)
        decay = jnp.exp(dtt[None, :] * a)  # (N, BD)
        drive = (dtt * xt)[None, :] * bt[:, None]  # (N, BD)
        h = decay * h + drive
        yt = (h * ct[:, None]).sum(axis=0) + dvec * xt  # (BD,)
        y_ref[0, t] = yt.astype(y_ref.dtype)
        return h

    h = lax.fori_loop(0, block_s, step, h_scr[...])
    h_scr[...] = h


@functools.partial(
    jax.jit, static_argnames=("block_d", "block_s", "interpret")
)
def selective_scan(
    x: jax.Array,
    dt: jax.Array,
    a: jax.Array,
    b: jax.Array,
    c: jax.Array,
    d: jax.Array,
    *,
    block_d: int = 512,
    block_s: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Fused mamba1 scan.

    Args:
      x:  (B, S, Di) post-conv activations.
      dt: (B, S, Di) positive step sizes (softplus already applied).
      a:  (Di, N) negative-real transition diagonal.
      b:  (B, S, N) input projections.
      c:  (B, S, N) output projections.
      d:  (Di,) skip gains.
    Returns:
      y: (B, S, Di), same dtype as x.
    """
    B, S, Di = x.shape
    N = a.shape[1]
    block_d = min(block_d, Di)
    block_s = min(block_s, S)
    if Di % block_d or S % block_s:
        raise ValueError(f"(S={S}, Di={Di}) not divisible by ({block_s},{block_d})")
    nd, ns = Di // block_d, S // block_s

    at = a.T  # (N, Di): lanes = model dim
    drow = d[None, :]  # (1, Di)

    kernel = functools.partial(_ssm_kernel, block_s=block_s)
    return pl.pallas_call(
        kernel,
        grid=(B, nd, ns),
        in_specs=[
            pl.BlockSpec((1, block_s, block_d), lambda bi, di, si: (bi, si, di)),
            pl.BlockSpec((1, block_s, block_d), lambda bi, di, si: (bi, si, di)),
            pl.BlockSpec((N, block_d), lambda bi, di, si: (0, di)),
            pl.BlockSpec((1, block_s, N), lambda bi, di, si: (bi, si, 0)),
            pl.BlockSpec((1, block_s, N), lambda bi, di, si: (bi, si, 0)),
            pl.BlockSpec((1, block_d), lambda bi, di, si: (0, di)),
        ],
        out_specs=pl.BlockSpec(
            (1, block_s, block_d), lambda bi, di, si: (bi, si, di)
        ),
        out_shape=jax.ShapeDtypeStruct((B, S, Di), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, block_d), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=compat.tpu_interpret(interpret),
        name="mamba1_selective_scan",
    )(x, dt, at, b, c, drow)

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this builds the full-scale model ABSTRACTLY (eval_shape — no
parameter allocation), constructs the jit'd step with explicit in/out
shardings, then::

    lowered  = jax.jit(step, in_shardings=..., ...).lower(*structs)
    compiled = lowered.compile()
    compiled.memory_analysis()   # proves the per-device working set
    compiled.cost_analysis()     # FLOPs / bytes for the roofline
    parse(compiled.as_text())    # per-type collective operand bytes

and writes one JSON record per cell (results/dryrun/<cell>.json) that
EXPERIMENTS.md §Dry-run and §Roofline read.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-405b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import dataclasses
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCHS, SHAPES, cell_runnable
from repro.launch.mesh import make_production_mesh, mesh_axes
from repro.models.build import ShapeConfig, build_model
from repro.optim import adamw
from repro.parallel.ctx import RunCtx

COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(m: re.Match) -> int:
    dt, dims = m.group(1), m.group(2)
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def parse_collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Sum operand bytes of every collective op in (post-SPMD) HLO."""
    per_type: Dict[str, int] = {c: 0 for c in COLLECTIVES}
    counts: Dict[str, int] = {c: 0 for c in COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "fusion" in s.split("=")[0]:
            continue
        for coll in COLLECTIVES:
            # match "= TYPE[...] coll(" and "coll-start(" variants
            if re.search(rf"\b{coll}(-start)?\(", s):
                # operand types appear inline in the argument list
                try:
                    args = s.split(f"{coll}", 1)[1]
                    args = args.split("(", 1)[1]
                except IndexError:
                    continue
                depth = 1
                arg_str = []
                for ch in args:
                    if ch == "(":
                        depth += 1
                    elif ch == ")":
                        depth -= 1
                        if depth == 0:
                            break
                    arg_str.append(ch)
                arg_str = "".join(arg_str)
                b = sum(_shape_bytes(m) for m in _SHAPE_RE.finditer(arg_str))
                per_type[coll] += b
                counts[coll] += 1
                break
    total = sum(per_type.values())
    return {"per_type": per_type, "counts": counts, "total": total}


# --------------------------------------------------------------------------- #
def build_ctx(mesh, *, attn_chunk: int = 512, remat: str = "full",
              moe_mode: str = "auto", fsdp_gather: bool = False,
              seq_shard_acts: bool = False, scan_impl: str = "ref") -> RunCtx:
    dp, tp = mesh_axes(mesh)
    return RunCtx(
        mesh=mesh, dp=dp, tp=tp, remat=remat, moe_mode=moe_mode,
        attn_impl="chunked", attn_chunk=attn_chunk, scan_impl=scan_impl,
        interpret=True, fsdp_gather=fsdp_gather,
        seq_shard_acts=seq_shard_acts,
    )


def make_step(model, ctx: RunCtx, shape: ShapeConfig, opt_cfg=None):
    """Returns (fn, arg_structs, in_shardings) for the cell's step kind."""
    from repro.parallel.sharding import named_shardings

    mesh = ctx.mesh
    opt_cfg = opt_cfg or adamw.AdamWConfig(
        schedule=adamw.warmup_cosine(3e-4, 2000, 100000),
        state_dtype=jnp.float32 if model.cfg.d_model < 8192 else jnp.bfloat16,
    )

    params_struct, specs = model.abstract_init(ctx)
    p_shard = named_shardings(specs, params_struct, mesh)

    batch_structs = model.input_structs(shape)
    batch_shard = named_shardings(
        model.input_specs(shape, ctx), batch_structs, mesh
    )

    if shape.kind == "train":
        opt_struct = jax.eval_shape(
            lambda p: adamw.init_state(p, opt_cfg), params_struct
        )
        o_shard = named_shardings(
            adamw.state_specs(specs), opt_struct, mesh
        )

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: model.train_loss(p, ctx, batch)
            )(params)
            params, opt_state, metrics = adamw.apply_updates(
                params, grads, opt_state, opt_cfg
            )
            metrics["loss"] = loss
            return params, opt_state, metrics

        fn = jax.jit(
            train_step,
            in_shardings=(p_shard, o_shard, batch_shard),
            out_shardings=(p_shard, o_shard, None),
            donate_argnums=(0, 1),
        )
        return fn, (params_struct, opt_struct, batch_structs)

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            return model.prefill(params, ctx, batch, cache_len=shape.seq_len)

        fn = jax.jit(
            prefill_step, in_shardings=(p_shard, batch_shard)
        )
        return fn, (params_struct, batch_structs)

    # decode
    cache_struct = model.cache_structs(shape, ctx)
    cache_shard = named_shardings(
        model.cache_specs(cache_struct, ctx), cache_struct, mesh
    )

    def serve_step(params, token, positions, caches):
        return model.decode_step(params, ctx, token, positions, caches)

    fn = jax.jit(
        serve_step,
        in_shardings=(
            p_shard,
            batch_shard["token"],
            batch_shard["positions"],
            cache_shard,
        ),
        donate_argnums=(3,),
    )
    structs = (
        params_struct,
        batch_structs["token"],
        batch_structs["positions"],
        cache_struct,
    )
    return fn, structs


def run_cell(
    arch: str,
    shape_name: str,
    mesh_kind: str,
    *,
    out_dir: str = "results/dryrun",
    overrides: Optional[Dict[str, Any]] = None,
    tag: str = "baseline",
) -> Dict[str, Any]:
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    overrides = overrides or {}
    ctx = build_ctx(mesh, **overrides.get("ctx", {}))
    model = build_model(
        dataclasses.replace(cfg, **overrides.get("cfg", {}))
        if overrides.get("cfg")
        else cfg
    )
    rec: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "tag": tag,
        "n_devices": mesh.devices.size,
        "status": "ok",
    }
    t0 = time.time()
    try:
        fn, structs = make_step(model, ctx, shape)
        lowered = fn.lower(*structs)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)
        try:
            ma = compiled.memory_analysis()
            rec["memory"] = {
                k: int(getattr(ma, k))
                for k in (
                    "argument_size_in_bytes",
                    "output_size_in_bytes",
                    "temp_size_in_bytes",
                    "alias_size_in_bytes",
                    "generated_code_size_in_bytes",
                )
                if hasattr(ma, k)
            }
        except Exception as e:  # pragma: no cover
            rec["memory"] = {"error": str(e)}
        try:
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0]
            rec["cost_xla"] = {
                "flops": float(ca.get("flops", -1)),
                "bytes_accessed": float(ca.get("bytes accessed", -1)),
            }
        except Exception as e:  # pragma: no cover
            rec["cost_xla"] = {"error": str(e)}
        text = compiled.as_text()
        # trip-count-aware analysis (XLA's cost_analysis counts while
        # bodies once; see launch/hlostats.py)
        from repro.launch import hlostats

        st = hlostats.analyze(text)
        rec["cost"] = {"flops": st.flops, "bytes_accessed": st.bytes}
        rec["collectives"] = {
            "per_type": st.collective_per_type,
            "counts": st.collective_counts,
            "total": st.collective_bytes,
        }
        rec["while_trips"] = {
            k: v for k, v in sorted(st.while_trips.items())[:40]
        }
        rec["unresolved_whiles"] = st.unresolved_whiles
        rec["hlo_chars"] = len(text)
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    finally:
        jax.clear_caches()  # bound sweep memory: drop executables between cells
    rec["total_s"] = round(time.time() - t0, 1)

    os.makedirs(out_dir, exist_ok=True)
    fname = f"{arch}__{shape_name}__{mesh_kind}__{tag}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--attn-chunk", type=int, default=512)
    ap.add_argument("--moe-mode", default="auto")
    ap.add_argument("--fsdp-gather", action="store_true")
    ap.add_argument("--seq-shard-acts", action="store_true")
    ap.add_argument("--scan-impl", default="ref")
    args = ap.parse_args()

    cells = []
    archs = list(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for a in archs:
        for s in shapes:
            ok, why = cell_runnable(a, s)
            if not ok:
                print(f"SKIP {a} × {s}: {why}")
                continue
            for m in meshes:
                cells.append((a, s, m))

    overrides = {
        "ctx": {
            "remat": args.remat,
            "attn_chunk": args.attn_chunk,
            "moe_mode": args.moe_mode,
            "fsdp_gather": args.fsdp_gather,
            "seq_shard_acts": args.seq_shard_acts,
            "scan_impl": args.scan_impl,
        }
    }
    for a, s, m in cells:
        rec = run_cell(a, s, m, out_dir=args.out, overrides=overrides,
                       tag=args.tag)
        status = rec["status"]
        extra = (
            f"flops={rec.get('cost', {}).get('flops', 0):.3e} "
            f"coll={rec.get('collectives', {}).get('total', 0):.3e}B "
            f"lower={rec.get('lower_s')}s compile={rec.get('compile_s')}s"
            if status == "ok"
            else rec.get("error", "")
        )
        print(f"[{status}] {a} × {s} × {m}: {extra}", flush=True)


if __name__ == "__main__":
    main()

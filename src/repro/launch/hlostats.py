"""Trip-count-aware cost analysis over compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
scan-over-layers model under-reports flops/bytes/collective traffic by the
layer count (demonstrated in tests/test_hlostats.py).  This module
re-derives the three roofline inputs from the compiled module text with
loop multiplication:

1. parse every computation, building a symbol table
   instruction/parameter name -> result type (operand types are NOT inline
   in scheduled HLO; they are resolved through the table);
2. recover each ``while`` loop's trip count from its
   ``backend_config={"known_trip_count":{"n":...}}`` (emitted by XLA's
   induction-variable analysis), falling back to the condition
   computation's compare-against-constant;
3. cost bottom-up: cost(while) = trips × (body + cond);
   fusion/call/map/conditional costs recurse into called computations.

Per-instruction model:
  flops      — dot: 2 × result numel × contracted size (MXU term);
               arithmetic elementwise/reduce: 1 flop per output element
               (VPU term; matters for the SSM/LRU scan cells).
  bytes      — operand + result sizes of materializing ops (fusion, dot,
               copy, custom-call, collectives, dynamic-update-slice...) —
               an HBM-traffic model in the TPU sense: every instruction
               boundary in scheduled HLO is a materialization point.
  collective — operand bytes per collective type (all-gather, all-reduce,
               reduce-scatter, all-to-all, collective-permute).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze", "HloStats"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->\s*(.*?)\s*\{\s*$")
_INST_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"
    r"((?:\([^()]*\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s*"
    r"([\w\-]+)\((.*)$"
)

COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs",
    "compare", "select", "and", "or", "xor", "exponential-minus-one",
    "log-plus-one", "sine", "cosine", "atan2", "reduce", "clamp",
    "round-nearest-even", "sign", "floor", "ceil", "logistic", "erf",
}

_MATERIALIZING = {
    "fusion", "dot", "copy", "custom-call", "dynamic-update-slice",
    "dynamic-slice", "gather", "scatter", "transpose", "reshape", "pad",
    "slice", "concatenate", "broadcast", "convert", "iota", "reduce",
    "sort", "select-and-scatter", "reduce-window", "rng",
}


def _shapes_in(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _type_bytes(type_text: str) -> int:
    total = 0
    for dt, dims in _shapes_in(type_text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _type_numel(type_text: str) -> int:
    total = 0
    for _, dims in _shapes_in(type_text):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclasses.dataclass
class Inst:
    name: str
    opcode: str
    result_type: str
    rest: str  # args + attrs (everything after the opening paren)

    def operand_names(self) -> List[str]:
        # operands are the %names before the closing paren of the arg list
        depth = 1
        buf = []
        for ch in self.rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            buf.append(ch)
        args = "".join(buf)
        return re.findall(r"%([\w\.\-]+)", args)

    @property
    def attrs(self) -> str:
        depth = 1
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return self.rest[i + 1:]
        return ""


@dataclasses.dataclass
class Computation:
    name: str
    insts: List[Inst]
    types: Dict[str, str]  # symbol -> result type text


@dataclasses.dataclass
class HloStats:
    flops: float
    bytes: float
    collective_bytes: float
    collective_per_type: Dict[str, float]
    collective_counts: Dict[str, float]
    while_trips: Dict[str, int]
    unresolved_whiles: List[str]

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


def _parse(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m and ("->" in line):
                cur = Computation(name=m.group(2), insts=[], types={})
                if m.group(1):
                    entry = m.group(2)
                # parameters from header: "name: type, name: type"
                for pm in re.finditer(
                    r"([\w\.\-]+):\s*((?:\([^()]*\))|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)",
                    m.group(3),
                ):
                    cur.types[pm.group(1)] = pm.group(2)
            continue
        if line.strip() == "}" or line.strip().startswith("} "):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INST_RE.match(line)
        if m:
            inst = Inst(
                name=m.group(1), result_type=m.group(2),
                opcode=m.group(3), rest=m.group(4),
            )
            cur.insts.append(inst)
            cur.types[inst.name] = inst.result_type
    return comps, entry


def _called(inst: Inst) -> List[str]:
    names = []
    for key in ("calls", "body", "condition", "to_apply",
                "true_computation", "false_computation"):
        for m in re.finditer(rf"{key}=%?([\w\.\-]+)", inst.attrs):
            names.append(m.group(1))
    for m in re.finditer(r"branch_computations=\{([^}]*)\}", inst.attrs):
        names.extend(p.strip().lstrip("%") for p in m.group(1).split(","))
    return names


def _trip_from_backend_config(inst: Inst) -> Optional[int]:
    m = re.search(r"backend_config=(\{.*?\})(?:,|$| )", inst.attrs)
    if not m:
        m = re.search(r"backend_config=(\{.*\})\s*$", inst.attrs)
    if not m:
        return None
    try:
        # backend_config JSON may contain nested braces; grab greedily
        start = inst.attrs.index("backend_config=") + len("backend_config=")
        depth = 0
        end = start
        for i in range(start, len(inst.attrs)):
            if inst.attrs[i] == "{":
                depth += 1
            elif inst.attrs[i] == "}":
                depth -= 1
                if depth == 0:
                    end = i + 1
                    break
        cfg = json.loads(inst.attrs[start:end])
        n = cfg.get("known_trip_count", {}).get("n")
        return int(n) if n is not None else None
    except Exception:
        return None


def _trip_from_condition(cond: Computation) -> Optional[int]:
    consts: Dict[str, int] = {}
    for inst in cond.insts:
        if inst.opcode == "constant":
            m = re.search(r"constant\((-?\d+)\)", f"{inst.opcode}({inst.rest}")
            if m:
                consts[inst.name] = int(m.group(1))
    best = None
    for inst in cond.insts:
        if inst.opcode == "compare" or "compare" in inst.rest:
            for op in inst.operand_names():
                if op in consts and consts[op] > 0:
                    best = max(best or 0, consts[op])
    return best


def analyze(text: str) -> HloStats:
    comps, entry = _parse(text)
    trips: Dict[str, int] = {}
    unresolved: List[str] = []

    for comp in comps.values():
        for inst in comp.insts:
            if inst.opcode != "while":
                continue
            mb = re.search(r"body=%?([\w\.\-]+)", inst.attrs)
            mc = re.search(r"condition=%?([\w\.\-]+)", inst.attrs)
            body = mb.group(1) if mb else None
            t = _trip_from_backend_config(inst)
            if t is None and mc and mc.group(1) in comps:
                t = _trip_from_condition(comps[mc.group(1)])
            if body:
                if t is None:
                    unresolved.append(inst.name)
                    t = 1
                trips[body] = t
                if mc:
                    trips[mc.group(1)] = t  # reuse map for cond comp

    memo: Dict[str, Tuple[float, float, float, Dict[str, float], Dict[str, float]]] = {}

    def cost(cname: str):
        if cname in memo:
            return memo[cname]
        comp = comps.get(cname)
        zero = (0.0, 0.0, 0.0,
                {c: 0.0 for c in COLLECTIVES}, {c: 0.0 for c in COLLECTIVES})
        if comp is None:
            return zero
        fl = by = co = 0.0
        ct = {c: 0.0 for c in COLLECTIVES}
        cn = {c: 0.0 for c in COLLECTIVES}

        def operand_bytes(inst: Inst) -> int:
            return sum(_type_bytes(comp.types.get(o, "")) for o in
                       inst.operand_names())

        for inst in comp.insts:
            op = inst.opcode
            if op == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", inst.attrs)
                mc = re.search(r"condition=%?([\w\.\-]+)", inst.attrs)
                t = trips.get(mb.group(1), 1) if mb else 1
                for sub in filter(None, [mb and mb.group(1), mc and mc.group(1)]):
                    sf, sb, sc, sct, scn = cost(sub)
                    fl += t * sf
                    by += t * sb
                    co += t * sc
                    for k in COLLECTIVES:
                        ct[k] += t * sct[k]
                        cn[k] += t * scn[k]
                continue
            for sub in _called(inst):
                sf, sb, sc, sct, scn = cost(sub)
                fl += sf
                by += sb
                co += sc
                for k in COLLECTIVES:
                    ct[k] += sct[k]
                    cn[k] += scn[k]
            base = op.replace("-start", "").replace("-done", "")
            if op == "dot":
                rnumel = _type_numel(inst.result_type)
                ops = inst.operand_names()
                lhs_type = comp.types.get(ops[0], "") if ops else ""
                contracted = 1
                m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", inst.attrs)
                shp = _shapes_in(lhs_type)
                if m and shp:
                    dims = shp[0][1]
                    for idx in m.group(1).split(","):
                        if idx and int(idx) < len(dims):
                            contracted *= dims[int(idx)]
                fl += 2.0 * rnumel * contracted
                by += _type_bytes(inst.result_type) + operand_bytes(inst)
            elif base in COLLECTIVES:
                if op.endswith("-done"):
                    continue
                b = operand_bytes(inst)
                co += b
                ct[base] += b
                cn[base] += 1
                by += b + _type_bytes(inst.result_type)
            elif op in _ELEMENTWISE:
                fl += _type_numel(inst.result_type)
                by += _type_bytes(inst.result_type) + operand_bytes(inst)
            elif op in _MATERIALIZING:
                by += _type_bytes(inst.result_type) + operand_bytes(inst)
        memo[cname] = (fl, by, co, ct, cn)
        return memo[cname]

    if entry is None:
        for n in comps:
            if n.startswith("main"):
                entry = n
    fl, by, co, ct, cn = cost(entry) if entry else (0, 0, 0, {}, {})
    return HloStats(
        flops=fl,
        bytes=by,
        collective_bytes=co,
        collective_per_type=ct,
        collective_counts=cn,
        while_trips=trips,
        unresolved_whiles=unresolved,
    )

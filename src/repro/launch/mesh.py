"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches JAX device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before the first
JAX device query, and smoke tests/benches must keep seeing 1 device.

Axis roles:
  pod   — inter-pod links (the expensive OCCC-like hop): pure data parallel
          by default, pipeline stages with ``--pipeline``.
  data  — intra-pod FSDP/data-parallel (batch + parameter dim 0).
  model — tensor/expert parallel (heads, d_ff columns, experts, vocab).

Heterogeneous node maps: a mesh axis can mix software and hardware GASNet
nodes (the paper's x86/ARM + GAScore cluster).  :func:`node_backends`
builds the per-rank backend tuple that ``repro.core.engine.make_engine``
(or ``gasnet.Context(backend=...)``) turns into an ``EngineMap``.
"""
from __future__ import annotations

from typing import Iterable, Optional, Tuple

import jax

__all__ = [
    "make_production_mesh",
    "make_mesh",
    "mesh_axes",
    "node_backends",
    "serve_roles",
    "decode_groups",
    "role_backends",
    "promote_spare",
]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> jax.sharding.Mesh:
    """Arbitrary mesh for tests / elastic restarts."""
    return jax.make_mesh(shape, axes)


def mesh_axes(mesh: jax.sharding.Mesh) -> Tuple[Tuple[str, ...], str]:
    """(dp_axes, tp_axis) role assignment for a mesh by convention."""
    names = mesh.axis_names
    tp = "model" if "model" in names else names[-1]
    dp = tuple(n for n in names if n != tp)
    return dp, tp


def node_backends(
    n_nodes: int,
    *,
    hw_ranks: Optional[Iterable[int]] = None,
    pattern: Optional[str] = None,
    software: str = "xla",
    hardware: str = "gascore",
) -> Tuple[str, ...]:
    """Per-rank engine backends for a heterogeneous node map.

    Either name the hardware ranks explicitly (``hw_ranks={1, 3}``) or
    pick a ``pattern``:

    - ``"alternating"`` — odd ranks are hardware nodes (the paper's mixed
      racks: every CPU node paired with an FPGA node),
    - ``"split"``       — the upper half of the ring is hardware,
    - ``None``          — all software.

    Feed the result to ``make_engine(...)`` / ``gasnet.Context(backend=...)``.
    """
    if hw_ranks is not None and pattern is not None:
        raise ValueError("pass hw_ranks or pattern, not both")
    if hw_ranks is not None:
        hw = {int(r) % n_nodes for r in hw_ranks}
    elif pattern == "alternating":
        hw = {r for r in range(n_nodes) if r % 2 == 1}
    elif pattern == "split":
        hw = set(range(n_nodes // 2, n_nodes))
    elif pattern is None:
        hw = set()
    else:
        raise ValueError(f"unknown node-map pattern {pattern!r}")
    return tuple(hardware if r in hw else software for r in range(n_nodes))


def serve_roles(
    n_prefill: int,
    n_decode: int,
    n_memory: int = 0,
    tp: int = 1,
    n_spare: int = 0,
) -> Tuple[str, ...]:
    """Per-rank roles of a disaggregated serving ring: the first
    ``n_prefill`` ranks are the prefill pool, then the decode pool, then
    ``n_memory`` *memory* ranks — the paper's memory-node archetype:
    ranks that export segment capacity into the global address space but
    run no model compute (the second tier of the KV hierarchy; see
    ``repro.serving.tier``).

    The convention is load-bearing: `repro.serving.disagg` derives
    dispatch targets, the KV handoff permutation, swap destinations, and
    segment slot ownership from rank order alone, so every node agrees on
    it without any exchange (the SPMD analogue of a static cluster map).

    ``tp`` carves the decode pool into tensor-parallel groups of ``tp``
    consecutive ranks (see :func:`decode_groups`): it must divide
    ``n_decode``, and every member of a group keeps the ``"decode"``
    role — group structure is a decode-pool refinement, not a new role.

    ``n_spare`` trailing *spare* ranks join the ring idle (segment
    capacity reserved, no assigned work) and are promoted into a pool by
    :func:`promote_spare` at elastic scale-out: membership changes
    without re-launching the job, since the ring size — which every
    permutation and segment shape depends on — never changes.
    """
    if n_prefill < 1 or n_decode < 1 or n_memory < 0 or n_spare < 0:
        raise ValueError(
            f"need at least 1 prefill and 1 decode rank (memory/spare "
            f">= 0), got {n_prefill}/{n_decode}/{n_memory}/{n_spare}"
        )
    if tp < 1 or n_decode % tp:
        raise ValueError(
            f"tp={tp} must divide the decode pool (n_decode={n_decode})"
        )
    return (
        ("prefill",) * n_prefill
        + ("decode",) * n_decode
        + ("memory",) * n_memory
        + ("spare",) * n_spare
    )


def decode_groups(
    n_prefill: int, n_decode: int, tp: int = 1
) -> Tuple[Tuple[int, ...], ...]:
    """The decode pool carved into TP groups of ``tp`` consecutive ranks.

    Group ``g`` is ranks ``[n_prefill + g*tp, n_prefill + (g+1)*tp)``;
    its first member is the *group leader* — the rank whose pool shard
    backs the group's page allocator and which receives the control-plane
    AMs (KV-ready, acks).  Consecutive placement keeps the per-step
    all-reduce on ring-adjacent edges.
    """
    serve_roles(n_prefill, n_decode, tp=tp)  # validate
    return tuple(
        tuple(range(n_prefill + g * tp, n_prefill + (g + 1) * tp))
        for g in range(n_decode // tp)
    )


def role_backends(
    roles: Tuple[str, ...],
    *,
    prefill: str = "xla",
    decode: str = "xla",
    memory: str = "xla",
    spare: Optional[str] = None,
) -> Tuple[str, ...]:
    """Per-rank engine backends keyed by serving role.

    The paper's split maps naturally onto disaggregation: prefill nodes
    can stay software GASNet nodes (``"xla"``) while the decode pool —
    whose KV installs are pure remote-DMA traffic — runs on hardware
    nodes (``"gascore"``), or any other mix; memory ranks (pure segment
    exporters, the FPGA memory-node archetype) take their own engine too.
    Feed the result to ``make_engine`` / ``gasnet.Context(backend=...)``
    to get an ``EngineMap`` when the pools differ.  Spare ranks default
    to the decode engine (they are promoted into the decode pool).
    """
    table = {
        "prefill": prefill,
        "decode": decode,
        "memory": memory,
        "spare": decode if spare is None else spare,
    }
    try:
        return tuple(table[r] for r in roles)
    except KeyError as e:
        raise ValueError(f"unknown serving role {e.args[0]!r}") from None


def promote_spare(
    roles: Tuple[str, ...], rank: int, to: str = "decode"
) -> Tuple[str, ...]:
    """Elastic scale-out: promote spare ``rank`` into pool ``to`` and
    return the regenerated role map.  Only ``"spare"`` ranks promote (a
    live pool member never changes role mid-job), and the ring size is
    unchanged — every derived permutation stays valid."""
    if not (0 <= rank < len(roles)):
        raise ValueError(f"rank {rank} outside the {len(roles)}-rank ring")
    if roles[rank] != "spare":
        raise ValueError(
            f"rank {rank} has role {roles[rank]!r}, only spares promote"
        )
    if to not in ("prefill", "decode", "memory"):
        raise ValueError(f"cannot promote a spare to {to!r}")
    return roles[:rank] + (to,) + roles[rank + 1 :]

"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches JAX device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before the first
JAX device query, and smoke tests/benches must keep seeing 1 device.

Axis roles:
  pod   — inter-pod links (the expensive OCCC-like hop): pure data parallel
          by default, pipeline stages with ``--pipeline``.
  data  — intra-pod FSDP/data-parallel (batch + parameter dim 0).
  model — tensor/expert parallel (heads, d_ff columns, experts, vocab).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

__all__ = ["make_production_mesh", "make_mesh", "mesh_axes"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> jax.sharding.Mesh:
    """Arbitrary mesh for tests / elastic restarts."""
    return jax.make_mesh(shape, axes)


def mesh_axes(mesh: jax.sharding.Mesh) -> Tuple[Tuple[str, ...], str]:
    """(dp_axes, tp_axis) role assignment for a mesh by convention."""
    names = mesh.axis_names
    tp = "model" if "model" in names else names[-1]
    dp = tuple(n for n in names if n != tp)
    return dp, tp

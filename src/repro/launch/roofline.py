"""Roofline derivation from dry-run artifacts.

Three terms per (arch × shape × mesh) cell, in seconds-per-step:

    compute    = HLO_FLOPs_global   / (chips × 197e12  bf16 FLOP/s)
    memory     = HLO_bytes_global   / (chips × 819e9   B/s HBM)
    collective = collective_bytes   / (chips × 50e9    B/s per ICI link)

``compiled.cost_analysis()`` on the SPMD-partitioned executable reports
PER-DEVICE flops/bytes (verified against an analytic matmul in
tests/test_roofline.py), so global = per_device × chips.  Collective bytes
are per-device operand sums from the HLO text (each device injects its
operand onto its links).

The dominant term is the bottleneck; MODEL_FLOPS/HLO_FLOPs measures how
much compiled compute is "useful" (remat recompute, padding and dead work
show up here).  MODEL_FLOPS = 6·N_active·tokens for training (fwd+bwd),
2·N_active·tokens for inference steps.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Any, Dict, List, Optional

from repro.configs.registry import ARCHS, SHAPES

PEAK_FLOPS = 197e12  # bf16 / chip (TPU v5e-class)
HBM_BW = 819e9  # B/s per chip
ICI_BW = 50e9  # B/s per link

__all__ = ["derive", "load_records", "table"]


def analytic_memory_bytes(arch: str, shape_name: str, chips: int,
                          state_bytes: int = 4) -> float:
    """Per-device HBM traffic per step under a TPU-quality schedule.

    The HLO-text bytes model (``hlostats.bytes``) charges every scheduled-HLO
    instruction boundary, which reflects CPU fusion granularity — orders of
    magnitude above what a fused TPU schedule moves (recorded in the JSON as
    the pessimistic bound).  The roofline *memory term* instead uses this
    analytic minimum: weights + optimizer states + saved activations + KV
    traffic, each moved the minimum number of times:

    train:   weights read fwd + bwd (bf16), grad write+read (f32),
             m/v read+write (state_bytes), param read+write;
             activations: one residual stream per layer saved + reread +
             recomputed under full remat (3 moves of B·S·D·2B per layer).
    prefill: weights once + KV cache write + activations write/read once.
    decode:  weights once (all experts resident for MoE: every expert is
             hit at batch>=128·topk) + full KV cache read + one slot write.
    """
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    total, active = cfg.param_counts()
    B, S = shape.global_batch, shape.seq_len
    D, L = cfg.d_model, cfg.n_layers + cfg.n_enc_layers
    KH, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
    w_b = 2  # bf16 weights
    if shape.kind == "train":
        weight_traffic = total * (2 * w_b + 2 * 4 + 2 * state_bytes * 2
                                  + 2 * w_b)
        act_traffic = L * B * S * D * w_b * 3  # save + reread + recompute
        return (weight_traffic + act_traffic) / chips
    if shape.kind == "prefill":
        kv = L * B * S * KH * Dh * 2 * w_b if not cfg.attention_free else (
            L * B * (cfg.resolved_d_inner * cfg.ssm_state) * 4)
        act = L * B * S * D * w_b * 2
        return (total * w_b + kv + act) / chips
    # decode: per generated token
    if cfg.attention_free:
        state = L * B * cfg.resolved_d_inner * (cfg.ssm_state + 3) * 4 * 2
        return (total * w_b + state) / chips
    window = cfg.local_window if "local" in cfg.pattern else S
    kinds = cfg.layer_kinds()
    kv_read = B * KH * Dh * 2 * w_b * sum(
        min(S, window) if k == "local" else
        (0 if k in ("mamba",) else S) for k in kinds
    )
    return (total * w_b + kv_read) / chips


def model_flops(arch: str, shape_name: str) -> float:
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    _, n_active = cfg.param_counts()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def derive(rec: Dict[str, Any]) -> Optional[Dict[str, Any]]:
    if rec.get("status") != "ok":
        return None
    chips = rec["n_devices"]
    flops_dev = rec["cost"].get("flops", 0.0)
    sched_bytes_dev = rec["cost"].get("bytes_accessed", 0.0)
    mem_dev = analytic_memory_bytes(rec["arch"], rec["shape"], chips)
    coll_dev = rec["collectives"]["total"]
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = mem_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_global = flops_dev * chips
    useful = mf / hlo_global if hlo_global else 0.0
    # roofline fraction: useful-compute time over the bound set by the
    # dominant term (how close the step is to the best this hardware allows
    # given the compiled schedule)
    t_useful = (mf / chips) / PEAK_FLOPS
    bound = max(terms.values())
    return {
        **{k: rec[k] for k in ("arch", "shape", "mesh", "tag", "n_devices")},
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "sched_bytes_dev": sched_bytes_dev,
        "mem_bytes_dev": mem_dev,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_ratio": useful,
        "roofline_fraction": (t_useful / bound) if bound else 0.0,
        "collectives": rec["collectives"]["per_type"],
        "memory": rec.get("memory", {}),
    }


def load_records(out_dir: str = "results/dryrun") -> List[Dict[str, Any]]:
    recs = []
    for fn in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


def _fmt_t(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}µs"


def table(out_dir: str = "results/dryrun", tag: str = None) -> str:
    rows = []
    for rec in load_records(out_dir):
        if tag and rec.get("tag") != tag:
            continue
        d = derive(rec)
        if d is None:
            rows.append(
                f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | "
                f"ERROR: {rec.get('error', '?')[:60]} | | | | | |"
            )
            continue
        rows.append(
            "| {arch} | {shape} | {mesh} | {tc} | {tm} | {tl} | **{dom}** | "
            "{ur:.2f} | {rf:.2f} |".format(
                arch=d["arch"], shape=d["shape"], mesh=d["mesh"],
                tc=_fmt_t(d["t_compute_s"]), tm=_fmt_t(d["t_memory_s"]),
                tl=_fmt_t(d["t_collective_s"]), dom=d["dominant"],
                ur=d["useful_ratio"], rf=d["roofline_fraction"],
            )
        )
    header = (
        "| arch | shape | mesh | compute | memory | collective | dominant | "
        "useful | roofline frac |\n|---|---|---|---|---|---|---|---|---|"
    )
    return header + "\n" + "\n".join(rows)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--tag", default=None)
    args = ap.parse_args()
    print(table(args.dir, args.tag))

"""Batched serving driver: prefill/decode with continuous batching (lite).

Request lifecycle: queued -> prefilled (KV cache slot assigned) -> decoding
in the fixed-width decode batch -> finished (EOS or max tokens) -> slot
recycled for the next queued request.

The decode step is one jit'd ``model.decode_step`` over the whole batch;
per-row positions let rows be at different generation depths (continuous
batching).  Prefill runs per-request (production would batch prefills and
overlap them with decode on separate cores; the scheduler hook is where
disaggregated prefill would hand the KV cache over the GAS layer — see
examples/heterogeneous_pipeline.py for that transfer demonstrated with
one-sided puts).

CPU-scale demo: ``python -m repro.launch.serve --arch qwen3-4b --smoke``.
"""
import argparse
import dataclasses
import time
from typing import Any, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int = 16
    out: List[int] = dataclasses.field(default_factory=list)
    t_enqueue: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


class Server:
    """Fixed-decode-batch continuous batching over Model prefill/decode."""

    def __init__(self, model, ctx, params, batch_size: int, cache_len: int,
                 eos_id: int = -1, greedy: bool = True, seed: int = 0):
        import jax
        import jax.numpy as jnp

        self.jax, self.jnp = jax, jnp
        self.model = model
        self.ctx = ctx
        self.params = params
        self.B = batch_size
        self.cache_len = cache_len
        self.eos_id = eos_id
        self.greedy = greedy
        self.key = jax.random.PRNGKey(seed)

        self.active: List[Optional[Request]] = [None] * batch_size
        self.positions = np.zeros((batch_size,), np.int32)
        self.last_token = np.zeros((batch_size, 1), np.int32)
        self.caches = None  # lazily built from first prefill
        self.queue: List[Request] = []
        self.finished: List[Request] = []

        self._decode = jax.jit(
            lambda p, t, pos, c: model.decode_step(p, ctx, t, pos, c)
        )
        self._prefill_one = jax.jit(
            lambda p, b: model.prefill(p, ctx, b, cache_len=cache_len)
        )

    # ------------------------------------------------------------------ #
    def submit(self, req: Request) -> None:
        req.t_enqueue = time.monotonic()
        self.queue.append(req)

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.active):
            if r is None:
                return i
        return None

    def _write_row(self, caches_one, slot: int) -> None:
        """Insert a single-request cache into batch row ``slot``."""
        jnp = self.jnp
        if self.caches is None:
            # build an empty batched cache from the single-row structure
            self.caches = self.jax.tree.map(
                lambda x: jnp.zeros((x.shape[0], self.B) + x.shape[2:],
                                    x.dtype),
                caches_one,
            )
        self.caches = self.jax.tree.map(
            lambda full, one: full.at[:, slot].set(one[:, 0]),
            self.caches, caches_one,
        )

    def _admit(self) -> None:
        while self.queue:
            slot = self._free_slot()
            if slot is None:
                return
            req = self.queue.pop(0)
            toks = self.jnp.asarray(req.prompt, self.jnp.int32)[None]
            logits, caches_one = self._prefill_one(
                self.params, {"inputs": toks}
            )
            tok = int(np.argmax(np.asarray(logits)[0]))
            req.out.append(tok)
            req.t_first = time.monotonic()
            self.active[slot] = req
            self.positions[slot] = len(req.prompt)
            self.last_token[slot, 0] = tok
            self._write_row(caches_one, slot)

    def _retire(self, slot: int) -> None:
        req = self.active[slot]
        req.t_done = time.monotonic()
        self.finished.append(req)
        self.active[slot] = None

    # ------------------------------------------------------------------ #
    def step(self) -> int:
        """One scheduler tick: admit, decode one token for all rows."""
        self._admit()
        live = [i for i, r in enumerate(self.active) if r is not None]
        if not live or self.caches is None:
            return 0
        jnp = self.jnp
        logits, self.caches = self._decode(
            self.params,
            jnp.asarray(self.last_token),
            jnp.asarray(self.positions),
            self.caches,
        )
        logits = np.asarray(logits)
        for i in live:
            req = self.active[i]
            tok = int(np.argmax(logits[i]))
            req.out.append(tok)
            self.positions[i] += 1
            self.last_token[i, 0] = tok
            if tok == self.eos_id or len(req.out) >= req.max_new:
                self._retire(i)
            if self.positions[i] >= self.cache_len - 1:
                self._retire(i)
        return len(live)

    def run_until_drained(self, max_ticks: int = 10000) -> Dict[str, Any]:
        t0 = time.monotonic()
        decoded = 0
        ticks = 0
        while (self.queue or any(self.active)) and ticks < max_ticks:
            decoded += self.step()
            ticks += 1
        dt = time.monotonic() - t0
        lat = [r.t_done - r.t_enqueue for r in self.finished]
        ttft = [r.t_first - r.t_enqueue for r in self.finished]
        return {
            "requests": len(self.finished),
            "decoded_tokens": decoded,
            "wall_s": dt,
            "tok_per_s": decoded / dt if dt else 0.0,
            "p50_latency_s": float(np.median(lat)) if lat else 0.0,
            "p50_ttft_s": float(np.median(ttft)) if ttft else 0.0,
        }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--cache-len", type=int, default=64)
    args = ap.parse_args()

    import jax

    from repro.configs.registry import SMOKE
    from repro.models.build import build_model
    from repro.parallel.ctx import RunCtx

    cfg = SMOKE[args.arch]
    model = build_model(cfg)
    ctx = RunCtx(mesh=None, remat="none")
    params, _ = model.init(ctx, jax.random.PRNGKey(0))
    server = Server(model, ctx, params, args.batch, args.cache_len)

    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        server.submit(
            Request(
                rid=rid,
                prompt=rng.integers(0, cfg.vocab, size=args.prompt_len).tolist(),
                max_new=args.max_new,
            )
        )
    stats = server.run_until_drained()
    for k, v in stats.items():
        print(f"{k}: {v}")


if __name__ == "__main__":
    main()

"""Role-based serving launcher: colocated or disaggregated prefill/decode.

Request lifecycle: queued -> prefilled (KV cache slot assigned) -> decoding
in the fixed-width decode batch -> finished (EOS or max tokens) -> slot
recycled for the next queued request.

The decode step is one jit'd ``model.decode_step`` over the whole batch;
per-row positions let rows be at different generation depths (continuous
batching).

Roles (``--role``):

- ``both`` (default) — the disaggregated cluster: a prefill pool and a
  decode pool as distinct GASNet ranks (``launch.mesh.serve_roles``, each
  pool optionally on its own engine via ``EngineMap``); finished KV caches
  cross over the GAS layer with ``sched.plan_p2p``-planned segmented puts
  and an AM request/reply control plane (``repro.serving.disagg``).
  Needs >= 2 host devices (set ``XLA_FLAGS`` before JAX imports).
- ``decode`` — the colocated path: one node prefills and decodes
  (:class:`Server` continuous batching, unchanged).  With ``--paged`` the
  KV cache lives in the global paged pool (:class:`PagedServer`): pages
  allocated and freed per admitted request, prompt prefixes shared by
  page table, token-identical to the dense server.
- ``prefill`` — the prefill pool alone: computes prefills and reports KV
  blocks/s, the feeder-side capacity number.

CPU-scale demo: ``python -m repro.launch.serve --arch qwen3-4b --smoke``.
"""
import argparse
import dataclasses
import time
from typing import Any, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int = 16
    out: List[int] = dataclasses.field(default_factory=list)
    t_enqueue: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


class Server:
    """Fixed-decode-batch continuous batching over Model prefill/decode."""

    def __init__(self, model, ctx, params, batch_size: int, cache_len: int,
                 eos_id: int = -1, greedy: bool = True, seed: int = 0):
        import jax
        import jax.numpy as jnp

        self.jax, self.jnp = jax, jnp
        self.model = model
        self.ctx = ctx
        self.params = params
        self.B = batch_size
        self.cache_len = cache_len
        self.eos_id = eos_id
        self.greedy = greedy
        self.key = jax.random.PRNGKey(seed)

        self.active: List[Optional[Request]] = [None] * batch_size
        self.positions = np.zeros((batch_size,), np.int32)
        self.last_token = np.zeros((batch_size, 1), np.int32)
        self.caches = None  # lazily built from first prefill
        self.queue: List[Request] = []
        self.finished: List[Request] = []

        self._decode = jax.jit(
            lambda p, t, pos, c: model.decode_step(p, ctx, t, pos, c)
        )
        self._prefill_one = jax.jit(
            lambda p, b: model.prefill(p, ctx, b, cache_len=cache_len)
        )

    # ------------------------------------------------------------------ #
    def submit(self, req: Request) -> None:
        req.t_enqueue = time.monotonic()
        self.queue.append(req)

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.active):
            if r is None:
                return i
        return None

    def _write_row(self, caches_one, slot: int) -> None:
        """Insert a single-request cache into batch row ``slot``."""
        jnp = self.jnp
        if self.caches is None:
            # build an empty batched cache from the single-row structure
            self.caches = self.jax.tree.map(
                lambda x: jnp.zeros((x.shape[0], self.B) + x.shape[2:],
                                    x.dtype),
                caches_one,
            )
        self.caches = self.jax.tree.map(
            lambda full, one: full.at[:, slot].set(one[:, 0]),
            self.caches, caches_one,
        )

    def admit_prefilled(
        self, req: Request, caches_one, first_token: int, position: int
    ) -> bool:
        """Install an externally prefilled request (the disaggregated
        handoff target: the KV cache arrived over the GAS layer, the
        first token and position rode in the block header).  Returns
        False when no decode row is free — the caller keeps the block
        staged and retries next tick."""
        slot = self._free_slot()
        if slot is None:
            return False
        if not req.out:
            req.out.append(int(first_token))
        if not req.t_first:
            req.t_first = time.monotonic()
        self.active[slot] = req
        self.positions[slot] = position
        self.last_token[slot, 0] = int(first_token)
        self._write_row(caches_one, slot)
        return True

    def _admit(self) -> None:
        while self.queue:
            if self._free_slot() is None:
                return
            req = self.queue.pop(0)
            toks = self.jnp.asarray(req.prompt, self.jnp.int32)[None]
            logits, caches_one = self._prefill_one(
                self.params, {"inputs": toks}
            )
            tok = int(np.argmax(np.asarray(logits)[0]))
            self.admit_prefilled(
                req, caches_one, first_token=tok, position=len(req.prompt)
            )

    def _retire(self, slot: int) -> None:
        req = self.active[slot]
        if req is None:  # already retired this step (eos at the cache cap)
            return
        req.t_done = time.monotonic()
        self.finished.append(req)
        self.active[slot] = None
        self._release(req)

    # -- paged-pool hooks (no-ops for the dense server) ----------------- #
    def _post_decode(self, live: List[int], written: Dict[int, int]) -> None:
        """Called after one decode step, before retirement: ``written``
        maps each live row to the cache position the step wrote."""

    def _release(self, req: Request) -> None:
        """Called when a request leaves its decode row."""

    # ------------------------------------------------------------------ #
    def step(self) -> int:
        """One scheduler tick: admit, decode one token for all rows."""
        self._admit()
        live = [i for i, r in enumerate(self.active) if r is not None]
        if not live or self.caches is None:
            return 0
        jnp = self.jnp
        logits, self.caches = self._decode(
            self.params,
            jnp.asarray(self.last_token),
            jnp.asarray(self.positions),
            self.caches,
        )
        logits = np.asarray(logits)
        self._post_decode(live, {i: int(self.positions[i]) for i in live})
        for i in live:
            req = self.active[i]
            tok = int(np.argmax(logits[i]))
            req.out.append(tok)
            self.positions[i] += 1
            self.last_token[i, 0] = tok
            if tok == self.eos_id or len(req.out) >= req.max_new:
                self._retire(i)
            if self.positions[i] >= self.cache_len - 1:
                self._retire(i)
        return len(live)

    def run_until_drained(self, max_ticks: int = 10000) -> Dict[str, Any]:
        t0 = time.monotonic()
        decoded = 0
        ticks = 0
        while (self.queue or any(self.active)) and ticks < max_ticks:
            decoded += self.step()
            ticks += 1
        dt = time.monotonic() - t0
        lat = [r.t_done - r.t_enqueue for r in self.finished]
        ttft = [r.t_first - r.t_enqueue for r in self.finished]
        return {
            "requests": len(self.finished),
            "decoded_tokens": decoded,
            "wall_s": dt,
            "tok_per_s": decoded / dt if dt else 0.0,
            "p50_latency_s": float(np.median(lat)) if lat else 0.0,
            "p50_ttft_s": float(np.median(ttft)) if ttft else 0.0,
        }


class PagedServer(Server):
    """Continuous batching over the paged KV pool (``repro.serving.pool``).

    The dense server hands each admitted request a private cache row; the
    paged server instead allocates fixed-size token *pages* from a
    refcounted pool per admitted request and frees them when the request
    retires.  Requests sharing a prompt prefix resolve to the *same
    physical pages* (copy-on-write protected), so a warm prefix costs no
    page storage — and, in the disaggregated cluster, no transfer bytes.

    The decode math is byte-identical to the dense server: admission
    writes the prefilled pages into the pool and reads the decode row
    back *through the page table*, and every decode step writes the page
    holding the new token back.  Token parity with :class:`Server` is the
    correctness bar (asserted in the smoke demo and tests).
    """

    def __init__(self, model, ctx, params, batch_size: int, cache_len: int,
                 eos_id: int = -1, greedy: bool = True, seed: int = 0,
                 page_tokens: int = 8, n_pool_pages: Optional[int] = None):
        super().__init__(model, ctx, params, batch_size, cache_len,
                         eos_id=eos_id, greedy=greedy, seed=seed)
        from repro.serving.pool import PagedKVStore, PagedLayout

        self.layout = PagedLayout.from_struct(
            model.kv_block_struct(ctx, prompt_len=4, cache_len=cache_len),
            cache_len=cache_len, page_tokens=page_tokens,
        )
        if n_pool_pages is None:
            n_pool_pages = (batch_size + 1) * self.layout.n_pages
        self.store = PagedKVStore(self.layout, n_pool_pages)

    # ------------------------------------------------------------------ #
    def _admit(self) -> None:
        while self.queue:
            if self._free_slot() is None:
                return
            # conservative gate: admission may need a full table of pages
            if self.store.n_free < self.layout.n_pages:
                return
            req = self.queue.pop(0)
            toks = self.jnp.asarray(req.prompt, self.jnp.int32)[None]
            logits, caches_one = self._prefill_one(
                self.params, {"inputs": toks}
            )
            tok = int(np.argmax(np.asarray(logits)[0]))
            pages = np.asarray(self.layout.flatten(caches_one))
            self.store.admit(req.rid, req.prompt, pages)
            # the decode row is read back THROUGH the page table, so the
            # pool (not the prefill output) is the source of truth
            self.admit_prefilled(
                req, self.store.gather(req.rid),
                first_token=tok, position=len(req.prompt),
            )

    # ------------------------------------------------------------------ #
    def _post_decode(self, live: List[int], written: Dict[int, int]) -> None:
        """Write each row's dirty page (the one holding the position this
        step wrote) back into the pool — pages stay canonical, and a page
        still shared at the prompt boundary is copy-on-write split.  Only
        that one page is flattened (the per-token hot path must not pay
        for the whole row)."""
        for i in live:
            req = self.active[i]
            row = self.jax.tree.map(lambda x: x[:, i : i + 1], self.caches)
            pos = written[i]
            page_row = self.layout.flatten_page(
                row, pos // self.layout.page_tokens
            )
            self.store.write_token_page(req.rid, pos, np.asarray(page_row))

    def _release(self, req: Request) -> None:
        self.store.release(req.rid)

    def run_until_drained(self, max_ticks: int = 10000) -> Dict[str, Any]:
        stats = super().run_until_drained(max_ticks)
        stats.update({f"pool_{k}": v for k, v in self.store.stats().items()})
        return stats


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--role", choices=("prefill", "decode", "both"),
                    default="both",
                    help="both = disaggregated cluster (prefill pool + "
                         "decode pool over the GAS layer); decode = "
                         "colocated continuous batching; prefill = "
                         "prefill pool alone")
    ap.add_argument("--n-prefill", type=int, default=1)
    ap.add_argument("--n-decode", type=int, default=1)
    ap.add_argument("--prefill-backend", default="xla",
                    help="engine of the prefill pool (xla|gascore)")
    ap.add_argument("--decode-backend", default="xla",
                    help="engine of the decode pool (xla|gascore)")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--paged", action="store_true",
                    help="KV lives in the global paged pool "
                         "(repro.serving.pool): pages allocated/freed per "
                         "request, prompt prefixes shared by page table")
    ap.add_argument("--page-tokens", type=int, default=8,
                    help="tokens per KV page (must divide --cache-len)")
    args = ap.parse_args()

    if args.role == "both":
        import os

        os.environ.setdefault(
            "XLA_FLAGS",
            "--xla_force_host_platform_device_count="
            f"{args.n_prefill + args.n_decode}",
        )

    import jax

    from repro.configs.registry import SMOKE
    from repro.models.build import build_model
    from repro.parallel.ctx import RunCtx

    cfg = SMOKE[args.arch]
    model = build_model(cfg)
    ctx = RunCtx(mesh=None, remat="none")
    params, _ = model.init(ctx, jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    reqs = [
        Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab, size=args.prompt_len).tolist(),
            max_new=args.max_new,
        )
        for rid in range(args.requests)
    ]

    if args.role == "decode":
        if args.paged:
            server = PagedServer(model, ctx, params, args.batch,
                                 args.cache_len, page_tokens=args.page_tokens)
        else:
            server = Server(model, ctx, params, args.batch, args.cache_len)
        for req in reqs:
            server.submit(req)
        stats = server.run_until_drained()
    elif args.role == "prefill":
        prefill = jax.jit(
            lambda p, b: model.prefill(p, ctx, b, cache_len=args.cache_len)
        )
        import jax.numpy as jnp

        t0 = time.monotonic()
        for req in reqs:
            logits, _ = prefill(
                params, {"inputs": jnp.asarray(req.prompt, jnp.int32)[None]}
            )
            jax.block_until_ready(logits)
        dt = time.monotonic() - t0
        stats = {
            "requests": len(reqs),
            "wall_s": dt,
            "kv_blocks_per_s": len(reqs) / dt if dt else 0.0,
        }
    else:
        from repro.serving.disagg import DisaggCluster

        cluster = DisaggCluster(
            model, ctx, params,
            n_prefill=args.n_prefill, n_decode=args.n_decode,
            decode_batch=args.batch, cache_len=args.cache_len,
            prefill_backend=args.prefill_backend,
            decode_backend=args.decode_backend,
            paged=args.paged, page_tokens=args.page_tokens,
        )
        for req in reqs:
            cluster.submit(req)
        stats = cluster.run_until_drained()

    for k, v in stats.items():
        print(f"{k}: {v}")


if __name__ == "__main__":
    main()

"""Role-based serving launcher: colocated or disaggregated prefill/decode.

Request lifecycle: queued -> prefilled (KV cache slot assigned) -> decoding
in the fixed-width decode batch -> finished (EOS or max tokens) -> slot
recycled for the next queued request.

The decode step is one jit'd ``model.decode_step`` over the whole batch;
per-row positions let rows be at different generation depths (continuous
batching).

Roles (``--role``):

- ``both`` (default) — the disaggregated cluster: a prefill pool and a
  decode pool as distinct GASNet ranks (``launch.mesh.serve_roles``, each
  pool optionally on its own engine via ``EngineMap``); finished KV caches
  cross over the GAS layer with ``sched.plan_p2p``-planned segmented puts
  and an AM request/reply control plane (``repro.serving.disagg``).
  Needs >= 2 host devices (set ``XLA_FLAGS`` before JAX imports).
- ``decode`` — the colocated path: one node prefills and decodes
  (:class:`Server` continuous batching, unchanged).  With ``--paged`` the
  KV cache lives in the global paged pool (:class:`PagedServer`): pages
  allocated and freed per admitted request, prompt prefixes shared by
  page table, token-identical to the dense server.
- ``prefill`` — the prefill pool alone: computes prefills and reports KV
  blocks/s, the feeder-side capacity number.

CPU-scale demo: ``python -m repro.launch.serve --arch qwen3-4b --smoke``.
"""
import argparse
import dataclasses
import functools
import time
from typing import Any, Dict, List, Optional

import numpy as np

from repro.obs import trace as obs_trace


def _paged_decode_fn(model, ctx, layout):
    """Build the fused paged decode step: pool carrier -> decode views ->
    ``model.decode_step_paged`` -> carrier, all inside ONE jit.

    Keeping the reshape/bitcast chain on device (and, for the colocated
    :class:`PagedServer`, the carrier itself resident across ticks) removes
    the per-tick host round trip over the whole pool that made the paged
    decode path slower than the dense baseline.  The carrier has one extra
    *scratch* row past the pool: dead rows and unmaterialised table slots
    scatter there, and it is wiped every step so garbage never accumulates.
    """
    import jax
    import jax.numpy as jnp

    empty_row = np.asarray(layout.empty_page_row())

    @jax.jit
    def step(params, token, positions, mem, tables):
        mem = mem.at[mem.shape[0] - 1].set(
            jnp.asarray(empty_row, mem.dtype)
        )
        views = layout.decode_views(mem)
        logits, views = model.decode_step_paged(
            params, ctx, token, positions, views, tables
        )
        return logits, layout.views_to_pool(views)

    return step


def _paged_decode_views_fn(model, ctx, layout):
    """The colocated variant of :func:`_paged_decode_fn`: the pool stays
    resident ON DEVICE in *decode-views* form (the per-layer page-pool
    pytree) across ticks, so a steady-state step runs zero carrier
    repacks — the carrier<->views conversion happens only at host sync
    points.  The views buffers are donated: the per-layer token scatter
    updates in place instead of copying every pool."""
    import jax
    import jax.numpy as jnp

    empty_views = layout.decode_views(
        jnp.asarray(np.asarray(layout.empty_page_row())[None])
    )

    @functools.partial(jax.jit, donate_argnums=(3,))
    def step(params, token, positions, views, tables):
        # wipe the scratch page (page axis 1 of every (L, P, T, ...)
        # pool): dead rows and unmaterialised slots scattered garbage
        # into it last step
        views = jax.tree_util.tree_map(
            lambda pool, init: pool.at[:, pool.shape[1] - 1].set(
                init[:, 0]
            ),
            views, empty_views,
        )
        logits, views = model.decode_step_paged(
            params, ctx, token, positions, views, tables
        )
        return logits, views

    return step


_PATCH_CHUNK = 8


def _pool_patch_fn(layout):
    """Jitted device-side pool patch for the views-resident pool: scatter
    ``rows`` (fresh page payloads — admissions, lazy materialisations)
    at ``write_dst`` and duplicate ``copy_src -> copy_dst`` (COW splits),
    without ever round-tripping the whole pool through the host.  All
    index operands are fixed-width (:data:`_PATCH_CHUNK`), padded with
    the scratch page index so one compilation serves every patch."""
    import jax

    @functools.partial(jax.jit, donate_argnums=(0,))
    def patch(views, write_dst, rows, copy_src, copy_dst):
        rowviews = layout.decode_views(rows)

        def apply(pool, rv):
            # writes first: a copy source may itself be a page written
            # this very tick (same-tick admission then COW share)
            pool = pool.at[:, write_dst].set(rv)
            pool = pool.at[:, copy_dst].set(pool[:, copy_src])
            return pool

        return jax.tree_util.tree_map(apply, views, rowviews)

    return patch


def _pool_write_need(store, layout, rid: int, position: int) -> int:
    """Fresh pages the next decode write needs: one when the position
    lands on an unmaterialised slot (lazy growth) or a shared page
    (copy-on-write split), none otherwise."""
    table = store.tables[rid]
    p = table[position // layout.page_tokens]
    if p < 0:
        return 1
    return 1 if store.state.refcnt[p] > 1 else 0


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int = 16
    out: List[int] = dataclasses.field(default_factory=list)
    t_enqueue: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0
    slo: Any = None  # Optional[repro.serving.scheduler.SLO]


class Server:
    """Fixed-decode-batch continuous batching over Model prefill/decode."""

    def __init__(self, model, ctx, params, batch_size: int, cache_len: int,
                 eos_id: int = -1, greedy: bool = True, seed: int = 0):
        import jax
        import jax.numpy as jnp

        self.jax, self.jnp = jax, jnp
        self.model = model
        self.ctx = ctx
        self.params = params
        self.B = batch_size
        self.cache_len = cache_len
        self.eos_id = eos_id
        self.greedy = greedy
        self.key = jax.random.PRNGKey(seed)

        # rank attributed to this server's trace events (the disagg
        # cluster sets it to the decode rank; standalone servers trace
        # on the program-wide row)
        self.trace_rank: Optional[int] = None
        self.active: List[Optional[Request]] = [None] * batch_size
        self.positions = np.zeros((batch_size,), np.int32)
        self.last_token = np.zeros((batch_size, 1), np.int32)
        self.caches = None  # lazily built from first prefill
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        # slot -> remaining tokens a recompute-resume must replay: the
        # decode path reproduces them bit-identically (same ops, same
        # inputs), rebuilding the KV cache without re-appending output
        self.replaying: Dict[int, List[int]] = {}

        self._decode = jax.jit(
            lambda p, t, pos, c: model.decode_step(p, ctx, t, pos, c)
        )
        self._prefill_one = jax.jit(
            lambda p, b: model.prefill(p, ctx, b, cache_len=cache_len)
        )

    # ------------------------------------------------------------------ #
    def submit(self, req: Request) -> None:
        req.t_enqueue = time.monotonic()
        tr = obs_trace.active()
        if tr.enabled:
            tr.instant("req_submit", cat="req", rank=self.trace_rank,
                       rid=req.rid, prompt_len=len(req.prompt))
        self.queue.append(req)

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.active):
            if r is None:
                return i
        return None

    def _write_row(self, caches_one, slot: int) -> None:
        """Insert a single-request cache into batch row ``slot``."""
        jnp = self.jnp
        if self.caches is None:
            # build an empty batched cache from the single-row structure
            self.caches = self.jax.tree.map(
                lambda x: jnp.zeros((x.shape[0], self.B) + x.shape[2:],
                                    x.dtype),
                caches_one,
            )
        self.caches = self.jax.tree.map(
            lambda full, one: full.at[:, slot].set(one[:, 0]),
            self.caches, caches_one,
        )

    def admit_prefilled(
        self, req: Request, caches_one, first_token: int, position: int
    ) -> bool:
        """Install an externally prefilled request (the disaggregated
        handoff target: the KV cache arrived over the GAS layer, the
        first token and position rode in the block header).  Returns
        False when no decode row is free — the caller keeps the block
        staged and retries next tick."""
        slot = self._free_slot()
        if slot is None:
            return False
        if not req.out:
            req.out.append(int(first_token))
        tr = obs_trace.active()
        if not req.t_first:
            req.t_first = time.monotonic()
            if tr.enabled:
                tr.instant("req_first_token", cat="req",
                           rank=self.trace_rank, rid=req.rid)
        if tr.enabled:
            tr.instant("req_admit", cat="req", rank=self.trace_rank,
                       rid=req.rid, slot=slot, position=position)
        self.active[slot] = req
        self.positions[slot] = position
        self.last_token[slot, 0] = int(first_token)
        self._write_row(caches_one, slot)
        return True

    def _admit(self) -> None:
        while self.queue:
            if self._free_slot() is None:
                return
            req = self.queue.pop(0)
            toks = self.jnp.asarray(req.prompt, self.jnp.int32)[None]
            logits, caches_one = self._prefill_one(
                self.params, {"inputs": toks}
            )
            tok = int(np.argmax(np.asarray(logits)[0]))
            self.admit_prefilled(
                req, caches_one, first_token=tok, position=len(req.prompt)
            )

    def _retire(self, slot: int) -> None:
        req = self.active[slot]
        if req is None:  # already retired this step (eos at the cache cap)
            return
        req.t_done = time.monotonic()
        tr = obs_trace.active()
        if tr.enabled:
            tr.instant("req_retire", cat="req", rank=self.trace_rank,
                       rid=req.rid, tokens=len(req.out))
        self.finished.append(req)
        self.active[slot] = None
        self._release(req)

    def evict_row(self, slot: int) -> Optional[Request]:
        """Remove a request from its decode row WITHOUT retiring it (the
        preemption path): the caller owns its KV state (swap or discard)
        and re-admits it later.  No release hook runs."""
        req = self.active[slot]
        self.active[slot] = None
        self.replaying.pop(slot, None)
        return req

    def start_replay(self, slot: int, tokens: List[int]) -> None:
        """Arm a recompute-resume: the next ``len(tokens)`` decode steps
        on ``slot`` rebuild the KV cache by re-deriving exactly those
        tokens (asserted — the decode path is deterministic), without
        re-appending them to the request's output."""
        if tokens:
            self.replaying[slot] = list(tokens)

    # -- paged-pool hooks (no-ops for the dense server) ----------------- #
    def _post_decode(self, live: List[int], written: Dict[int, int]) -> None:
        """Called after one decode step, before retirement: ``written``
        maps each live row to the cache position the step wrote."""

    def _release(self, req: Request) -> None:
        """Called when a request leaves its decode row."""

    def _advance(self, live: List[int], logits: np.ndarray) -> None:
        """Shared post-decode token handling: append/advance each live
        row, replaying preempted-and-recomputed rows without appending."""
        for i in live:
            req = self.active[i]
            tok = int(np.argmax(logits[i]))
            replay = self.replaying.get(i)
            if replay:
                expect = replay.pop(0)
                if tok != expect:
                    raise AssertionError(
                        f"recompute replay diverged on rid {req.rid}: "
                        f"step produced {tok}, original was {expect}"
                    )
                if not replay:
                    del self.replaying[i]
                self.positions[i] += 1
                self.last_token[i, 0] = tok
                continue  # the token is already in req.out
            req.out.append(tok)
            self.positions[i] += 1
            self.last_token[i, 0] = tok
            if tok == self.eos_id or len(req.out) >= req.max_new:
                self._retire(i)
            if self.positions[i] >= self.cache_len - 1:
                self._retire(i)

    # ------------------------------------------------------------------ #
    def step(self) -> int:
        """One scheduler tick: admit, decode one token for all rows.
        Subclasses override :meth:`_step`; this wrapper is the single
        place every server's tick gets its ``decode_step`` span."""
        tr = obs_trace.active()
        if not tr.enabled:
            return self._step()
        with tr.span("decode_step", cat="decode",
                     rank=self.trace_rank) as sp:
            n = self._step()
            sp.args["live"] = n
            return n

    def _step(self) -> int:
        self._admit()
        live = [i for i, r in enumerate(self.active) if r is not None]
        if not live or self.caches is None:
            return 0
        jnp = self.jnp
        logits, self.caches = self._decode(
            self.params,
            jnp.asarray(self.last_token),
            jnp.asarray(self.positions),
            self.caches,
        )
        logits = np.asarray(logits)
        self._post_decode(live, {i: int(self.positions[i]) for i in live})
        self._advance(live, logits)
        return len(live)

    def _pending(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.active)

    def run_until_drained(self, max_ticks: int = 10000) -> Dict[str, Any]:
        t0 = time.monotonic()
        decoded = 0
        ticks = 0
        while self._pending() and ticks < max_ticks:
            decoded += self.step()
            ticks += 1
        dt = time.monotonic() - t0
        lat = [r.t_done - r.t_enqueue for r in self.finished]
        ttft = [r.t_first - r.t_enqueue for r in self.finished]
        return {
            "requests": len(self.finished),
            "decoded_tokens": decoded,
            "wall_s": dt,
            "tok_per_s": decoded / dt if dt else 0.0,
            "p50_latency_s": float(np.median(lat)) if lat else 0.0,
            "p50_ttft_s": float(np.median(ttft)) if ttft else 0.0,
        }


class PagedServer(Server):
    """Continuous batching over the paged KV pool (``repro.serving.pool``)
    with SLO-aware preemptive scheduling over a tiered KV memory.

    The dense server hands each admitted request a private cache row; the
    paged server instead allocates fixed-size token *pages* from a
    refcounted pool per admitted request and frees them when the request
    retires.  Requests sharing a prompt prefix resolve to the *same
    physical pages* (copy-on-write protected), so a warm prefix costs no
    page storage — and, in the disaggregated cluster, no transfer bytes.

    With ``paged_decode=True`` (default) the decode step itself runs
    THROUGH the page table — the new token's K/V scatter straight into
    the pool and attention is ``kernels.paged_attention`` over the
    physical pages; no dense per-request cache row exists anywhere.
    Admission is **lazy** (only prompt pages materialise; the generation
    tail allocates page by page as positions are written), so the pool
    *oversubscribes*: when the free list runs dry the
    :class:`~repro.serving.scheduler.AdmissionScheduler` preempts victims
    — swap (pages copied to the :class:`~repro.serving.tier.MemoryTier`,
    restored bit-exactly at resume) or recompute (pages dropped; resume
    replays the generated tokens, re-deriving them bit-identically),
    priced per victim by the measured β cost model.

    Token parity with :class:`Server` — pressured or not — is the
    correctness bar (asserted in the smoke demo and tests).
    """

    def __init__(self, model, ctx, params, batch_size: int, cache_len: int,
                 eos_id: int = -1, greedy: bool = True, seed: int = 0,
                 page_tokens: int = 8, n_pool_pages: Optional[int] = None,
                 paged_decode: bool = True, tier_slots: Optional[int] = None,
                 sched_costs: Optional[Dict[str, Any]] = None,
                 decode_step_us: float = 2000.0, prefill_us: float = 4000.0,
                 health: Optional[Any] = None):
        super().__init__(model, ctx, params, batch_size, cache_len,
                         eos_id=eos_id, greedy=greedy, seed=seed)
        from repro.serving.pool import PagedKVStore, PagedLayout
        from repro.serving.scheduler import AdmissionScheduler
        from repro.serving.tier import MemoryTier

        self.layout = PagedLayout.from_struct(
            model.kv_block_struct(ctx, prompt_len=4, cache_len=cache_len),
            cache_len=cache_len, page_tokens=page_tokens,
        )
        if n_pool_pages is None:
            n_pool_pages = (batch_size + 1) * self.layout.n_pages
        self.store = PagedKVStore(self.layout, n_pool_pages)
        self.paged_decode = paged_decode
        if tier_slots is None:
            tier_slots = max(
                n_pool_pages, batch_size * self.layout.n_pages
            )
        self.tier = MemoryTier(
            1, tier_slots, self.layout.page_elems, host_backed=True
        )
        self.scheduler = AdmissionScheduler(
            page_bytes=self.layout.page_bytes, costs=sched_costs,
            decode_step_us=decode_step_us, prefill_us=prefill_us,
        )
        # live SLO monitor (repro.obs.health.HealthMonitor): tracked per
        # submit, ticked per step; when its backpressure is enabled the
        # scheduler defers below-floor admissions while deadlines are at
        # risk.  Inert (risk 0) for requests without finite deadlines.
        self.health = health
        self._tick_no = 0
        if health is not None and getattr(health, "backpressure", False):
            self.scheduler.attach_health(health)
        self._by_rid: Dict[int, Request] = {}
        self._preempted: Dict[int, Dict[str, Any]] = {}
        self._decode_paged = _paged_decode_views_fn(model, ctx, self.layout)
        # device-resident pool in decode-views form (each per-layer pool
        # has P+1 rows, scratch last), kept across ticks; None whenever
        # the host mirror is authoritative
        self._dev_views = None
        # live high-water mark of page-table width (monotonic; each
        # growth is one fused-step recompile)
        self._table_width = 1
        # host-side page mutations queued for the device-resident pool:
        # fresh payload rows (admissions, lazy materialisations) and COW
        # src->dst splits, applied by the jitted patch program before the
        # next decode step (or before any host sync)
        self._patch = _pool_patch_fn(self.layout)
        self._pending_rows: Dict[int, np.ndarray] = {}
        self._pending_copies: List[tuple] = []

    def _apply_pending(self) -> None:
        """Flush queued page writes/copies into the device-resident pool.
        Writes flush (in chunks) before any copy: a COW split may source
        a page admitted this same tick."""
        jnp = self.jnp
        P = self.store.state.n_pages  # scratch index pads the chunks
        elems = self.layout.page_elems
        rows = list(self._pending_rows.items())
        copies = list(self._pending_copies)
        self._pending_rows.clear()
        self._pending_copies.clear()
        pad_idx = np.full((_PATCH_CHUNK,), P, np.int32)
        pad_rows = np.zeros((_PATCH_CHUNK, elems), np.float32)
        while rows:
            chunk, rows = rows[:_PATCH_CHUNK], rows[_PATCH_CHUNK:]
            wd, wr = pad_idx.copy(), pad_rows.copy()
            for j, (pg, row) in enumerate(chunk):
                wd[j], wr[j] = pg, row
            self._dev_views = self._patch(
                self._dev_views, jnp.asarray(wd), jnp.asarray(wr),
                jnp.asarray(pad_idx), jnp.asarray(pad_idx),
            )
        while copies:
            chunk, copies = copies[:_PATCH_CHUNK], copies[_PATCH_CHUNK:]
            cs, cd = pad_idx.copy(), pad_idx.copy()
            for j, (src, dst) in enumerate(chunk):
                cs[j], cd[j] = src, dst
            self._dev_views = self._patch(
                self._dev_views, pad_idx, pad_rows,
                jnp.asarray(cs), jnp.asarray(cd),
            )

    def _sync_host(self) -> None:
        """Land the device-resident pool back in the host mirror before
        any host-side read or write of page payloads (swap staging,
        resume restores, bulk admission rewrites).  Queued page patches
        flush to the device first so the download is complete.  The
        device copy is dropped; the next decode step re-uploads the
        mutated mirror."""
        if self._dev_views is not None:
            if self._pending_rows or self._pending_copies:
                self._apply_pending()
            P = self.store.state.n_pages
            mem = self.layout.views_to_pool(self._dev_views)
            self.store.mem[:] = np.asarray(mem)[:P]
            self._dev_views = None

    # ------------------------------------------------------------------ #
    def submit(self, req: Request) -> None:
        from repro.serving.scheduler import SLO

        super().submit(req)
        self._by_rid[req.rid] = req
        self.scheduler.submit(
            req.rid, req.slo or SLO(), prompt_len=len(req.prompt),
            now=req.t_enqueue,
        )
        if self.health is not None:
            self.health.track(req.rid, req.slo or SLO(), req.t_enqueue)

    def _pending(self) -> bool:
        return super()._pending() or bool(self._preempted)

    # ------------------------------------------------------------------ #
    # capacity management: preemption + tiered swap
    # ------------------------------------------------------------------ #
    def _running_rids(self) -> List[int]:
        return [r.rid for r in self.active if r is not None]

    def _slot_of(self, rid: int) -> Optional[int]:
        for i, r in enumerate(self.active):
            if r is not None and r.rid == rid:
                return i
        return None

    def _freeable(self, rid: int) -> int:
        return self.store.freeable(rid)

    def _write_need(self, rid: int, position: int) -> int:
        return _pool_write_need(self.store, self.layout, rid, position)

    def _preempt(self, rid: int, mode: Optional[str] = None) -> None:
        from repro.serving import tier as tier_lib

        self._sync_host()  # swap staging reads page payloads
        slot = self._slot_of(rid)
        req = self._by_rid[rid]
        table = self.store.page_table(rid)
        logical = [lp for lp, pp in enumerate(table) if pp >= 0]
        chosen, swap_us, rec_us = self.scheduler.choose_mode(
            rid, len(logical))
        if mode is None:
            mode = chosen
        tr = obs_trace.active()
        if tr.enabled:
            tr.instant(
                "req_preempt", cat="req", rank=self.trace_rank, rid=rid,
                mode=mode, n_pages=len(logical),
                swap_est_us=round(swap_us, 1),
                recompute_est_us=round(rec_us, 1),
            )
        if mode == "swap":
            try:
                self.tier.plan_swap_out(rid, logical)
            except tier_lib.OutOfSlotsError:
                mode = "recompute"  # tier full: drop and replay instead
        if mode == "swap":
            rows = np.stack([self.store.mem[table[lp]] for lp in logical])
            self.tier.host_store(rid, rows)
        snap = {
            "mode": mode,
            "logical": tuple(logical),
            "position": int(self.positions[slot]),
            "last_token": int(self.last_token[slot, 0]),
            # a victim caught mid-replay must finish its replay after a
            # swap-resume (evict_row drops the row's replay state)
            "replay": list(self.replaying.get(slot, [])),
        }
        self.store.evict_request(rid)
        self.evict_row(slot)
        self._preempted[rid] = snap
        # keep the β model honest: replayed tokens are not new generation
        self.scheduler.entry(rid).generated = max(0, len(req.out) - 1)
        self.scheduler.on_preempted(rid, mode)

    def _make_room(self, need: int, beneficiary: int, strict: bool) -> bool:
        """Free at least ``need`` pool pages by preempting victims chosen
        by the scheduler; False when no eligible victim set suffices."""
        while self.store.n_free < need:
            victims = self.scheduler.pick_victims(
                self._running_rids(), need - self.store.n_free,
                self._freeable, beneficiary=beneficiary, strict=strict,
            )
            if not victims:
                return False
            for rid in victims:
                self._preempt(rid)
        return True

    # ------------------------------------------------------------------ #
    # admission + resume (scheduler-ordered)
    # ------------------------------------------------------------------ #
    def _bind_row(
        self, req: Request, slot: int, position: int, last_token: int
    ) -> None:
        tr = obs_trace.active()
        if not req.t_first:
            req.t_first = time.monotonic()
            if tr.enabled:
                tr.instant("req_first_token", cat="req",
                           rank=self.trace_rank, rid=req.rid)
            if self.health is not None:
                self.health.first_token(req.rid, req.t_first)
        if tr.enabled:
            tr.instant("req_admit", cat="req", rank=self.trace_rank,
                       rid=req.rid, slot=slot, position=position)
        self.active[slot] = req
        self.positions[slot] = position
        self.last_token[slot, 0] = int(last_token)

    def _prefill_pages(self, req: Request):
        toks = self.jnp.asarray(req.prompt, self.jnp.int32)[None]
        logits, caches_one = self._prefill_one(self.params, {"inputs": toks})
        tok = int(np.argmax(np.asarray(logits)[0]))
        return tok, np.asarray(self.layout.flatten(caches_one)), caches_one

    def _resume(self, rid: int, slot: int) -> bool:
        st = self._preempted[rid]
        req = self._by_rid[rid]
        self._sync_host()  # restores / re-prefills write page payloads
        if st["mode"] == "swap":
            if self.store.n_free < len(st["logical"]):
                return False
            phys = self.store.admit_resume(rid, st["logical"])
            rows = self.tier.host_load(rid)
            self.tier.release(rid)
            for row, pp in zip(rows, phys):
                self.store.mem[pp] = row
            self._bind_row(req, slot, st["position"], st["last_token"])
            self.start_replay(slot, st.get("replay", []))
        else:  # recompute: re-prefill the prompt, replay the generation
            if self.store.n_free < self.layout.pages_for(len(req.prompt)):
                return False
            tok, pages, _ = self._prefill_pages(req)
            plan = self.store.plan_admit(req.prompt, lazy=True)
            self.store.write_pages(plan, pages)
            self.store.commit(rid, plan)
            self._bind_row(req, slot, len(req.prompt), req.out[0])
            self.start_replay(slot, req.out[1:])
        del self._preempted[rid]
        tr = obs_trace.active()
        if tr.enabled:
            tr.instant("req_resume", cat="req", rank=self.trace_rank,
                       rid=rid, slot=slot, mode=st["mode"])
        self.scheduler.on_admitted(rid, time.monotonic())
        return True

    def _admit(self) -> None:
        if not self.paged_decode:
            return self._admit_dense()
        for rid in self.scheduler.admission_order():
            slot = self._free_slot()
            if slot is None:
                return
            if rid in self._preempted:
                self._resume(rid, slot)
                continue
            req = self._by_rid.get(rid)
            if req is None or req not in self.queue:
                continue
            need = self.layout.pages_for(len(req.prompt))
            if self.store.n_free < need and not self._make_room(
                need, rid, strict=True
            ):
                continue
            self.queue.remove(req)
            tok, pages, _ = self._prefill_pages(req)
            plan = self.store.plan_admit(req.prompt, lazy=True)
            self.store.write_pages(plan, pages)
            self.store.commit(req.rid, plan)
            if self._dev_views is not None:
                # the pool stays device-resident across admissions: queue
                # only the fresh prompt pages as patches instead of
                # round-tripping the whole pool through the host mirror
                for page_id, is_fresh in zip(plan.table, plan.fresh):
                    if is_fresh:
                        self._pending_rows[page_id] = self.store.mem[
                            page_id
                        ].copy()
            if not req.out:
                req.out.append(tok)
            self._bind_row(req, slot, len(req.prompt), req.out[0])
            self.scheduler.on_admitted(rid, time.monotonic())

    def _admit_dense(self) -> None:
        """The PR-4 path (``paged_decode=False``): full-table admission,
        decode on dense rows gathered through the page table."""
        while self.queue:
            if self._free_slot() is None:
                return
            # conservative gate: admission may need a full table of pages
            if self.store.n_free < self.layout.n_pages:
                return
            req = self.queue.pop(0)
            tok, pages, _ = self._prefill_pages(req)
            self.store.admit(req.rid, req.prompt, pages)
            # the decode row is read back THROUGH the page table, so the
            # pool (not the prefill output) is the source of truth
            self.admit_prefilled(
                req, self.store.gather(req.rid),
                first_token=tok, position=len(req.prompt),
            )
            self.scheduler.on_admitted(req.rid, time.monotonic())

    # ------------------------------------------------------------------ #
    # the end-to-end paged decode step
    # ------------------------------------------------------------------ #
    def _step(self) -> int:
        if not self.paged_decode:
            return super()._step()
        self._admit()
        live = [i for i, r in enumerate(self.active) if r is not None]
        if not live:
            return 0
        # write capacity row by row: lazy materialisation / COW splits may
        # need fresh pages — the oversubscription pressure point.  Each
        # row claims its page immediately after the capacity check (no
        # under-reservation across rows); a row that cannot get one (even
        # after preempting eligible victims) self-preempts and resumes
        # once pages free up.
        from repro.serving.pool import UNMATERIALIZED

        for i in list(live):
            req = self.active[i]
            if req is None:
                continue  # already evicted by an earlier row's make_room
            need = self._write_need(req.rid, int(self.positions[i]))
            if need and self.store.n_free < need:
                if not self._make_room(need, req.rid, strict=False):
                    self._preempt(req.rid)
                    continue
            pos = int(self.positions[i])
            if need and self._dev_views is not None:
                # materialisation / COW split mutates page payloads: mirror
                # the host-side bookkeeping write as a device patch rather
                # than syncing the whole pool down and back up
                before = self.store.tables[req.rid][
                    pos // self.layout.page_tokens
                ]
                dst = self.store.prepare_write(req.rid, pos)
                if before == UNMATERIALIZED:
                    self._pending_rows[dst] = np.asarray(
                        self.layout.empty_page_row()
                    )
                elif dst != before:  # COW split: clone the shared payload
                    self._pending_copies.append((int(before), int(dst)))
            else:
                self.store.prepare_write(req.rid, pos)
        live = [i for i, r in enumerate(self.active) if r is not None]
        if not live:
            return 0
        # device tables: unmaterialised slots (and dead rows) target the
        # scratch page appended past the pool — always masked by lengths.
        # The table is sized to the batch's live high-water mark (grown
        # monotonically so the fused step recompiles at most once per
        # growth step, never thrashes): paged attention then reads ONLY
        # pages any request can occupy, instead of paying the full
        # cache_len width the dense rows are stuck with.
        P = self.store.state.n_pages
        T = self.layout.page_tokens
        need = max(int(self.positions[i]) // T + 1 for i in live)
        need = min(self.layout.n_pages, -(-need // 4) * 4)  # 4-page buckets
        self._table_width = max(self._table_width, need)
        tables = np.full((self.B, self._table_width), P, np.int32)
        for i in live:
            row = self.store.device_table(self.active[i].rid, absent=P)
            tables[i] = row[: self._table_width]
        logits = self._decode_via_tables(tables)
        for i in live:
            if i not in self.replaying:  # replays are not new generation
                self.scheduler.on_step(self.active[i].rid)
        self._advance(live, logits)
        return len(live)

    def step(self) -> int:
        n = super().step()
        if self.health is not None:
            self._tick_no += 1
            self.health.tick(
                self._tick_no, time.monotonic(),
                progress={
                    r.rid: len(r.out)
                    for r in self.active if r is not None
                },
            )
        return n

    def profile_decode(self, profiler, iters: int = 6,
                       warmup: int = 2) -> Optional[float]:
        """Offline device-timing of the fused paged decode step over the
        server's *current* page tables (re-execution is idempotent: the
        step rewrites the same K/V slots from the same inputs, and the
        sampled token is discarded).  Never called on the serving path —
        benchmarks drive it between bursts.  Returns the best wall/device
        microseconds, or None when no rows are live."""
        live = [i for i, r in enumerate(self.active) if r is not None]
        if not live or not self.paged_decode:
            return None
        P = self.store.state.n_pages
        T = self.layout.page_tokens
        need = max(int(self.positions[i]) // T + 1 for i in live)
        need = min(self.layout.n_pages, -(-need // 4) * 4)
        width = max(self._table_width, need)
        tables = np.full((self.B, width), P, np.int32)
        for i in live:
            tables[i] = self.store.device_table(
                self.active[i].rid, absent=P)[:width]
        return profiler.profile(
            "paged_decode_step",
            lambda: self._decode_via_tables(tables),
            iters=iters, warmup=warmup,
            live=len(live), table_width=width,
        )

    def _decode_via_tables(self, tables: np.ndarray) -> np.ndarray:
        """Upload the pool when host-resident, flush queued page patches,
        run the fused paged decode; returns host logits.  The device-pool
        representation is this method's private affair — the TP variant
        swaps in a head-sharded stacked carrier and a ``shard_map``-ped
        step without touching the scheduler loop above."""
        if self._dev_views is None:  # (re-)upload the mutated host mirror
            self._dev_views = self.layout.decode_views(self.jnp.asarray(
                np.concatenate(
                    [self.store.mem, self.layout.empty_page_row()[None]],
                    axis=0,
                )
            ))
        if self._pending_rows or self._pending_copies:
            self._apply_pending()
        logits, self._dev_views = self._decode_paged(
            self.params,
            self.jnp.asarray(self.last_token),
            self.jnp.asarray(self.positions),
            self._dev_views,
            self.jnp.asarray(tables),
        )
        return np.asarray(logits)

    # ------------------------------------------------------------------ #
    def _post_decode(self, live: List[int], written: Dict[int, int]) -> None:
        """Dense-path (``paged_decode=False``) per-step writeback: the
        page holding the position this step wrote goes back into the pool
        (copy-on-write split if still shared), keeping the pool canonical
        — only that one page is flattened.  The paged-decode path writes
        on device and never comes through here."""
        if self.paged_decode:
            return
        for i in live:
            req = self.active[i]
            row = self.jax.tree.map(lambda x: x[:, i : i + 1], self.caches)
            pos = written[i]
            page_row = self.layout.flatten_page(
                row, pos // self.layout.page_tokens
            )
            self.store.write_token_page(req.rid, pos, np.asarray(page_row))

    def _release(self, req: Request) -> None:
        self.store.release(req.rid)
        if req.rid in self._by_rid:
            self.scheduler.on_done(req.rid)
        if self.health is not None:
            self.health.retire(req.rid)

    def run_until_drained(self, max_ticks: int = 10000) -> Dict[str, Any]:
        stats = super().run_until_drained(max_ticks)
        self._sync_host()  # callers may inspect the pool post-drain
        stats.update({f"pool_{k}": v for k, v in self.store.stats().items()})
        stats.update(self.tier.stats())
        stats.update(self.scheduler.stats())
        return stats


def _tp_paged_decode_fn(model, ctx, shard_layout, tp: int, backend,
                        mesh, costs=None):
    """The tensor-parallel fused paged decode step: a ``shard_map`` over a
    ``("tp",)`` mesh where every rank holds one head shard of the weights
    and one head shard of the page pool, and each sub-block's partial sum
    crosses the group through ``sched.all_reduce`` — the planned,
    engine-aware collective (``backend`` may be a mixed spec like
    ``"xla,gascore"``, planning against the worst member edge).  Logits
    are replicated across the group (bit-identically: the 2-rank ring and
    recursive-doubling schedules commute)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from repro.core import sched as core_sched
    from repro.core.engine import make_engine
    from repro.parallel.tp import TPGroup

    empty_row = np.asarray(shard_layout.empty_page_row())

    def body(params, token, positions, mem, tables):
        params = jax.tree_util.tree_map(lambda x: x[0], params)
        mem = mem[0]
        mem = mem.at[mem.shape[0] - 1].set(jnp.asarray(empty_row, mem.dtype))
        engine = make_engine(backend, "tp", tp, interpret=ctx.interpret)
        group = TPGroup(
            tp, lambda x: core_sched.all_reduce(engine, x, costs=costs)
        )
        views = shard_layout.decode_views(mem)
        logits, views = model.decode_step_paged(
            params, ctx, token, positions, views, tables, tp=group
        )
        return logits, shard_layout.views_to_pool(views)[None]

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P("tp"), P(), P(), P("tp"), P()),
        out_specs=(P(), P("tp")),
        check_vma=False,
    )
    return jax.jit(fn, donate_argnums=(3,))


def _tp_pool_patch_fn():
    """Stacked-carrier variant of :func:`_pool_patch_fn`: the pool is the
    raw ``(tp, P+1, shard_page_elems)`` carrier (one head shard per
    rank), rows arrive pre-sharded ``(tp, chunk, shard_page_elems)``."""
    import jax

    @functools.partial(jax.jit, donate_argnums=(0,))
    def patch(pool, write_dst, rows, copy_src, copy_dst):
        pool = pool.at[:, write_dst].set(rows)
        pool = pool.at[:, copy_dst].set(pool[:, copy_src])
        return pool

    return patch


class TPPagedServer(PagedServer):
    """:class:`PagedServer` whose decode runs over a tensor-parallel group
    of ``tp`` GAS ranks: attention heads and MLP columns sharded per rank
    (``repro.parallel.tp``), each rank's device pool holding only its
    heads' slice of every page (``PagedLayout.shard_heads``), one planned
    all-reduce per sub-block inside the tick program.

    Everything host-side is unchanged from the base class: the allocator,
    page tables, prefix index, scheduler, tier, and the host ``mem``
    mirror all stay in the FULL layout (pages are sharded by *bytes*, not
    by id — every rank holds the same table).  Only the device residency
    differs: ``_dev_views`` becomes the stacked ``(tp, P+1, shard_elems)``
    carrier, patches pre-shard queued host rows through ``shard_cols``,
    and ``_sync_host`` reassembles the shards bit-exactly.  Token streams
    are identical to ``tp=1`` (asserted in tests and the bench section).
    """

    def __init__(self, model, ctx, params, batch_size: int, cache_len: int,
                 tp: int = 2, tp_backend: str = "xla",
                 sched_cost_table: Optional[Dict[str, Any]] = None, **kw):
        super().__init__(model, ctx, params, batch_size, cache_len, **kw)
        import jax
        from jax.sharding import Mesh, NamedSharding
        from jax.sharding import PartitionSpec as P

        from repro.parallel import tp as tp_lib

        tp_lib.validate_tp(model.cfg, tp)
        if jax.device_count() < tp:
            raise ValueError(
                f"tp={tp} needs {tp} devices, have {jax.device_count()} "
                f"(set --xla_force_host_platform_device_count)"
            )
        self.tp = tp
        self.shard_layout, self.shard_cols = self.layout.shard_heads(
            tp, model.cfg.n_kv_heads
        )
        self._tp_mesh = Mesh(np.array(jax.devices()[:tp]), ("tp",))
        self._tp_sharding = NamedSharding(self._tp_mesh, P("tp"))
        self._stacked_params = jax.device_put(
            tp_lib.stack_shards(params, tp), self._tp_sharding
        )
        self._decode_tp = _tp_paged_decode_fn(
            model, ctx, self.shard_layout, tp, tp_backend, self._tp_mesh,
            costs=sched_cost_table,
        )
        self._patch_tp = _tp_pool_patch_fn()

    # -- device-pool representation overrides --------------------------- #
    def _apply_pending(self) -> None:
        jnp = self.jnp
        Pn = self.store.state.n_pages  # scratch index pads the chunks
        elems = self.shard_layout.page_elems
        rows = list(self._pending_rows.items())
        copies = list(self._pending_copies)
        self._pending_rows.clear()
        self._pending_copies.clear()
        pad_idx = np.full((_PATCH_CHUNK,), Pn, np.int32)
        pad_rows = np.zeros((self.tp, _PATCH_CHUNK, elems), np.float32)
        while rows:
            chunk, rows = rows[:_PATCH_CHUNK], rows[_PATCH_CHUNK:]
            wd, wr = pad_idx.copy(), pad_rows.copy()
            for j, (pg, row) in enumerate(chunk):
                wd[j] = pg
                wr[:, j] = row[self.shard_cols]  # pre-shard the full row
            self._dev_views = self._patch_tp(
                self._dev_views, jnp.asarray(wd), jnp.asarray(wr),
                jnp.asarray(pad_idx), jnp.asarray(pad_idx),
            )
        while copies:
            chunk, copies = copies[:_PATCH_CHUNK], copies[_PATCH_CHUNK:]
            cs, cd = pad_idx.copy(), pad_idx.copy()
            for j, (src, dst) in enumerate(chunk):
                cs[j], cd[j] = src, dst
            self._dev_views = self._patch_tp(
                self._dev_views, pad_idx, pad_rows,
                jnp.asarray(cs), jnp.asarray(cd),
            )

    def _sync_host(self) -> None:
        if self._dev_views is None:
            return
        if self._pending_rows or self._pending_copies:
            self._apply_pending()
        Pn = self.store.state.n_pages
        stacked = np.asarray(self._dev_views)  # (tp, P+1, shard_elems)
        full = np.empty((Pn, self.layout.page_elems), np.float32)
        for s in range(self.tp):
            full[:, self.shard_cols[s]] = stacked[s, :Pn]
        self.store.mem[:] = full
        self._dev_views = None

    def _decode_via_tables(self, tables: np.ndarray) -> np.ndarray:
        jnp = self.jnp
        if self._dev_views is None:  # upload, pre-sharded per rank
            mem_cat = np.concatenate(
                [self.store.mem, self.layout.empty_page_row()[None]], axis=0
            )
            stacked = np.stack([mem_cat[:, c] for c in self.shard_cols])
            self._dev_views = self.jax.device_put(
                jnp.asarray(stacked), self._tp_sharding
            )
        if self._pending_rows or self._pending_copies:
            self._apply_pending()
        logits, self._dev_views = self._decode_tp(
            self._stacked_params,
            jnp.asarray(self.last_token),
            jnp.asarray(self.positions),
            self._dev_views,
            jnp.asarray(tables),
        )
        return np.asarray(logits)


class PooledDecodeServer(Server):
    """Decode server whose KV lives in an EXTERNAL paged store — the
    disaggregated cluster's per-rank pool shard.

    Rows are bound to page tables by rid (:meth:`admit_paged`); no dense
    cache row is ever built, and every tick decodes through
    ``Model.decode_step_paged`` — the same single decode path the
    colocated :class:`PagedServer` runs, so dense ``decode_step`` survives
    only as the test oracle.

    Division of labour with the cluster:

    - the cluster owns prefill, admission (page puts over the GAS layer),
      preemption policy, release, and resume;
    - the server owns the per-tick write-page claim
      (``store.prepare_write``) and the batched paged decode;
    - :meth:`drain_dirty` exposes the physical pages each tick wrote so
      the cluster can replay them onto a freshly *consumed* pool segment
      — the decode step overlaps an in-flight transfer program whose
      result replaces the whole segment the store's mirror aliases.

    When the pool shard runs dry mid-growth (tiered clusters
    oversubscribe), ``on_page_shortage(rid, need)`` asks the cluster to
    preempt; if pages still aren't free the row *stalls* one tick: its
    write slot is remapped to the scratch page (so a pending
    copy-on-write split can't corrupt sharers) and its logits are
    discarded — it retries once the swap-out lands.
    """

    def __init__(self, model, ctx, params, batch_size: int, cache_len: int,
                 store, eos_id: int = -1, greedy: bool = True, seed: int = 0,
                 on_page_shortage=None):
        super().__init__(model, ctx, params, batch_size, cache_len,
                         eos_id=eos_id, greedy=greedy, seed=seed)
        self.store = store
        self.layout = store.layout
        self.on_page_shortage = on_page_shortage
        self.paged_decode_steps = 0
        self._dirty: Dict[int, np.ndarray] = {}
        self._decode_paged = _paged_decode_fn(model, ctx, self.layout)

    def _admit(self) -> None:
        """Admission belongs to the cluster (prefill nodes + GAS puts)."""

    def admit_paged(
        self, req: Request, first_token: int, position: int
    ) -> bool:
        """Bind an installed request's decode row to its page table: the
        pool shard — not any dense copy — is the KV source of truth.
        Returns False when no decode row is free."""
        slot = self._free_slot()
        if slot is None:
            return False
        if not req.out:
            req.out.append(int(first_token))
        tr = obs_trace.active()
        if not req.t_first:
            req.t_first = time.monotonic()
            if tr.enabled:
                tr.instant("req_first_token", cat="req",
                           rank=self.trace_rank, rid=req.rid)
        if tr.enabled:
            tr.instant("req_admit", cat="req", rank=self.trace_rank,
                       rid=req.rid, slot=slot, position=position)
        self.active[slot] = req
        self.positions[slot] = position
        self.last_token[slot, 0] = int(first_token)
        return True

    def drain_dirty(self) -> Dict[int, np.ndarray]:
        """Physical page -> row payload written since the last drain."""
        d = self._dirty
        self._dirty = {}
        return d

    def _step(self) -> int:
        live = [i for i, r in enumerate(self.active) if r is not None]
        if not live:
            return 0
        jnp = self.jnp
        # row -> the physical page this tick's write lands in; rows absent
        # here at decode time are stalled (no write page) and discarded
        written: Dict[int, int] = {}
        for i in list(live):
            req = self.active[i]
            if req is None:
                continue  # evicted by an earlier row's shortage handling
            pos = int(self.positions[i])
            need = _pool_write_need(self.store, self.layout, req.rid, pos)
            if need and self.store.n_free < need:
                ok = bool(self.on_page_shortage) and self.on_page_shortage(
                    req.rid, need
                )
                if self.active[i] is None:
                    continue  # the shortage handler preempted this row
                if not ok:
                    continue  # stall: retry once freed pages land
            written[i] = self.store.prepare_write(req.rid, pos)
        live = [i for i, r in enumerate(self.active) if r is not None]
        if not live:
            return 0
        P = self.store.state.n_pages
        tables = np.full((self.B, self.layout.n_pages), P, np.int32)
        for i in live:
            tables[i] = self.store.device_table(self.active[i].rid, absent=P)
            if i not in written:
                # stalled: scatter into scratch, never a shared page
                slot = int(self.positions[i]) // self.layout.page_tokens
                tables[i, slot] = P
        logits = self._decode_and_write(written, tables)
        advanced = [i for i in live if i in written]
        self._advance(advanced, logits)
        return len(advanced)

    def _decode_and_write(self, written: Dict[int, int],
                          tables: np.ndarray) -> np.ndarray:
        """Run the fused paged decode over the pool mirror and land this
        tick's written pages back in it (plus the dirty set the cluster
        replays after a transfer consume)."""
        jnp = self.jnp
        mem = np.concatenate(
            [self.store.mem, self.layout.empty_page_row()[None]], axis=0
        )
        logits, newmem = self._decode_paged(
            self.params,
            jnp.asarray(self.last_token),
            jnp.asarray(self.positions),
            jnp.asarray(mem),
            jnp.asarray(tables),
        )
        self.paged_decode_steps += 1
        # download ONLY the pages this tick wrote — the rest of the shard
        # is bit-identical to the mirror the transfer program already read
        pages = sorted(set(written.values()))
        if pages:
            rows = np.asarray(newmem[np.asarray(pages, np.int32)])
            for pp, row in zip(pages, rows):
                self.store.mem[pp] = row
                self._dirty[pp] = row
        return np.asarray(logits)


class TPPooledDecodeServer(PooledDecodeServer):
    """One logical decode server for a tensor-parallel GROUP of cluster
    ranks: the group's page-pool shard is striped across the members'
    GASNet segments BY HEADS (member ``s`` holds every page's slice for
    its heads — ``PagedLayout.shard_heads``), and each tick's decode runs
    as a ``shard_map`` over the group's devices with one planned
    all-reduce per sub-block (:func:`_tp_paged_decode_fn`).

    The allocator, page tables, and request rows are group-level (one
    logical server, one store); only page *payloads* are sharded.  The
    cluster aliases ``shard_mems`` — a live, re-bound-per-consume list of
    the members' pool-partition mirrors (entry 0 is ``store.mem``, the
    leader's) — and ``drain_dirty`` hands back stacked ``(tp, elems)``
    rows so the replay lands on every member mirror."""

    def __init__(self, model, ctx, params, batch_size: int, cache_len: int,
                 store, shard_mems: List[np.ndarray], tp: int,
                 tp_backend: str = "xla", tp_mesh=None,
                 costs: Optional[Dict[str, Any]] = None, eos_id: int = -1,
                 greedy: bool = True, seed: int = 0, on_page_shortage=None):
        super().__init__(model, ctx, params, batch_size, cache_len,
                         store=store, eos_id=eos_id, greedy=greedy,
                         seed=seed, on_page_shortage=on_page_shortage)
        import jax
        from jax.sharding import Mesh, NamedSharding
        from jax.sharding import PartitionSpec as P

        from repro.parallel import tp as tp_lib

        tp_lib.validate_tp(model.cfg, tp)
        self.tp = tp
        self.shard_mems = shard_mems  # cluster-owned, re-aliased in place
        if tp_mesh is None:
            tp_mesh = Mesh(np.array(jax.devices()[:tp]), ("tp",))
        self._tp_sharding = NamedSharding(tp_mesh, P("tp"))
        self._stacked_params = jax.device_put(
            tp_lib.stack_shards(params, tp), self._tp_sharding
        )
        # self.layout is the SHARD layout (the store is built with it)
        self._decode_tp = _tp_paged_decode_fn(
            model, ctx, self.layout, tp, tp_backend, tp_mesh, costs=costs
        )

    def _decode_and_write(self, written: Dict[int, int],
                          tables: np.ndarray) -> np.ndarray:
        jnp = self.jnp
        empty = np.asarray(self.layout.empty_page_row())
        mem = np.stack([
            np.concatenate([sm, empty[None]], axis=0)
            for sm in self.shard_mems
        ])
        logits, newmem = self._decode_tp(
            self._stacked_params,
            jnp.asarray(self.last_token),
            jnp.asarray(self.positions),
            jnp.asarray(mem),
            jnp.asarray(tables),
        )
        self.paged_decode_steps += 1
        pages = sorted(set(written.values()))
        if pages:
            rows = np.asarray(newmem[:, np.asarray(pages, np.int32)])
            for j, pp in enumerate(pages):
                for s in range(self.tp):
                    self.shard_mems[s][pp] = rows[s, j]
                self._dirty[pp] = rows[:, j].copy()
        return np.asarray(logits)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--role", choices=("prefill", "decode", "memory", "both"),
                    default="both",
                    help="both = disaggregated cluster (prefill pool + "
                         "decode pool + optional memory ranks over the "
                         "GAS layer); decode = colocated continuous "
                         "batching; prefill = prefill pool alone; memory "
                         "= a memory-only GAS rank (segment capacity, no "
                         "model compute — reports its tier geometry)")
    ap.add_argument("--n-prefill", type=int, default=1)
    ap.add_argument("--n-decode", type=int, default=1)
    ap.add_argument("--n-memory", type=int, default=0,
                    help="memory-only ranks joining the paged cluster: "
                         "their segments hold the swap tier "
                         "(repro.serving.tier)")
    ap.add_argument("--prefill-backend", default="xla",
                    help="engine of the prefill pool (xla|gascore)")
    ap.add_argument("--decode-backend", default="xla",
                    help="engine of the decode pool (xla|gascore)")
    ap.add_argument("--memory-backend", default="xla",
                    help="engine of the memory ranks (xla|gascore)")
    ap.add_argument("--mem-slots", type=int, default=None,
                    help="tier page slots per memory rank")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--paged", action="store_true",
                    help="KV lives in the global paged pool "
                         "(repro.serving.pool): pages allocated/freed per "
                         "request, prompt prefixes shared by page table")
    ap.add_argument("--page-tokens", type=int, default=8,
                    help="tokens per KV page (must divide --cache-len)")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel group size: decode shards "
                         "attention heads / MLP columns over tp GAS ranks "
                         "with a planned all-reduce per sub-block "
                         "(requires --paged; with --role both, tp must "
                         "divide --n-decode)")
    ap.add_argument("--tp-backend", default="xla",
                    help="engine spec of the TP group's all-reduce — a "
                         "single backend or a mixed per-rank list "
                         "(e.g. 'xla,gascore')")
    args = ap.parse_args()

    if args.role == "both" or (args.role == "decode" and args.tp > 1):
        import os

        os.environ.setdefault(
            "XLA_FLAGS",
            "--xla_force_host_platform_device_count="
            f"{max(args.n_prefill + args.n_decode + args.n_memory, args.tp)}",
        )

    import jax

    from repro.configs.registry import SMOKE
    from repro.models.build import build_model
    from repro.parallel.ctx import RunCtx

    cfg = SMOKE[args.arch]
    model = build_model(cfg)
    ctx = RunCtx(mesh=None, remat="none")
    params, _ = model.init(ctx, jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    reqs = [
        Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab, size=args.prompt_len).tolist(),
            max_new=args.max_new,
        )
        for rid in range(args.requests)
    ]

    if args.role == "decode":
        if args.paged and args.tp > 1:
            server = TPPagedServer(model, ctx, params, args.batch,
                                   args.cache_len,
                                   page_tokens=args.page_tokens,
                                   tp=args.tp, tp_backend=args.tp_backend)
        elif args.paged:
            server = PagedServer(model, ctx, params, args.batch,
                                 args.cache_len, page_tokens=args.page_tokens)
        else:
            if args.tp > 1:
                raise SystemExit("--tp > 1 requires --paged")
            server = Server(model, ctx, params, args.batch, args.cache_len)
        for req in reqs:
            server.submit(req)
        stats = server.run_until_drained()
    elif args.role == "memory":
        # a memory-only GAS rank: it exports segment capacity into the
        # global address space and runs no model compute — report the
        # tier geometry it would contribute to a paged cluster.
        from repro.serving.pool import PagedLayout
        from repro.serving.tier import MemoryTier

        layout = PagedLayout.from_struct(
            model.kv_block_struct(
                ctx, prompt_len=4, cache_len=args.cache_len
            ),
            cache_len=args.cache_len, page_tokens=args.page_tokens,
        )
        slots = args.mem_slots or 2 * args.batch * layout.n_pages
        tier = MemoryTier(1, slots, layout.page_elems)
        stats = dict(tier.stats())
        stats.update({
            "role": "memory",
            "page_bytes": layout.page_bytes,
            "segment_bytes": slots * layout.page_bytes,
        })
    elif args.role == "prefill":
        prefill = jax.jit(
            lambda p, b: model.prefill(p, ctx, b, cache_len=args.cache_len)
        )
        import jax.numpy as jnp

        t0 = time.monotonic()
        for req in reqs:
            logits, _ = prefill(
                params, {"inputs": jnp.asarray(req.prompt, jnp.int32)[None]}
            )
            jax.block_until_ready(logits)
        dt = time.monotonic() - t0
        stats = {
            "requests": len(reqs),
            "wall_s": dt,
            "kv_blocks_per_s": len(reqs) / dt if dt else 0.0,
        }
    else:
        from repro.serving.disagg import DisaggCluster

        cluster = DisaggCluster(
            model, ctx, params,
            n_prefill=args.n_prefill, n_decode=args.n_decode,
            n_memory=args.n_memory,
            decode_batch=args.batch, cache_len=args.cache_len,
            prefill_backend=args.prefill_backend,
            decode_backend=args.decode_backend,
            memory_backend=args.memory_backend,
            paged=args.paged or args.n_memory > 0 or args.tp > 1,
            page_tokens=args.page_tokens,
            mem_slots_per_rank=args.mem_slots,
            tp=args.tp, tp_backend=args.tp_backend,
        )
        for req in reqs:
            cluster.submit(req)
        stats = cluster.run_until_drained()

    for k, v in stats.items():
        print(f"{k}: {v}")


if __name__ == "__main__":
    main()

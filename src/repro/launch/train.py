"""Training driver.

CPU-scale entry point exercising the full production path: config registry,
mesh construction, sharded params, AdamW, deterministic data, async
checkpoints, restart.  On a real TPU fleet the same driver runs with
``--mesh single|multi`` under one process per host (jax.distributed); here
``--devices N`` forces N host devices for multi-device CPU runs.

Examples::

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --smoke \
        --steps 100 --ckpt-dir /tmp/ckpt
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --smoke \
        --devices 8 --mesh-shape 4,2 --steps 50
"""
import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-trainable)")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (set before jax init)")
    ap.add_argument("--mesh-shape", default="",
                    help="e.g. 4,2 -> mesh (data=4, model=2)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ga-steps", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--remat", default="none")
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}"
        )

    import jax
    from repro.configs.registry import ARCHS, SMOKE
    from repro.data.synthetic import ShardedLoader, SyntheticLM
    from repro.launch.mesh import make_mesh, mesh_axes
    from repro.models.build import build_model
    from repro.optim import adamw
    from repro.parallel.ctx import RunCtx
    from repro.runtime.trainer import Trainer, TrainerConfig

    cfg = (SMOKE if args.smoke else ARCHS)[args.arch]
    model = build_model(cfg)

    mesh = None
    dp, tp = ("data",), "model"
    if args.mesh_shape:
        shape = tuple(int(x) for x in args.mesh_shape.split(","))
        axes = ("data", "model")[: len(shape)] if len(shape) <= 2 else (
            "pod", "data", "model")
        mesh = make_mesh(shape, axes)
        dp, tp = mesh_axes(mesh)

    ctx = RunCtx(mesh=mesh, dp=dp, tp=tp, remat=args.remat)
    opt = adamw.AdamWConfig(
        lr=args.lr,
        weight_decay=0.0,
        schedule=adamw.warmup_cosine(args.lr, max(args.steps // 20, 1),
                                     args.steps),
    )
    tcfg = TrainerConfig(
        steps=args.steps,
        ga_steps=args.ga_steps,
        ckpt_every=args.ckpt_every if args.ckpt_dir else 0,
        ckpt_dir=args.ckpt_dir or None,
        log_every=max(args.steps // 20, 1),
    )
    trainer = Trainer(model, ctx, opt, tcfg)

    key = jax.random.PRNGKey(args.seed)
    start_step, extra = 0, {}
    if args.resume and args.ckpt_dir:
        params, opt_state, start_step, extra = trainer.recover(key)
        print(f"resumed from step {start_step}")
    else:
        params, opt_state = trainer.init(key)

    src = SyntheticLM(cfg, batch=args.batch, seq_len=args.seq, seed=args.seed)
    loader = ShardedLoader(
        src, mesh=mesh, dp_axes=dp,
        start_step=int(extra.get("data_step", start_step)),
    )
    try:
        params, opt_state, history = trainer.run(
            params, opt_state, loader, start_step=start_step,
            on_step=lambda s, m: print(
                f"step {s:5d} loss {m['loss']:.4f} "
                f"gnorm {m['grad_norm']:.3f} {m['step_time_s']*1e3:.0f}ms",
                flush=True,
            ),
        )
    finally:
        loader.close()
    print(f"final loss: {history[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()

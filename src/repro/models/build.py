"""Model facade: init / train_loss / prefill / decode for every arch,
plus the ShapeDtypeStruct input builders the multi-pod dry-run lowers
against (no allocation — the shannon/kernels stand-in pattern).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import transformer as T
from repro.models.common import ArchConfig, Segment
from repro.parallel.ctx import RunCtx, shard

__all__ = ["Model", "ShapeConfig", "build_model"]


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


@dataclasses.dataclass
class Model:
    """All entry points close over (cfg, segment structure); params are
    explicit pytrees so the launcher controls sharding and checkpointing."""

    cfg: ArchConfig
    dec_segments: List[Segment]
    enc_segments: Optional[List[Segment]] = None

    # ------------------------------------------------------------------ #
    def init(self, ctx: RunCtx, key) -> Tuple[Dict, Dict]:
        cfg = self.cfg
        kio, kdec, kenc = jax.random.split(key, 3)
        io_p, io_s = T.lm_io_init(cfg, ctx, kio)
        _, dec_p, dec_s = T.stack_init(cfg.layer_kinds(), cfg, ctx, kdec)
        params = {"io": io_p, "dec": dec_p}
        specs = {"io": io_s, "dec": dec_s}
        if cfg.n_enc_layers:
            _, enc_p, enc_s = T.stack_init(
                ["enc"] * cfg.n_enc_layers, cfg, ctx, kenc
            )
            params["enc"] = enc_p
            specs["enc"] = enc_s
        return params, specs

    def abstract_init(self, ctx: RunCtx) -> Tuple[Any, Any]:
        """(params ShapeDtypeStructs, PartitionSpecs) with NO allocation.

        Specs are plain Python objects built during tracing, so they can be
        captured from an ``eval_shape`` of ``init`` — this is how the
        dry-run stands up a 1T-parameter model on a CPU host.
        """
        captured = {}

        def f(k):
            p, s = self.init(ctx, k)
            captured["specs"] = s
            return p

        params_struct = jax.eval_shape(f, jax.random.PRNGKey(0))
        return params_struct, captured["specs"]

    # ------------------------------------------------------------------ #
    def _encode(self, params, ctx: RunCtx, frames: jax.Array) -> jax.Array:
        B, S, _ = frames.shape
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        x = shard(frames.astype(self.cfg.dtype), ctx, ctx.hidden_spec())
        x, _ = T.stack_apply(
            self.enc_segments, params["enc"], self.cfg, ctx, x,
            mode="train", positions=pos,
        )
        return T.final_hidden(params["io"], self.cfg, x)

    def _xkv(self, params, ctx: RunCtx, batch: Dict) -> Optional[jax.Array]:
        if self.cfg.n_enc_layers:
            return self._encode(params, ctx, batch["frames"])
        if "xkv" in batch:
            return batch["xkv"].astype(self.cfg.dtype)
        return None

    # ------------------------------------------------------------------ #
    def train_hidden(self, params, ctx: RunCtx, batch: Dict) -> jax.Array:
        cfg = self.cfg
        tokens = batch["inputs"]
        B, S = tokens.shape
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        x = T.embed(params["io"], cfg, ctx, tokens)
        x, _ = T.stack_apply(
            self.dec_segments, params["dec"], cfg, ctx, x,
            mode="train", positions=pos, xkv=self._xkv(params, ctx, batch),
        )
        return x

    def train_loss(self, params, ctx: RunCtx, batch: Dict) -> jax.Array:
        h = self.train_hidden(params, ctx, batch)
        return T.chunked_ce_loss(
            params["io"], self.cfg, ctx, h, batch["targets"], batch["mask"]
        )

    def train_logits(self, params, ctx: RunCtx, batch: Dict) -> jax.Array:
        """Full logits (small configs / tests only)."""
        h = self.train_hidden(params, ctx, batch)
        return T.logits_fn(params["io"], self.cfg, ctx, h)

    # ------------------------------------------------------------------ #
    def prefill(
        self, params, ctx: RunCtx, batch: Dict, cache_len: int
    ) -> Tuple[jax.Array, Any]:
        cfg = self.cfg
        tokens = batch["inputs"]
        B, S = tokens.shape
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        x = T.embed(params["io"], cfg, ctx, tokens)
        x, caches = T.stack_apply(
            self.dec_segments, params["dec"], cfg, ctx, x,
            mode="prefill", cache_len=cache_len, positions=pos,
            xkv=self._xkv(params, ctx, batch),
        )
        logits = T.logits_fn(params["io"], cfg, ctx, x[:, -1:, :])[:, 0]
        return logits, caches

    def decode_step(
        self,
        params,
        ctx: RunCtx,
        token: jax.Array,  # (B, 1) int32
        positions: jax.Array,  # (B,) int32 — index of the new token
        caches: Any,
    ) -> Tuple[jax.Array, Any]:
        cfg = self.cfg
        pos = positions[:, None]
        x = T.embed(params["io"], cfg, ctx, token)
        x, caches = T.stack_apply(
            self.dec_segments, params["dec"], cfg, ctx, x,
            mode="decode", caches=caches, positions=pos, xkv=None,
        )
        logits = T.logits_fn(params["io"], cfg, ctx, x)[:, 0]
        return logits, caches

    def decode_step_paged(
        self,
        params,
        ctx: RunCtx,
        token: jax.Array,  # (B, 1) int32
        positions: jax.Array,  # (B,) int32 — index of the new token
        pool_caches: Any,
        page_table: jax.Array,  # (B, NP) int32 physical page ids
        tp=None,
    ) -> Tuple[jax.Array, Any]:
        """Decode one token for every request THROUGH the page table.

        ``pool_caches`` is the dense cache pytree with every leaf's token
        axis re-laid as ``(physical pages, page_tokens)`` — the
        ``PagedLayout.decode_views`` of one pool shard, shared by the
        whole batch; each request addresses its pages via ``page_table``.
        The new token's K/V scatter straight into the pool and attention
        runs on ``kernels.paged_attention`` — no dense per-request cache
        rows exist anywhere (the end-to-end paged decode that retires the
        row gathered at admission).

        ``tp`` (a :class:`~repro.parallel.tp.TPGroup`) runs this rank's
        head shard: ``params`` and ``pool_caches`` hold only this rank's
        heads (``tp.shard_decode_params`` / ``PagedLayout.shard_heads``)
        and each sub-block's partial sum crosses the group via
        ``tp.psum`` — one planned all-reduce per attention/MLP, logits
        replicated."""
        cfg = self.cfg
        pos = positions[:, None]
        x = T.embed(params["io"], cfg, ctx, token)
        x, pool_caches = T.stack_apply(
            self.dec_segments, params["dec"], cfg, ctx, x,
            mode="decode", caches=pool_caches, positions=pos, xkv=None,
            page_table=page_table, tp=tp,
        )
        logits = T.logits_fn(params["io"], cfg, ctx, x)[:, 0]
        return logits, pool_caches

    # ------------------------------------------------------------------ #
    # dry-run stand-ins
    # ------------------------------------------------------------------ #
    def input_structs(self, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        sds = jax.ShapeDtypeStruct
        if shape.kind == "train":
            batch = {
                "inputs": sds((B, S), i32),
                "targets": sds((B, S), i32),
                "mask": sds((B, S), jnp.float32),
            }
        elif shape.kind == "prefill":
            batch = {"inputs": sds((B, S), i32)}
        else:  # decode
            batch = {"token": sds((B, 1), i32), "positions": sds((B,), i32)}
        if cfg.n_enc_layers:
            if shape.kind != "decode":
                batch["frames"] = sds((B, S, cfg.d_model), cfg.dtype)
        elif cfg.cross_kv_len and shape.kind != "decode":
            batch["xkv"] = sds((B, cfg.cross_kv_len, cfg.d_model), cfg.dtype)
        return batch

    def input_specs(self, shape: ShapeConfig, ctx: RunCtx) -> Dict[str, P]:
        specs: Dict[str, P] = {}
        for k, v in self.input_structs(shape).items():
            if k in ("inputs", "targets", "mask", "token"):
                specs[k] = P(ctx.dp, None)
            elif k == "positions":
                specs[k] = P(ctx.dp)
            else:  # frames / xkv
                specs[k] = P(ctx.dp, None, None)
        return specs

    def cache_structs(self, shape: ShapeConfig, ctx: RunCtx) -> Any:
        """Abstract cache pytree for decode dry-runs (eval_shape of prefill)."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        params_struct = jax.eval_shape(
            lambda k: self.init(ctx_local(ctx), k)[0], jax.random.PRNGKey(0)
        )
        pre_batch = {"inputs": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if cfg.n_enc_layers:
            pre_batch["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.dtype)
        elif cfg.cross_kv_len:
            pre_batch["xkv"] = jax.ShapeDtypeStruct(
                (B, cfg.cross_kv_len, cfg.d_model), cfg.dtype
            )
        _, cache_struct = jax.eval_shape(
            lambda p, b: self.prefill(p, ctx_local(ctx), b, cache_len=S),
            params_struct, pre_batch,
        )
        return cache_struct

    def kv_block_struct(
        self, ctx: RunCtx, prompt_len: int, cache_len: int, batch: int = 1
    ) -> Any:
        """Abstract per-request KV-cache pytree (an ``eval_shape`` of
        :meth:`prefill`) — the *block layout* a disaggregated serving
        cluster ships between prefill and decode nodes.

        The shapes depend only on ``(cache_len, batch)`` — prefill pads
        every cache to ``cache_len`` — so one layout covers all prompt
        lengths and the GASNet segment slot size is static.
        """
        cfg = self.cfg
        lctx = ctx_local(ctx)
        params_struct = jax.eval_shape(
            lambda k: self.init(lctx, k)[0], jax.random.PRNGKey(0)
        )
        pre_batch: Dict[str, Any] = {
            "inputs": jax.ShapeDtypeStruct((batch, prompt_len), jnp.int32)
        }
        if cfg.n_enc_layers:
            pre_batch["frames"] = jax.ShapeDtypeStruct(
                (batch, prompt_len, cfg.d_model), cfg.dtype
            )
        elif cfg.cross_kv_len:
            pre_batch["xkv"] = jax.ShapeDtypeStruct(
                (batch, cfg.cross_kv_len, cfg.d_model), cfg.dtype
            )
        _, cache_struct = jax.eval_shape(
            lambda p, b: self.prefill(p, lctx, b, cache_len=cache_len),
            params_struct, pre_batch,
        )
        return cache_struct

    def kv_page_struct(
        self,
        ctx: RunCtx,
        cache_len: int,
        page_tokens: int,
        batch: int = 1,
    ) -> Tuple[Any, int]:
        """Paged variant of :meth:`kv_block_struct`: the abstract pytree of
        ONE KV *page* (every leaf's token axis cut from ``cache_len`` to
        ``page_tokens``) plus the page count — the unit the global paged
        KV pool (``repro.serving.pool``) allocates, refcounts and ships.

        Raises when ``page_tokens`` does not divide ``cache_len`` or a
        leaf has no unambiguous token axis (such caches cannot be paged).
        """
        from repro.serving.pool import PagedLayout

        struct = self.kv_block_struct(
            ctx, prompt_len=min(4, cache_len), cache_len=cache_len,
            batch=batch,
        )
        layout = PagedLayout.from_struct(
            struct, cache_len=cache_len, page_tokens=page_tokens
        )
        return layout.page_struct(), layout.n_pages

    def cache_specs(self, cache_struct: Any, ctx: RunCtx) -> Any:
        """PartitionSpecs for a cache pytree (see sharding rules in DESIGN)."""
        cfg = self.cfg
        tp_heads = ctx.tp_size and cfg.n_kv_heads % max(ctx.tp_size, 1) == 0

        def spec_for(path, leaf) -> P:
            name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
            nd = len(leaf.shape)
            if name in ("k", "v"):  # (L, B, W, KH, Dh)
                if tp_heads:
                    return P(None, ctx.dp, None, ctx.tp, None)
                return P(None, ctx.dp, ctx.tp, None, None)
            if name == "pos":  # (L, B, W)
                if tp_heads:
                    return P(None, ctx.dp, None)
                return P(None, ctx.dp, ctx.tp)
            if name == "conv":  # (L, B, Wc-1, C)
                return P(None, ctx.dp, None, ctx.tp)
            if name == "ssm":  # (L, B, Di, N)
                return P(None, ctx.dp, ctx.tp, None)
            if name == "h":  # (L, B, W)
                return P(None, ctx.dp, ctx.tp)
            return P(*([None] * nd))

        return jax.tree_util.tree_map_with_path(spec_for, cache_struct)


def ctx_local(ctx: RunCtx) -> RunCtx:
    """ctx variant with no mesh (for eval_shape structure derivation)."""
    return dataclasses.replace(ctx, mesh=None, moe_mode="local")


def build_model(cfg: ArchConfig) -> Model:
    from repro.models.common import build_layer_program

    dec_segments = build_layer_program(cfg.layer_kinds())
    enc_segments = (
        build_layer_program(["enc"] * cfg.n_enc_layers)
        if cfg.n_enc_layers
        else None
    )
    return Model(cfg=cfg, dec_segments=dec_segments, enc_segments=enc_segments)

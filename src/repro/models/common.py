"""Architecture configuration schema + the layer-program machinery.

Every assigned architecture is expressed as an :class:`ArchConfig` plus a
repeating *pattern* of block kinds (e.g. gemma3's 5 local : 1 global).  The
pattern is compiled into :class:`Segment`\\ s — maximal runs of identical
repeating units — each executed as one ``jax.lax.scan`` over stacked layer
parameters, which keeps the HLO size O(kinds) instead of O(layers) even for
the 126-layer llama3-405b.

Block kinds:

- ``global``  — GQA self-attention (full causal) + MLP
- ``local``   — GQA self-attention (sliding window) + MLP
- ``moe``     — GQA self-attention + mixture-of-experts FFN
- ``dense``   — like ``global`` (used for MoE models' leading dense layers)
- ``mamba``   — mamba1 selective-SSM mixer (no MLP)
- ``rec``     — RG-LRU recurrent mixer + MLP (griffin/recurrentgemma)
- ``cross``   — GQA self-attention + gated cross-attention + MLP (VLM)
- ``enc``     — bidirectional self-attention + MLP (encoder stacks)
- ``xdec``    — causal self-attention + encoder cross-attention + MLP
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence, Tuple

import jax.numpy as jnp

__all__ = ["ArchConfig", "Segment", "build_layer_program", "KNOWN_KINDS"]

KNOWN_KINDS = (
    "global",
    "local",
    "moe",
    "dense",
    "mamba",
    "rec",
    "cross",
    "enc",
    "xdec",
)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """Static description of one architecture (exact published numbers)."""

    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    # layer pattern (repeating unit of block kinds); padded/truncated to
    # n_layers by build_layer_program.
    pattern: Tuple[str, ...] = ("global",)
    head_dim: Optional[int] = None
    qk_norm: bool = False
    local_window: int = 1024
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "silu"  # silu | gelu
    mlp_gated: bool = True  # SwiGLU-style; False = classic 2-matrix FFN
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_dense_residual: bool = False  # arctic: parallel dense FFN
    n_shared_experts: int = 0  # kimi: always-on experts
    first_dense_layers: int = 0  # kimi: leading dense layers
    d_ff_dense: Optional[int] = None  # d_ff of dense/residual FFN if different
    # --- SSM (mamba1) ---
    ssm_state: int = 16
    d_inner: int = 0  # 0 -> 2 * d_model
    conv_width: int = 4
    dt_rank: int = 0  # 0 -> d_model // 16
    # --- hybrid (RG-LRU) ---
    lru_width: int = 0  # 0 -> d_model
    # --- VLM / enc-dec frontends (stubs provide the embeddings) ---
    cross_kv_len: int = 0  # vision tokens / encoder length for cross blocks
    n_enc_layers: int = 0  # encoder stack depth (seamless)
    # --- numerics ---
    dtype: Any = jnp.bfloat16
    sub_quadratic: bool = False  # eligible for long_500k decode

    # ------------------------------------------------------------------ #
    def __post_init__(self):
        for k in self.pattern:
            if k not in KNOWN_KINDS:
                raise ValueError(f"unknown block kind {k!r}")

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // max(self.n_heads, 1)

    @property
    def resolved_d_inner(self) -> int:
        return self.d_inner or 2 * self.d_model

    @property
    def resolved_dt_rank(self) -> int:
        return self.dt_rank or max(self.d_model // 16, 1)

    @property
    def resolved_lru_width(self) -> int:
        return self.lru_width or self.d_model

    @property
    def resolved_d_ff_dense(self) -> int:
        return self.d_ff_dense or self.d_ff

    @property
    def attention_free(self) -> bool:
        return all(k == "mamba" for k in self.pattern)

    def layer_kinds(self) -> List[str]:
        """Per-layer kinds for the decoder stack (length n_layers)."""
        kinds: List[str] = []
        if self.first_dense_layers:
            kinds.extend(["dense"] * self.first_dense_layers)
        i = 0
        while len(kinds) < self.n_layers:
            kinds.append(self.pattern[i % len(self.pattern)])
            i += 1
        return kinds[: self.n_layers]

    # parameter counting (for roofline MODEL_FLOPS) ---------------------- #
    def param_counts(self) -> Tuple[int, int]:
        """(total_params, active_params) — embedding included once."""
        D, F, V = self.d_model, self.d_ff, self.vocab
        H, KH, Dh = self.n_heads, self.n_kv_heads, self.resolved_head_dim
        total = V * D  # embedding
        if not self.tie_embeddings:
            total += V * D  # lm head
        active = total

        def attn_params() -> int:
            return D * H * Dh + 2 * D * KH * Dh + H * Dh * D

        def mlp_params(f: int) -> int:
            return (3 if self.mlp_gated else 2) * D * f

        for kind in self.layer_kinds():
            if kind in ("global", "local", "dense", "enc"):
                f = self.resolved_d_ff_dense if kind == "dense" else F
                p = attn_params() + mlp_params(
                    f if kind != "dense" else self.resolved_d_ff_dense
                )
                total += p
                active += p
            elif kind == "moe":
                shared = self.n_shared_experts * mlp_params(F)
                router = D * self.n_experts
                experts_total = self.n_experts * mlp_params(F)
                experts_active = self.top_k * mlp_params(F)
                dense_res = (
                    mlp_params(self.resolved_d_ff_dense)
                    if self.moe_dense_residual
                    else 0
                )
                total += attn_params() + router + experts_total + shared + dense_res
                active += attn_params() + router + experts_active + shared + dense_res
            elif kind == "mamba":
                Di, N = self.resolved_d_inner, self.ssm_state
                R = self.resolved_dt_rank
                p = (
                    D * 2 * Di  # in_proj
                    + self.conv_width * Di  # conv
                    + Di * (R + 2 * N)  # x_proj
                    + R * Di  # dt_proj
                    + Di * N  # A
                    + Di  # D skip
                    + Di * D  # out_proj
                )
                total += p
                active += p
            elif kind == "rec":
                W = self.resolved_lru_width
                p = (
                    2 * D * W  # linear + gate branches
                    + self.conv_width * W  # causal conv
                    + 2 * W * W  # RG-LRU input & recurrence gate projections
                    + W  # Lambda (recurrence decay)
                    + W * D  # out proj
                    + mlp_params(F)
                )
                total += p
                active += p
            elif kind in ("cross", "xdec"):
                p = 2 * attn_params() + mlp_params(F)
                total += p
                active += p
        if self.n_enc_layers:
            enc = self.n_enc_layers * (attn_params() + mlp_params(F))
            total += enc
            active += enc
        return total, active


@dataclasses.dataclass(frozen=True)
class Segment:
    """A maximal run of identical repeating units, executed as one scan.

    ``unit``: tuple of block kinds applied in order inside the scan body.
    ``count``: number of scan iterations (stacked-parameter leading dim).
    """

    unit: Tuple[str, ...]
    count: int

    @property
    def n_layers(self) -> int:
        return len(self.unit) * self.count


def build_layer_program(kinds: Sequence[str], max_unit: int = 8) -> List[Segment]:
    """Compile a per-layer kind list into scan segments.

    Greedy: find the shortest repeating unit (length <= max_unit) covering a
    maximal prefix, emit it as a Segment, recurse on the rest.  Guarantees
    segment order == layer order.
    """
    kinds = list(kinds)
    segments: List[Segment] = []
    i = 0
    n = len(kinds)
    while i < n:
        best = (1, 1)  # (unit_len, count)
        for ul in range(1, min(max_unit, n - i) + 1):
            unit = kinds[i : i + ul]
            count = 1
            while (
                i + (count + 1) * ul <= n
                and kinds[i + count * ul : i + (count + 1) * ul] == unit
            ):
                count += 1
            if count * ul > best[0] * best[1] or (
                count * ul == best[0] * best[1] and ul < best[0]
            ):
                best = (ul, count)
        ul, count = best
        segments.append(Segment(unit=tuple(kinds[i : i + ul]), count=count))
        i += ul * count
    assert sum(s.n_layers for s in segments) == n
    return segments

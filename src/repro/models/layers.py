"""Composable model layers (pure-functional, params-as-pytrees).

Every ``*_init`` returns ``(params, specs)`` — two trees of identical
structure, the second holding ``PartitionSpec`` leaves.  Sharding therefore
travels with the parameters (FSDP over ``data``, tensor parallel over
``model``), and stacking layers for scan simply prepends ``None``.

Activations receive explicit constraints only at block boundaries; XLA's
sharding propagation handles the interior from the parameter specs.

The MoE layer has two execution paths (the paper's software/hardware story
at the *parallelism* level):

- ``local``        — single-device reference (smoke tests, examples).
- ``ep_shardmap``  — expert parallelism via an explicit Active-Message-style
  dispatch: tokens are routed into capacity-bounded per-expert buffers
  (``kernels.moe_router`` semantics), exchanged with an all-to-all over the
  ``model`` axis — through the GAS engine, so the transport can be the XLA
  software path or the GAScore ring — computed by the expert's home device,
  and combined back.  Expert-weight gradients reduce over ``data``
  automatically via the shard_map transpose.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.kernels import ops
from repro.models.common import ArchConfig
from repro.parallel.ctx import RunCtx, use_weight
from repro.compat import shard_map

Params = Dict[str, Any]


# --------------------------------------------------------------------------- #
# initializers
# --------------------------------------------------------------------------- #
def _normal(key, shape, dtype, scale):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def linear_init(key, in_dim: int, out_dims, dtype, scale=None):
    shape = (in_dim,) + tuple(out_dims if isinstance(out_dims, tuple) else (out_dims,))
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    return _normal(key, shape, dtype, scale)


def norm_init(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def norm_specs() -> Params:
    return {"scale": P(None)}


def apply_norm(p: Params, x: jax.Array, kind: str = "rmsnorm", eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    else:  # layernorm (no bias)
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(x.dtype)


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


# --------------------------------------------------------------------------- #
# RoPE
# --------------------------------------------------------------------------- #
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, Dh); positions: (B, S) int32."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- #
# attention
# --------------------------------------------------------------------------- #
def _kv_spec(cfg: ArchConfig, ctx: RunCtx) -> P:
    """KV projections: heads over tp when divisible, else replicated heads."""
    if ctx.tp_size and cfg.n_kv_heads % ctx.tp_size == 0:
        return P("data", ctx.tp, None)
    return P("data", None, None)


def attention_init(cfg: ArchConfig, ctx: RunCtx, key) -> Tuple[Params, Params]:
    dh = cfg.resolved_head_dim
    D, H, KH = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 6)
    params = {
        "norm": norm_init(D),
        "wq": linear_init(ks[0], D, (H, dh), cfg.dtype),
        "wk": linear_init(ks[1], D, (KH, dh), cfg.dtype),
        "wv": linear_init(ks[2], D, (KH, dh), cfg.dtype),
        "wo": linear_init(ks[3], H * dh, (D,), cfg.dtype),
    }
    specs = {
        "norm": norm_specs(),
        "wq": P("data", ctx.tp, None),
        "wk": _kv_spec(cfg, ctx),
        "wv": _kv_spec(cfg, ctx),
        "wo": P(ctx.tp, "data"),
    }
    if cfg.qk_norm:
        params["q_norm"] = norm_init(dh)
        params["k_norm"] = norm_init(dh)
        specs["q_norm"] = norm_specs()
        specs["k_norm"] = norm_specs()
    return params, specs


def _gqa_scores_softmax_v(q, k, v, mask, scale):
    """q: (B,Sq,H,Dh), k/v: (B,Sk,KH,Dh), mask: (B,Sq,Sk) bool."""
    B, Sq, H, Dh = q.shape
    KH = k.shape[2]
    G = H // KH
    qg = q.reshape(B, Sq, KH, G, Dh)
    # keep activations in model dtype; accumulate the dots in f32
    # (preferred_element_type) so backward cotangents stay bf16 — the f32
    # cotangent all-reduces were a measured 1e12 B/device in the llama
    # baseline (§Perf iteration D).
    s = jnp.einsum(
        "bqkgd,bskd->bkgqs", qg * jnp.asarray(scale, q.dtype), k,
        preferred_element_type=jnp.float32,
    )  # (B, KH, G, Sq, Sk) f32
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum(
        "bkgqs,bskd->bqkgd", p.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    visible = mask.any(axis=-1)  # (B, Sq)
    o = jnp.where(visible[:, :, None, None, None], o, 0.0)
    return o.reshape(B, Sq, H, Dh).astype(q.dtype)


def _chunked_attention(q, k, v, qpos, kpos, *, causal, window, scale, chunk):
    """Blockwise-over-queries attention (jnp; differentiable; O(S·chunk) mem).

    qpos: (B, Sq) absolute query positions; kpos: (B, Sk) key positions
    (-1 = empty cache slot).
    """
    B, Sq, H, Dh = q.shape
    chunk = min(chunk, Sq)
    pad = (-Sq) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        qpos = jnp.pad(qpos, ((0, 0), (0, pad)), constant_values=-1)
    nq = q.shape[1] // chunk

    def one_chunk(ci):
        qs = lax.dynamic_slice_in_dim(q, ci * chunk, chunk, axis=1)
        qp = lax.dynamic_slice_in_dim(qpos, ci * chunk, chunk, axis=1)
        mask = kpos[:, None, :] >= 0
        if causal:
            mask &= qp[:, :, None] >= kpos[:, None, :]
        if window is not None:
            mask &= (qp[:, :, None] - kpos[:, None, :]) < window
            if not causal:
                mask &= (kpos[:, None, :] - qp[:, :, None]) < window
        mask &= qp[:, :, None] >= 0
        return _gqa_scores_softmax_v(qs, k, v, mask, scale)

    outs = lax.map(one_chunk, jnp.arange(nq))  # (nq, B, chunk, H, Dh)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * chunk, H, Dh)
    return out[:, :Sq]


def apply_attention(
    p: Params,
    cfg: ArchConfig,
    ctx: RunCtx,
    x: jax.Array,
    *,
    positions: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    mode: str = "train",
    cache: Optional[Params] = None,
    cache_len: int = 0,
    xkv: Optional[jax.Array] = None,
    page_table: Optional[jax.Array] = None,
    tp=None,
) -> Tuple[jax.Array, Optional[Params]]:
    """Self- or cross-attention sub-block (pre-norm, residual added by caller).

    Modes:
      train    — full-sequence self-attention, no cache.
      prefill  — full sequence; returns a cache of capacity ``cache_len``.
      decode   — x is (B, 1, D); reads/updates ``cache``.
    Cross-attention (``xkv`` given): keys/values come from ``xkv``
    (B, S_enc, D); cache (mode != train) stores the projected enc KV.

    Paged decode (``page_table`` given, decode mode only): ``cache`` holds
    the layer's slice of the global KV *page pool* — ``k``/``v`` shaped
    ``(P, page_tokens, KH, Dh)`` and ``pos`` ``(P, page_tokens)`` — and
    ``page_table`` is ``(B, NP)`` physical ids per request.  The new
    token's K/V scatter straight into the request's (COW-resolved,
    materialised) page and attention runs through the table via
    ``kernels.paged_attention`` — no dense per-request rows anywhere.

    Tensor-parallel (``tp`` a :class:`~repro.parallel.tp.TPGroup`): the
    caller passes head-sharded ``wq/wk/wv/wo`` — every per-head
    computation (projections, rope, softmax, the paged pool writes) is
    rank-local and identical to the matching head slice of the
    unsharded run; only the output projection's partial sum crosses the
    group, via ``tp.psum``.
    """
    dh = cfg.resolved_head_dim
    scale = 1.0 / math.sqrt(dh)
    B, S, D = x.shape
    h = apply_norm(p["norm"], x, cfg.norm)
    wq = use_weight(p["wq"], ctx, P(None, ctx.tp, None))
    wk = use_weight(p["wk"], ctx, P(None, ctx.tp, None))
    wv = use_weight(p["wv"], ctx, P(None, ctx.tp, None))
    wo = use_weight(p["wo"], ctx, P(ctx.tp, None))
    q = jnp.einsum("bsd,dhk->bshk", h, wq)
    if cfg.qk_norm:
        q = apply_norm(p["q_norm"], q, "rmsnorm")

    is_cross = xkv is not None
    if is_cross:
        if cache is not None and mode == "decode":
            k, v, kpos = cache["k"], cache["v"], cache["pos"]
        else:
            k = jnp.einsum("bsd,dhk->bshk", xkv, wk)
            v = jnp.einsum("bsd,dhk->bshk", xkv, wv)
            if cfg.qk_norm:
                k = apply_norm(p["k_norm"], k, "rmsnorm")
            kpos = jnp.broadcast_to(
                jnp.arange(k.shape[1], dtype=jnp.int32)[None], k.shape[:2]
            )
        if mode == "decode":
            mask = jnp.broadcast_to(
                (kpos >= 0)[:, None, :], (B, S, kpos.shape[1])
            )  # cross: no causal mask
            out = _gqa_scores_softmax_v(q, k, v, mask, scale)
        else:
            out = _chunked_attention(
                q, k, v, positions, kpos, causal=False, window=None,
                scale=scale, chunk=ctx.attn_chunk,
            )
        new_cache = (
            {"k": k, "v": v, "pos": kpos} if mode == "prefill" else cache
        )
        o = jnp.einsum("bshk,hkd->bsd", out, wo.reshape(-1, dh, D))
        if tp is not None:
            o = tp.maybe_psum(o)
        return o.astype(x.dtype), new_cache

    k = jnp.einsum("bsd,dhk->bshk", h, wk)
    v = jnp.einsum("bsd,dhk->bshk", h, wv)
    if cfg.qk_norm:
        k = apply_norm(p["k_norm"], k, "rmsnorm")
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    if mode == "train":
        kpos = positions
        if ctx.attn_impl == "pallas" and window != 0:
            out = ops.attention(
                jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2),
                jnp.moveaxis(v, 1, 2), causal=causal, window=window,
                scale=scale, impl="pallas", interpret=ctx.interpret,
            )
            out = jnp.moveaxis(out, 1, 2)
        else:
            out = _chunked_attention(
                q, k, v, positions, kpos, causal=causal, window=window,
                scale=scale, chunk=ctx.attn_chunk,
            )
        new_cache = None
    elif mode == "prefill":
        W = cache_len if window is None else min(window, cache_len)
        # ring-buffer write of the last W positions
        kc = jnp.zeros((B, W) + k.shape[2:], k.dtype)
        vc = jnp.zeros_like(kc)
        pc = jnp.full((B, W), -1, jnp.int32)
        take = min(W, S)
        sl = slice(S - take, S)
        idx = positions[:, sl] % W  # (B, take)
        b_idx = jnp.arange(B)[:, None]
        kc = kc.at[b_idx, idx].set(k[:, sl])
        vc = vc.at[b_idx, idx].set(v[:, sl])
        pc = pc.at[b_idx, idx].set(positions[:, sl])
        out = _chunked_attention(
            q, k, v, positions, positions, causal=causal, window=window,
            scale=scale, chunk=ctx.attn_chunk,
        )
        new_cache = {"k": kc, "v": vc, "pos": pc}
    elif mode == "decode" and page_table is not None:
        if window is not None:
            raise ValueError("paged decode does not support local windows")
        kp, vp, pp = cache["k"], cache["v"], cache["pos"]  # page pools
        T = kp.shape[1]  # page_tokens
        pos = positions[:, 0]  # (B,)
        # the write page: COW-resolved and materialised by the host before
        # the step, so live rows never collide (dead rows all target the
        # scratch page with identical values — deterministic scatter)
        phys = page_table[jnp.arange(B), pos // T]
        slot = pos % T
        kp = kp.at[phys, slot].set(k[:, 0])
        vp = vp.at[phys, slot].set(v[:, 0])
        pp = pp.at[phys, slot].set(pos)
        out = ops.paged_attention(
            q[:, 0], kp, vp, page_table, pos + 1, scale=scale,
            impl="pallas" if ctx.attn_impl == "pallas" else "ref",
            interpret=ctx.interpret,
        )[:, None]
        new_cache = {"k": kp, "v": vp, "pos": pp}
    elif mode == "decode":
        kc, vc, pc = cache["k"], cache["v"], cache["pos"]
        W = kc.shape[1]
        pos = positions[:, 0]  # (B,)
        slot = pos % W
        b_idx = jnp.arange(B)
        kc = kc.at[b_idx, slot].set(k[:, 0])
        vc = vc.at[b_idx, slot].set(v[:, 0])
        pc = pc.at[b_idx, slot].set(pos)
        mask = pc[:, None, :] >= 0  # (B, 1, W)
        mask &= pc[:, None, :] <= pos[:, None, None]
        if window is not None:
            mask &= (pos[:, None, None] - pc[:, None, :]) < window
        out = _gqa_scores_softmax_v(q, kc, vc, mask, scale)
        new_cache = {"k": kc, "v": vc, "pos": pc}
    else:
        raise ValueError(mode)

    o = jnp.einsum("bshk,hkd->bsd", out, wo.reshape(-1, dh, D))
    if tp is not None:
        o = tp.maybe_psum(o)
    return o.astype(x.dtype), new_cache


# --------------------------------------------------------------------------- #
# MLP
# --------------------------------------------------------------------------- #
def mlp_init(cfg: ArchConfig, ctx: RunCtx, key, d_ff: Optional[int] = None):
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    params = {
        "norm": norm_init(D),
        "wi": linear_init(ks[0], D, (F,), cfg.dtype),
        "wo": linear_init(ks[2], F, (D,), cfg.dtype),
    }
    specs = {
        "norm": norm_specs(),
        "wi": P("data", ctx.tp),
        "wo": P(ctx.tp, "data"),
    }
    if cfg.mlp_gated:
        params["wg"] = linear_init(ks[1], D, (F,), cfg.dtype)
        specs["wg"] = P("data", ctx.tp)
    return params, specs


def apply_mlp(p: Params, cfg: ArchConfig, x: jax.Array,
              ctx: RunCtx = None, tp=None) -> jax.Array:
    h = apply_norm(p["norm"], x, cfg.norm)
    act = _act(cfg.act)
    ctx = ctx or RunCtx(mesh=None)
    wi = use_weight(p["wi"], ctx, P(None, ctx.tp))
    wo = use_weight(p["wo"], ctx, P(ctx.tp, None))
    if cfg.mlp_gated:
        wg = use_weight(p["wg"], ctx, P(None, ctx.tp))
        z = act(h @ wg) * (h @ wi)
    else:
        z = act(h @ wi)
    y = z @ wo
    if tp is not None:
        y = tp.maybe_psum(y)
    return y.astype(x.dtype)


# --------------------------------------------------------------------------- #
# MoE
# --------------------------------------------------------------------------- #
def moe_init(cfg: ArchConfig, ctx: RunCtx, key):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 6)
    params = {
        "norm": norm_init(D),
        "router": linear_init(ks[0], D, (E,), jnp.float32),
        "wi": _normal(ks[1], (E, D, F), cfg.dtype, 1.0 / math.sqrt(D)),
        "wg": _normal(ks[2], (E, D, F), cfg.dtype, 1.0 / math.sqrt(D)),
        "wo": _normal(ks[3], (E, F, D), cfg.dtype, 1.0 / math.sqrt(F)),
    }
    specs = {
        "norm": norm_specs(),
        "router": P("data", None),
        "wi": P(ctx.tp, "data", None),
        "wg": P(ctx.tp, "data", None),
        "wo": P(ctx.tp, None, "data"),
    }
    if cfg.n_shared_experts:
        sp, ss = mlp_init(cfg, ctx, ks[4], d_ff=cfg.d_ff * cfg.n_shared_experts)
        params["shared"], specs["shared"] = sp, ss
    if cfg.moe_dense_residual:
        dp_, ds = mlp_init(cfg, ctx, ks[5], d_ff=cfg.resolved_d_ff_dense)
        params["dense_res"], specs["dense_res"] = dp_, ds
    return params, specs


def _moe_local(p, cfg: ArchConfig, ctx: RunCtx, x2d: jax.Array, capacity: int):
    """Single-device reference MoE (router oracle + dense dispatch)."""
    from repro.kernels import ref as kref

    logits = x2d.astype(jnp.float32) @ p["router"]
    e, s, w, keep = ops.moe_router(
        logits, k=cfg.top_k, capacity=capacity, impl="ref"
    )
    buf = kref.moe_dispatch(
        x2d, e, s, keep, n_experts=cfg.n_experts, capacity=capacity
    )
    act = _act(cfg.act)
    hidden = act(jnp.einsum("ecd,edf->ecf", buf, p["wg"])) * jnp.einsum(
        "ecd,edf->ecf", buf, p["wi"]
    )
    out_buf = jnp.einsum("ecf,efd->ecd", hidden, p["wo"])
    return kref.moe_combine(out_buf, e, s, w, keep).astype(x2d.dtype)


def _moe_ep(p, cfg: ArchConfig, ctx: RunCtx, x2d: jax.Array):
    """Expert-parallel MoE: AM-style dispatch + all-to-all over ``model``.

    Tokens are sharded over (data[, model]); experts over model.  Inside the
    shard_map each device routes its local tokens into per-expert capacity
    buffers (Active-Message send queues), the buffers are exchanged with an
    all-to-all through the GAS engine, experts compute at home, and results
    travel back the same way.
    """
    from repro.core.engine import make_engine
    from repro.kernels import ref as kref

    mesh = ctx.mesh
    tp = ctx.tp
    tp_size = ctx.tp_size
    E = cfg.n_experts
    E_l = E // tp_size
    T, D = x2d.shape
    tok_axes = ctx.dp + ((tp,) if T % (ctx.dp_size * tp_size) == 0 else ())
    n_shards = math.prod(mesh.shape[a] for a in tok_axes)
    T_l = T // n_shards
    C_l = max(4, int(math.ceil(T_l * cfg.top_k * cfg.capacity_factor / E)))

    data_axes = tuple(a for a in ctx.dp if a == "data")

    def body(x_l, router_w, wi, wg, wo):
        eng = make_engine(ctx.moe_backend, tp, tp_size, interpret=ctx.interpret)
        if data_axes:
            # FSDP unshard-at-use for expert weights (explicit all-gather
            # over the data axis INSIDE the EP region; its transpose is the
            # reduce-scatter of expert grads).  Without this the shard_map
            # boundary re-gathers the full stacked experts every layer —
            # the dominant all-gather cost measured in the kimi baseline.
            wi = lax.all_gather(wi, data_axes, axis=1, tiled=True)
            wg = lax.all_gather(wg, data_axes, axis=1, tiled=True)
            wo = lax.all_gather(wo, data_axes, axis=1, tiled=True)
        logits = x_l.astype(jnp.float32) @ router_w
        e, s, w, keep = kref.route_topk(
            logits, k=cfg.top_k, capacity=C_l, renormalize=True
        )
        buf = kref.moe_dispatch(x_l, e, s, keep, n_experts=E, capacity=C_l)
        # (E, C_l, D) -> exchange so expert home devices receive all shards
        send = buf.reshape(tp_size * E_l * C_l, D)
        recv = eng.all_to_all(send)
        rows = recv.reshape(tp_size, E_l, C_l, D).transpose(1, 0, 2, 3)
        rows = rows.reshape(E_l, tp_size * C_l, D)
        act = _act(cfg.act)
        hid = act(jnp.einsum("ecd,edf->ecf", rows, wg)) * jnp.einsum(
            "ecd,edf->ecf", rows, wi
        )
        out_rows = jnp.einsum("ecf,efd->ecd", hid, wo)
        back = out_rows.reshape(E_l, tp_size, C_l, D).transpose(1, 0, 2, 3)
        back = eng.all_to_all(back.reshape(tp_size * E_l * C_l, D))
        out_buf = back.reshape(E, C_l, D)
        y = kref.moe_combine(out_buf, e, s, w, keep)
        return y.astype(x_l.dtype)

    tok_spec = P(tok_axes, None)
    expert_spec = P(tp, "data", None)  # matches moe_init specs (FSDP dim 1)
    out = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            tok_spec,
            P(None, None),  # router replicated
            expert_spec,
            expert_spec,
            expert_spec,
        ),
        out_specs=tok_spec,
        check_vma=False,
    )(x2d, p["router"], p["wi"], p["wg"], p["wo"])
    return out


def apply_moe(p: Params, cfg: ArchConfig, ctx: RunCtx, x: jax.Array) -> jax.Array:
    B, S, D = x.shape
    h = apply_norm(p["norm"], x, cfg.norm)
    x2d = h.reshape(B * S, D)
    use_ep = (
        ctx.moe_mode == "ep_shardmap"
        or (
            ctx.moe_mode == "auto"
            and ctx.mesh is not None
            and cfg.n_experts % max(ctx.tp_size, 1) == 0
            and (B * S) % ctx.dp_size == 0
        )
    )
    if use_ep and ctx.mesh is not None:
        y2d = _moe_ep(p, cfg, ctx, x2d)
    else:
        cap = max(4, int(math.ceil(B * S * cfg.top_k * cfg.capacity_factor
                                   / cfg.n_experts)))
        y2d = _moe_local(p, cfg, ctx, x2d, cap)
    y = y2d.reshape(B, S, D)
    if "shared" in p:
        y = y + apply_mlp(p["shared"], cfg, x, ctx)
    if "dense_res" in p:
        y = y + apply_mlp(p["dense_res"], cfg, x, ctx)
    return y.astype(x.dtype)


# --------------------------------------------------------------------------- #
# causal conv (width w, depthwise)
# --------------------------------------------------------------------------- #
def causal_conv(x: jax.Array, w: jax.Array, b: Optional[jax.Array],
                state: Optional[jax.Array] = None):
    """Depthwise causal conv1d.  x: (B, S, C), w: (W, C).

    With ``state`` (B, W-1, C): uses it as left context (decode/chunked);
    returns (y, new_state).
    """
    W = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(
        xp[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(W)
    )
    if b is not None:
        y = y + b[None, None, :]
    new_state = xp[:, -(W - 1):] if W > 1 else jnp.zeros_like(x[:, :0])
    return y.astype(x.dtype), new_state


# --------------------------------------------------------------------------- #
# mamba1 mixer
# --------------------------------------------------------------------------- #
def mamba_init(cfg: ArchConfig, ctx: RunCtx, key):
    D = cfg.d_model
    Di = cfg.resolved_d_inner
    N = cfg.ssm_state
    R = cfg.resolved_dt_rank
    Wc = cfg.conv_width
    ks = jax.random.split(key, 6)
    params = {
        "norm": norm_init(D),
        # separate x/gate projections: a fused (D, 2*Di) + split would
        # straddle the tp sharding boundary and cost a collective-permute
        # per layer (measured §Perf falcon iteration C)
        "in_x": linear_init(ks[0], D, (Di,), cfg.dtype),
        "in_gate": linear_init(jax.random.fold_in(ks[0], 1), D, (Di,),
                               cfg.dtype),
        "conv_w": _normal(ks[1], (Wc, Di), cfg.dtype, 1.0 / math.sqrt(Wc)),
        "conv_b": jnp.zeros((Di,), cfg.dtype),
        "x_proj": linear_init(ks[2], Di, (R + 2 * N,), cfg.dtype),
        "dt_proj": linear_init(ks[3], R, (Di,), cfg.dtype),
        "dt_bias": jnp.log(
            jnp.expm1(
                jnp.exp(
                    jax.random.uniform(
                        ks[4], (Di,), jnp.float32,
                        math.log(1e-3), math.log(1e-1),
                    )
                )
            )
        ).astype(jnp.float32),
        "a_log": jnp.log(
            jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None], (Di, 1))
        ),
        "d_skip": jnp.ones((Di,), jnp.float32),
        "out_proj": linear_init(ks[5], Di, (D,), cfg.dtype),
    }
    specs = {
        "norm": norm_specs(),
        "in_x": P("data", ctx.tp),
        "in_gate": P("data", ctx.tp),
        "conv_w": P(None, ctx.tp),
        "conv_b": P(ctx.tp),
        "x_proj": P(ctx.tp, None),
        "dt_proj": P(None, ctx.tp),
        "dt_bias": P(ctx.tp),
        "a_log": P(ctx.tp, None),
        "d_skip": P(ctx.tp),
        "out_proj": P(ctx.tp, "data"),
    }
    return params, specs


def apply_mamba(
    p: Params,
    cfg: ArchConfig,
    ctx: RunCtx,
    x: jax.Array,
    *,
    mode: str = "train",
    cache: Optional[Params] = None,
) -> Tuple[jax.Array, Optional[Params]]:
    B, S, D = x.shape
    N = cfg.ssm_state
    R = cfg.resolved_dt_rank
    h = apply_norm(p["norm"], x, cfg.norm)
    w_inx = use_weight(p["in_x"], ctx, P(None, ctx.tp))
    w_ing = use_weight(p["in_gate"], ctx, P(None, ctx.tp))
    w_out = use_weight(p["out_proj"], ctx, P(ctx.tp, None))
    xin = h @ w_inx  # (B, S, Di)
    gate = h @ w_ing

    conv_state = cache["conv"] if cache is not None else None
    xin, new_conv = causal_conv(xin, p["conv_w"], p["conv_b"], conv_state)
    xin = jax.nn.silu(xin)

    dbc = xin @ p["x_proj"]  # (B, S, R+2N)
    dt_low, bmat, cmat = jnp.split(dbc, [R, R + N], axis=-1)
    dt = jax.nn.softplus(dt_low @ p["dt_proj"] + p["dt_bias"]).astype(jnp.float32)
    a = -jnp.exp(p["a_log"])  # (Di, N)

    if mode == "decode":
        # single-step closed form
        hprev = cache["ssm"]  # (B, Di, N) f32
        dtt = dt[:, 0]  # (B, Di)
        xt = xin[:, 0].astype(jnp.float32)
        bt = bmat[:, 0].astype(jnp.float32)
        ct = cmat[:, 0].astype(jnp.float32)
        decay = jnp.exp(dtt[..., None] * a[None])
        hnew = decay * hprev + (dtt * xt)[..., None] * bt[:, None, :]
        y = (hnew * ct[:, None, :]).sum(-1) + p["d_skip"][None] * xt
        y = y[:, None, :]
        new_cache = {"conv": new_conv, "ssm": hnew}
    else:
        y = ops.selective_scan(
            xin, dt, a, bmat, cmat, p["d_skip"],
            impl=ctx.scan_impl, interpret=ctx.interpret,
        )
        if mode == "prefill":
            # final SSM state for decode continuation (exact oracle scan;
            # fusing this into the y-scan is a TPU-path optimization).
            hfin = _mamba_final_state(xin, dt, a, bmat)
            new_cache = {"conv": new_conv, "ssm": hfin}
        else:
            new_cache = None

    y = (y * jax.nn.silu(gate.astype(jnp.float32))).astype(x.dtype)
    out = y @ w_out
    return out.astype(x.dtype), new_cache


def _mamba_final_state(xin, dt, a, bmat):
    """Final SSM state h_S (B, Di, N) via lax.scan (f32)."""

    def step(h, inp):
        xt, dtt, bt = inp
        decay = jnp.exp(dtt[..., None] * a[None])
        h = decay * h + (dtt * xt)[..., None] * bt[:, None, :]
        return h, None

    B, S, Di = xin.shape
    N = a.shape[1]
    xs = (
        jnp.moveaxis(xin.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
        jnp.moveaxis(bmat.astype(jnp.float32), 1, 0),
    )
    h0 = jnp.zeros((B, Di, N), jnp.float32)
    hfin, _ = lax.scan(step, h0, xs)
    return hfin


# --------------------------------------------------------------------------- #
# RG-LRU mixer (griffin / recurrentgemma)
# --------------------------------------------------------------------------- #
def rec_init(cfg: ArchConfig, ctx: RunCtx, key):
    D = cfg.d_model
    W = cfg.resolved_lru_width
    Wc = cfg.conv_width
    ks = jax.random.split(key, 6)
    params = {
        "norm": norm_init(D),
        "in_x": linear_init(ks[0], D, (W,), cfg.dtype),
        "in_gate": linear_init(ks[1], D, (W,), cfg.dtype),
        "conv_w": _normal(ks[2], (Wc, W), cfg.dtype, 1.0 / math.sqrt(Wc)),
        "conv_b": jnp.zeros((W,), cfg.dtype),
        "w_rgate": linear_init(ks[3], W, (W,), cfg.dtype),
        "w_igate": linear_init(ks[4], W, (W,), cfg.dtype),
        "lam": jax.random.uniform(ks[5], (W,), jnp.float32, 0.5, 4.0),
        "out_proj": linear_init(jax.random.fold_in(key, 7), W, (D,), cfg.dtype),
    }
    specs = {
        "norm": norm_specs(),
        "in_x": P("data", ctx.tp),
        "in_gate": P("data", ctx.tp),
        "conv_w": P(None, ctx.tp),
        "conv_b": P(ctx.tp),
        "w_rgate": P("data", ctx.tp),
        "w_igate": P("data", ctx.tp),
        "lam": P(ctx.tp),
        "out_proj": P(ctx.tp, "data"),
    }
    return params, specs


_RGLRU_C = 8.0


def apply_rec(
    p: Params,
    cfg: ArchConfig,
    ctx: RunCtx,
    x: jax.Array,
    *,
    mode: str = "train",
    cache: Optional[Params] = None,
) -> Tuple[jax.Array, Optional[Params]]:
    B, S, D = x.shape
    h = apply_norm(p["norm"], x, cfg.norm)
    w_inx = use_weight(p["in_x"], ctx, P(None, ctx.tp))
    w_ing = use_weight(p["in_gate"], ctx, P(None, ctx.tp))
    w_rg = use_weight(p["w_rgate"], ctx, P(None, ctx.tp))
    w_ig = use_weight(p["w_igate"], ctx, P(None, ctx.tp))
    w_outp = use_weight(p["out_proj"], ctx, P(ctx.tp, None))
    xb = h @ w_inx  # (B, S, W)
    gb = jax.nn.gelu((h @ w_ing).astype(jnp.float32))

    conv_state = cache["conv"] if cache is not None else None
    xb, new_conv = causal_conv(xb, p["conv_w"], p["conv_b"], conv_state)

    xf = xb.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ w_rg.astype(jnp.float32))
    i = jax.nn.sigmoid(xf @ w_ig.astype(jnp.float32))
    log_a = -_RGLRU_C * jax.nn.softplus(p["lam"])[None, None] * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-9)) * (i * xf)

    if mode == "decode":
        hprev = cache["h"]  # (B, W) f32
        hnew = a[:, 0] * hprev + b[:, 0]
        y = hnew[:, None, :]
        new_cache = {"conv": new_conv, "h": hnew}
    else:
        y = ops.gated_linear_scan(
            a, b, impl=ctx.scan_impl, interpret=ctx.interpret
        )
        new_cache = (
            {"conv": new_conv, "h": y[:, -1, :].astype(jnp.float32)}
            if mode == "prefill"
            else None
        )

    out = (y * gb).astype(x.dtype) @ w_outp
    return out.astype(x.dtype), new_cache

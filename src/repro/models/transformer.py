"""Generic layer-program stack: one assembler for all 10 architectures.

A model is (embedding) + a sequence of :class:`Segment` scans + (lm head).
Each segment scans over stacked per-layer parameters; the scan body applies
the segment's repeating unit of block kinds.  The same assembler therefore
builds llama (global×N), gemma3 (5 local + 1 global), arctic/kimi (MoE),
falcon-mamba (mamba×N), recurrentgemma (rec,rec,local), llama-vision
(4 self + 1 cross) and the seamless encoder/decoder stacks.

Modes: ``train`` (no caches, optional remat), ``prefill`` (returns caches),
``decode`` (one token, consumes/returns caches).  Cache pytrees carry the
scan-stacked leading dimension, so prefill outputs plug directly into
decode inputs — and their ShapeDtypeStructs are what the multi-pod dry-run
lowers ``serve_step`` against.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models.common import ArchConfig, Segment, build_layer_program
from repro.parallel.ctx import RunCtx, shard

Params = Dict[str, Any]


# --------------------------------------------------------------------------- #
# blocks
# --------------------------------------------------------------------------- #
def block_init(kind: str, cfg: ArchConfig, ctx: RunCtx, key) -> Tuple[Params, Params]:
    ks = jax.random.split(key, 4)
    if kind in ("global", "local", "dense", "enc"):
        d_ff = cfg.resolved_d_ff_dense if kind == "dense" else cfg.d_ff
        ap, asp = L.attention_init(cfg, ctx, ks[0])
        mp, msp = L.mlp_init(cfg, ctx, ks[1], d_ff=d_ff)
        return {"attn": ap, "mlp": mp}, {"attn": asp, "mlp": msp}
    if kind == "moe":
        ap, asp = L.attention_init(cfg, ctx, ks[0])
        mp, msp = L.moe_init(cfg, ctx, ks[1])
        return {"attn": ap, "moe": mp}, {"attn": asp, "moe": msp}
    if kind == "mamba":
        mp, msp = L.mamba_init(cfg, ctx, ks[0])
        return {"mix": mp}, {"mix": msp}
    if kind == "rec":
        rp, rsp = L.rec_init(cfg, ctx, ks[0])
        mp, msp = L.mlp_init(cfg, ctx, ks[1])
        return {"mix": rp, "mlp": mp}, {"mix": rsp, "mlp": msp}
    if kind in ("cross", "xdec"):
        ap, asp = L.attention_init(cfg, ctx, ks[0])
        xp, xsp = L.attention_init(cfg, ctx, ks[1])
        mp, msp = L.mlp_init(cfg, ctx, ks[2])
        params = {"attn": ap, "xattn": xp, "mlp": mp}
        specs = {"attn": asp, "xattn": xsp, "mlp": msp}
        if kind == "cross":
            params["xgate"] = jnp.zeros((), jnp.float32)
            specs["xgate"] = P()
        return params, specs
    raise ValueError(kind)


def block_apply(
    kind: str,
    p: Params,
    cfg: ArchConfig,
    ctx: RunCtx,
    x: jax.Array,
    *,
    mode: str,
    cache: Optional[Params],
    cache_len: int,
    positions: jax.Array,
    xkv: Optional[jax.Array],
    page_table: Optional[jax.Array] = None,
    tp=None,
) -> Tuple[jax.Array, Optional[Params]]:
    get = lambda k: None if cache is None else cache.get(k)
    new_cache: Dict[str, Any] = {}

    from jax.ad_checkpoint import checkpoint_name

    if kind in ("global", "local", "dense", "enc", "moe"):
        window = cfg.local_window if kind == "local" else None
        causal = kind != "enc"
        a, ac = L.apply_attention(
            p["attn"], cfg, ctx, x, positions=positions, causal=causal,
            window=window, mode=mode, cache=get("attn"), cache_len=cache_len,
            page_table=page_table, tp=tp,
        )
        x = x + checkpoint_name(a, "attn_out")
        if ac is not None:
            new_cache["attn"] = ac
        if kind == "moe":
            # MoE experts stay replicated inside a TP group (expert
            # parallelism is the model-axis story); no psum needed
            x = x + checkpoint_name(L.apply_moe(p["moe"], cfg, ctx, x),
                                    "moe_out")
        else:
            x = x + checkpoint_name(L.apply_mlp(p["mlp"], cfg, x, ctx, tp=tp),
                                    "mlp_out")
    elif kind == "mamba":
        if page_table is not None:
            raise ValueError("paged decode unsupported for 'mamba' blocks")
        m, mc = L.apply_mamba(p["mix"], cfg, ctx, x, mode=mode, cache=get("mix"))
        x = x + checkpoint_name(m, "mix_out")
        if mc is not None:
            new_cache["mix"] = mc
    elif kind == "rec":
        if page_table is not None:
            raise ValueError("paged decode unsupported for 'rec' blocks")
        m, mc = L.apply_rec(p["mix"], cfg, ctx, x, mode=mode, cache=get("mix"))
        x = x + checkpoint_name(m, "mix_out")
        if mc is not None:
            new_cache["mix"] = mc
        x = x + checkpoint_name(L.apply_mlp(p["mlp"], cfg, x, ctx, tp=tp),
                                "mlp_out")
    elif kind in ("cross", "xdec"):
        if page_table is not None:
            raise ValueError(f"paged decode unsupported for {kind!r} blocks")
        a, ac = L.apply_attention(
            p["attn"], cfg, ctx, x, positions=positions, causal=True,
            mode=mode, cache=get("attn"), cache_len=cache_len, tp=tp,
        )
        x = x + a
        if ac is not None:
            new_cache["attn"] = ac
        c, cc = L.apply_attention(
            p["xattn"], cfg, ctx, x, positions=positions, mode=mode,
            cache=get("xattn"), cache_len=cache_len, xkv=xkv, tp=tp,
        )
        if kind == "cross":
            c = jnp.tanh(p["xgate"]).astype(c.dtype) * c
        x = x + c
        if cc is not None:
            new_cache["xattn"] = cc
        x = x + L.apply_mlp(p["mlp"], cfg, x, ctx, tp=tp)
    else:
        raise ValueError(kind)
    x = shard(x, ctx, ctx.hidden_spec())
    return x, (new_cache if new_cache else None)


# --------------------------------------------------------------------------- #
# stacks (segment scans)
# --------------------------------------------------------------------------- #
def stack_init(
    kinds: Sequence[str], cfg: ArchConfig, ctx: RunCtx, key
) -> Tuple[List[Segment], List[Params], List[Params]]:
    segments = build_layer_program(kinds)
    seg_params: List[Params] = []
    seg_specs: List[Params] = []
    for si, seg in enumerate(segments):
        def unit_init(k):
            ks = jax.random.split(k, len(seg.unit))
            pd, sd = {}, {}
            for i, kind in enumerate(seg.unit):
                pd[f"b{i}_{kind}"], sd[f"b{i}_{kind}"] = block_init(
                    kind, cfg, ctx, ks[i]
                )
            return pd, sd

        keys = jax.random.split(jax.random.fold_in(key, si), seg.count)
        _, sspec = unit_init(keys[0])
        stacked = jax.vmap(lambda k: unit_init(k)[0])(keys)
        seg_params.append(stacked)
        seg_specs.append(
            jax.tree.map(
                lambda s: P(*((None,) + tuple(s))),
                sspec,
                is_leaf=lambda s: isinstance(s, P),
            )
        )
    return segments, seg_params, seg_specs


def _maybe_remat(fn: Callable, ctx: RunCtx, mode: str) -> Callable:
    if mode != "train" or ctx.remat == "none":
        return fn
    if ctx.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    if ctx.remat == "names":
        # §Perf iteration: save exactly the post-collective sub-block
        # outputs.  The backward pass then never re-runs the tensor-parallel
        # all-reduces that full remat duplicates (the dominant collective
        # cost measured in the baseline), at the price of two extra saved
        # (B, S, D) tensors per layer (shard them with seq_shard_acts).
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.save_only_these_names(
                "attn_out", "mlp_out", "moe_out", "mix_out"
            ),
        )
    return jax.checkpoint(fn)


def stack_apply(
    segments: List[Segment],
    seg_params: List[Params],
    cfg: ArchConfig,
    ctx: RunCtx,
    x: jax.Array,
    *,
    mode: str,
    caches: Optional[List[Any]] = None,
    cache_len: int = 0,
    positions: jax.Array,
    xkv: Optional[jax.Array] = None,
    page_table: Optional[jax.Array] = None,
    tp=None,
) -> Tuple[jax.Array, Optional[List[Any]]]:
    new_caches: List[Any] = []
    for si, (seg, sp) in enumerate(zip(segments, seg_params)):
        sc = caches[si] if caches is not None else None

        def unit_body(xc, lp, lc):
            ncs = {}
            for i, kind in enumerate(seg.unit):
                key = f"b{i}_{kind}"
                xc, nc = block_apply(
                    kind, lp[key], cfg, ctx, xc, mode=mode,
                    cache=None if lc is None else lc[key],
                    cache_len=cache_len, positions=positions, xkv=xkv,
                    page_table=page_table, tp=tp,
                )
                if nc is not None:
                    ncs[key] = nc
            return xc, ncs

        if mode == "train":
            body = _maybe_remat(
                lambda xc, lp: (unit_body(xc, lp, None)[0], None), ctx, mode
            )
            x, _ = lax.scan(body, x, sp)
            new_caches.append(None)
        elif mode == "prefill":
            def body_p(xc, lp):
                return unit_body(xc, lp, None)

            x, ncs = lax.scan(body_p, x, sp)
            new_caches.append(ncs)
        elif mode == "decode":
            def body_d(xc, lp_lc):
                lp, lc = lp_lc
                xc, ncs = unit_body(xc, lp, lc)
                return xc, ncs

            x, ncs = lax.scan(body_d, x, (sp, sc))
            new_caches.append(ncs)
        else:
            raise ValueError(mode)
    return x, (new_caches if mode != "train" else None)


# --------------------------------------------------------------------------- #
# embedding + head + loss
# --------------------------------------------------------------------------- #
def lm_io_init(cfg: ArchConfig, ctx: RunCtx, key) -> Tuple[Params, Params]:
    ks = jax.random.split(key, 2)
    params = {
        "tok": L._normal(ks[0], (cfg.vocab, cfg.d_model), cfg.dtype, 0.02),
        "norm_f": L.norm_init(cfg.d_model),
    }
    specs = {"tok": P(ctx.tp, "data"), "norm_f": L.norm_specs()}
    if not cfg.tie_embeddings:
        params["out"] = L.linear_init(ks[1], cfg.d_model, (cfg.vocab,), cfg.dtype)
        specs["out"] = P("data", ctx.tp)
    return params, specs


def embed(io: Params, cfg: ArchConfig, ctx: RunCtx, tokens: jax.Array) -> jax.Array:
    x = jnp.take(io["tok"], tokens, axis=0)
    return shard(x, ctx, ctx.hidden_spec())


def _proj_logits(io: Params, cfg: ArchConfig, h: jax.Array,
                 ctx: RunCtx = None) -> jax.Array:
    from repro.parallel.ctx import use_weight

    ctx = ctx or RunCtx(mesh=None)
    if cfg.tie_embeddings:
        tok = use_weight(io["tok"], ctx, P(ctx.tp, None))
        return h @ tok.T
    out = use_weight(io["out"], ctx, P(None, ctx.tp))
    return h @ out


def final_hidden(io: Params, cfg: ArchConfig, h: jax.Array) -> jax.Array:
    return L.apply_norm(io["norm_f"], h, cfg.norm)


def logits_fn(io: Params, cfg: ArchConfig, ctx: RunCtx, h: jax.Array) -> jax.Array:
    out = _proj_logits(io, cfg, final_hidden(io, cfg, h), ctx)
    return shard(out, ctx, P(ctx.dp, None, ctx.tp))


def chunked_ce_loss(
    io: Params,
    cfg: ArchConfig,
    ctx: RunCtx,
    h: jax.Array,
    targets: jax.Array,
    mask: jax.Array,
    chunk: int = 512,
) -> jax.Array:
    """Cross-entropy without materializing the full (B, S, V) logits.

    Scans over sequence chunks; each chunk projects to the vocabulary,
    reduces, and is discarded — peak logits memory drops from O(S·V) to
    O(chunk·V) per batch row (decisive for the 262k/256k vocab archs).
    """
    B, S, D = h.shape
    h = final_hidden(io, cfg, h)
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nc = h.shape[1] // chunk

    def body(acc, ci):
        hs = lax.dynamic_slice_in_dim(h, ci * chunk, chunk, axis=1)
        ts = lax.dynamic_slice_in_dim(targets, ci * chunk, chunk, axis=1)
        ms = lax.dynamic_slice_in_dim(mask, ci * chunk, chunk, axis=1)
        logits = _proj_logits(io, cfg, hs, ctx).astype(jnp.float32)
        logits = shard(logits, ctx, P(ctx.dp, None, ctx.tp))
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, ts[..., None], axis=-1)[..., 0]
        nll = (lse - tgt) * ms
        return (acc[0] + nll.sum(), acc[1] + ms.sum()), None

    (tot, cnt), _ = lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        jnp.arange(nc),
    )
    return tot / jnp.maximum(cnt, 1.0)

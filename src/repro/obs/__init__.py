"""Cluster-wide observability: tracing, typed metrics, trace export.

The paper's hardware engine is a black box once an operation is
initiated — real GASNet grew ``GASNET_TRACE`` operation tracing for
exactly that reason, and ACCL+ instruments its collective engine with
hardware performance counters.  This package is our software analogue:

- :mod:`repro.obs.metrics` — a typed Counter/Gauge/Histogram registry
  that the serving ``stats()`` dicts are built on (explicit kinds, a
  ``reset()`` that only clears counters).
- :mod:`repro.obs.trace` — a per-rank span/event tracer clocked on the
  SPMD tick counter.  Spans are host-side (around initiation and sync,
  never inside compiled code), ring-buffered, and free when disabled:
  every instrumentation site is guarded by one attribute check on a
  no-op recorder.
- :mod:`repro.obs.export` — merges per-rank streams on the tick clock
  into Chrome-trace JSON (load in ``chrome://tracing`` / Perfetto) and
  dumps a flight-recorder ring of the last N ticks on rank death.
- :mod:`repro.obs.profile` — device-time kernel profiling by (honest,
  labelled) timed re-execution; measures the receiver epilogue alone so
  ``EngineCost`` finally fits γ from data.
- :mod:`repro.obs.attrib` — per-request critical-path attribution over
  the recorded lifecycle instants; ``why_slow(rid)`` names the dominant
  segment and the convoying co-residents.
- :mod:`repro.obs.health` — live SLO monitor on the tick clock:
  deadline-risk gauges, ``slo_at_risk``/``slo_violated`` instants, and
  the admission backpressure floor.

Nothing here imports the rest of ``repro`` — core and serving layers
import ``obs``, never the other way around.
"""
from repro.obs import attrib, export, health, metrics, profile, trace
from repro.obs.attrib import Breakdown, attribute, why_slow
from repro.obs.health import HealthMonitor
from repro.obs.metrics import Counter, Gauge, Histogram, Registry
from repro.obs.profile import DeviceProfiler
from repro.obs.trace import (
    NullTracer,
    Span,
    Tracer,
    active,
    disable,
    enable,
)

__all__ = [
    "Breakdown",
    "Counter",
    "DeviceProfiler",
    "Gauge",
    "HealthMonitor",
    "Histogram",
    "NullTracer",
    "Registry",
    "Span",
    "Tracer",
    "active",
    "attribute",
    "attrib",
    "disable",
    "enable",
    "export",
    "health",
    "metrics",
    "profile",
    "trace",
    "why_slow",
]

"""Cluster-wide observability: tracing, typed metrics, trace export.

The paper's hardware engine is a black box once an operation is
initiated — real GASNet grew ``GASNET_TRACE`` operation tracing for
exactly that reason, and ACCL+ instruments its collective engine with
hardware performance counters.  This package is our software analogue:

- :mod:`repro.obs.metrics` — a typed Counter/Gauge/Histogram registry
  that the serving ``stats()`` dicts are built on (explicit kinds, a
  ``reset()`` that only clears counters).
- :mod:`repro.obs.trace` — a per-rank span/event tracer clocked on the
  SPMD tick counter.  Spans are host-side (around initiation and sync,
  never inside compiled code), ring-buffered, and free when disabled:
  every instrumentation site is guarded by one attribute check on a
  no-op recorder.
- :mod:`repro.obs.export` — merges per-rank streams on the tick clock
  into Chrome-trace JSON (load in ``chrome://tracing`` / Perfetto) and
  dumps a flight-recorder ring of the last N ticks on rank death.

Nothing here imports the rest of ``repro`` — core and serving layers
import ``obs``, never the other way around.
"""
from repro.obs import export, metrics, trace
from repro.obs.metrics import Counter, Gauge, Histogram, Registry
from repro.obs.trace import (
    NullTracer,
    Span,
    Tracer,
    active,
    disable,
    enable,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "NullTracer",
    "Registry",
    "Span",
    "Tracer",
    "active",
    "disable",
    "enable",
    "export",
    "metrics",
    "trace",
]

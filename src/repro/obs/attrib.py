"""Per-request critical-path attribution: where did this request's time go?

GASNet postmortems answer "which transfer hung" by replaying a
``GASNET_TRACE`` log against the program's structure; the serving
analogue of that question is *which segment of a request's lifecycle
dominated its latency* — and PR 9's tracer already records everything
needed to answer it: the lifecycle instants (``req_submit`` /
``req_first_token`` / ``req_admit`` / ``req_preempt`` / ``req_resume``
/ ``req_retire``), the per-request ``prefill`` span, and the tick-phase
spans around them.  This module folds those events into a per-request
breakdown over seven segments:

==================  ====================================================
queue               submit -> prefill start (or first admission when the
                    server prefills inline)
prefill             the request's own prefill span(s) before first
                    admission
handoff_wire /      prefill end -> decode admission (the KV transfer
handoff_epilogue    window in the disaggregated cluster), split by the
                    cost model's measured β : γ ratio when one is given
decode              resident decode time (admission -> retirement, minus
                    evicted windows)
swap                evicted windows whose preemption chose ``swap``
replay              evicted windows whose preemption chose ``recompute``
                    (plus any re-prefill spans the replay paid)
==================  ====================================================

:func:`why_slow` then names the dominant segment and the co-resident
requests whose residency overlapped it — the convoy a victim sat
behind.  Everything here is a pure fold over a :class:`Tracer`'s ring;
nothing is recorded, so it can run post-hoc on a flight dump's worth of
events.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

__all__ = [
    "Breakdown",
    "attribute",
    "why_slow",
]

SEGMENTS = (
    "queue", "prefill", "handoff_wire", "handoff_epilogue",
    "decode", "swap", "replay",
)


@dataclasses.dataclass
class Breakdown:
    """One request's lifecycle, folded into segment walls (us)."""

    rid: Any
    state: str  # "retired" | "in-flight"
    total_us: float
    segments: Dict[str, float]
    n_preempts: int
    # wall windows (t0_us, t1_us) backing the non-derived segments —
    # what why_slow intersects against other requests' residency
    windows: Dict[str, List[Tuple[float, float]]]
    # residency: admitted/resumed -> preempted/retired intervals
    resident: List[Tuple[float, float]]

    def dominant(self) -> str:
        return max(SEGMENTS, key=lambda s: self.segments.get(s, 0.0))

    def share(self, seg: str) -> float:
        return self.segments.get(seg, 0.0) / self.total_us \
            if self.total_us > 0 else 0.0


def _fold_events(events) -> Dict[Any, Dict[str, Any]]:
    """Group the request-lifecycle events by rid, time-ordered."""
    per: Dict[Any, Dict[str, Any]] = {}
    for e in events:
        if e.cat != "req":
            continue
        rid = e.args.get("rid")
        if rid is None:
            continue
        rec = per.setdefault(rid, {
            "submit": None, "first": None, "retire": None,
            "prefills": [], "admits": [], "preempts": [], "resumes": [],
            "last_seen": 0.0,
        })
        rec["last_seen"] = max(rec["last_seen"], e.t1_us)
        if e.name == "req_submit":
            rec["submit"] = e.t0_us
        elif e.name == "req_first_token":
            if rec["first"] is None:
                rec["first"] = e.t0_us
        elif e.name == "req_retire":
            rec["retire"] = e.t0_us
        elif e.name == "prefill":
            rec["prefills"].append((e.t0_us, e.t1_us))
        elif e.name == "req_admit":
            rec["admits"].append(e.t0_us)
        elif e.name == "req_resume":
            rec["resumes"].append(e.t0_us)
        elif e.name == "req_preempt":
            rec["preempts"].append((e.t0_us, e.args.get("mode", "swap")))
    return per


def attribute(tracer, cost: Optional[Any] = None) -> Dict[Any, Breakdown]:
    """Fold the tracer's request-lifecycle events into per-rid
    :class:`Breakdown` objects.

    ``cost`` (an :class:`~repro.core.sched.EngineCost`) splits the
    handoff window into wire vs epilogue by its measured β : γ ratio;
    without one the whole window is attributed to the wire."""
    out: Dict[Any, Breakdown] = {}
    for rid, rec in _fold_events(tracer.events).items():
        t_submit = rec["submit"]
        if t_submit is None:
            continue
        t_end = rec["retire"] if rec["retire"] is not None \
            else rec["last_seen"]
        state = "retired" if rec["retire"] is not None else "in-flight"
        total = max(t_end - t_submit, 0.0)
        segs = {s: 0.0 for s in SEGMENTS}
        windows: Dict[str, List[Tuple[float, float]]] = \
            {s: [] for s in SEGMENTS}

        first_admit = min(rec["admits"]) if rec["admits"] else None
        # prefill spans before first admission are the request's own
        # prefill; later ones are recompute re-prefills -> replay
        for p0, p1 in sorted(rec["prefills"]):
            if first_admit is None or p0 <= first_admit:
                segs["prefill"] += p1 - p0
                windows["prefill"].append((p0, p1))
            else:
                segs["replay"] += p1 - p0
                windows["replay"].append((p0, p1))

        own_prefills = windows["prefill"]
        if own_prefills:
            q_end = own_prefills[0][0]
            handoff0 = own_prefills[-1][1]
        else:
            q_end = first_admit if first_admit is not None else t_end
            handoff0 = None
        segs["queue"] = max(q_end - t_submit, 0.0)
        windows["queue"].append((t_submit, q_end))
        if handoff0 is not None and first_admit is not None \
                and first_admit > handoff0:
            hand = first_admit - handoff0
            wire_frac = 1.0
            if cost is not None:
                denom = cost.beta_us_per_kib + cost.gamma_us_per_kib
                if denom > 0:
                    wire_frac = cost.beta_us_per_kib / denom
            segs["handoff_wire"] = hand * wire_frac
            segs["handoff_epilogue"] = hand * (1.0 - wire_frac)
            windows["handoff_wire"].append((handoff0, first_admit))
            windows["handoff_epilogue"].append((handoff0, first_admit))

        # pair each preemption with the resume/re-admission that ends it
        reentries = sorted(rec["resumes"] + [
            t for t in rec["admits"]
            if first_admit is None or t > first_admit
        ])
        evicted = 0.0
        for t_p, mode in sorted(rec["preempts"]):
            t_r = next((t for t in reentries if t > t_p), t_end)
            seg = "swap" if mode == "swap" else "replay"
            segs[seg] += max(t_r - t_p, 0.0)
            windows[seg].append((t_p, t_r))
            evicted += max(t_r - t_p, 0.0)

        if first_admit is not None:
            # evicted windows already contain any re-prefill spans the
            # replay paid, so subtracting them once is exact
            resident_total = max(t_end - first_admit, 0.0)
            segs["decode"] = max(resident_total - evicted, 0.0)
            windows["decode"].append((first_admit, t_end))

        # residency intervals: admitted/resumed -> preempted/retired
        starts = sorted(rec["admits"] + rec["resumes"])
        stops = sorted([t for t, _ in rec["preempts"]]
                       + ([rec["retire"]] if rec["retire"] is not None
                          else []))
        resident = []
        for s in starts:
            e = next((t for t in stops if t > s), t_end)
            resident.append((s, e))

        out[rid] = Breakdown(
            rid=rid, state=state, total_us=total, segments=segs,
            n_preempts=len(rec["preempts"]), windows=windows,
            resident=resident,
        )
    return out


def _overlap(a: Tuple[float, float], b: Tuple[float, float]) -> float:
    return max(0.0, min(a[1], b[1]) - max(a[0], b[0]))


def why_slow(
    tracer,
    rid: Any,
    cost: Optional[Any] = None,
    top: int = 4,
) -> str:
    """The postmortem report: name the dominant lifecycle segment of
    ``rid`` and the co-resident requests that convoyed it.

    The convoy set is computed against the dominant segment's wall
    window: every other request whose residency (admitted -> preempted
    or retired) overlaps that window held decode capacity — slots,
    pool pages — while ``rid`` waited in it."""
    downs = attribute(tracer, cost=cost)
    if rid not in downs:
        return f"why_slow(rid={rid}): no lifecycle events recorded"
    bd = downs[rid]
    dom = bd.dominant()
    dom_windows = bd.windows.get(dom) or [(0.0, 0.0)]
    # the longest window of the dominant segment is the stall to explain
    stall = max(dom_windows, key=lambda w: w[1] - w[0])

    lines = [
        f"why_slow(rid={rid}): {bd.state}, total "
        f"{bd.total_us / 1e3:.2f}ms, {bd.n_preempts} preemption(s) — "
        f"dominant: {dom} "
        f"({bd.share(dom) * 100:.0f}%, {bd.segments[dom] / 1e3:.2f}ms)"
    ]
    for seg in SEGMENTS:
        v = bd.segments.get(seg, 0.0)
        if v <= 0.0:
            continue
        lines.append(
            f"  {seg:<17s} {v / 1e3:9.2f}ms  {bd.share(seg) * 100:5.1f}%"
        )
    convoy = []
    for other_rid, other in downs.items():
        if other_rid == rid:
            continue
        ov = sum(_overlap(stall, w) for w in other.resident)
        if ov > 0.0:
            convoy.append((ov, other_rid, other.state))
    convoy.sort(reverse=True)
    if convoy:
        lines.append(
            f"  convoyed by (co-resident during the {dom} window "
            f"[{stall[0] / 1e3:.2f}, {stall[1] / 1e3:.2f}]ms):"
        )
        for ov, other_rid, state in convoy[:top]:
            lines.append(
                f"    rid {other_rid}: resident {ov / 1e3:.2f}ms "
                f"of the window ({state})"
            )
    else:
        lines.append("  no co-resident requests during the dominant window")
    return "\n".join(lines)

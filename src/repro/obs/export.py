"""Merge per-rank trace streams into Chrome-trace JSON + flight dumps.

The export clocks everything on the deterministic tick clock: an event
at tick T with per-tick sequence s lands at ``ts = T*1000 + s`` virtual
microseconds, so every rank's tick-T activity lines up in one column of
the timeline regardless of host wall time, and begin/end sequence
numbers guarantee scoped spans nest strictly.  Wall-clock durations
(``dur_us``) ride along in ``args`` for real measurements.

Rows: one ``tid`` per rank, plus row 0 (``gas``) for program-wide
transport/collective events that aren't attributable to a single rank
(an ``all_to_all`` belongs to everyone).  Scoped spans export as
complete (``ph="X"``) events; split-phase RMA spans as async
(``ph="b"/"e"``) pairs riding their span id; fault-tolerance events
(rank death, heartbeat miss, quorum restore, elastic join) as instant
(``ph="i"``) events with global scope so they draw a line across the
whole timeline.

``validate`` re-checks the two invariants the acceptance gate cares
about: complete spans nest per row, and the per-op byte totals summed
from exported RMA spans are bit-equal to the tracer's migrated metrics
counters.

``flight_dump`` is the chaos postmortem: the ring's last N ticks plus
the replay seed, small enough to drop into a CI step summary.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Sequence, Union

from repro.obs.trace import Span, Tracer

__all__ = [
    "chrome_trace",
    "event_dict",
    "flight_dump",
    "render_flight_summary",
    "validate",
    "write_trace",
]

# per-tick sequence numbers are folded into a 1000-slot window per tick;
# a tick with more host events than this still exports (clamped), it
# just stops being strictly ordered within the overflow tail.
_TICK_WINDOW = 1000


def _ts(tick: int, seq: int) -> int:
    return tick * _TICK_WINDOW + min(seq, _TICK_WINDOW - 1)


def _tid(rank: Optional[int]) -> int:
    return 0 if rank is None else int(rank) + 1


def event_dict(e: Span) -> Dict[str, Any]:
    """Raw (lossless) dict form of one recorded event — the flight-dump
    payload, and handy for jq-style offline queries."""
    return {
        "sid": e.sid,
        "name": e.name,
        "cat": e.cat,
        "kind": e.kind,
        "rank": e.rank,
        "tick0": e.tick0,
        "seq0": e.seq0,
        "tick1": e.tick1,
        "seq1": e.seq1,
        "t0_us": round(e.t0_us, 3),
        "dur_us": round(e.dur_us, 3),
        "args": e.args,
    }


def chrome_trace(
    tracers: Union[Tracer, Sequence[Tracer]],
    labels: Optional[Sequence[str]] = None,
) -> Dict[str, Any]:
    """Merge one or more per-rank tracer streams into a Chrome-trace
    dict (``{"traceEvents": [...]}`` — load in chrome://tracing or
    https://ui.perfetto.dev).  Multiple streams land as separate pids
    merged on the shared tick clock."""
    if isinstance(tracers, Tracer):
        tracers = [tracers]
    events: List[Dict[str, Any]] = []
    ranks_seen: Dict[int, set] = {}
    for pid, tr in enumerate(tracers):
        seen = ranks_seen.setdefault(pid, set())
        for e in tr.events:
            tid = _tid(e.rank)
            seen.add(tid)
            args = dict(e.args)
            args["tick"] = e.tick0
            base = {
                "name": e.name,
                "cat": e.cat,
                "pid": pid,
                "tid": tid,
            }
            if e.kind == "instant":
                events.append({
                    **base, "ph": "i", "ts": _ts(e.tick0, e.seq0),
                    "s": "g" if e.cat == "ft" else "t", "args": args,
                })
            elif e.kind == "async":
                args["dur_us"] = round(e.dur_us, 3)
                events.append({
                    **base, "ph": "b", "id": e.sid,
                    "ts": _ts(e.tick0, e.seq0), "args": args,
                })
                events.append({
                    **base, "ph": "e", "id": e.sid,
                    "ts": _ts(e.tick1, e.seq1),
                })
            else:
                t0 = _ts(e.tick0, e.seq0)
                t1 = _ts(e.tick1, e.seq1)
                args["dur_us"] = round(e.dur_us, 3)
                events.append({
                    **base, "ph": "X", "ts": t0,
                    "dur": max(t1 - t0, 1), "args": args,
                })
    # row names so the viewer shows "rank N" instead of bare tids
    meta: List[Dict[str, Any]] = []
    for pid, tids in ranks_seen.items():
        label = labels[pid] if labels else f"stream{pid}"
        meta.append({
            "name": "process_name", "ph": "M", "pid": pid,
            "args": {"name": label},
        })
        for tid in sorted(tids):
            meta.append({
                "name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
                "args": {"name": "gas" if tid == 0 else f"rank{tid - 1}"},
            })
    return {
        "traceEvents": meta + events,
        "displayTimeUnit": "ms",
        "otherData": {"clock": "tick*1000+seq (virtual us)"},
    }


# -------------------------------------------------------------------- #
# validation
# -------------------------------------------------------------------- #
def validate(trace: Dict[str, Any], registry=None) -> List[str]:
    """Check the exported trace; returns a list of problems (empty =
    valid).

    - complete (``X``) spans must nest properly within each row;
    - async (``b``/``e``) pairs must match up, with ``e`` not before
      ``b``;
    - when ``registry`` is given (the tracer's metrics registry), the
      per-op byte totals summed over exported RMA spans must be
      bit-equal to the ``rma_<op>_bytes``/``rma_<op>_ops`` counters.
    """
    problems: List[str] = []
    events = trace.get("traceEvents", [])

    # --- X nesting per row ---
    by_row: Dict[tuple, List[dict]] = {}
    for ev in events:
        if ev.get("ph") == "X":
            by_row.setdefault((ev["pid"], ev["tid"]), []).append(ev)
    for row, evs in sorted(by_row.items()):
        evs.sort(key=lambda ev: (ev["ts"], -ev["dur"]))
        stack: List[tuple] = []  # (end_ts, name)
        for ev in evs:
            t0, t1 = ev["ts"], ev["ts"] + ev["dur"]
            while stack and stack[-1][0] <= t0:
                stack.pop()
            if stack and t1 > stack[-1][0]:
                problems.append(
                    f"row {row}: span {ev['name']!r} [{t0},{t1}) "
                    f"overlaps parent {stack[-1][1]!r} ending at "
                    f"{stack[-1][0]}"
                )
            stack.append((t1, ev["name"]))

    # --- async pairing ---
    opens: Dict[tuple, dict] = {}
    pairs: List[tuple] = []
    for ev in events:
        ph = ev.get("ph")
        if ph == "b":
            key = (ev["pid"], ev["cat"], ev["id"])
            if key in opens:
                problems.append(f"async span id {ev['id']} opened twice")
            opens[key] = ev
        elif ph == "e":
            key = (ev["pid"], ev["cat"], ev["id"])
            b = opens.pop(key, None)
            if b is None:
                problems.append(
                    f"async end id {ev['id']} without a begin"
                )
            else:
                if ev["ts"] < b["ts"]:
                    problems.append(
                        f"async span {b['name']!r} id {ev['id']} ends "
                        f"before it begins"
                    )
                pairs.append((b, ev))
    for key, b in opens.items():
        problems.append(
            f"async span {b['name']!r} id {key[2]} never ended "
            f"(initiated but never synced)"
        )

    # --- RMA byte totals vs the migrated metrics counters ---
    if registry is not None:
        sums: Dict[str, int] = {}
        ops: Dict[str, int] = {}
        for b, _e in pairs:
            if b.get("cat") != "rma":
                continue
            nbytes = b.get("args", {}).get("bytes")
            if nbytes is None:
                problems.append(
                    f"rma span {b['name']!r} id {b['id']} has no bytes tag"
                )
                continue
            sums[b["name"]] = sums.get(b["name"], 0) + int(nbytes)
            ops[b["name"]] = ops.get(b["name"], 0) + 1
        counted = {
            m.name for m in registry
            if m.kind == "counter" and m.name.startswith("rma_")
            and m.name.endswith("_bytes")
        }
        for op in sorted(set(sums) | {
            n[len("rma_"):-len("_bytes")] for n in counted
        }):
            want_b = registry.counter(f"rma_{op}_bytes").get()
            want_n = registry.counter(f"rma_{op}_ops").get()
            got_b, got_n = sums.get(op, 0), ops.get(op, 0)
            if got_b != want_b or got_n != want_n:
                problems.append(
                    f"rma {op!r}: trace total {got_b}B/{got_n} ops != "
                    f"counter {want_b}B/{want_n} ops (byte accounting "
                    f"must be bit-equal)"
                )
    return problems


# -------------------------------------------------------------------- #
# flight recorder
# -------------------------------------------------------------------- #
def flight_dump(tracer: Tracer, last_ticks: int = 64, *,
                reason: str = "", seed: Optional[int] = None,
                rank: Optional[int] = None) -> Dict[str, Any]:
    """Dump the ring's last ``last_ticks`` ticks — triggered on rank
    death (and on chaos-scenario failure) so a postmortem sees what the
    cluster was doing when it died, plus the seed to replay it."""
    return {
        "reason": reason,
        "tick": tracer.tick,
        "last_ticks": last_ticks,
        "seed": seed,
        "rank": rank,
        "events": [event_dict(e) for e in tracer.flight(last_ticks)],
        "metrics": tracer.registry.snapshot(),
    }


def render_flight_summary(dump: Dict[str, Any],
                          max_events: int = 40) -> str:
    """Markdown rendering of a flight dump for ``GITHUB_STEP_SUMMARY``."""
    lines = [
        f"### flight recorder — {dump.get('reason') or 'dump'} "
        f"at tick {dump.get('tick')}",
    ]
    if dump.get("seed") is not None:
        lines.append(
            f"replay: `python -m repro.testing.fault_suite "
            f"--seed {dump['seed']}`"
        )
    events = dump.get("events", [])
    lines.append(
        f"last {dump.get('last_ticks')} ticks, "
        f"{len(events)} events (showing {min(len(events), max_events)}):"
    )
    lines.append("")
    lines.append("| tick | rank | kind | cat | name | args |")
    lines.append("|---|---|---|---|---|---|")
    for e in events[-max_events:]:
        rank = "gas" if e["rank"] is None else e["rank"]
        args = {
            k: v for k, v in e.get("args", {}).items()
            if k not in ("dur_us",)
        }
        lines.append(
            f"| {e['tick0']} | {rank} | {e['kind']} | {e['cat']} "
            f"| {e['name']} | `{json.dumps(args, default=str)}` |"
        )
    return "\n".join(lines)


def write_trace(trace: Dict[str, Any], path: str) -> None:
    with open(path, "w") as f:
        json.dump(trace, f, indent=None, separators=(",", ":"))
        f.write("\n")

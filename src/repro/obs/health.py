"""Live SLO health: deadline-risk gauges, at-risk instants, backpressure.

The attribution module (:mod:`repro.obs.attrib`) answers *after the
fact* where a request's time went; this monitor answers the live
question — *is the cluster about to miss its SLOs?* — on the same tick
clock the tracer merges ranks on.  Per tracked request it projects:

- **TTFT** — elapsed wait vs the request's ``ttft_deadline_s`` while no
  first token exists yet;
- **TPOT** — the larger of the observed inter-token EWMA and the
  current stall (time since the last token) vs ``tpot_deadline_s``.

A projection crossing ``risk_frac`` of its deadline emits one
``slo_at_risk`` trace instant (cat ``"slo"``) and enters the at-risk
set; crossing the deadline itself emits ``slo_violated`` and counts on
the registry.  With ``risk_frac < 1`` and a monitor clocked every tick,
``slo_at_risk`` fires strictly before the violation tick — the early
warning the scheduler can still act on: the **backpressure floor**
(:meth:`backpressure_floor`, the highest at-risk priority) tells the
:class:`~repro.serving.scheduler.AdmissionScheduler` to defer admitting
work below that priority until the at-risk set drains, so a deadline-
critical request stops competing with bulk traffic for pool pages.

Deadlines default to ``inf`` (:class:`~repro.serving.scheduler.SLO`),
so an always-wired monitor is inert until a request actually carries
one — risk is 0, the at-risk set stays empty, no admission is ever
deferred.  All clocks are injected (``now`` parameters), which is what
makes the pressure scenarios deterministic under test.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Iterable, List, Optional

from repro.obs import trace as obs_trace
from repro.obs.metrics import Registry

__all__ = [
    "HealthMonitor",
]


@dataclasses.dataclass
class _Tracked:
    rid: Any
    priority: int
    ttft_deadline_s: float
    tpot_deadline_s: float
    t_submit: float
    t_first: Optional[float] = None
    t_last_token: Optional[float] = None
    tokens: int = 0
    tpot_ewma_s: Optional[float] = None
    at_risk: bool = False
    violated: bool = False


class HealthMonitor:
    """Tick-clocked SLO monitor (see module docstring).

    ``backpressure=False`` keeps the monitor observing (risk gauges,
    instants, violation counts) without ever raising the admission
    floor — the A/B control arm of the oversubscription bench."""

    def __init__(
        self,
        registry: Optional[Registry] = None,
        *,
        risk_frac: float = 0.8,
        ewma: float = 0.25,
        backpressure: bool = True,
    ):
        if not 0.0 < risk_frac <= 1.0:
            raise ValueError(f"risk_frac must be in (0, 1], got {risk_frac}")
        self.registry = registry if registry is not None else Registry()
        self.risk_frac = risk_frac
        self.ewma_alpha = ewma
        self.backpressure = backpressure
        self._reqs: Dict[Any, _Tracked] = {}
        self.last_summary: Dict[str, Any] = {}

    # ---------------------------------------------------------------- #
    # lifecycle feed (the serving layers call these)
    # ---------------------------------------------------------------- #
    def track(self, rid: Any, slo: Any, now: float) -> None:
        """Start monitoring one request against its SLO deadlines."""
        self._reqs[rid] = _Tracked(
            rid=rid,
            priority=int(getattr(slo, "priority", 0) or 0),
            ttft_deadline_s=float(
                getattr(slo, "ttft_deadline_s", math.inf)),
            tpot_deadline_s=float(
                getattr(slo, "tpot_deadline_s", math.inf)),
            t_submit=now,
        )

    def first_token(self, rid: Any, now: float) -> None:
        t = self._reqs.get(rid)
        if t is not None and t.t_first is None:
            t.t_first = now
            t.t_last_token = now
            t.tokens = 1

    def progress(self, rid: Any, tokens: int, now: float) -> None:
        """Observed generated-token count for a tracked request; the
        inter-token gap feeds the TPOT EWMA."""
        t = self._reqs.get(rid)
        if t is None or tokens <= t.tokens:
            return
        if t.t_last_token is not None and tokens > t.tokens:
            gap = (now - t.t_last_token) / (tokens - t.tokens)
            if t.tpot_ewma_s is None:
                t.tpot_ewma_s = gap
            else:
                a = self.ewma_alpha
                t.tpot_ewma_s = a * gap + (1.0 - a) * t.tpot_ewma_s
        t.tokens = tokens
        t.t_last_token = now

    def retire(self, rid: Any) -> None:
        self._reqs.pop(rid, None)

    # ---------------------------------------------------------------- #
    def _risk(self, t: _Tracked, now: float) -> tuple:
        """(risk fraction, which deadline) for one tracked request —
        risk >= 1.0 means the deadline has passed."""
        if t.t_first is None:
            if math.isfinite(t.ttft_deadline_s) and t.ttft_deadline_s > 0:
                return (now - t.t_submit) / t.ttft_deadline_s, "ttft"
            return 0.0, "ttft"
        if math.isfinite(t.tpot_deadline_s) and t.tpot_deadline_s > 0:
            stall = (now - t.t_last_token) if t.t_last_token is not None \
                else 0.0
            proj = max(t.tpot_ewma_s or 0.0, stall)
            return proj / t.tpot_deadline_s, "tpot"
        return 0.0, "tpot"

    def tick(
        self,
        tick_no: int,
        now: float,
        progress: Optional[Dict[Any, int]] = None,
        retired: Optional[Iterable[Any]] = None,
    ) -> Dict[str, Any]:
        """One monitor step on the cluster's tick clock.

        ``progress`` maps rid -> generated-token count for currently
        resident requests (fed through :meth:`progress`); ``retired``
        drops finished rids.  Recomputes every projection, emits
        ``slo_at_risk`` / ``slo_violated`` instants on transitions,
        publishes the gauges, and returns (and stores on
        :attr:`last_summary`) the per-tick health summary."""
        if retired is not None:
            for rid in retired:
                self.retire(rid)
        if progress is not None:
            for rid, tokens in progress.items():
                self.progress(rid, tokens, now)

        tr = obs_trace.active()
        risk_by_prio: Dict[int, float] = {}
        at_risk: List[Any] = []
        violated: List[Any] = []
        for t in self._reqs.values():
            risk, kind = self._risk(t, now)
            prev = risk_by_prio.get(t.priority, 0.0)
            risk_by_prio[t.priority] = max(prev, risk)
            if risk >= 1.0:
                at_risk.append(t.rid)
                violated.append(t.rid)
                if not t.violated:
                    t.violated = True
                    self.registry.counter("slo_violations").inc()
                    if tr.enabled:
                        tr.instant(
                            "slo_violated", cat="slo", rid=t.rid,
                            deadline=kind, priority=t.priority,
                            risk=round(risk, 3),
                        )
            elif risk >= self.risk_frac:
                at_risk.append(t.rid)
                if not t.at_risk:
                    t.at_risk = True
                    if tr.enabled:
                        tr.instant(
                            "slo_at_risk", cat="slo", rid=t.rid,
                            deadline=kind, priority=t.priority,
                            risk=round(risk, 3),
                        )
            else:
                t.at_risk = False

        for prio, risk in risk_by_prio.items():
            self.registry.gauge(f"slo_risk_p{prio}").set(round(risk, 4))
        self.registry.gauge("slo_at_risk").set(len(at_risk))

        self.last_summary = {
            "tick": tick_no,
            "tracked": len(self._reqs),
            "at_risk": sorted(at_risk, key=repr),
            "violated": sorted(violated, key=repr),
            "risk_by_priority": {
                p: round(r, 4) for p, r in sorted(risk_by_prio.items())
            },
            "tpot_ewma_s": {
                t.rid: round(t.tpot_ewma_s, 6)
                for t in self._reqs.values() if t.tpot_ewma_s is not None
            },
            "backpressure_floor": self.backpressure_floor(),
        }
        return self.last_summary

    # ---------------------------------------------------------------- #
    def at_risk_rids(self) -> List[Any]:
        return [t.rid for t in self._reqs.values() if t.at_risk or t.violated]

    def backpressure_floor(self) -> Optional[int]:
        """The admission floor: the highest priority among at-risk
        requests, or None when the at-risk set is empty (or this
        monitor was built with ``backpressure=False``).  The scheduler
        defers admitting work *below* the floor."""
        if not self.backpressure:
            return None
        prios = [
            t.priority for t in self._reqs.values()
            if t.at_risk or t.violated
        ]
        return max(prios) if prios else None

    def render(self) -> str:
        """One-line health summary the cluster can print per tick."""
        s = self.last_summary
        if not s:
            return "health: no ticks yet"
        risks = ", ".join(
            f"p{p}={r:.2f}" for p, r in s["risk_by_priority"].items()
        ) or "-"
        floor = s["backpressure_floor"]
        return (
            f"health@tick {s['tick']}: tracked={s['tracked']} "
            f"at_risk={len(s['at_risk'])} violated={len(s['violated'])} "
            f"risk[{risks}]"
            + (f" backpressure<p{floor}" if floor is not None else "")
        )

"""Typed metrics: Counter / Gauge / Histogram on a named registry.

The serving layers used to keep flat ``stats()`` dicts that mixed
cumulative counters (``kv_pages_sent``) with point-in-time gauges
(``pool_free_pages``) — indistinguishable to a consumer that wants to
rate, diff, or reset them.  Here every metric declares its kind once;
``Registry.reset()`` clears counters (and histogram samples) but never
gauges, and ``snapshot()`` flattens back into the dict shape the
existing consumers read.

Histograms keep a bounded, deterministically decimated sample list for
streaming p50/p99 — no randomness (reservoir sampling would make runs
irreproducible), no unbounded memory: when the sample list exceeds its
cap it is sorted and every second sample dropped, which preserves the
quantile shape to well under the noise floor of anything we measure.
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Union

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "Registry",
    "counter_property",
]


class Counter:
    """Monotonic cumulative count.  Cleared by :meth:`Registry.reset`."""

    kind = "counter"
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: Union[int, float] = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name}: negative inc {n}")
        self.value += n

    def get(self) -> Union[int, float]:
        return self.value


class Gauge:
    """Point-in-time value.  Survives :meth:`Registry.reset`."""

    kind = "gauge"
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def set(self, v: Union[int, float]) -> None:
        self.value = v

    def get(self) -> Union[int, float]:
        return self.value


class Histogram:
    """Streaming distribution with deterministic bounded memory.

    ``observe(v)`` is O(1) amortised; ``quantile(q)`` sorts the current
    samples (cheap at the cap).  ``count``/``total`` are exact even
    after decimation; quantiles are approximate once the cap is hit.
    """

    kind = "histogram"
    __slots__ = ("name", "cap", "count", "total", "_samples", "_sorted")

    def __init__(self, name: str, cap: int = 4096):
        if cap < 2:
            raise ValueError("histogram cap must be >= 2")
        self.name = name
        self.cap = cap
        self.count = 0
        self.total = 0.0
        self._samples: List[float] = []
        self._sorted = True

    def observe(self, v: Union[int, float]) -> None:
        self.count += 1
        self.total += v
        self._samples.append(float(v))
        self._sorted = False
        if len(self._samples) > self.cap:
            self._samples.sort()
            # deterministic decimation: keep every second sample
            self._samples = self._samples[::2]
            self._sorted = True

    def quantile(self, q: float) -> float:
        """Linear-interpolated quantile over the current samples.

        Defined explicitly at the edges: n=0 returns 0.0 (no data), n=1
        returns the single sample for every q.  For n >= 2 the rank
        ``q * (n - 1)`` interpolates between its two neighbours — so a
        tiny sample no longer degenerates to its max (the old
        index-truncation rule mapped p99 of [a, b] to b outright)."""
        if not self._samples:
            return 0.0
        if not self._sorted:
            self._samples.sort()
            self._sorted = True
        n = len(self._samples)
        if n == 1:
            return self._samples[0]
        pos = min(max(q, 0.0), 1.0) * (n - 1)
        lo = int(pos)
        hi = min(lo + 1, n - 1)
        frac = pos - lo
        return self._samples[lo] + (self._samples[hi] - self._samples[lo]) * frac

    @property
    def p50(self) -> float:
        return self.quantile(0.50)

    @property
    def p99(self) -> float:
        return self.quantile(0.99)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def get(self) -> Dict[str, float]:
        return {
            "count": self.count,
            "mean": self.mean,
            "p50": self.p50,
            "p99": self.p99,
        }

    def clear(self) -> None:
        self.count = 0
        self.total = 0.0
        self._samples = []
        self._sorted = True


Metric = Union[Counter, Gauge, Histogram]


class Registry:
    """Named metrics with kind checking.

    ``counter(name)`` / ``gauge(name)`` / ``histogram(name)`` are
    get-or-create; asking for an existing name under a different kind
    raises (the schema ambiguity the typed registry exists to prevent).
    """

    def __init__(self):
        self._metrics: Dict[str, Metric] = {}

    def _get(self, name: str, cls, **kw) -> Metric:
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, **kw)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} is a {m.kind}, not a {cls.kind}"
            )
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, cap: int = 4096) -> Histogram:
        h = self._metrics.get(name)
        if h is None:
            return self._get(name, Histogram, cap=cap)
        return self._get(name, Histogram)

    def kind(self, name: str) -> str:
        return self._metrics[name].kind

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __iter__(self) -> Iterator[Metric]:
        return iter(self._metrics.values())

    def snapshot(self) -> Dict[str, Union[int, float]]:
        """Flatten to the ``stats()`` dict shape: counters and gauges map
        to their value, histograms expand to ``name_count`` / ``name_p50``
        / ``name_p99`` / ``name_mean``."""
        out: Dict[str, Union[int, float]] = {}
        for m in self._metrics.values():
            if isinstance(m, Histogram):
                out[f"{m.name}_count"] = m.count
                out[f"{m.name}_mean"] = m.mean
                out[f"{m.name}_p50"] = m.p50
                out[f"{m.name}_p99"] = m.p99
            else:
                out[m.name] = m.value
        return out

    def reset(self) -> None:
        """Zero the cumulative metrics (counters, histogram samples).
        Gauges describe *current* state, not history — they survive."""
        for m in self._metrics.values():
            if isinstance(m, Counter):
                m.value = 0
            elif isinstance(m, Histogram):
                m.clear()


def counter_property(name: str) -> property:
    """Class-level proxy migrating a plain integer attribute onto the
    owner's typed registry: reads and writes go to ``self.metrics``'s
    Counter of the given name, so existing ``obj.x += 1`` increment
    sites keep their syntax while the value lives on the registry (with
    an explicit kind, visible to ``snapshot()`` and ``reset()``)."""

    def fget(self):
        return self.metrics.counter(name).value

    def fset(self, v):
        self.metrics.counter(name).value = v

    return property(fget, fset)

"""Device-time kernel profiling: the measurement side of the cost loop.

PR 9's tracer records host walls *around* initiation and sync — good
enough to fit α/β from end-to-end transfer spans, but blind to the one
constant the GAScore's hardware counters measure directly: γ, the
receiver-side epilogue per KiB, which overlaps the wire by design and
therefore never separates out of an end-to-end wall.  This module
closes that gap the way ACCL+'s engine counters do — time the epilogue
program *alone*, at several sizes, and hand its per-KiB slope to
:meth:`repro.core.sched.EngineCost.fit_from_trace` as
``epilogue_spans``.

Measurement discipline:

- **On-device events where available.**  A backend with real device
  timers (a TPU) could stamp kernel launch/retire on device; the forced
  host-platform runs this repo's CI uses (and interpret-mode Pallas)
  have none, so the profiler falls back to *interleaved timed
  re-execution*: run the target repeatedly under ``perf_counter`` with
  ``block_until_ready`` fencing each call, interleaving targets
  round-robin so machine-load drift lands on all of them equally, and
  keep the best-of-N (scheduler noise only ever adds time).
- **Honest labelling.**  Every recorded sample carries
  ``measured="device"`` or ``measured="wall"`` so a consumer (the
  bench artifact, a fit) knows which clock produced it.
- **Never on the serving hot path.**  Profiling is an offline,
  explicit re-execution of a target — the ``obs_overhead`` gate
  (< 1.02x with tracing on, profiler idle) is unaffected by anything
  in this module.

Like the rest of ``repro.obs`` this module imports nothing from the
core/serving layers; servers and benches hand it plain callables.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence

from repro.obs import trace as obs_trace

__all__ = [
    "DeviceProfiler",
    "device_events_available",
    "measure",
]


def device_events_available() -> bool:
    """True when the backend exposes on-device event timers.

    The forced host-platform (CPU) backend — where interpret-mode
    Pallas runs — does not; profiled samples are then wall-clocked
    re-executions, marked ``measured="wall"``."""
    try:
        import jax

        return jax.default_backend() not in ("cpu",)
    except Exception:  # pragma: no cover - jax always importable here
        return False


def _block(x: Any) -> Any:
    """Fence a target's result: device work must retire before the
    timer stops.  Host-side results (numpy, floats) pass through."""
    try:
        import jax

        return jax.block_until_ready(x)
    except Exception:
        return x


def measure(
    fn: Callable[[], Any],
    *,
    iters: int = 8,
    warmup: int = 2,
) -> tuple:
    """Time ``fn()`` by re-execution: ``warmup`` unrecorded calls (JIT
    compile + cache warm), then ``iters`` timed calls, each fenced with
    ``block_until_ready``.  Returns ``(best_us, measured)`` where
    ``measured`` names the clock (``"device"`` | ``"wall"``)."""
    for _ in range(max(warmup, 0)):
        _block(fn())
    best = None
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        _block(fn())
        dt = (time.perf_counter() - t0) * 1e6
        best = dt if best is None or dt < best else best
    return best, ("device" if device_events_available() else "wall")


class DeviceProfiler:
    """Records timed kernel/program samples as profile spans.

    Each :meth:`profile` call produces one record — a plain dict with
    ``name`` / ``dur_us`` / ``measured`` plus caller tags (``bytes=``
    makes it a valid fit point for
    :meth:`~repro.core.sched.EngineCost.fit_from_trace`) — kept on
    ``self.records`` and, when tracing is enabled, mirrored onto the
    active tracer as a ``cat="profile"`` instant so profiled kernels
    appear in the exported timeline next to the spans they explain.
    """

    def __init__(self, tracer: Optional[Any] = None):
        self._tracer = tracer
        self.records: List[Dict[str, Any]] = []

    def _emit(self, rec: Dict[str, Any]) -> None:
        self.records.append(rec)
        tr = self._tracer if self._tracer is not None else obs_trace.active()
        if tr.enabled:
            tr.instant(rec["name"], cat="profile",
                       **{k: v for k, v in rec.items() if k != "name"})

    # ---------------------------------------------------------------- #
    def profile(
        self,
        name: str,
        fn: Callable[[], Any],
        *,
        iters: int = 8,
        warmup: int = 2,
        **tags: Any,
    ) -> float:
        """Time one target; returns its best-of-N microseconds."""
        best_us, measured = measure(fn, iters=iters, warmup=warmup)
        self._emit({"name": name, "dur_us": round(best_us, 3),
                    "measured": measured, **tags})
        return best_us

    def profile_many(
        self,
        targets: Sequence[tuple],
        *,
        rounds: int = 6,
        warmup: int = 2,
    ) -> Dict[str, float]:
        """Interleaved timed re-execution of several targets.

        ``targets`` is a sequence of ``(name, fn)`` or
        ``(name, fn, tags)`` tuples.  Each round times every target
        once, round-robin, so load drift during the run biases none of
        them; per-target best-of-rounds is recorded.  Returns
        ``{name: best_us}``."""
        norm = [
            (t[0], t[1], t[2] if len(t) > 2 else {}) for t in targets
        ]
        for name, fn, _ in norm:
            for _ in range(max(warmup, 0)):
                _block(fn())
        best: Dict[str, float] = {}
        for _ in range(max(rounds, 1)):
            for name, fn, _ in norm:
                t0 = time.perf_counter()
                _block(fn())
                dt = (time.perf_counter() - t0) * 1e6
                if name not in best or dt < best[name]:
                    best[name] = dt
        measured = "device" if device_events_available() else "wall"
        for name, _, tags in norm:
            self._emit({"name": name, "dur_us": round(best[name], 3),
                        "measured": measured, **tags})
        return best

    def profile_epilogue(
        self,
        make_fn: Callable[[int], Callable[[], Any]],
        sizes: Iterable[int],
        *,
        name: str = "epilogue",
        iters: int = 8,
        warmup: int = 2,
    ) -> List[Dict[str, Any]]:
        """Time the receiver-epilogue program alone at several payload
        sizes — the γ measurement.  ``make_fn(nbytes)`` must return a
        zero-arg callable executing the epilogue (the install/
        accumulate/store a receiver runs per landed segment) over a
        payload of ``nbytes``.  The returned records carry ``bytes``
        tags and feed ``EngineCost.fit_from_trace(...,
        epilogue_spans=...)`` / ``fit_gamma_from_trace`` directly."""
        out = []
        for nbytes in sizes:
            fn = make_fn(int(nbytes))
            best_us, measured = measure(fn, iters=iters, warmup=warmup)
            rec = {"name": name, "dur_us": round(best_us, 3),
                   "measured": measured, "bytes": int(nbytes)}
            self._emit(rec)
            out.append(rec)
        return out

    # ---------------------------------------------------------------- #
    def spans(self, name: Optional[str] = None) -> List[Dict[str, Any]]:
        """Recorded samples (optionally filtered by name) — dict-shaped
        fit points accepted by ``EngineCost._points``."""
        if name is None:
            return list(self.records)
        return [r for r in self.records if r["name"] == name]

"""Per-rank span/event tracing clocked on the SPMD tick counter.

GASNet's split-phase operations are invisible between initiation and
sync — ``GASNET_TRACE`` exists because a hung ``gasnet_put_nb`` tells
you nothing about *which* transfer, to where, how big.  This tracer is
the software analogue, with two hard constraints from running under
JAX:

- **Compiled-code-safe.**  Spans are recorded on the *host*, around
  initiation (``put_nb`` returning a handle) and sync (``sync`` /
  ``sync_all``) — never inside traced/compiled code.  Handles are
  trace-time Python objects, so a split-phase span simply rides the
  handle from initiation to sync.
- **Zero-cost when disabled.**  ``active()`` returns a module-level
  no-op recorder unless tracing was enabled; every instrumentation
  site in the hot path guards on one attribute check
  (``tr = trace.active(); if tr.enabled: ...``).

Timestamps are dual: the **tick clock** (``set_tick`` + a per-tick
sequence number) is deterministic and is what the export merges ranks
on; the wall clock (``perf_counter``) rides along in every event for
real durations (e.g. ``EngineCost.fit_from_trace``).  Span ids are a
plain counter — deterministic across replays of the same schedule.

Events live in a bounded ring (``collections.deque``), which is what
makes the flight recorder free: the last-N-ticks dump on rank death is
just a filter over the ring.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Any, Dict, Iterator, List, Optional

from repro.obs.metrics import Registry

__all__ = [
    "NullTracer",
    "Span",
    "Tracer",
    "active",
    "disable",
    "enable",
]


class Span:
    """One recorded event: a closed span, an async (split-phase) span,
    or an instant.  ``tick0/seq0`` is the tick-clock position of the
    begin, ``tick1/seq1`` of the end (equal for instants); ``t0_us`` /
    ``t1_us`` are wall-clock microseconds since the tracer's epoch."""

    __slots__ = (
        "sid", "name", "cat", "kind", "rank",
        "tick0", "seq0", "tick1", "seq1", "t0_us", "t1_us", "args",
    )

    def __init__(self, sid, name, cat, kind, rank,
                 tick0, seq0, t0_us, args):
        self.sid = sid
        self.name = name
        self.cat = cat
        self.kind = kind  # "span" | "async" | "instant"
        self.rank = rank
        self.tick0 = tick0
        self.seq0 = seq0
        self.tick1 = tick0
        self.seq1 = seq0
        self.t0_us = t0_us
        self.t1_us = t0_us
        self.args = args

    @property
    def dur_us(self) -> float:
        """Wall-clock duration (microseconds)."""
        return self.t1_us - self.t0_us

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"Span({self.name!r}, cat={self.cat!r}, kind={self.kind!r}, "
            f"rank={self.rank}, tick={self.tick0}->{self.tick1}, "
            f"args={self.args})"
        )


class _NoopCtx:
    """Reusable no-op context manager (``NullTracer.span``)."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NOOP_CTX = _NoopCtx()


class NullTracer:
    """The disabled recorder: every method is a no-op, ``enabled`` is
    False.  Instrumentation sites check ``enabled`` once and skip; the
    per-tick sites that use ``span(...)`` contexts get a shared no-op
    context object."""

    enabled = False
    __slots__ = ()

    def set_tick(self, tick: int) -> None:
        pass

    def set_rank(self, rank: Optional[int]) -> None:
        pass

    def begin(self, name, cat="span", rank=None, **args):
        return None

    def end(self, span, **args) -> None:
        pass

    def begin_async(self, name, cat="span", rank=None, **args):
        return None

    def end_async(self, span, **args) -> None:
        pass

    def instant(self, name, cat="event", rank=None, **args) -> None:
        pass

    def span(self, name, cat="span", rank=None, **args):
        return _NOOP_CTX


class _SpanCtx:
    """Context manager pairing ``begin``/``end`` for scoped spans."""

    __slots__ = ("_tr", "_span")

    def __init__(self, tr: "Tracer", span: Span):
        self._tr = tr
        self._span = span

    def __enter__(self) -> Span:
        return self._span

    def __exit__(self, *exc) -> bool:
        self._tr.end(self._span)
        return False


class Tracer:
    """Recording tracer.  See module docstring for the clock model."""

    enabled = True

    def __init__(self, capacity: int = 65536,
                 registry: Optional[Registry] = None):
        self.capacity = capacity
        self.registry = registry if registry is not None else Registry()
        self.events: deque = deque(maxlen=capacity)
        self.tick = 0
        self.rank: Optional[int] = None
        self._sid = 0
        self._seq = 0
        self._epoch_ns = time.perf_counter_ns()

    # ---------------------------------------------------------------- #
    # clocks
    # ---------------------------------------------------------------- #
    def set_tick(self, tick: int) -> None:
        """Advance the deterministic tick clock (the disagg cluster calls
        this once per ``tick()``); the per-tick sequence counter resets."""
        self.tick = tick
        self._seq = 0

    def set_rank(self, rank: Optional[int]) -> None:
        """Default rank attributed to events that don't pass ``rank=``.
        ``None`` means the program-wide (collective/transport) row."""
        self.rank = rank

    def _now_us(self) -> float:
        return (time.perf_counter_ns() - self._epoch_ns) / 1e3

    def _stamp(self) -> tuple:
        seq = self._seq
        self._seq = seq + 1
        return self.tick, seq, self._now_us()

    # ---------------------------------------------------------------- #
    # recording
    # ---------------------------------------------------------------- #
    def _open(self, name, cat, kind, rank, args) -> Span:
        sid = self._sid
        self._sid = sid + 1
        tick, seq, t_us = self._stamp()
        return Span(sid, name, cat, kind,
                    self.rank if rank is None else rank,
                    tick, seq, t_us, args)

    def begin(self, name: str, cat: str = "span",
              rank: Optional[int] = None, **args) -> Span:
        """Open a scoped span (must ``end`` before its parent ends —
        use :meth:`span` for the with-statement form)."""
        return self._open(name, cat, "span", rank, args)

    def end(self, span: Span, **args) -> None:
        if args:
            span.args.update(args)
        span.tick1, span.seq1, span.t1_us = self._stamp()
        self.events.append(span)

    def begin_async(self, name: str, cat: str = "span",
                    rank: Optional[int] = None, **args) -> Span:
        """Open a split-phase span: initiation now, sync later, possibly
        ticks later, with other spans opening and closing in between."""
        return self._open(name, cat, "async", rank, args)

    def end_async(self, span: Span, **args) -> None:
        if args:
            span.args.update(args)
        span.tick1, span.seq1, span.t1_us = self._stamp()
        self.events.append(span)
        # RMA byte accounting: the per-op byte counters the export's
        # validation compares against span byte totals, bit-for-bit.
        if span.cat == "rma":
            b = span.args.get("bytes")
            if b is not None:
                reg = self.registry
                reg.counter(f"rma_{span.name}_bytes").inc(int(b))
                reg.counter(f"rma_{span.name}_ops").inc()

    def instant(self, name: str, cat: str = "event",
                rank: Optional[int] = None, **args) -> Span:
        sp = self._open(name, cat, "instant", rank, args)
        self.events.append(sp)
        return sp

    def span(self, name: str, cat: str = "span",
             rank: Optional[int] = None, **args) -> _SpanCtx:
        return _SpanCtx(self, self.begin(name, cat, rank=rank, **args))

    # ---------------------------------------------------------------- #
    # queries
    # ---------------------------------------------------------------- #
    def spans(self, cat: Optional[str] = None,
              name: Optional[str] = None) -> Iterator[Span]:
        for e in self.events:
            if cat is not None and e.cat != cat:
                continue
            if name is not None and e.name != name:
                continue
            yield e

    def flight(self, last_ticks: int) -> List[Span]:
        """The flight-recorder window: every event whose end lands in
        the last ``last_ticks`` ticks (inclusive of the current one)."""
        lo = self.tick - last_ticks + 1
        return [e for e in self.events if e.tick1 >= lo]

    def request_stats(self) -> Dict[Any, Dict[str, float]]:
        """Derive per-request timing from lifecycle events — TTFT, TPOT
        and end-to-end latency become trace queries instead of
        hand-maintained timers on the Request object.

        Consumes ``cat="req"`` instants: ``req_submit``,
        ``req_first_token`` and ``req_retire`` (the latter carrying
        ``tokens=<generated count>``).  Returns seconds, keyed by rid.

        Lifecycle edge cases are first-class: a preempted-and-resumed
        (or recompute-replayed) request's ``req_preempt``/``req_resume``
        instants land in ``preempts`` / ``resumes`` counts (with
        ``preempt_modes`` naming swap vs recompute), and a request still
        in flight at dump time has ``state="in-flight"`` with no
        ``latency_s``/``tpot_s`` — its ``ttft_s`` still derives when the
        first token already exists.  TTFT/latency are unchanged by
        preemption (first-token-wins; the retire instant is terminal).
        """
        out: Dict[Any, Dict[str, float]] = {}
        for e in self.events:
            if e.cat != "req":
                continue
            rid = e.args.get("rid")
            if rid is None:
                continue
            rec = out.setdefault(rid, {})
            if e.name == "req_submit":
                rec["t_submit_us"] = e.t0_us
            elif e.name == "req_first_token":
                rec.setdefault("t_first_us", e.t0_us)
            elif e.name == "req_retire":
                rec["t_retire_us"] = e.t0_us
                rec["tokens"] = e.args.get("tokens", 0)
            elif e.name == "req_preempt":
                rec["preempts"] = rec.get("preempts", 0) + 1
                rec.setdefault("preempt_modes", []).append(
                    e.args.get("mode", "?"))
            elif e.name == "req_resume":
                rec["resumes"] = rec.get("resumes", 0) + 1
        for rec in out.values():
            rec["state"] = ("retired" if "t_retire_us" in rec
                            else "in-flight")
            t0 = rec.get("t_submit_us")
            tf = rec.get("t_first_us")
            td = rec.get("t_retire_us")
            if t0 is not None and tf is not None:
                rec["ttft_s"] = (tf - t0) / 1e6
            if t0 is not None and td is not None:
                rec["latency_s"] = (td - t0) / 1e6
            if tf is not None and td is not None:
                n = rec.get("tokens", 0)
                rec["tpot_s"] = (td - tf) / 1e6 / max(n - 1, 1)
        return out


# -------------------------------------------------------------------- #
# module-level switch
# -------------------------------------------------------------------- #
_NULL = NullTracer()
_ACTIVE: Any = _NULL


def active() -> Any:
    """The current recorder — a :class:`Tracer` when enabled, the no-op
    :class:`NullTracer` otherwise.  Hot paths call this then guard on
    ``.enabled``."""
    return _ACTIVE


def enable(tracer: Optional[Tracer] = None, **kw) -> Tracer:
    """Install (and return) the active tracer.  ``kw`` is forwarded to
    the :class:`Tracer` constructor when none is passed."""
    global _ACTIVE
    _ACTIVE = tracer if tracer is not None else Tracer(**kw)
    return _ACTIVE


def disable() -> Optional[Tracer]:
    """Swap the no-op recorder back in; returns the tracer that was
    active (so callers can still export it), or None."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = _NULL
    return None if prev is _NULL else prev

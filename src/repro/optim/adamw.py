"""Sharded AdamW with ZeRO-style state partitioning and schedule.

States inherit the parameter PartitionSpecs (FSDP: states are sharded the
same 2-D (data × model) way as the weights — ZeRO-3-equivalent under
GSPMD).  ``state_dtype`` lets the huge archs halve optimizer memory
(bf16 moments; the dry-run memory analysis quantifies the trade)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "init_state", "apply_updates", "state_specs",
           "warmup_cosine", "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: Any = jnp.float32  # bf16 halves optimizer HBM
    schedule: Optional[Callable[[jax.Array], jax.Array]] = None


def warmup_cosine(base_lr: float, warmup: int, total: int, floor: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * jnp.where(step < warmup, warm, cos)

    return fn


def init_state(params: Any, cfg: AdamWConfig) -> Any:
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def state_specs(param_specs: Any) -> Any:
    """Optimizer-state PartitionSpecs mirror the parameter specs."""
    from jax.sharding import PartitionSpec as P

    return {
        "m": param_specs,
        "v": param_specs,
        "step": P(),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    scaled = jax.tree.map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads
    )
    return scaled, norm


def apply_updates(
    params: Any, grads: Any, state: Any, cfg: AdamWConfig
) -> Tuple[Any, Any, dict]:
    step = state["step"] + 1
    if cfg.grad_clip:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    lr = cfg.schedule(step) if cfg.schedule else cfg.lr
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = mf / bc1
        vhat = vf / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return (
            newp.astype(p.dtype),
            mf.astype(cfg.state_dtype),
            vf.astype(cfg.state_dtype),
        )

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics

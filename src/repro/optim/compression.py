"""Int8 error-feedback gradient compression over the GAS ring.

A distributed-optimization trick only expressible because the paper's model
makes the reduction ring *explicit*: each reduce-scatter hop carries int8
payloads + per-chunk scales (4.25 bytes/4 bytes ≈ 3.8× wire-byte saving vs
f32, ≈ 1.9× vs bf16), dequantizes, accumulates in f32, and requantizes for
the next hop.  Error feedback keeps the quantization noise from biasing
convergence: each node remembers what quantization destroyed and re-adds it
next step.

Used by the explicit-DP trainer (``examples/train_lm.py --compress``) and
benchmarked in ``benchmarks/collectives.py``.  Under GSPMD the reduction is
fused inside XLA and cannot be intercepted; that path reports the analytic
wire-byte saving instead (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.engine import CommEngine

__all__ = [
    "quantize_int8",
    "dequantize_int8",
    "ef_prepare",
    "compressed_ring_all_reduce",
    "compressed_all_reduce_tree",
]


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor absmax int8 quantization."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_prepare(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Error-feedback compensation: compress (g + err), remember residual."""
    comp = g.astype(jnp.float32) + err
    q, s = quantize_int8(comp)
    new_err = comp - dequantize_int8(q, s)
    return q, s, new_err


def compressed_ring_all_reduce(
    engine: CommEngine, x: jax.Array, err: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """All-reduce of ``x`` (flat, length divisible by n) with int8 hops.

    Ring RS with dequant-accumulate-requant per hop, then int8 ring AG.
    Returns (reduced f32, new error-feedback state).  Must run inside
    shard_map over ``engine.axis``.
    """
    n = engine.n_nodes
    me = engine.my_id()
    L = x.shape[0]
    if L % n:
        raise ValueError(f"length {L} not divisible by {n}")
    m = L // n
    q, s, new_err = ef_prepare(x, err)
    qb = q.reshape(n, m)

    # --- reduce-scatter: packet for chunk c starts at node c+1 ------------ #
    start = lax.rem(me - 1 + n, n)
    cur_q = lax.dynamic_slice_in_dim(qb, start, 1, axis=0)[0]
    cur_s = s
    for h in range(1, n):
        cur_q = engine.shift(cur_q, 1)
        cur_s = engine.shift(cur_s, 1)
        c = lax.rem(me - h - 1 + 2 * n, n)
        mine = lax.dynamic_slice_in_dim(qb, c, 1, axis=0)[0]
        acc = dequantize_int8(cur_q, cur_s) + dequantize_int8(mine, s)
        cur_q, cur_s = quantize_int8(acc)
    # cur now holds the full sum of chunk ``me`` (int8-compressed)

    # --- all-gather the reduced chunks (int8 wire) ------------------------ #
    out = jnp.zeros((n, m), jnp.float32)
    out = lax.dynamic_update_slice_in_dim(
        out, dequantize_int8(cur_q, cur_s)[None], me, axis=0
    )
    gq, gs = cur_q, cur_s
    for k in range(1, n):
        gq = engine.shift(gq, 1)
        gs = engine.shift(gs, 1)
        src = lax.rem(me - k + n, n)
        out = lax.dynamic_update_slice_in_dim(
            out, dequantize_int8(gq, gs)[None], src, axis=0
        )
    return out.reshape(L), new_err


def compressed_all_reduce_tree(
    engine: CommEngine, grads: Any, err: Any
) -> Tuple[Any, Any]:
    """Tree version: flatten-concat-pad, one ring, unflatten.

    Mean (not sum) over nodes, matching data-parallel averaging.
    """
    leaves, treedef = jax.tree.flatten(grads)
    sizes = [x.size for x in leaves]
    flat = jnp.concatenate([x.reshape(-1).astype(jnp.float32) for x in leaves])
    n = engine.n_nodes
    pad = (-flat.shape[0]) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    reduced, new_err = compressed_ring_all_reduce(engine, flat, err)
    reduced = reduced / n
    outs = []
    off = 0
    for x, sz in zip(leaves, sizes):
        outs.append(reduced[off : off + sz].reshape(x.shape).astype(x.dtype))
        off += sz
    return treedef.unflatten(outs), new_err


def init_error_state(grads: Any, n_nodes: int) -> jax.Array:
    total = sum(x.size for x in jax.tree.leaves(grads))
    total += (-total) % n_nodes
    return jnp.zeros((total,), jnp.float32)

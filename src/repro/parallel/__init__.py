"""Parallelism substrate: run context, sharding rules, pipeline stages."""
from repro.parallel.ctx import RunCtx, shard

__all__ = ["RunCtx", "shard"]

"""Run context: mesh, axis roles, and implementation switches.

One immutable object threaded through model code so that the *same* model
definition runs:

- single-device (smoke tests, examples): ``mesh=None`` — no collectives;
- GSPMD production: mesh + axis names; parameter PartitionSpecs from the
  layer inits + boundary constraints drive the partitioner;
- paper-mode migrations: ``attn_impl``/``scan_impl``/``moe_backend`` flip
  individual hot spots between the verified software path ("ref"/"xla")
  and the hardware path ("pallas"/"gascore") with no model changes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = ["RunCtx", "shard"]


@dataclasses.dataclass(frozen=True)
class RunCtx:
    mesh: Optional[jax.sharding.Mesh] = None
    dp: Tuple[str, ...] = ("data",)  # batch / FSDP axes (includes "pod")
    tp: str = "model"  # tensor/expert-parallel axis
    pp: Optional[str] = None  # pipeline axis ("pod") when enabled
    # implementation switches (software <-> hardware migration points)
    moe_mode: str = "auto"  # auto | ep_shardmap | local
    moe_backend: str = "xla"  # xla | gascore
    attn_impl: str = "chunked"  # chunked | pallas
    attn_chunk: int = 512
    scan_impl: str = "ref"  # ref | pallas
    remat: str = "full"  # none | full | dots
    interpret: bool = True
    # §Perf iteration A: constrain weights to their FSDP-gathered form at
    # the point of use, so the partitioner all-gathers the (small) weight
    # shard instead of all-reducing the (huge) activations.  False =
    # paper-faithful baseline (leave the partitioner to choose).
    fsdp_gather: bool = False
    # §Perf iteration D: shard the saved residual stream's sequence dim
    # over tp between blocks (sequence parallelism for stored activations).
    seq_shard_acts: bool = False

    @property
    def dp_size(self) -> int:
        if self.mesh is None:
            return 1
        return math.prod(self.mesh.shape[a] for a in self.dp)

    @property
    def tp_size(self) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.shape[self.tp]

    def batch_spec(self) -> P:
        return P(self.dp)

    def hidden_spec(self) -> P:
        """(B, S, D) activations: batch over dp axes."""
        if self.seq_shard_acts:
            return P(self.dp, self.tp, None)
        return P(self.dp, None, None)


def use_weight(w, ctx: "RunCtx", spec: P):
    """FSDP unshard-at-use (iteration A): see RunCtx.fsdp_gather."""
    if not ctx.fsdp_gather or ctx is None or ctx.mesh is None:
        return w
    from repro.parallel.sharding import sanitize

    return shard(w, ctx, sanitize(spec, w.shape, ctx.mesh))


def shard(x: jax.Array, ctx: RunCtx, spec: P) -> jax.Array:
    """Sharding constraint that is a no-op without a mesh."""
    if ctx is None or ctx.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec)
    )

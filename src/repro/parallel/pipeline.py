"""Pipeline parallelism over the pod axis (GPipe fill–drain).

The paper's two-level interconnect (on-chip network vs OCCC) maps to
intra-pod ICI vs inter-pod links; pipeline stages are the classic way to
put the *slow* link on the lowest-frequency traffic: one activation
transfer per microbatch per stage boundary instead of per-layer parameter
or gradient traffic.

Implementation: layers are already scan-stacked, so a stage is simply a
shard of the layer-stack dimension.  ``gpipe`` runs inside ``shard_map``
over the pipeline axis; stage boundaries are *split-phase* one-sided
neighbor puts through a :class:`~repro.core.engine.CommEngine`
(``engine.shift_nb`` — the software XLA node by default, the GAScore
Pallas node via ``engine=``): the activation put to stage s+1 is initiated
as soon as the stage body finishes, and the output bookkeeping of the
current tick overlaps the transfer (Extended-API comm/compute overlap at
the stage boundary).  Autodiff through the XLA engine's ppermute gives the
reverse-direction backward schedule for free; remat on the stage body
bounds activation memory.

Schedule (S stages, M microbatches, T = M + S - 1 ticks):

  tick t: stage s computes microbatch (t - s) if 0 <= t - s < M
          then puts its activation to stage s+1.

Bubble fraction = (S-1)/T, the standard GPipe overhead; the multi-pod
mesh uses S=2, M>=8 -> <= 11% bubble.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.engine import CommEngine, XlaEngine, wait_all
from repro.compat import shard_map

__all__ = ["gpipe", "pipelined"]


def gpipe(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params: Any,
    x_micro: jax.Array,  # (M, mb, ...) microbatched input (stage-0 content)
    *,
    axis: str,
    n_stages: int,
    broadcast_out: bool = True,
    engine: Optional[CommEngine] = None,
    boundary_segments: Optional[int] = None,
) -> jax.Array:
    """Run ``stage_fn`` as a GPipe pipeline inside shard_map over ``axis``.

    Every device holds ``stage_params`` for ITS stage (layer-stack shard).
    Returns the final-stage outputs (M, mb, ...).  With ``broadcast_out``
    the result is psum-broadcast to every stage (cheap relative to the
    steady-state activation traffic, and lets the loss epilogue run
    replicated); otherwise it is valid on the last stage only.

    ``engine`` is the stage-boundary transport (default: the software
    ``XlaEngine``; pass a ``GascoreEngine`` — or a heterogeneous
    ``EngineMap`` mixing software and hardware stages — to ship
    activations with the Pallas remote-DMA kernels; forward only, the
    Pallas path defines no VJP).  On engines with partial-permute support
    (``engine.can_permute_partial``) the boundary put is a chain permute
    (s -> s+1, no wrap — no dead traffic); bijection-only transports
    (GAScore: every recv semaphore signalled exactly once) use a ring
    ``Shift(1)`` whose wrap edge (S-1 -> 0) is dead: stage 0 always
    injects fresh microbatches and ignores its carry.

    The boundary transport is *plan-driven*: ``repro.core.sched.plan_p2p``
    sizes ``boundary_segments`` from the activation bytes and the engine
    cost model (pass it explicitly to pin); with >1 segments the
    activation ships as multiple puts in flight, so the wire overlaps the
    per-tick output bookkeeping.
    """
    S = n_stages
    M = x_micro.shape[0]
    eng = engine or XlaEngine(axis, S)
    chain = tuple(range(1, S)) + (None,)  # s -> s+1, last stage sends nowhere
    use_chain = eng.can_permute_partial
    if boundary_segments is None:
        from repro.core import sched

        mb_bytes = math.prod(x_micro.shape[1:]) * x_micro.dtype.itemsize
        boundary_segments = sched.plan_p2p(nbytes=mb_bytes, engine=eng).n_segments
    n_seg = max(1, int(boundary_segments))

    def _one_put_nb(y):
        return eng.permute_nb(y, chain) if use_chain else eng.shift_nb(y, 1)

    def boundary_put_nb(y):
        """Initiate the stage-boundary put as n_seg in-flight segments."""
        if n_seg == 1 or y.ndim == 0 or y.shape[0] < n_seg:
            return [_one_put_nb(y)]
        from repro.core.collectives import segment_bounds

        return [
            _one_put_nb(lax.slice_in_dim(y, lo, hi, axis=0))
            for lo, hi in segment_bounds(y.shape[0], n_seg)
        ]

    def boundary_wait(pendings):
        parts = wait_all(pendings)
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)

    stage = eng.my_id()
    mb_shape = x_micro.shape[1:]
    carry_in = jnp.zeros(mb_shape, x_micro.dtype)
    outputs = jnp.zeros_like(x_micro)

    for t in range(M + S - 1):
        # stage 0 injects microbatch t; others consume the neighbor put
        mb_idx = jnp.clip(t - stage, 0, M - 1)
        inject = lax.dynamic_index_in_dim(x_micro, jnp.clip(t, 0, M - 1), 0,
                                          keepdims=False)
        x_in = jnp.where(stage == 0, inject, carry_in)
        active = (t - stage >= 0) & (t - stage < M)
        y = stage_fn(stage_params, x_in)
        y = jnp.where(active, y, jnp.zeros_like(y))
        # split-phase put of activations to the next stage: initiate as
        # soon as y exists, record outputs while the transfer is in flight
        pendings = boundary_put_nb(y)
        # last stage records its result (overlaps the boundary put)
        outputs = lax.cond(
            active & (stage == S - 1),
            lambda o: lax.dynamic_update_index_in_dim(o, y, mb_idx, 0),
            lambda o: o,
            outputs,
        )
        carry_in = boundary_wait(pendings)
    if broadcast_out:
        outputs = lax.psum(outputs, axis)  # only the last stage is nonzero
    return outputs


def pipelined(
    stage_fn: Callable,
    *,
    mesh: jax.sharding.Mesh,
    axis: str = "pod",
    n_micro: int,
    params_spec: Any,
    x_spec: P,
    out_spec: Optional[P] = None,
    remat: bool = True,
    engine: Optional[CommEngine] = None,
    boundary_segments: Optional[int] = None,
) -> Callable:
    """Wrap a stage function into a jit-able pipelined forward.

    ``params_spec`` must shard the layer-stack dimension over ``axis``;
    ``x_spec``/``out_spec`` shard the microbatch dimension over nothing
    (microbatches stream through stages, data-parallel axes can shard the
    per-microbatch batch dim as usual).  ``engine`` selects the
    stage-boundary transport and ``boundary_segments`` its segmentation
    (default: planned from the activation size, see :func:`gpipe`).
    """
    n_stages = mesh.shape[axis]
    body = jax.checkpoint(stage_fn) if remat else stage_fn

    def fn(stage_params, x_micro):
        return gpipe(
            body, stage_params, x_micro, axis=axis, n_stages=n_stages,
            engine=engine, boundary_segments=boundary_segments,
        )

    return shard_map(
        fn,
        mesh=mesh,
        in_specs=(params_spec, x_spec),
        out_specs=out_spec if out_spec is not None else x_spec,
        check_vma=False,
    )

"""Pipeline parallelism over the pod axis (GPipe fill–drain).

The paper's two-level interconnect (on-chip network vs OCCC) maps to
intra-pod ICI vs inter-pod links; pipeline stages are the classic way to
put the *slow* link on the lowest-frequency traffic: one activation
transfer per microbatch per stage boundary instead of per-layer parameter
or gradient traffic.

Implementation: layers are already scan-stacked, so a stage is simply a
shard of the layer-stack dimension.  ``gpipe`` runs inside ``shard_map``
over the pipeline axis; stage boundaries are one-sided neighbor puts
(``lax.ppermute`` — or the GAScore engine, same interface).  Autodiff
through ppermute gives the reverse-direction backward schedule for free;
remat on the stage body bounds activation memory.

Schedule (S stages, M microbatches, T = M + S - 1 ticks):

  tick t: stage s computes microbatch (t - s) if 0 <= t - s < M
          then puts its activation to stage s+1.

Bubble fraction = (S-1)/T, the standard GPipe overhead; the multi-pod
mesh uses S=2, M>=8 -> <= 11% bubble.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

__all__ = ["gpipe", "pipelined"]


def gpipe(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params: Any,
    x_micro: jax.Array,  # (M, mb, ...) microbatched input (stage-0 content)
    *,
    axis: str,
    n_stages: int,
    broadcast_out: bool = True,
) -> jax.Array:
    """Run ``stage_fn`` as a GPipe pipeline inside shard_map over ``axis``.

    Every device holds ``stage_params`` for ITS stage (layer-stack shard).
    Returns the final-stage outputs (M, mb, ...).  With ``broadcast_out``
    the result is psum-broadcast to every stage (cheap relative to the
    steady-state activation traffic, and lets the loss epilogue run
    replicated); otherwise it is valid on the last stage only.
    """
    S = n_stages
    M = x_micro.shape[0]
    stage = lax.axis_index(axis)
    mb_shape = x_micro.shape[1:]
    carry_in = jnp.zeros(mb_shape, x_micro.dtype)
    outputs = jnp.zeros_like(x_micro)
    pairs = [(i, i + 1) for i in range(S - 1)]  # forward chain (no wrap)

    for t in range(M + S - 1):
        # stage 0 injects microbatch t; others consume the neighbor put
        mb_idx = jnp.clip(t - stage, 0, M - 1)
        inject = lax.dynamic_index_in_dim(x_micro, jnp.clip(t, 0, M - 1), 0,
                                          keepdims=False)
        x_in = jnp.where(stage == 0, inject, carry_in)
        active = (t - stage >= 0) & (t - stage < M)
        y = stage_fn(stage_params, x_in)
        y = jnp.where(active, y, jnp.zeros_like(y))
        # last stage records its result
        outputs = lax.cond(
            active & (stage == S - 1),
            lambda o: lax.dynamic_update_index_in_dim(o, y, mb_idx, 0),
            lambda o: o,
            outputs,
        )
        # one-sided put of activations to the next stage
        carry_in = lax.ppermute(y, axis, pairs)
    if broadcast_out:
        outputs = lax.psum(outputs, axis)  # only the last stage is nonzero
    return outputs


def pipelined(
    stage_fn: Callable,
    *,
    mesh: jax.sharding.Mesh,
    axis: str = "pod",
    n_micro: int,
    params_spec: Any,
    x_spec: P,
    out_spec: Optional[P] = None,
    remat: bool = True,
) -> Callable:
    """Wrap a stage function into a jit-able pipelined forward.

    ``params_spec`` must shard the layer-stack dimension over ``axis``;
    ``x_spec``/``out_spec`` shard the microbatch dimension over nothing
    (microbatches stream through stages, data-parallel axes can shard the
    per-microbatch batch dim as usual).
    """
    n_stages = mesh.shape[axis]
    body = jax.checkpoint(stage_fn) if remat else stage_fn

    def fn(stage_params, x_micro):
        return gpipe(
            body, stage_params, x_micro, axis=axis, n_stages=n_stages
        )

    return jax.shard_map(
        fn,
        mesh=mesh,
        in_specs=(params_spec, x_spec),
        out_specs=out_spec if out_spec is not None else x_spec,
        check_vma=False,
    )

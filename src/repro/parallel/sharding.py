"""Sharding utilities: divisibility-safe spec resolution.

Explicit jit ``in_shardings`` are strict: a dimension must be exactly
divisible by the product of its mesh axes (unlike internal propagation,
which pads).  Real configs violate this routinely — vocab 256206, 1601
vision tokens, 56 attention heads, batch-1 long-context decode — so every
spec that reaches a NamedSharding goes through :func:`sanitize`, which
drops the axis assignment of any non-dividing dimension (falling back to
replication for that dim, the conservative-but-correct choice; the
roofline table then shows the replication cost explicitly, e.g. arctic's
56 heads staying unsharded over model=16).
"""
from __future__ import annotations

import math
from typing import Any, Sequence

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = ["sanitize", "sanitize_tree", "named_shardings"]


def _axes_size(mesh: jax.sharding.Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        return math.prod(mesh.shape[a] for a in entry)
    return mesh.shape[entry]


def sanitize(spec: P, shape: Sequence[int], mesh: jax.sharding.Mesh) -> P:
    """Drop per-dimension axis assignments that don't divide the dim."""
    entries = tuple(spec)
    out = []
    for d, entry in enumerate(entries):
        if entry is None or d >= len(shape):
            out.append(entry)
            continue
        size = _axes_size(mesh, entry)
        if size and shape[d] % size == 0:
            out.append(entry)
        elif isinstance(entry, (tuple, list)):
            # try dropping trailing axes until it divides
            cand = list(entry)
            while cand and shape[d] % _axes_size(mesh, tuple(cand)) != 0:
                cand.pop()
            out.append(tuple(cand) if cand else None)
        else:
            out.append(None)
    return P(*out)


def sanitize_tree(spec_tree: Any, struct_tree: Any,
                  mesh: jax.sharding.Mesh) -> Any:
    """tree_map sanitize over matching (specs, shapes) trees."""
    return jax.tree.map(
        lambda spec, st: sanitize(spec, st.shape, mesh),
        spec_tree,
        struct_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def named_shardings(spec_tree: Any, struct_tree: Any,
                    mesh: jax.sharding.Mesh) -> Any:
    """Specs -> NamedShardings with divisibility sanitation."""
    return jax.tree.map(
        lambda spec, st: NamedSharding(mesh, sanitize(spec, st.shape, mesh)),
        spec_tree,
        struct_tree,
        is_leaf=lambda x: isinstance(x, P),
    )

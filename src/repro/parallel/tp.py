"""Tensor-parallel decode groups: head/column sharding over GAS ranks.

A *TP group* is ``tp`` GAS ranks jointly serving one logical decode
server: attention heads and MLP columns split over the group, each rank
holding only its shard of the weights (and, in paged serving, only its
heads' slice of the KV page pool).  The per-layer partial sums meet in
one all-reduce per sub-block — planned by :mod:`repro.core.sched`
(size-aware ring/tree/recursive-doubling, heterogeneous
:class:`~repro.core.engine.EngineMap` members allowed), which is the
paper's offloaded-collective-engine archetype at decode-step payload
sizes.

The model layers stay pure: they receive a :class:`TPGroup` whose
``psum`` closes over whatever transport the caller runs under —
``sched.all_reduce`` inside a ``shard_map`` for real groups,
``lax.psum`` under ``vmap(axis_name=...)`` for single-device property
tests, or the identity at ``tp=1``.

Sharding is by parameter *name*, mirroring the ``*_init`` spec trees in
:mod:`repro.models.layers` (axes counted from the end so the rules hold
for scan-stacked leaves too):

=============  ===========================  =========================
leaf           unstacked shape              shard
=============  ===========================  =========================
``wq/wk/wv``   (D, H, dh) / (D, KH, dh)     head axis (-2)
``wi``/``wg``  (D, F)                       columns (-1)
``wo``         (H*dh, D) or (F, D)          rows (-2; head-major)
MoE subtree    —                            replicated (expert
                                            parallelism is the
                                            ``model``-axis story)
everything     norms, router, io, gates     replicated
=============  ===========================  =========================

Every sharded matmul's partial output is summed by ``tp.psum``; all
activations (and therefore the logits) are replicated across the group.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import numpy as np

import jax

__all__ = [
    "TPGroup",
    "validate_tp",
    "shard_axis_for",
    "shard_decode_params",
    "stack_shards",
]


@dataclasses.dataclass(frozen=True)
class TPGroup:
    """One tensor-parallel decode group, as seen from inside a layer.

    ``size``  — number of ranks in the group.
    ``psum``  — sum a partial activation over the group; must be callable
                from traced code (all engine collectives and
                ``lax.psum`` qualify).
    """

    size: int
    psum: Callable[[jax.Array], jax.Array]

    def maybe_psum(self, x: jax.Array) -> jax.Array:
        return self.psum(x) if self.size > 1 else x


def validate_tp(cfg: Any, tp: int) -> None:
    """TP degree must divide both head counts (GQA group size preserved:
    each rank keeps H/tp query heads over KH/tp KV heads)."""
    if tp <= 1:
        return
    if cfg.n_heads % tp or cfg.n_kv_heads % tp:
        raise ValueError(
            f"tp={tp} must divide n_heads={cfg.n_heads} and "
            f"n_kv_heads={cfg.n_kv_heads}"
        )


def _path_names(path) -> list:
    return [p.key if hasattr(p, "key") else str(p) for p in path]


def shard_axis_for(path) -> Optional[int]:
    """The axis (negative, from the end) a leaf shards over, or None to
    replicate.  ``path`` is a ``tree_map_with_path`` key path."""
    names = _path_names(path)
    if "moe" in names:  # the whole MoE subtree (incl. shared/dense_res)
        return None
    name = names[-1] if names else ""
    if name in ("wq", "wk", "wv"):
        return -2
    if name in ("wi", "wg"):
        return -1
    if name == "wo":
        return -2
    return None


def _slice_axis(x, axis: int, tp: int, rank: int):
    n = x.shape[axis]
    if n % tp:
        raise ValueError(
            f"cannot shard axis {axis} of shape {x.shape} over tp={tp}"
        )
    k = n // tp
    idx = [slice(None)] * x.ndim
    idx[axis] = slice(rank * k, (rank + 1) * k)
    return x[tuple(idx)]


def shard_decode_params(params: Any, tp: int, rank: int) -> Any:
    """Rank ``rank``'s parameter shard (replicated leaves pass through)."""
    if tp <= 1:
        return params

    def f(path, leaf):
        ax = shard_axis_for(path)
        return leaf if ax is None else _slice_axis(leaf, ax, tp, rank)

    return jax.tree_util.tree_map_with_path(f, params)


def stack_shards(params: Any, tp: int) -> Any:
    """Stack all ranks' shards on a new leading axis: the ``(tp, ...)``
    operand a ``shard_map`` over a ``("tp",)`` mesh scatters one shard
    per device (replicated leaves are duplicated — fine at decode scale,
    where the KV pool dominates memory, not the weights)."""
    shards = [
        jax.tree.map(np.asarray, shard_decode_params(params, tp, r))
        for r in range(tp)
    ]
    return jax.tree.map(lambda *xs: np.stack(xs), *shards)

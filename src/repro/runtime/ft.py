"""Fault tolerance: failure detection, elastic re-meshing, stragglers.

Hardware-independent control-plane logic with injectable clocks and
failure sources, so the policies are fully testable on CPU and reusable
unchanged on a real cluster (where heartbeats come from the coordinator
service instead of the test injector).

Pieces:

- :class:`HeartbeatMonitor` — per-node liveness with timeout-based failure
  detection (the OCCC link-liveness analogue).
- :class:`StragglerTracker` — per-node step-time EWMA; flags nodes slower
  than ``threshold ×`` the fleet median; policy decides quarantine vs
  rebalance.  (Mitigation at step granularity: a quarantined node's shard
  is re-assigned, matching the checkpoint/elastic path below.)
- :func:`elastic_plan` — given survivors and a required model-parallel
  width, propose the largest usable (pod, data, model) mesh.
- :class:`FTTrainer`-side integration lives in ``repro.runtime.trainer``:
  on failure -> rebuild mesh -> ``checkpoint.restore`` with the new
  shardings -> resume from the deterministic data cursor.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

__all__ = [
    "HeartbeatMonitor",
    "StragglerTracker",
    "StragglerDecision",
    "elastic_plan",
]


class HeartbeatMonitor:
    """Timeout-based failure detector over explicit heartbeats."""

    def __init__(
        self,
        node_ids: Sequence[int],
        timeout_s: float = 10.0,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.timeout_s = timeout_s
        self.clock = clock
        now = clock()
        self.last_seen: Dict[int, float] = {n: now for n in node_ids}
        self._failed: set = set()

    def beat(self, node_id: int, at: Optional[float] = None) -> None:
        if node_id in self._failed:
            return  # a failed node must rejoin via admit()
        self.last_seen[node_id] = self.clock() if at is None else at

    def admit(self, node_id: int) -> None:
        self._failed.discard(node_id)
        self.last_seen[node_id] = self.clock()

    def check(self) -> List[int]:
        """Returns newly failed nodes (monotone: stays failed until admit)."""
        now = self.clock()
        newly = [
            n
            for n, t in self.last_seen.items()
            if n not in self._failed and now - t > self.timeout_s
        ]
        self._failed.update(newly)
        return newly

    @property
    def failed(self) -> List[int]:
        return sorted(self._failed)

    @property
    def alive(self) -> List[int]:
        return sorted(set(self.last_seen) - self._failed)


@dataclasses.dataclass(frozen=True)
class StragglerDecision:
    node_id: int
    action: str  # "observe" | "quarantine"
    ratio: float  # node EWMA / fleet median


class StragglerTracker:
    """EWMA step-time tracking with median-relative straggler flagging."""

    def __init__(
        self,
        node_ids: Sequence[int],
        alpha: float = 0.3,
        threshold: float = 1.8,
        patience: int = 3,
    ):
        self.alpha = alpha
        self.threshold = threshold
        self.patience = patience
        self.ewma: Dict[int, Optional[float]] = {n: None for n in node_ids}
        self.strikes: Dict[int, int] = {n: 0 for n in node_ids}

    def record(self, node_id: int, step_time_s: float) -> None:
        prev = self.ewma[node_id]
        self.ewma[node_id] = (
            step_time_s
            if prev is None
            else self.alpha * step_time_s + (1 - self.alpha) * prev
        )

    def _median(self) -> Optional[float]:
        vals = sorted(v for v in self.ewma.values() if v is not None)
        if not vals:
            return None
        return vals[len(vals) // 2]

    def assess(self) -> List[StragglerDecision]:
        med = self._median()
        if med is None or med <= 0:
            return []
        out = []
        for n, v in self.ewma.items():
            if v is None:
                continue
            ratio = v / med
            if ratio > self.threshold:
                self.strikes[n] += 1
                action = (
                    "quarantine" if self.strikes[n] >= self.patience else "observe"
                )
                out.append(StragglerDecision(n, action, ratio))
            else:
                self.strikes[n] = 0
        return out

    def drop(self, node_id: int) -> None:
        self.ewma.pop(node_id, None)
        self.strikes.pop(node_id, None)


def elastic_plan(
    n_alive: int, model_width: int, prefer_pods: int = 1
) -> Optional[Tuple[int, int, int]]:
    """Largest (pod, data, model) mesh using <= n_alive nodes.

    ``model_width`` is fixed by the parallelism plan (TP degree must match
    the checkpointed layout for cheap resharding; changing it is a restore-
    time re-shard, which the checkpoint format also supports).  Data-
    parallel width shrinks to the largest fit; pods collapse before DP.
    """
    if model_width <= 0 or n_alive < model_width:
        return None
    best = None
    best_used = -1
    for pods in range(prefer_pods, 0, -1):
        per_pod = n_alive // pods
        dp = per_pod // model_width
        used = pods * dp * model_width
        if dp >= 1 and used > best_used:
            best, best_used = (pods, dp, model_width), used
    return best

"""Training loop: jit'd step, async checkpoints, elastic restart.

The loop is deliberately small and policy-driven:

  make_train_step  — loss+grad+AdamW in one jit (donated carry, sharded via
                     the model's parameter specs when a mesh is present,
                     optional microbatch gradient accumulation).
  Trainer.run      — step loop with async snapshots every ``ckpt_every``,
                     straggler assessment hooks, and a failure callback.
  recover          — rebuild on a (possibly smaller) mesh from the latest
                     checkpoint; the deterministic data stream resumes from
                     the saved cursor, so the token stream is identical to
                     an uninterrupted run (asserted in tests).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.models.build import Model
from repro.optim import adamw
from repro.parallel.ctx import RunCtx
from repro.parallel.sharding import named_shardings

__all__ = ["TrainerConfig", "Trainer"]


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ga_steps: int = 1  # gradient-accumulation microbatches
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep_last: int = 2


def _shardings(mesh, spec_tree, struct_tree):
    if mesh is None:
        return None
    return named_shardings(spec_tree, struct_tree, mesh)


class Trainer:
    def __init__(
        self,
        model: Model,
        ctx: RunCtx,
        opt_cfg: adamw.AdamWConfig,
        tcfg: TrainerConfig,
    ):
        self.model = model
        self.ctx = ctx
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self._step_fn = None
        self._ckpt_handle: Optional[ckpt.AsyncHandle] = None

    # ------------------------------------------------------------------ #
    def init(self, key) -> Tuple[Any, Any]:
        params, specs = self.model.init(self.ctx, key)
        self.param_specs = specs
        self._params_struct = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params
        )
        if self.ctx.mesh is not None:
            shardings = _shardings(self.ctx.mesh, specs, self._params_struct)
            params = jax.tree.map(
                lambda x, s: jax.device_put(x, s), params, shardings
            )
        opt_state = adamw.init_state(params, self.opt_cfg)
        self._opt_struct = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), opt_state
        )
        return params, opt_state

    # ------------------------------------------------------------------ #
    def make_train_step(self) -> Callable:
        model, ctx, opt_cfg = self.model, self.ctx, self.opt_cfg
        ga = self.tcfg.ga_steps

        def loss_fn(params, batch):
            return model.train_loss(params, ctx, batch)

        def step(params, opt_state, batch):
            if ga > 1:
                def micro(carry, mb):
                    acc = carry
                    l, g = jax.value_and_grad(loss_fn)(params, mb)
                    return (
                        acc[0] + l / ga,
                        jax.tree.map(lambda a, b: a + b / ga, acc[1], g),
                    ), None

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                )
                mbs = jax.tree.map(
                    lambda x: x.reshape((ga, x.shape[0] // ga) + x.shape[1:]),
                    batch,
                )
                (loss, grads), _ = jax.lax.scan(
                    micro, (jnp.zeros((), jnp.float32), zeros), mbs
                )
            else:
                loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            params, opt_state, metrics = adamw.apply_updates(
                params, grads, opt_state, opt_cfg
            )
            metrics["loss"] = loss
            return params, opt_state, metrics

        if self.ctx.mesh is not None:
            pspec = _shardings(self.ctx.mesh, self.param_specs,
                               self._params_struct)
            ospec = _shardings(
                self.ctx.mesh, adamw.state_specs(self.param_specs),
                self._opt_struct,
            )
            self._step_fn = jax.jit(
                step,
                in_shardings=(pspec, ospec, None),
                out_shardings=(pspec, ospec, None),
                donate_argnums=(0, 1),
            )
        else:
            self._step_fn = jax.jit(step, donate_argnums=(0, 1))
        return self._step_fn

    # ------------------------------------------------------------------ #
    def save(self, step: int, params, opt_state, extra: Dict) -> None:
        if not self.tcfg.ckpt_dir:
            return
        if self._ckpt_handle is not None:
            self._ckpt_handle.wait()  # one write in flight at a time
        self._ckpt_handle = ckpt.save(
            self.tcfg.ckpt_dir, step,
            {"params": params, "opt": opt_state},
            extra={"data_step": extra.get("data_step", step), **extra},
        )
        ckpt.cleanup(self.tcfg.ckpt_dir, self.tcfg.keep_last)

    def recover(self, key) -> Tuple[Any, Any, int, Dict]:
        """Rebuild from the latest checkpoint onto the CURRENT ctx.mesh
        (which may be smaller than the one that wrote it — elastic)."""
        assert self.tcfg.ckpt_dir
        step = ckpt.latest_step(self.tcfg.ckpt_dir)
        if step is None:
            params, opt_state = self.init(key)
            return params, opt_state, 0, {}
        params, opt_state = self.init(key)  # structure + shardings
        shardings = None
        if self.ctx.mesh is not None:
            shardings = {
                "params": _shardings(self.ctx.mesh, self.param_specs,
                                     self._params_struct),
                "opt": _shardings(
                    self.ctx.mesh, adamw.state_specs(self.param_specs),
                    self._opt_struct,
                ),
            }
        tree, extra = ckpt.restore(
            self.tcfg.ckpt_dir, step,
            {"params": params, "opt": opt_state},
            sharding_tree=shardings,
        )
        return tree["params"], tree["opt"], step, extra

    # ------------------------------------------------------------------ #
    def run(
        self,
        params,
        opt_state,
        loader,
        start_step: int = 0,
        on_step: Optional[Callable[[int, Dict], None]] = None,
        failure_at: Optional[Callable[[int], bool]] = None,
    ) -> Tuple[Any, Any, list]:
        step_fn = self._step_fn or self.make_train_step()
        history = []
        t_prev = time.monotonic()
        for step in range(start_step, self.tcfg.steps):
            batch = next(loader)
            if failure_at is not None and failure_at(step):
                raise RuntimeError(f"injected node failure at step {step}")
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if step % self.tcfg.log_every == 0 or step == self.tcfg.steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step
                m["step_time_s"] = time.monotonic() - t_prev
                history.append(m)
                if on_step:
                    on_step(step, m)
            t_prev = time.monotonic()
            if self.tcfg.ckpt_every and (step + 1) % self.tcfg.ckpt_every == 0:
                self.save(step + 1, params, opt_state, {"data_step": loader.step})
        if self._ckpt_handle is not None:
            self._ckpt_handle.wait()
        return params, opt_state, history

"""Disaggregated serving over the GAS layer.

- :mod:`repro.serving.kv` — the KV-cache *data plane*: bit-transparent
  block flattening plus ``sched.plan_p2p``-planned segmented split-phase
  puts between prefill and decode nodes.
- :mod:`repro.serving.pool` — the **global paged KV pool**: fixed-size
  token pages in a PGAS segment sharded across the decode ranks, with a
  functional refcounted free-list allocator, per-request page tables,
  copy-on-write prefix sharing, and a ``sched.plan_p2p``-planned
  split-phase vectored page fetch (``Node.get_nbv``).
- :mod:`repro.serving.disagg` — the cluster: a prefill pool, a decode pool
  running continuous batching unchanged, and an Active-Message
  request/reply *control plane* (dispatch, install acks, completions).
  ``paged=True`` lands prefilled pages straight into the pool shards.
"""

from repro.serving.kv import (
    KVLayout,
    handoff_permutation,
    push_block,
    segment_bounds,
    sync_push,
)
from repro.serving.pool import (
    PagedKVStore,
    PagedLayout,
    PoolMap,
    fetch_pages,
    sync_fetch,
)

__all__ = [
    "KVLayout",
    "handoff_permutation",
    "push_block",
    "segment_bounds",
    "sync_push",
    "PagedKVStore",
    "PagedLayout",
    "PoolMap",
    "fetch_pages",
    "sync_fetch",
]

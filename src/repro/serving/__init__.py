"""Disaggregated serving over the GAS layer.

- :mod:`repro.serving.kv` — the KV-cache *data plane*: bit-transparent
  block flattening plus ``sched.plan_p2p``-planned segmented split-phase
  puts between prefill and decode nodes.
- :mod:`repro.serving.disagg` — the cluster: a prefill pool, a decode pool
  running continuous batching unchanged, and an Active-Message
  request/reply *control plane* (dispatch, install acks, completions).
"""

from repro.serving.kv import (
    KVLayout,
    handoff_permutation,
    push_block,
    segment_bounds,
    sync_push,
)

__all__ = [
    "KVLayout",
    "handoff_permutation",
    "push_block",
    "segment_bounds",
    "sync_push",
]

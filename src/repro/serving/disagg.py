"""Disaggregated prefill/decode serving over the GAS layer.

The cluster is one GASNet job over a ``node`` mesh axis
(``launch.mesh.serve_roles``): the first ``n_prefill`` ranks form the
prefill pool, the rest the decode pool, each pool optionally on its own
engine (``role_backends`` -> ``EngineMap`` — the paper's mixed
software/hardware cluster, serving-shaped).  Two planes:

- **Data plane** — a finished request's KV cache is flattened into one
  carrier block (:class:`~repro.serving.kv.KVLayout`), published in the
  prefill node's GASNet segment, and pushed into a staging slot of the
  decode node's segment with ``sched.plan_p2p``-planned segmented
  split-phase puts (:func:`~repro.serving.kv.push_block`).

  With ``paged=True`` the decode segments instead hold the **global
  paged KV pool** (:mod:`repro.serving.pool`): the prefill rank writes
  fixed-size token *pages* directly into the pool shard of the decode
  rank — one pred-gated put per page, landing at the exact page slots
  the (host-side, functional) allocator assigned, with NO dense staging
  copy in between.  Pages whose prompt-prefix chain is already resident
  on the target rank are not shipped at all: their puts trace with
  ``pred=False`` and the new request's page table simply maps the same
  physical pages (refcounted prefix sharing).
- **Control plane** — pure Active Messages: a ``kv_ready`` *request*
  (AMShort: request id, slot, origin) rides with the data; the decode
  node's handler records the slot in its inbox and returns an AMShort
  *reply* acknowledging installation, which resolves the prefill node's
  :class:`~repro.core.extended.AckHandle`; when decode finishes a request
  a ``req_done`` AM notifies the origin prefill rank (completion plane).
- **Tier plane** (``n_memory > 0``, paged only) — the paper's memory-node
  archetype: extra *memory* ranks export segment capacity but run no
  model compute, and the pool becomes a two-tier hierarchy.  Admission is
  lazy (only prompt pages materialise), so the pool oversubscribes; when
  a queued request cannot place, the SLO-aware scheduler
  (:mod:`repro.serving.scheduler`) preempts victims — pages swap OUT to a
  memory rank as one vectored put (``Node.put_nbv``: payloads + tier-slot
  offsets in one command block, ``repro.serving.tier.swap_out_pages``)
  and swap back IN at resume as one vectored get, both riding the same
  tick's SPMD transfer program; or, when the β model prices it cheaper,
  the victim recomputes (pages dropped, resume re-prefills and replays
  its generated tokens bit-identically).  Preempted requests resume
  bit-exactly: the restored pages are the carrier bytes that left.

Every tick the host launches the (jitted, perm-cached) SPMD transfer
program asynchronously, runs one continuous-batching decode step on every
decode server while the transfer is in flight, then consumes the
transfer's results — transfer/decode overlap in the split-phase style the
Extended API exists for.

All of this is single-process SPMD emulation (host devices as nodes),
exactly like the testing suites; the GAS programs are the same ones a
multi-host launch would run.
"""

from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.launch import mesh as mesh_lib
from repro.obs import export as obs_export
from repro.obs import health as health_lib
from repro.obs import trace as obs_trace
from repro.obs.metrics import Registry, counter_property
from repro.serving import kv as kv_lib


class DisaggCluster:
    """A role-based serving cluster: prefill pool + decode pool + AM
    control plane, all over one GAS context.

    ``prefill_backend`` / ``decode_backend`` name each pool's engine
    (mixing them yields an ``EngineMap``).  ``n_slots`` is the number of
    KV staging slots per decode node's segment (in paged mode: in-flight
    installs per rank — the data lands in pages); ``decode_batch`` the
    continuous-batching width of each decode server.

    ``paged=True`` replaces the dense staging slots with the global paged
    KV pool: each decode rank's segment is its pool shard
    (``pages_per_rank`` pages of ``page_tokens`` tokens), prefill ranks
    put pages straight into their allocator-assigned slots, and
    prompt-prefix-shared pages are mapped, not moved.

    Cluster statistics live on one typed
    :class:`~repro.obs.metrics.Registry` (``self.metrics``, shared with
    the admission scheduler and the memory tier): cumulative counts are
    Counters, point-in-time values are Gauges, and
    :meth:`reset_metrics` clears only the former.  Pass ``metrics`` to
    share a registry with a tracer so RMA byte accounting and cluster
    counters land in one place.
    """

    HEADER = 2  # carrier elems prepended to each block: first_token, pos

    # cumulative counters, registry-backed (explicit Counter kind); the
    # increment sites keep plain `self.x += 1` syntax via the proxy
    kv_transfers = counter_property("kv_transfers")
    kv_acked = counter_property("kv_acked")
    kv_pages_sent = counter_property("kv_pages_sent")
    kv_pages_shared = counter_property("kv_pages_shared")
    decoded_tokens = counter_property("decoded_tokens")
    dropped_am = counter_property("am_dropped")
    swap_out_bytes = counter_property("swap_out_bytes")
    swap_in_bytes = counter_property("swap_in_bytes")
    rank_failures = counter_property("rank_failures")
    recovered_recompute = counter_property("recovered_recompute")
    recovered_reroutes = counter_property("recovered_reroutes")
    elastic_joins = counter_property("elastic_joins")
    migrated_prefix_pages = counter_property("migrated_prefix_pages")

    def __init__(
        self,
        model: Any,
        ctx: Any,
        params: Any,
        *,
        n_prefill: int = 1,
        n_decode: int = 1,
        n_memory: int = 0,
        decode_batch: int = 4,
        cache_len: int = 64,
        n_slots: int = 2,
        prefill_backend: str = "xla",
        decode_backend: str = "xla",
        memory_backend: str = "xla",
        interpret: bool = True,
        node_axis: str = "node",
        eos_id: int = -1,
        costs: Optional[Dict[str, Any]] = None,
        paged: bool = False,
        page_tokens: int = 8,
        pages_per_rank: Optional[int] = None,
        mem_slots_per_rank: Optional[int] = None,
        decode_step_us: float = 2000.0,
        prefill_us: float = 4000.0,
        tp: int = 1,
        tp_backend: Optional[str] = None,
        heartbeat_timeout: int = 3,
        tier_replicas: int = 1,
        replicate_all_swaps: bool = False,
        n_spare: int = 0,
        metrics: Optional[Registry] = None,
        flight_ticks: int = 64,
    ):
        import jax
        import jax.numpy as jnp

        from repro.core import am, gasnet, sched
        from repro.compat import shard_map
        from repro.launch.serve import (
            PooledDecodeServer, Server, TPPooledDecodeServer,
        )
        from repro.runtime.ft import HeartbeatMonitor
        from repro.serving import pool as pool_lib
        from repro.serving import scheduler as sched_lib
        from repro.serving import tier as tier_lib

        if n_memory and not paged:
            raise ValueError("memory ranks require paged=True (page swap)")
        if n_spare and not paged:
            raise ValueError("spare ranks require paged=True (elastic join)")
        if tp > 1:
            if not paged:
                raise ValueError(
                    "tp > 1 requires paged=True (the TP group shards the "
                    "page pool by heads)"
                )
            if n_memory:
                raise ValueError(
                    "TP decode groups not yet composed with memory tiering"
                )

        self.jax, self.jnp = jax, jnp
        self.gasnet = gasnet
        self.shard_map = shard_map
        # the typed registry every counter/gauge below lives on — created
        # before any counter_property assignment runs
        self.metrics = metrics if metrics is not None else Registry()
        self.flight_ticks = flight_ticks
        self.flight_dumps: List[Dict[str, Any]] = []
        self.model, self.ctx, self.params = model, ctx, params
        self.n_prefill, self.n_decode = n_prefill, n_decode
        self.n_memory = n_memory
        self.n_spare = n_spare
        self.n = n_prefill + n_decode + n_memory + n_spare
        self._memory_base = n_prefill + n_decode
        self.cache_len = cache_len
        self.n_slots = n_slots
        self.node_axis = node_axis
        self.max_done = decode_batch
        self.costs = costs
        self.paged = paged
        self.tp = tp
        self.tp_backend = tp_backend or decode_backend
        self.n_groups = n_decode // tp if tp else n_decode
        self._interpret = interpret
        self._decode_batch = decode_batch
        self._eos_id = eos_id

        self.roles = mesh_lib.serve_roles(
            n_prefill, n_decode, n_memory, tp=tp, n_spare=n_spare
        )
        backends = mesh_lib.role_backends(
            self.roles, prefill=prefill_backend, decode=decode_backend,
            memory=memory_backend,
        )
        self._backends = backends
        # decode-group leader ranks, extensible: an elastic join appends a
        # promoted spare here, so every "decode rank of group g" lookup
        # stays a table read and survives membership changes
        self.group_leaders = [n_prefill + g * tp for g in range(self.n_groups)]
        self.mesh = mesh_lib.make_mesh((self.n,), (node_axis,))
        self.gas = gasnet.Context(
            self.mesh,
            node_axis=node_axis,
            backend=backends,
            interpret=interpret,
            am_capacity=self.max_done + 4,
            am_payload_width=1,
        )

        # ---- KV layout (static: shapes depend only on cache_len) --------
        if paged:
            # page-granular pool shards: one per decode rank's segment
            self.playout = pool_lib.PagedLayout.from_struct(
                model.kv_block_struct(ctx, prompt_len=4, cache_len=cache_len),
                cache_len=cache_len,
                page_tokens=page_tokens,
            )
            self.pages_per_rank = pages_per_rank or (
                (decode_batch + n_slots) * self.playout.n_pages
            )
            self.block_elems = self.playout.n_pages * self.playout.page_elems
            self.block_bytes = self.block_elems * 4
            # TP groups stripe every page BY HEADS across the group's
            # member segments: each member's pool partition holds the
            # shard-layout slice of all pages (tp=1: the full layout, an
            # identity stripe — one unified code path).
            self.shard_layout, self.shard_cols = self.playout.shard_heads(
                tp, model.cfg.n_kv_heads
            )
            self.seg_elems = self.pages_per_rank * self.shard_layout.page_elems
            # per-PAGE put plan: each page (shard slice) is its own
            # planned transfer
            self.plan = sched.plan_p2p(
                nbytes=self.shard_layout.page_bytes,
                engine=self.gas.make_engine(),
                costs=costs,
            )
            self.stores = [
                pool_lib.PagedKVStore(self.shard_layout, self.pages_per_rank)
                for _ in range(self.n_groups)
            ]
            # ---- tiered KV memory: memory-only ranks + preemption ------
            self.max_swap = self.playout.n_pages  # one request per tick
            # one request's pages per vectored swap/fetch transfer; built
            # even without memory ranks — the elastic-join prefix
            # migration rides the same vectored-get plane
            self.swap_plan = sched.plan_p2p(
                nbytes=self.max_swap * self.playout.page_bytes,
                engine=self.gas.make_engine(),
                costs=costs,
            )
            if n_memory:
                self.mem_slots = mem_slots_per_rank or (
                    2 * decode_batch * self.playout.n_pages
                )
                self.tier = tier_lib.MemoryTier(
                    n_memory, self.mem_slots, self.playout.page_elems,
                    replicas=max(1, min(tier_replicas, n_memory)),
                    registry=self.metrics,
                )
                self.seg_elems = max(
                    self.seg_elems, self.mem_slots * self.playout.page_elems
                )
            else:
                self.tier = None
            self.scheduler = sched_lib.AdmissionScheduler(
                page_bytes=self.playout.page_bytes, costs=costs,
                decode_step_us=decode_step_us, prefill_us=prefill_us,
                registry=self.metrics,
            )
            # live SLO monitor on the tick clock: tracked per submit,
            # ticked per cluster tick; its backpressure floor makes the
            # scheduler defer below-floor admissions while a deadline is
            # at risk.  Inert until a request carries finite deadlines.
            self.health = health_lib.HealthMonitor(registry=self.metrics)
            self.scheduler.attach_health(self.health)
        else:
            self.layout = kv_lib.KVLayout.from_struct(
                model.kv_block_struct(ctx, prompt_len=4, cache_len=cache_len)
            )
            self.block_elems = self.layout.total + self.HEADER
            self.block_bytes = self.block_elems * 4
            self.seg_elems = self.n_slots * self.block_elems
            self.plan = sched.plan_p2p(
                nbytes=self.block_bytes,
                engine=self.gas.make_engine(),
                costs=costs,
            )
            self.tier = None
            self.swap_plan = None
            self.scheduler = None
            self.health = None
            self.max_swap = 1

        # ---- AM control plane ------------------------------------------
        handlers = self.gas.handlers

        def kv_ack(state, payload, args):
            del payload
            out = dict(state)
            out["acks"] = state["acks"].at[args[1]].set(args[0] + 1)
            return out

        ack_id = handlers.register("kv_ack", kv_ack)

        def kv_ready(state, payload, args):
            rid, slot, origin = args[0], args[1], args[2]
            row = jnp.stack([jnp.ones((), jnp.int32), rid, origin])
            out = dict(state)
            out["inbox"] = state["inbox"].at[slot].set(row)
            return out, am.reply_short(ack_id, args=(rid, slot), like=payload)

        handlers.register("kv_ready", kv_ready, replies=True)

        def req_done(state, payload, args):
            del payload, args
            out = dict(state)
            out["done"] = state["done"] + 1
            return out

        handlers.register("req_done", req_done)

        # ---- device-side cluster state (host-managed between ticks) ----
        self.kvseg = np.zeros((self.n, self.seg_elems), np.float32)
        self.inbox = np.zeros((self.n, n_slots, 3), np.int32)
        self.acks = np.zeros((self.n, n_slots), np.int32)
        self.done = np.zeros((self.n, 1), np.int32)
        # live views of each group member's pool-partition mirror, re-bound
        # in place after every transfer consume (entry 0 = the leader's,
        # aliased as store.mem)
        self.shard_mems: List[List[Optional[np.ndarray]]] = [
            [None] * tp for _ in range(self.n_groups)
        ]
        if paged:
            self._alias_store_mem()

        # ---- pools ------------------------------------------------------
        # paged clusters decode THROUGH the page table — the same single
        # decode path (Model.decode_step_paged) as the colocated
        # PagedServer; the dense Server survives only as the oracle for
        # the unpaged (paged=False) handoff.
        if paged and tp > 1:
            from jax.sharding import Mesh

            devices = jax.devices()
            self.decode_servers = [
                TPPooledDecodeServer(
                    model, ctx, params, decode_batch, cache_len,
                    store=self.stores[g], shard_mems=self.shard_mems[g],
                    tp=tp, tp_backend=self.tp_backend,
                    tp_mesh=Mesh(
                        np.array(devices[
                            self.decode_rank(g): self.decode_rank(g) + tp
                        ]),
                        ("tp",),
                    ),
                    costs=costs, eos_id=eos_id,
                    on_page_shortage=(
                        lambda rid, need, g=g:
                        self._decode_shortage(g, rid, need)
                    ),
                )
                for g in range(self.n_groups)
            ]
        elif paged:
            self.decode_servers = [
                PooledDecodeServer(
                    model, ctx, params, decode_batch, cache_len,
                    store=self.stores[d], eos_id=eos_id,
                    on_page_shortage=(
                        lambda rid, need, d=d:
                        self._decode_shortage(d, rid, need)
                    ),
                )
                for d in range(n_decode)
            ]
        else:
            self.decode_servers = [
                Server(model, ctx, params, decode_batch, cache_len,
                       eos_id=eos_id)
                for _ in range(n_decode)
            ]
        for d, srv in enumerate(self.decode_servers):
            srv.trace_rank = self.decode_rank(d)
        self._prefill_fn = jax.jit(
            lambda p, b: model.prefill(p, ctx, b, cache_len=cache_len)
        )

        # ---- host scheduler state --------------------------------------
        self.queue: List[Any] = []
        self.by_rid: Dict[int, Any] = {}
        self.finished: List[Any] = []
        # one in-flight push per prefill worker: (request, pool, slot, block)
        self.pending_push: List[Optional[Tuple]] = [None] * n_prefill
        self.staged: List[Dict[int, int]] = [
            dict() for _ in range(self.n_groups)
        ]
        self._done_queue: List[Tuple[int, int, int]] = []  # (g, rid+1, origin)
        self._finished_seen = [0] * self.n_groups
        self._rr_decode = 0
        self._transfer_fns: Dict[Tuple, Any] = {}
        self.kv_transfers = 0
        self.kv_acked = 0
        self.kv_pages_sent = 0
        self.kv_pages_shared = 0
        self.decoded_tokens = 0
        self.dropped_am = 0
        # ---- tiered-memory scheduler state -----------------------------
        # rid -> preemption snapshot (mode, decode pos, last token, pages)
        self._preempted: Dict[int, Dict[str, Any]] = {}
        # staged swap-outs: (rid, d, src_offsets, legs) — legs is a tuple
        # of (memory rank, dst_offsets), one vectored put per replica leg
        self._swap_jobs: List[Tuple] = []
        # staged swap-ins: (rid, d, remote_offsets, local_offsets,
        # src_rank) — src is a memory rank for tier resumes, a donor
        # decode leader for elastic-join prefix migration (rid == -1)
        self._fetch_jobs: List[Tuple] = []
        self._inflight_swap: Optional[Tuple] = None
        self._inflight_fetch: Optional[Tuple] = None
        # rid -> decode pool index whose shard holds the restored pages,
        # waiting for a free decode row
        self._installable: Dict[int, int] = {}
        self.swap_out_bytes = 0
        self.swap_in_bytes = 0
        # ---- fault tolerance + elasticity ------------------------------
        # membership is tick-clocked: every live rank "beats" once per
        # tick (in a multi-host launch the beat would be an AM), and the
        # monitor declares a rank dead after ``heartbeat_timeout`` missed
        # ticks — detection within K ticks by construction.
        self._tick_no = 0
        self.monitor = HeartbeatMonitor(
            list(range(self.n)),
            timeout_s=float(heartbeat_timeout),
            clock=lambda: float(self._tick_no),
        )
        self.killed: set = set()       # fault injection: ranks to stop beating
        self.dead_ranks: set = set()   # monitor-declared failures
        self.dead_groups: set = set()  # decode groups with a dead member
        self.fault_hook = None         # callable(cluster, phase, tick)
        self.beat_filter = None        # callable(rank, tick) -> bool
        self.replicate_all_swaps = replicate_all_swaps
        self.max_replicas = self.tier.replicas if self.tier is not None else 1
        self.rank_failures = 0
        self.recovered_recompute = 0
        self.recovered_reroutes = 0
        self.elastic_joins = 0
        self.migrated_prefix_pages = 0
        # in-flight prefix-index migration to a freshly joined group:
        # {"donor": g, "n": pages} until its vectored get lands
        self._pending_migration: Optional[Dict[str, int]] = None

    # ------------------------------------------------------------------ #
    # role views
    # ------------------------------------------------------------------ #
    def decode_rank(self, d: int) -> int:
        """Rank of decode GROUP ``d``'s leader (= its only member at
        tp=1): the rank whose pool partition backs the group's store and
        which receives the group's control-plane AMs."""
        return self.group_leaders[d]

    def member_rank(self, g: int, s: int) -> int:
        """Rank of member ``s`` of decode group ``g`` (its head shard)."""
        return self.group_leaders[g] + s

    def memory_rank(self, m: int) -> int:
        return self._memory_base + m

    def _group_down(self, g: int) -> bool:
        """True when any member rank of decode group ``g`` is killed or
        declared dead — a TP group fails as a unit."""
        if g in self.dead_groups:
            return True
        return any(
            self.member_rank(g, s) in self.killed
            or self.member_rank(g, s) in self.dead_ranks
            for s in range(self.tp)
        )

    def _alias_store_mem(self) -> None:
        """Point each decode store's physical page array at its group
        leader's partition of the (freshly consumed) pool segment — the
        host mirror of the PGAS shard — and re-bind every group member's
        shard mirror (``shard_mems``) in place.  Pages arrive over the
        wire (admission puts, swap-in gets) AND from the paged decode
        step, which writes each tick's token page in place; decode writes
        made while a transfer was in flight are replayed onto the fresh
        mirror by :meth:`_apply_decode_writes`."""
        pool_elems = self.pages_per_rank * self.shard_layout.page_elems
        for g, store in enumerate(self.stores):
            views = [
                self.kvseg[self.member_rank(g, s)][:pool_elems].reshape(
                    self.pages_per_rank, self.shard_layout.page_elems
                )
                for s in range(self.tp)
            ]
            store.mem = views[0]
            for s in range(self.tp):
                self.shard_mems[g][s] = views[s]

    # ------------------------------------------------------------------ #
    # request intake
    # ------------------------------------------------------------------ #
    def submit(self, req: Any) -> None:
        req.t_enqueue = time.monotonic()
        tr = obs_trace.active()
        if tr.enabled:
            tr.instant(
                "req_submit", cat="req", rid=req.rid,
                prompt_len=len(req.prompt),
            )
        self.queue.append(req)
        self.by_rid[req.rid] = req
        if self.scheduler is not None:
            from repro.serving.scheduler import SLO

            self.scheduler.submit(
                req.rid, getattr(req, "slo", None) or SLO(),
                prompt_len=len(req.prompt), now=req.t_enqueue,
            )
            if self.health is not None:
                self.health.track(
                    req.rid, getattr(req, "slo", None) or SLO(),
                    req.t_enqueue,
                )

    # ------------------------------------------------------------------ #
    # SPMD transfer program (data plane + control plane, one launch)
    # ------------------------------------------------------------------ #
    def _transfer_fn(
        self,
        perm: Tuple[int, ...],
        perm_swap: Optional[Tuple[Tuple[int, ...], ...]] = None,
        perm_fetch: Optional[Tuple[int, ...]] = None,
    ) -> Any:
        key = (perm, perm_swap, perm_fetch)
        cached = self._transfer_fns.get(key)
        if cached is not None:
            return cached
        jax = self.jax
        gasnet = self.gasnet
        from jax.sharding import PartitionSpec as P

        from repro.serving import pool as pool_lib
        from repro.serving import tier as tier_lib

        spec = P(self.node_axis)
        block = self.block_elems

        def data_plane_dense(node, kvseg, outflat, meta):
            has = meta[0, 0] > 0
            slot = meta[0, 2]
            handles, _ = kv_lib.push_block(
                node,
                kvseg,
                outflat[0],
                to=gasnet.Perm(perm),
                base_index=slot * block,
                pred=has,
                plan=self.plan,
            )
            return handles

        def data_plane_paged(node, kvseg, outflat, meta, page_meta):
            # one pred-gated put per page PER HEAD SHARD, each shard's
            # slice landing at the allocator's slot of its group member's
            # segment (page_meta[j] = flat pool offset, send flag — the
            # same offset on every member: the partitions are congruent);
            # prefix-shared pages trace with pred=False and ship nothing.
            # ``perm`` is a tuple of per-shard permutations (length tp).
            has = meta[0, 0] > 0
            handles = []
            for s, pm in enumerate(perm):
                for j in range(self.playout.n_pages):
                    hs, _ = kv_lib.push_block(
                        node,
                        kvseg,
                        outflat[0, s, j],
                        to=gasnet.Perm(pm),
                        base_index=page_meta[0, j, 0],
                        pred=has & (page_meta[0, j, 1] > 0),
                        plan=self.plan,
                    )
                    handles.extend(hs)
            return handles

        def body(kvseg, inbox, acks, done, outflat, meta, page_meta,
                 done_meta, swap_meta, fetch_meta):
            node = self.gas.make_node()
            has = meta[0, 0] > 0
            rid, slot, dst = meta[0, 1], meta[0, 2], meta[0, 3]
            # data plane: planned segmented split-phase puts
            if self.paged:
                handles = data_plane_paged(node, kvseg, outflat, meta, page_meta)
            else:
                handles = data_plane_dense(node, kvseg, outflat, meta)
            # tier plane: swap-out rides the vectored put (victim pages +
            # tier slot offsets in one command block), swap-in the
            # vectored get — both split-phase, in flight alongside the
            # admission puts and the AM control plane.  Replication fans
            # the SAME victim pages once per placement leg: one vectored
            # put per replica, each to its own memory rank (perm_swap is
            # a tuple of per-leg permutations; unused legs gate off via
            # zero flags).
            swap_handles = []
            fetch_handles = None
            if perm_swap is not None:
                for li, pm in enumerate(perm_swap):
                    hs, _ = tier_lib.swap_out_pages(
                        node, kvseg,
                        swap_meta[0, li, :, 0], swap_meta[0, li, :, 1],
                        to=gasnet.Perm(pm),
                        page_elems=self.playout.page_elems,
                        flags=swap_meta[0, li, :, 2],
                        plan=self.swap_plan,
                    )
                    swap_handles.extend(hs)
            if perm_fetch is not None:
                # in-step page prefetch: the pool's split-phase vectored
                # fetch (plan-batched get_nbv) is issued HERE and drained
                # only after the puts and control plane below — and the
                # host overlaps one whole paged decode step before
                # consuming this program, so the wire hides behind the
                # decode compute.
                fetch_handles, _ = pool_lib.fetch_pages(
                    node,
                    kvseg,
                    fetch_meta[0, :, 0],
                    frm=gasnet.Perm(perm_fetch),
                    page_elems=self.playout.page_elems,
                    plan=self.swap_plan,
                    pred=fetch_meta[0, :, 2].max() > 0,
                )
            # control plane rides while the puts are in flight
            ackh = node.am_call(
                dst,
                "kv_ready",
                args=(rid, slot, node.my_id),
                pred=has,
                ack=lambda st: st["acks"],
            )
            for j in range(self.max_done):
                node.am_short(
                    done_meta[0, j, 1],
                    "req_done",
                    args=(done_meta[0, j, 0],),
                    pred=done_meta[0, j, 0] > 0,
                )
            kvseg = kv_lib.sync_push(node, kvseg, handles)
            for h in swap_handles:
                kvseg = node.sync(h)
            if fetch_handles is not None:
                fetched = pool_lib.sync_fetch(node, fetch_handles)
                kvseg = tier_lib.install_pages(
                    node, kvseg, fetched,
                    fetch_meta[0, :, 1], fetch_meta[0, :, 2],
                )
            state = {"inbox": inbox[0], "acks": acks[0], "done": done[0]}
            state = node.am_flush(state)
            acked = node.sync(ackh)
            return (
                kvseg,
                state["inbox"][None],
                acked[None],
                state["done"][None],
                node.dropped[None],
            )

        fn = jax.jit(
            self.shard_map(
                body,
                mesh=self.mesh,
                in_specs=(spec,) * 10,
                out_specs=(spec,) * 5,
                check_vma=False,
            )
        )
        self._transfer_fns[key] = fn
        return fn

    # ------------------------------------------------------------------ #
    # host scheduler
    # ------------------------------------------------------------------ #
    def _pick_target(
        self, taken: set, prompt: Optional[Any] = None
    ) -> Optional[Tuple[int, int]]:
        """(decode pool index, staging slot) with capacity, round-robin.

        Paged mode adds two rules: the target rank must hold enough free
        pool pages for a worst-case (unshared) admission, and ranks are
        tried in order of *prefix affinity* — the rank whose pool already
        holds the longest leading run of the prompt's pages wins, so the
        shared pages are mapped instead of moved."""
        order = [
            d
            for d in (
                (self._rr_decode + i) % self.n_groups
                for i in range(self.n_groups)
            )
            if not self._group_down(d)
        ]
        if self.paged and prompt is not None:
            matches = {d: self.stores[d].prefix_match(prompt) for d in order}
            best = max(matches.values())
            if best > 0:
                # hard affinity: only ranks holding the longest resident
                # prefix qualify — admitting elsewhere would re-ship pages
                # that already exist.  If they are busy this tick the
                # request waits one tick (head-of-line, bounded: slots and
                # pages free as decodes finish).
                order = [d for d in order if matches[d] == best]
        for d in order:
            if d in taken:
                continue
            if self.paged:
                need = (
                    self.playout.pages_for(len(prompt))
                    if prompt is not None
                    else self.playout.n_pages
                )
                if self.stores[d].n_free < need:
                    continue
            for slot in range(self.n_slots):
                if slot not in self.staged[d]:
                    self._rr_decode = (d + 1) % self.n_groups
                    return d, slot
        return None

    def _admission_queue(self) -> List[Any]:
        """The submit queue in scheduler order (priority-major, EDF within
        a priority) when paged; FIFO otherwise."""
        if not (self.paged and self.scheduler is not None):
            return list(self.queue)
        pos = {
            rid: i for i, rid in enumerate(self.scheduler.admission_order())
        }
        return sorted(
            self.queue, key=lambda r: pos.get(r.rid, len(pos) + r.rid)
        )

    def _run_prefills(self) -> None:
        """Assign queued requests to idle prefill workers (host compute)."""
        taken = {push[1] for push in self.pending_push if push is not None}
        order = self._admission_queue()
        for p in range(self.n_prefill):
            if p in self.killed or p in self.dead_ranks:
                continue  # dead prefill workers take no new requests
            if self.pending_push[p] is not None or not order:
                continue
            req = order[0]
            target = self._pick_target(taken, prompt=req.prompt)
            if target is None:
                # oversubscribed: try to preempt for the head-of-order
                # request (tiered clusters only)
                if self.paged and self.tier is not None:
                    self._try_preempt_for(req)
                return
            d, slot = target
            order.pop(0)
            self.queue.remove(req)
            jnp = self.jnp
            tr = obs_trace.active()
            with tr.span(
                "prefill", cat="req", rank=p, rid=req.rid,
                prompt_len=len(req.prompt), group=d,
            ):
                toks = jnp.asarray(req.prompt, jnp.int32)[None]
                logits, caches_one = self._prefill_fn(
                    self.params, {"inputs": toks}
                )
                tok = int(np.argmax(np.asarray(logits)[0]))
            if not req.out:  # a recompute-resume already holds its tokens
                req.out.append(tok)
                req.t_first = time.monotonic()
                if tr.enabled:
                    tr.instant(
                        "req_first_token", cat="req", rank=p, rid=req.rid
                    )
                if self.health is not None:
                    self.health.first_token(req.rid, req.t_first)
            if self.paged:
                # the pool's allocator assigns the pages NOW (host control
                # plane); the page payloads go one-sided into those exact
                # slots of the decode rank's pool shard — no staging copy,
                # and prefix-shared pages ship nothing at all.  Lazy: only
                # prompt pages materialise, so the pool oversubscribes.
                pages = np.asarray(self.playout.flatten(caches_one))
                # pre-stripe each page by heads for the group members:
                # (tp, n_pages, shard_page_elems); tp=1 is the identity
                # stripe (one unified path)
                shards = pages[:, self.shard_cols].transpose(1, 0, 2)
                plan = self.stores[d].plan_admit(req.prompt, lazy=True)
                self.stores[d].commit(req.rid, plan)
                self.pending_push[p] = (req, d, slot, shards, plan)
            else:
                header = np.asarray([tok, len(req.prompt)], np.int32).view(np.float32)
                flat = np.concatenate(
                    [header, np.asarray(self.layout.flatten(caches_one))]
                )
                self.pending_push[p] = (req, d, slot, flat, None)
            self.staged[d][slot] = req.rid
            taken.add(d)

    # ------------------------------------------------------------------ #
    # tiered memory: preemption, swap staging, resume
    # ------------------------------------------------------------------ #
    def _freeable(self, d: int, rid: int) -> int:
        return self.stores[d].freeable(rid)

    def _try_preempt_for(self, req: Any) -> None:
        """Head-of-order request found no rank with pages: preempt victims
        on the rank that can reclaim enough.  Strictly-lower-priority
        victims always qualify; equal-priority victims only once the
        beneficiary's TTFT deadline has expired (SLO pressure)."""
        from repro.serving.scheduler import SLO

        if self._swap_jobs or self._inflight_swap is not None:
            return  # one staged swap-out at a time
        need = self.playout.pages_for(len(req.prompt))
        slo = getattr(req, "slo", None) or SLO()
        expired = time.monotonic() > req.t_enqueue + slo.ttft_deadline_s
        for d in range(self.n_groups):
            if self._group_down(d):
                continue
            shortage = need - self.stores[d].n_free
            if shortage <= 0:
                continue  # pages are not this rank's blocker (slots are)
            if all(s in self.staged[d] for s in range(self.n_slots)):
                continue  # no staging slot: freeing pages would not help
            running = [
                r.rid for r in self.decode_servers[d].active if r is not None
            ]
            victims = self.scheduler.pick_victims(
                running,
                shortage,
                lambda rid, d=d: self._freeable(d, rid),
                beneficiary=req.rid,
                strict=not expired,
            )
            if victims:
                for rid in victims:
                    self._preempt(d, rid)
                return

    def _preempt(self, d: int, rid: int) -> None:
        """Evict one running request from decode rank ``d``: swap its
        pages to a memory rank (vectored-put job staged for this tick's
        transfer) or drop them for recompute-replay, per the β cost
        model."""
        from repro.serving import pool as pool_lib
        from repro.serving import tier as tier_lib

        server = self.decode_servers[d]
        store = self.stores[d]
        i = next(
            ix for ix, r in enumerate(server.active)
            if r is not None and r.rid == rid
        )
        req = server.active[i]
        pos = int(server.positions[i])
        last = int(server.last_token[i, 0])
        n_mat = self.playout.pages_for(pos)
        self.scheduler.entry(rid).generated = max(0, len(req.out) - 1)
        mode, swap_us, recompute_us = self.scheduler.choose_mode(rid, n_mat)
        tr = obs_trace.active()
        if tr.enabled:
            tr.instant(
                "req_preempt", cat="req", rank=self.decode_rank(d),
                rid=rid, mode=mode, n_pages=n_mat,
                swap_est_us=round(swap_us, 1),
                recompute_est_us=round(recompute_us, 1),
            )
        hold = None
        if mode == "swap":
            # replication policy: hot (prefix-shared) pages get every
            # tier replica — losing them would take several requests'
            # prefixes down at once; cold private pages default to one
            # leg unless the cluster opts everything in.
            want = 1
            if self.tier.replicas > 1 and (
                self.replicate_all_swaps or store.shared_page_count(rid) > 0
            ):
                want = self.tier.replicas
            try:
                store.materialize_through(rid, n_mat)
                hold = self.tier.plan_swap_out(
                    rid, list(range(n_mat)), replicas=want
                )
            except (pool_lib.OutOfPagesError, tier_lib.OutOfSlotsError):
                mode = "recompute"  # no room to stage: drop and replay
        if mode == "swap":
            # the pool shard IS the victim's current state: the paged
            # decode step writes every generated token's page in place,
            # and prompt pages are written once at admission (prefix
            # sharers included) — so unlike the old dense decode rows
            # there is nothing to stage; the swap-out job just ships the
            # victim's resident pages as they sit in the mirror, fanned
            # once per placement leg (ONE put_nbv per replica).
            table = store.page_table(rid)
            src = [table[lp] * self.playout.page_elems for lp in range(n_mat)]
            legs = tuple(
                (
                    self.memory_rank(pl.rank),
                    [self.tier.slot_offset(pl.rank, s) for s in pl.slots],
                )
                for pl in hold.placements
            )
            self._swap_jobs.append((rid, d, src, legs))
            self.swap_out_bytes += (
                n_mat * self.playout.page_bytes * len(legs)
            )
        else:
            store.evict_request(rid)
            self.queue.append(req)  # resume = re-prefill + replay
        replay = list(server.replaying.get(i, []))
        server.evict_row(i)
        self._preempted[rid] = {
            "mode": mode,
            "position": pos,
            "last_token": last,
            "n_mat": n_mat,
            "swapped": False,
            # a victim caught mid-replay resumes with its replay tail
            "replay": replay,
        }
        self.scheduler.on_preempted(rid, mode)

    def _decode_shortage(self, d: int, rid: int, need: int) -> bool:
        """A decode row's lazy page growth found rank ``d``'s pool shard
        dry mid-tick (tiered clusters oversubscribe): preempt victims for
        the growing row.  Returns False when no pages freed up *this
        tick* — a swap-mode victim's pages are released only once its
        vectored put lands on the memory rank — in which case the row
        stalls one tick and retries (see PooledDecodeServer.step)."""
        if self.scheduler is None:
            return False
        store = self.stores[d]
        # pages are already on their way: a staged/in-flight swap-out from
        # this rank frees its victim's pages when the vectored put lands —
        # stall instead of preempting MORE (else two residents ping-pong
        # through swap/resume without ever decoding)
        if any(job[1] == d for job in self._swap_jobs) or (
            self._inflight_swap is not None and self._inflight_swap[1] == d
        ):
            return False
        running = [
            r.rid for r in self.decode_servers[d].active
            if r is not None and r.rid != rid
        ]
        victims = self.scheduler.pick_victims(
            running, need - store.n_free,
            lambda v, d=d: self._freeable(d, v),
            beneficiary=rid, strict=False,
        )
        # no eligible victim and no landing pending: the growing row
        # preempts itself so its pages can serve whoever CAN progress
        for v in (victims or [rid]):
            self._preempt(d, v)
        return store.n_free >= need

    def _apply_decode_writes(self) -> None:
        """Replay this tick's decode-written pages onto the pool mirror.

        The decode step overlaps an in-flight transfer program whose
        consumed result REPLACES the whole segment the stores alias, so
        page writes made during the overlap must land again on the fresh
        mirror.  Transfer targets are disjoint from decode write pages by
        construction: admission puts and swap-in installs land only in
        freshly allocated (hence non-free, non-writable) pages, and
        swap-out destinations live on memory ranks."""
        if not self.paged:
            return
        for g, server in enumerate(self.decode_servers):
            if self._group_down(g):
                continue
            for pp, row in server.drain_dirty().items():
                if self.tp > 1:
                    # stacked (tp, shard_elems) rows: one slice per member
                    for s in range(self.tp):
                        self.shard_mems[g][s][pp] = row[s]
                else:
                    self.stores[g].mem[pp] = row

    def _run_resumes(self) -> None:
        """Stage swap-ins: a preempted-by-swap request whose pages sit in
        the tier resumes onto the decode rank with room — one vectored-get
        job per tick; the fetched pages install into a decode row once the
        transfer lands."""
        if not (self.paged and self.tier is not None):
            return
        if self._fetch_jobs or self._inflight_fetch is not None:
            return
        for rid in self.scheduler.admission_order():
            snap = self._preempted.get(rid)
            if (
                snap is None
                or snap["mode"] != "swap"
                or not snap["swapped"]
                or snap.get("staged")
                or rid in self._installable
            ):
                continue
            hold = self.tier.holdings[rid]
            # quorum restore: read from the first placement leg whose
            # memory rank is still alive — with a replica surviving, a
            # dead primary is invisible to the resume path
            pl = self.tier.restore_placement(rid)
            # growth headroom: when the resume position opens a FRESH page
            # (position on a page boundary), the first decode tick after
            # install needs one page beyond the restored set — resuming
            # without it would bounce straight back out
            need = len(hold.logical)
            if snap["position"] % self.playout.page_tokens == 0:
                need += 1
            best = None
            for d in range(self.n_groups):
                if self._group_down(d):
                    continue
                if self.stores[d].n_free >= need:
                    best = d
                    break
            if best is None:
                continue
            phys = self.stores[best].admit_resume(rid, hold.logical)
            remote = [self.tier.slot_offset(pl.rank, s) for s in pl.slots]
            local = [pp * self.playout.page_elems for pp in phys]
            self._fetch_jobs.append(
                (rid, best, remote, local, self.memory_rank(pl.rank))
            )
            snap["staged"] = True
            return

    def _install_resumed(self) -> None:
        """Bind restored requests to free decode rows: the swapped pages
        landed back in the pool shard at their new table slots, so the
        row resumes decoding through the page table exactly at the
        preempted position (bit-identical continuation)."""
        for rid, d in list(self._installable.items()):
            server = self.decode_servers[d]
            snap = self._preempted[rid]
            req = self.by_rid[rid]
            ok = server.admit_paged(
                req,
                first_token=snap["last_token"],
                position=snap["position"],
            )
            if not ok:
                continue  # no free row yet; pages stay resident
            if snap.get("replay"):
                row = next(
                    ix for ix, r in enumerate(server.active)
                    if r is not None and r.rid == rid
                )
                server.start_replay(row, snap["replay"])
            # a memory-rank failure may have scrubbed the holding after
            # the pages landed (they are already safe in the pool shard)
            if rid in self.tier.holdings:
                self.tier.release(rid)
            for s in self.stores:
                s.note_swap_in(rid)
            del self._installable[rid]
            del self._preempted[rid]
            self.scheduler.on_admitted(rid, time.monotonic())
            tr = obs_trace.active()
            if tr.enabled:
                tr.instant(
                    "req_resume", cat="req", rank=self.decode_rank(d),
                    rid=rid, position=snap["position"],
                )

    def _launch_transfer(self) -> Optional[Tuple[Any, ...]]:
        """Build this tick's transfer inputs and dispatch the SPMD program
        (asynchronously — the caller overlaps decode before consuming)."""
        pushes = [
            (p, push)
            for p, push in enumerate(self.pending_push)
            if push is not None
        ]
        if (
            not pushes
            and not self._done_queue
            and not self._swap_jobs
            and not self._fetch_jobs
        ):
            return None
        if self.paged:
            # one handoff permutation per head shard: prefill rank p's
            # shard-s slice goes to member s of its target group (at tp=1
            # a 1-tuple of the classic leader permutation)
            perm = tuple(
                kv_lib.handoff_permutation(
                    self.n,
                    {p: self.member_rank(d, s) for p, (_, d, _, _, _) in pushes},
                )
                for s in range(self.tp)
            )
        else:
            edges = {p: self.decode_rank(d) for p, (_, d, _, _, _) in pushes}
            perm = kv_lib.handoff_permutation(self.n, edges)
        # tier plane: at most one swap-out and one swap-in job per tick,
        # each its own completed bijection (decode rank -> memory rank);
        # a replicated swap-out fans one bijection per placement leg
        perm_swap = perm_fetch = None
        R = getattr(self, "max_replicas", 1)
        swap_meta = np.zeros((self.n, R, self.max_swap, 3), np.int32)
        fetch_meta = np.zeros((self.n, self.max_swap, 3), np.int32)
        if self.paged:
            if self._swap_jobs:
                job = self._swap_jobs.pop(0)
                _, d, src, legs = job
                rank = self.decode_rank(d)
                perms = []
                for li, (mrank, dst) in enumerate(legs):
                    for j, (s, t) in enumerate(zip(src, dst)):
                        swap_meta[rank, li, j] = (s, t, 1)
                    perms.append(
                        kv_lib.handoff_permutation(self.n, {rank: mrank})
                    )
                # pad unused legs with the identity permutation (their
                # flags are zero, so nothing ships) — the leg count stays
                # static across ticks and the jit cache stays small
                while len(perms) < R:
                    perms.append(kv_lib.handoff_permutation(self.n, {}))
                perm_swap = tuple(perms)
                self._inflight_swap = job
            if self._fetch_jobs:
                job = self._fetch_jobs.pop(0)
                _, d, remote, local, mrank = job
                rank = self.decode_rank(d)
                for j, (s, t) in enumerate(zip(remote, local)):
                    fetch_meta[rank, j] = (s, t, 1)
                perm_fetch = kv_lib.handoff_permutation(self.n, {rank: mrank})
                self._inflight_fetch = job
        if self.paged:
            outflat = np.zeros(
                (self.n, self.tp, self.playout.n_pages,
                 self.shard_layout.page_elems),
                np.float32,
            )
            page_meta = np.zeros((self.n, self.playout.n_pages, 2), np.int32)
        else:
            outflat = np.zeros((self.n, self.block_elems), np.float32)
            page_meta = np.zeros((self.n, 1, 2), np.int32)
        meta = np.zeros((self.n, 4), np.int32)
        for p, (req, d, slot, flat, aplan) in pushes:
            outflat[p] = flat
            meta[p] = (1, req.rid, slot, self.decode_rank(d))
            if self.paged:
                for j, (page_id, fresh) in enumerate(zip(aplan.table, aplan.fresh)):
                    # unmaterialised slots (lazy tail) park at offset 0,
                    # gated off like prefix-shared pages
                    page_meta[p, j] = (
                        max(page_id, 0) * self.shard_layout.page_elems,
                        1 if fresh else 0,
                    )
            if not getattr(req, "_push_counted", False):
                req._push_counted = True
                self.kv_transfers += 1
                if self.paged:
                    n_fresh = sum(aplan.fresh)
                    self.kv_pages_sent += n_fresh
                    self.kv_pages_shared += sum(
                        1 for pid, f in zip(aplan.table, aplan.fresh)
                        if pid >= 0 and not f
                    )
        done_meta = np.zeros((self.n, self.max_done, 2), np.int32)
        per_rank_counts = [0] * self.n
        leftover: List[Tuple[int, int, int]] = []
        for d, rid_plus1, origin in self._done_queue:
            rank = self.decode_rank(d)
            j = per_rank_counts[rank]
            if j < self.max_done:
                done_meta[rank, j] = (rid_plus1, origin)
                per_rank_counts[rank] = j + 1
            else:
                leftover.append((d, rid_plus1, origin))
        self._done_queue = leftover
        tr = obs_trace.active()
        if tr.enabled:
            # split-phase handoff span: initiation here, ended when the
            # consume lands — the KV-handoff window a decode step overlaps
            self._transfer_span = tr.begin_async(
                "kv_handoff", cat="transfer", pushes=len(pushes),
                done_reports=int(sum(per_rank_counts)),
                swap=self._inflight_swap is not None,
                fetch=self._inflight_fetch is not None,
                est_us=round(self.plan.est_us, 1),
            )
        fn = self._transfer_fn(perm, perm_swap, perm_fetch)
        return fn(
            self.kvseg,
            self.inbox,
            self.acks,
            self.done,
            outflat,
            meta,
            page_meta,
            done_meta,
            swap_meta,
            fetch_meta,
        )

    def _decode_step(self) -> None:
        """One continuous-batching tick on every decode server; collect
        newly finished requests as completion reports for the next
        transfer launch."""
        for d, server in enumerate(self.decode_servers):
            if self._group_down(d):
                continue  # a dead rank computes nothing from the kill on
            self.decoded_tokens += server.step()
            fresh = server.finished[self._finished_seen[d] :]
            self._finished_seen[d] = len(server.finished)
            for req in fresh:
                self.finished.append(req)
                if self.paged:
                    # drop the request's page references; prefix pages
                    # shared with live requests stay resident
                    self.stores[d].release(req.rid)
                    if self.scheduler is not None:
                        self.scheduler.on_done(req.rid)
                    if self.health is not None:
                        self.health.retire(req.rid)
                origin = getattr(req, "origin_rank", 0)
                self._done_queue.append((d, req.rid + 1, origin))

    def _consume_transfer(self, results: Tuple[Any, ...]) -> None:
        # np.array (not asarray): host copies must stay writable — the
        # scheduler clears inbox flags after installs
        kvseg, inbox, acks, done, dropped = (np.array(r) for r in results)
        sp = getattr(self, "_transfer_span", None)
        if sp is not None:
            self._transfer_span = None
            obs_trace.active().end_async(sp)
        self.kvseg, self.inbox, self.acks, self.done = kvseg, inbox, acks, done
        # death emulation: the consumed result replaces the whole segment
        # array, so re-poison every dead rank's mirror — any recovery path
        # that erroneously reads a "dead" rank's bytes breaks token parity
        # instead of silently passing
        for r in self.killed | self.dead_ranks:
            self.kvseg[r, :] = np.nan
        if self.paged:
            self._alias_store_mem()  # fresh host mirror of the pool shards
        self.dropped_am += int(dropped.sum())
        # tier plane completions: a landed swap-out releases the victim's
        # pool pages (never before the bytes are safe in the memory rank);
        # a landed swap-in becomes installable into a decode row.
        if self._inflight_swap is not None:
            rid, d, src, legs = self._inflight_swap
            if self._group_down(d):
                # the source rank died mid-put: the tier bytes are not
                # trustworthy — requeue; detection converts to recompute
                self._swap_jobs.insert(0, self._inflight_swap)
                self._inflight_swap = None
            else:
                self.stores[d].note_swap_out(
                    rid, len(src), replicas=len(legs) - 1
                )
                self.stores[d].evict_request(rid)
                self._preempted[rid]["swapped"] = True
                self._inflight_swap = None
        if self._inflight_fetch is not None:
            rid, d, remote, _, src_rank = self._inflight_fetch
            if (
                src_rank in self.killed
                or src_rank in self.dead_ranks
                or (rid >= 0 and self._group_down(d))
            ):
                # source or target died mid-get: the fetched bytes are
                # poison — requeue; detection re-stages or recomputes
                self._fetch_jobs.insert(0, self._inflight_fetch)
                self._inflight_fetch = None
            elif rid < 0:
                # elastic-join prefix migration landed: the joined group's
                # adopted pages now hold the donor's prefix bytes — unpin
                # the donor side and count the moved pages
                mig = self._pending_migration or {}
                donor = mig.get("donor")
                if donor is not None:
                    self.stores[donor].unpin_pages()
                self.migrated_prefix_pages += len(remote)
                self._pending_migration = None
                self.swap_in_bytes += len(remote) * self.playout.page_bytes
                self._inflight_fetch = None
            else:
                self._installable[rid] = d
                self.swap_in_bytes += len(remote) * self.playout.page_bytes
                self._inflight_fetch = None
        # prefill side: retire acknowledged pushes — but NEVER on the word
        # of a dead group: in the emulation the killed rank's program
        # still ran, so its acks must be voided host-side (on real
        # hardware they would simply never arrive)
        for p, push in enumerate(self.pending_push):
            if push is None:
                continue
            req, d, slot, _, _ = push
            if self._group_down(d):
                continue
            if int(self.acks[p, slot]) == req.rid + 1:
                self.kv_acked += 1
                req.origin_rank = p
                self.pending_push[p] = None
        # decode side: install staged blocks into servers with free rows
        for d, server in enumerate(self.decode_servers):
            if self._group_down(d):
                continue
            rank = self.decode_rank(d)
            for slot in range(self.n_slots):
                occupied = int(self.inbox[rank, slot, 0])
                rid = int(self.inbox[rank, slot, 1])
                if not occupied:
                    continue
                req = self.by_rid.get(int(rid))
                if req is None or self.staged[d].get(slot) != int(rid):
                    continue
                if self._install(server, rank, slot, req):
                    self.inbox[rank, slot, 0] = 0
                    del self.staged[d][slot]

    def _install(self, server, rank: int, slot: int, req) -> bool:
        if self.paged:
            # bind the decode row straight to the page table: the pool
            # shard is the KV source of truth and every decode tick runs
            # THROUGH it (Model.decode_step_paged) — no dense row is ever
            # gathered in the cluster hot path.
            ok = server.admit_paged(
                req, first_token=req.out[0], position=len(req.prompt)
            )
            if ok and self.scheduler is not None:
                snap = self._preempted.get(req.rid)
                if snap is not None and snap["mode"] == "recompute":
                    # recompute-resume: replay the generated tokens to
                    # rebuild the KV bit-identically before continuing
                    row = next(
                        ix for ix, r in enumerate(server.active)
                        if r is not None and r.rid == req.rid
                    )
                    server.start_replay(row, req.out[1:])
                    del self._preempted[req.rid]
                self.scheduler.on_admitted(req.rid, time.monotonic())
            return ok
        block = self.kvseg[
            rank, slot * self.block_elems : (slot + 1) * self.block_elems
        ]
        header = block[: self.HEADER].view(np.int32)
        tok, position = int(header[0]), int(header[1])
        caches_one = self.layout.unflatten(self.jnp.asarray(block[self.HEADER :]))
        return server.admit_prefilled(
            req, caches_one, first_token=tok, position=position
        )

    # ------------------------------------------------------------------ #
    # fault tolerance: heartbeats, death recovery, elastic scale-out
    # ------------------------------------------------------------------ #
    def kill_rank(self, rank: int) -> None:
        """Fault injection: rank ``rank`` stops beating, computing, and
        acknowledging from this instant.  Its segment mirror is poisoned
        with NaN so any recovery path that erroneously consumes a "dead"
        rank's bytes breaks token parity instead of silently passing.
        Detection is automatic within ``heartbeat_timeout`` ticks."""
        if not self.paged:
            raise ValueError("fault injection requires paged=True")
        if not (0 <= rank < self.n):
            raise ValueError(f"rank {rank} outside the {self.n}-rank ring")
        self.killed.add(rank)
        self.kvseg[rank, :] = np.nan

    def _heartbeat(self) -> None:
        """Tick-clocked liveness: every live rank beats once per tick (on
        a real cluster the beat is an AM to the coordinator); the monitor
        declares a silent rank dead after ``heartbeat_timeout`` missed
        ticks and recovery runs before any scheduling decision."""
        if not self.paged:
            return
        tr = obs_trace.active()
        for r in range(self.n):
            if r in self.killed or r in self.dead_ranks:
                continue
            if self.beat_filter is not None and not self.beat_filter(
                r, self._tick_no
            ):
                if tr.enabled:
                    tr.instant("heartbeat_miss", cat="ft", rank=r)
                continue
            self.monitor.beat(r)
        for r in self.monitor.check():
            self._on_rank_failed(r)

    def _on_rank_failed(self, rank: int) -> None:
        if rank in self.dead_ranks:
            return
        self.dead_ranks.add(rank)
        self.rank_failures += 1
        role = self.roles[rank]
        tr = obs_trace.active()
        if tr.enabled:
            tr.instant("rank_death", cat="ft", rank=rank, role=role)
            # flight recorder: freeze the last few ticks of the ring at
            # the moment of death, before recovery mutates anything
            self.flight_dumps.append(
                obs_export.flight_dump(
                    tr,
                    self.flight_ticks,
                    reason=f"rank {rank} ({role}) died",
                    rank=rank,
                )
            )
        if role == "decode":
            g = next(
                g for g, lead in enumerate(self.group_leaders)
                if lead <= rank < lead + self.tp
            )
            self._recover_decode(g)
        elif role == "memory":
            self._recover_memory(rank - self._memory_base)
        elif role == "prefill":
            self._recover_prefill(rank)
        # spares are idle: nothing to recover
        self._rebuild_plans()

    def _to_recompute(self, rid: int) -> None:
        """Route a request whose pages (pool or tier) died through the
        bit-exact recompute-resume path: re-prefill, replay the generated
        history, continue — the tokens already streamed are kept."""
        req = self.by_rid[rid]
        snap = self._preempted.get(rid)
        if snap is None:
            self._preempted[rid] = {
                "mode": "recompute",
                "position": 0,
                "last_token": 0,
                "n_mat": 0,
                "swapped": False,
                "replay": [],
            }
            if self.scheduler is not None:
                self.scheduler.on_preempted(rid, "recompute")
        else:
            snap["mode"] = "recompute"
            snap["swapped"] = False
            snap.pop("staged", None)
        if req not in self.queue:
            self.queue.append(req)
        self.recovered_recompute += 1

    def _recover_decode(self, g: int) -> None:
        """Decode group ``g`` died: re-route its in-flight admissions,
        convert its resident requests to recompute-resume, re-stage its
        pending tier restores to surviving groups, and retire its pool
        shard.  Detection-to-recovery is one host step — the surviving
        ranks never stall."""
        from repro.serving import pool as pool_lib

        self.dead_groups.add(g)
        server = self.decode_servers[g]
        lead = self.decode_rank(g)
        # in-flight pushes targeting the dead group re-route: the pages
        # never became visible to a live rank (acks from a dead group are
        # voided), so the request re-enters the queue; the prefill token
        # it already produced is kept, so re-admission elsewhere is
        # bit-exact
        for p, push in enumerate(self.pending_push):
            if push is not None and push[1] == g:
                self.pending_push[p] = None
                self.queue.append(push[0])
                self.recovered_reroutes += 1
        self.staged[g].clear()
        self.inbox[lead] = 0
        # completion AMs the dead group can no longer send
        self._done_queue = [e for e in self._done_queue if e[0] != g]
        # staged swap-outs FROM the dead group: the victim's pages lived
        # in its (now lost) pool shard — release the planned tier slots
        # and recompute
        for job in [j for j in self._swap_jobs if j[1] == g]:
            self._swap_jobs.remove(job)
            rid = job[0]
            if self.tier is not None and rid in self.tier.holdings:
                self.tier.release(rid)
            self._to_recompute(rid)
        # staged fetches INTO the dead group: the tier copy survives
        # (holdings release only at install) — re-stage to a live group
        for job in [j for j in self._fetch_jobs if j[1] == g]:
            self._fetch_jobs.remove(job)
            if job[0] >= 0:
                self._preempted[job[0]]["staged"] = False
        # prefix migrations SOURCED at the dead group: the donor bytes
        # never arrived — drop the target's adopted-but-empty pages
        for job in [
            j for j in self._fetch_jobs if j[0] < 0 and j[4] == lead
        ]:
            self._fetch_jobs.remove(job)
            self.stores[job[1]].release_prefix_cache()
            self._pending_migration = None
        # restored-but-not-installed requests on the dead group: same
        # re-stage (their pool copy died with the shard)
        for rid, d in list(self._installable.items()):
            if d == g:
                del self._installable[rid]
                self._preempted[rid]["staged"] = False
        # resident rows recover through recompute-resume replay
        for i, r in enumerate(server.active):
            if r is None:
                continue
            server.evict_row(i)
            self._to_recompute(r.rid)
        for req in list(server.queue):
            server.queue.remove(req)
            if req not in self.queue:
                self.queue.append(req)
        # fresh (empty) shard bookkeeping so survivor invariants hold and
        # nothing references the lost pages
        self.stores[g] = pool_lib.PagedKVStore(
            self.shard_layout, self.pages_per_rank
        )
        server.store = self.stores[g]
        self._alias_store_mem()

    def _recover_memory(self, m: int) -> None:
        """Memory rank ``m`` died: scrub its tier placements.  Requests
        with a surviving replica leg restore from it (the quorum read);
        requests whose last copy died fall back to recompute-resume."""
        mrank = self.memory_rank(m)
        handled: set = set()
        # staged swap-outs with a leg on the dead rank: drop that leg;
        # a job with no live leg left converts to recompute
        for job in list(self._swap_jobs):
            rid, d, src, legs = job
            live = tuple(leg for leg in legs if leg[0] != mrank)
            if len(live) == len(legs):
                continue
            if live:
                self._swap_jobs[self._swap_jobs.index(job)] = (
                    rid, d, src, live,
                )
            else:
                self._swap_jobs.remove(job)
                if not self._group_down(d):
                    self.stores[d].evict_request(rid)
                if rid in self.tier.holdings:
                    self.tier.release(rid)
                self._to_recompute(rid)
                handled.add(rid)
        # staged fetches sourced at the dead rank: undo the target-side
        # resume allocation; the re-stage picks a surviving leg
        for job in [
            j for j in self._fetch_jobs if j[0] >= 0 and j[4] == mrank
        ]:
            self._fetch_jobs.remove(job)
            rid, d = job[0], job[1]
            if not self._group_down(d):
                self.stores[d].evict_request(rid)
            self._preempted[rid]["staged"] = False
        lost = self.tier.mark_failed(m)
        for rid in lost:
            if rid in handled:
                continue
            if rid in self._installable:
                continue  # restored copy already safe in a pool shard
            self._to_recompute(rid)

    def _recover_prefill(self, p: int) -> None:
        """Prefill worker ``p`` died: its in-flight push (if any) is
        undone on the live target and the request re-queued for a
        surviving worker (the prefill is recomputed — still bit-exact,
        prefill is deterministic)."""
        push = self.pending_push[p]
        if push is None:
            return
        req, d, slot, _, _ = push
        self.pending_push[p] = None
        if self.paged and not self._group_down(d):
            self.stores[d].evict_request(req.rid)
        self.staged[d].pop(slot, None)
        if req not in self.queue:
            self.queue.append(req)
        self.recovered_reroutes += 1

    def _rebuild_plans(self) -> None:
        """Re-plan the collective schedules for the surviving engine map:
        a dead rank's engine leaves the cost model, so segment counts and
        batching re-derive from the ranks that remain (ACCL+-style
        re-planning on membership change).  The jitted transfer cache is
        dropped — its programs closed over the stale plans."""
        from repro.core import engine as engine_lib
        from repro.core import sched

        alive = tuple(
            b for r, b in enumerate(self._backends)
            if r not in self.dead_ranks
        )
        if not alive:
            return
        engine = engine_lib.make_engine(
            alive, self.node_axis, len(alive), interpret=self._interpret
        )
        if self.paged:
            self.plan = sched.plan_p2p(
                nbytes=self.shard_layout.page_bytes,
                engine=engine, costs=self.costs,
            )
            self.swap_plan = sched.plan_p2p(
                nbytes=self.max_swap * self.playout.page_bytes,
                engine=engine, costs=self.costs,
            )
        else:
            self.plan = sched.plan_p2p(
                nbytes=self.block_bytes, engine=engine, costs=self.costs,
            )
        self._transfer_fns.clear()

    def join_decode_rank(self) -> int:
        """Elastic scale-out: promote an idle spare rank into a NEW
        decode group (``launch.mesh.promote_spare`` regenerates the role
        map; the ring size never changes, so every permutation and
        segment shape stays valid).  The joined rank gets a fresh pool
        shard, and the busiest live group's prefix index migrates to it —
        index entries adopted host-side, page bytes shipped as ONE
        vectored RMA get on the swap plane.  Returns the promoted rank."""
        from repro.launch.serve import PooledDecodeServer
        from repro.serving import pool as pool_lib

        if not self.paged or self.tp != 1:
            raise ValueError("elastic join requires paged=True and tp == 1")
        spare = next(
            (
                r for r, role in enumerate(self.roles)
                if role == "spare"
                and r not in self.killed
                and r not in self.dead_ranks
            ),
            None,
        )
        if spare is None:
            raise RuntimeError("no live spare rank to promote")
        self.roles = mesh_lib.promote_spare(self.roles, spare, to="decode")
        g = self.n_groups
        self.group_leaders.append(spare)
        store = pool_lib.PagedKVStore(self.shard_layout, self.pages_per_rank)
        self.stores.append(store)
        self.shard_mems.append([None])
        self.staged.append({})
        self._finished_seen.append(0)
        self.n_groups += 1
        self.decode_servers.append(
            PooledDecodeServer(
                self.model, self.ctx, self.params, self._decode_batch,
                self.cache_len, store=store, eos_id=self._eos_id,
                on_page_shortage=(
                    lambda rid, need, g=g:
                    self._decode_shortage(g, rid, need)
                ),
            )
        )
        self.decode_servers[-1].trace_rank = spare
        self._alias_store_mem()
        self.elastic_joins += 1
        tr = obs_trace.active()
        if tr.enabled:
            tr.instant("elastic_join", cat="ft", rank=spare, group=g)
        # prefix-index migration: warm the new shard from the live group
        # holding the largest index so affinity routing can target it
        donor, best = None, 0
        for d in range(self.n_groups - 1):
            if self._group_down(d):
                continue
            n = len(self.stores[d].prefix_entries())
            if n > best:
                donor, best = d, n
        if donor is not None and self._pending_migration is None:
            entries = self.stores[donor].prefix_entries()[: self.max_swap]
            pairs = store.adopt_prefix(entries)
            if pairs:
                self.stores[donor].pin_pages([dp for dp, _ in pairs])
                remote = [
                    dp * self.playout.page_elems for dp, _ in pairs
                ]
                local = [
                    lp * self.playout.page_elems for _, lp in pairs
                ]
                self._fetch_jobs.append(
                    (-1, g, remote, local, self.decode_rank(donor))
                )
                self._pending_migration = {
                    "donor": donor, "n": len(pairs),
                }
        return spare

    # ------------------------------------------------------------------ #
    def tick(self) -> None:
        """One cluster tick: prefill (possibly preempting for the queue
        head), stage resumes, launch the KV transfer (admission puts +
        swap puts + swap-in gets + AM control plane), overlap a decode
        step with it, consume the results, and install restored
        requests."""
        self._tick_no += 1
        tr = obs_trace.active()
        tr.set_tick(self._tick_no)
        if self.fault_hook is not None:
            self.fault_hook(self, "tick", self._tick_no)
        with tr.span("tick", cat="tick"):
            with tr.span("heartbeat", cat="tick_phase"):
                self._heartbeat()
            with tr.span("prefill", cat="tick_phase"):
                self._run_prefills()
            with tr.span("resume_stage", cat="tick_phase"):
                self._run_resumes()
            with tr.span("transfer_launch", cat="tick_phase"):
                results = self._launch_transfer()
            with tr.span("decode", cat="tick_phase"):
                self._decode_step()  # overlaps the in-flight transfer
            if self.fault_hook is not None:
                # fires between transfer launch and consume: a kill here
                # lands AFTER the put went on the wire but BEFORE its
                # kv_ready ack is processed — the mid-handoff death window
                self.fault_hook(self, "pre_consume", self._tick_no)
            if results is not None:
                with tr.span("transfer_consume", cat="tick_phase"):
                    self._consume_transfer(results)
            with tr.span("install", cat="tick_phase"):
                self._apply_decode_writes()
                if self.paged and self.tier is not None:
                    self._install_resumed()
            if self.health is not None:
                # live SLO projections over everything still tracked;
                # the rendered one-liner rides the trace so a flight dump
                # shows cluster health next to the phase spans it explains
                with tr.span("health", cat="tick_phase"):
                    self.health.tick(
                        self._tick_no, time.monotonic(),
                        progress={
                            r.rid: len(r.out)
                            for s in self.decode_servers
                            for r in s.active if r is not None
                        },
                    )
                    if tr.enabled:
                        tr.instant(
                            "health_summary", cat="slo",
                            line=self.health.render(),
                        )

    def idle(self) -> bool:
        return (
            not self.queue
            and all(p is None for p in self.pending_push)
            and not any(self.staged[d] for d in range(self.n_groups))
            and not any(any(s.active) or s.queue for s in self.decode_servers)
            and not self._preempted
            and not self._swap_jobs
            and not self._fetch_jobs
            and not self._installable
            and self._inflight_swap is None
            and self._inflight_fetch is None
            and self._pending_migration is None
        )

    def _latencies(self) -> Tuple[List[float], List[float]]:
        """Per-request (latency, ttft) lists, preferring trace-derived
        numbers: when tracing is on and every finished request's
        lifecycle instants (``req_submit`` / ``req_first_token`` /
        ``req_retire``) are still in the ring, TTFT and latency come
        from :meth:`~repro.obs.trace.Tracer.request_stats`.  Otherwise
        (tracing off, or the ring evicted early events) the Request
        wall timers are the fallback."""
        tr = obs_trace.active()
        if tr.enabled and self.finished:
            per = tr.request_stats()
            lat = [
                per[r.rid]["latency_s"] for r in self.finished
                if r.rid in per and "latency_s" in per[r.rid]
            ]
            ttft = [
                per[r.rid]["ttft_s"] for r in self.finished
                if r.rid in per and "ttft_s" in per[r.rid]
            ]
            if len(lat) == len(self.finished) == len(ttft):
                return lat, ttft
        lat = [r.t_done - r.t_enqueue for r in self.finished]
        ttft = [r.t_first - r.t_enqueue for r in self.finished]
        return lat, ttft

    def reset_metrics(self) -> None:
        """Zero the cluster's cumulative counters (scheduler and tier
        share the registry, so theirs clear too); gauges survive."""
        self.metrics.reset()

    def stats(self) -> Dict[str, Any]:
        """Cumulative counters and point-in-time gauges — everything in
        :meth:`run_until_drained`'s dict except the run-scoped rates and
        latencies.  Counter values read off the typed registry; the
        derived gauges (free pages, prefix hit rate) are published onto
        it here so a registry ``snapshot()`` sees them too."""
        if self.paged:
            kv_bytes = self.kv_pages_sent * self.playout.page_bytes
        else:
            kv_bytes = self.kv_transfers * self.block_bytes
        stats = {
            "requests": len(self.finished),
            "decoded_tokens": self.decoded_tokens,
            "kv_transfers": self.kv_transfers,
            "kv_acked": self.kv_acked,
            "kv_bytes": kv_bytes,
            "kv_block_bytes": self.block_bytes,
            "kv_plan": self.plan.describe(),
            "completions_notified": int(self.done[: self.n_prefill].sum()),
            "am_dropped": self.dropped_am,
        }
        if self.paged:
            # hit rate over SHAREABLE pages only (full prompt pages — the
            # store's counters); tail pages can never be shared and would
            # dilute the number
            hits = sum(s.prefix_hits for s in self.stores)
            misses = sum(s.prefix_misses for s in self.stores)
            hit_rate = hits / (hits + misses) if hits + misses else 0.0
            free_pages = sum(s.n_free for s in self.stores)
            self.metrics.gauge("pool_free_pages").set(free_pages)
            self.metrics.gauge("prefix_hit_rate").set(hit_rate)
            stats.update({
                "paged": True,
                "tp": self.tp,
                "n_decode_groups": self.n_groups,
                "page_tokens": self.playout.page_tokens,
                "page_bytes": self.playout.page_bytes,
                "pages_per_rank": self.pages_per_rank,
                "kv_pages_sent": self.kv_pages_sent,
                "kv_pages_shared": self.kv_pages_shared,
                "prefix_hit_rate": hit_rate,
                "pool_free_pages": free_pages,
                "decode_paged_steps": sum(
                    getattr(s, "paged_decode_steps", 0)
                    for s in self.decode_servers
                ),
                "rank_failures": self.rank_failures,
                "recovered_recompute": self.recovered_recompute,
                "recovered_reroutes": self.recovered_reroutes,
                "elastic_joins": self.elastic_joins,
                "migrated_prefix_pages": self.migrated_prefix_pages,
                "heartbeat_failed": list(self.monitor.failed),
            })
            if self.scheduler is not None:
                stats.update(self.scheduler.stats())
            if self.health is not None:
                stats["slo_violations"] = int(
                    self.metrics.counter("slo_violations").value)
                stats["health"] = dict(self.health.last_summary)
            if self.tier is not None:
                stats.update(self.tier.stats())
                stats.update({
                    "n_memory_ranks": self.n_memory,
                    "swap_out_bytes": self.swap_out_bytes,
                    "swap_in_bytes": self.swap_in_bytes,
                    "swap_plan": self.swap_plan.describe(),
                })
        return stats

    def run_until_drained(self, max_ticks: int = 10000) -> Dict[str, Any]:
        t0 = time.monotonic()
        ticks = 0
        while not self.idle() and ticks < max_ticks:
            self.tick()
            ticks += 1
        # final flushes so the last completions reach their origin ranks
        # (bounded: an unacknowledged push must not spin forever)
        for _ in range(2 * self.n + 2):
            results = self._launch_transfer()
            if results is None:
                break
            self._consume_transfer(results)
        dt = time.monotonic() - t0
        lat, ttft = self._latencies()
        stats = self.stats()
        stats.update({
            "wall_s": dt,
            "ticks": ticks,
            "tok_per_s": self.decoded_tokens / dt if dt else 0.0,
            "p50_latency_s": float(np.median(lat)) if lat else 0.0,
            "p99_latency_s": float(np.percentile(lat, 99)) if lat else 0.0,
            "p50_ttft_s": float(np.median(ttft)) if ttft else 0.0,
            "kv_bytes_per_s": stats["kv_bytes"] / dt if dt else 0.0,
        })
        return stats

"""Disaggregated prefill/decode serving over the GAS layer.

The cluster is one GASNet job over a ``node`` mesh axis
(``launch.mesh.serve_roles``): the first ``n_prefill`` ranks form the
prefill pool, the rest the decode pool, each pool optionally on its own
engine (``role_backends`` -> ``EngineMap`` — the paper's mixed
software/hardware cluster, serving-shaped).  Two planes:

- **Data plane** — a finished request's KV cache is flattened into one
  carrier block (:class:`~repro.serving.kv.KVLayout`), published in the
  prefill node's GASNet segment, and pushed into a staging slot of the
  decode node's segment with ``sched.plan_p2p``-planned segmented
  split-phase puts (:func:`~repro.serving.kv.push_block`).

  With ``paged=True`` the decode segments instead hold the **global
  paged KV pool** (:mod:`repro.serving.pool`): the prefill rank writes
  fixed-size token *pages* directly into the pool shard of the decode
  rank — one pred-gated put per page, landing at the exact page slots
  the (host-side, functional) allocator assigned, with NO dense staging
  copy in between.  Pages whose prompt-prefix chain is already resident
  on the target rank are not shipped at all: their puts trace with
  ``pred=False`` and the new request's page table simply maps the same
  physical pages (refcounted prefix sharing).
- **Control plane** — pure Active Messages: a ``kv_ready`` *request*
  (AMShort: request id, slot, origin) rides with the data; the decode
  node's handler records the slot in its inbox and returns an AMShort
  *reply* acknowledging installation, which resolves the prefill node's
  :class:`~repro.core.extended.AckHandle`; when decode finishes a request
  a ``req_done`` AM notifies the origin prefill rank (completion plane).

Every tick the host launches the (jitted, perm-cached) SPMD transfer
program asynchronously, runs one continuous-batching decode step on every
decode server while the transfer is in flight, then consumes the
transfer's results — transfer/decode overlap in the split-phase style the
Extended API exists for.

All of this is single-process SPMD emulation (host devices as nodes),
exactly like the testing suites; the GAS programs are the same ones a
multi-host launch would run.
"""

from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.launch import mesh as mesh_lib
from repro.serving import kv as kv_lib


class DisaggCluster:
    """A role-based serving cluster: prefill pool + decode pool + AM
    control plane, all over one GAS context.

    ``prefill_backend`` / ``decode_backend`` name each pool's engine
    (mixing them yields an ``EngineMap``).  ``n_slots`` is the number of
    KV staging slots per decode node's segment (in paged mode: in-flight
    installs per rank — the data lands in pages); ``decode_batch`` the
    continuous-batching width of each decode server.

    ``paged=True`` replaces the dense staging slots with the global paged
    KV pool: each decode rank's segment is its pool shard
    (``pages_per_rank`` pages of ``page_tokens`` tokens), prefill ranks
    put pages straight into their allocator-assigned slots, and
    prompt-prefix-shared pages are mapped, not moved.
    """

    HEADER = 2  # carrier elems prepended to each block: first_token, pos

    def __init__(
        self,
        model: Any,
        ctx: Any,
        params: Any,
        *,
        n_prefill: int = 1,
        n_decode: int = 1,
        decode_batch: int = 4,
        cache_len: int = 64,
        n_slots: int = 2,
        prefill_backend: str = "xla",
        decode_backend: str = "xla",
        interpret: bool = True,
        node_axis: str = "node",
        eos_id: int = -1,
        costs: Optional[Dict[str, Any]] = None,
        paged: bool = False,
        page_tokens: int = 8,
        pages_per_rank: Optional[int] = None,
    ):
        import jax
        import jax.numpy as jnp

        from repro.core import am, gasnet, sched
        from repro.compat import shard_map
        from repro.launch.serve import Server
        from repro.serving import pool as pool_lib

        self.jax, self.jnp = jax, jnp
        self.gasnet = gasnet
        self.shard_map = shard_map
        self.model, self.ctx, self.params = model, ctx, params
        self.n_prefill, self.n_decode = n_prefill, n_decode
        self.n = n_prefill + n_decode
        self.cache_len = cache_len
        self.n_slots = n_slots
        self.node_axis = node_axis
        self.max_done = decode_batch
        self.costs = costs
        self.paged = paged

        self.roles = mesh_lib.serve_roles(n_prefill, n_decode)
        backends = mesh_lib.role_backends(
            self.roles, prefill=prefill_backend, decode=decode_backend
        )
        self.mesh = mesh_lib.make_mesh((self.n,), (node_axis,))
        self.gas = gasnet.Context(
            self.mesh,
            node_axis=node_axis,
            backend=backends,
            interpret=interpret,
            am_capacity=self.max_done + 4,
            am_payload_width=1,
        )

        # ---- KV layout (static: shapes depend only on cache_len) --------
        if paged:
            # page-granular pool shards: one per decode rank's segment
            self.playout = pool_lib.PagedLayout.from_struct(
                model.kv_block_struct(ctx, prompt_len=4, cache_len=cache_len),
                cache_len=cache_len,
                page_tokens=page_tokens,
            )
            self.pages_per_rank = pages_per_rank or (
                (decode_batch + n_slots) * self.playout.n_pages
            )
            self.block_elems = self.playout.n_pages * self.playout.page_elems
            self.block_bytes = self.block_elems * 4
            self.seg_elems = self.pages_per_rank * self.playout.page_elems
            # per-PAGE put plan: each page is its own planned transfer
            self.plan = sched.plan_p2p(
                nbytes=self.playout.page_bytes,
                engine=self.gas.make_engine(),
                costs=costs,
            )
            self.stores = [
                pool_lib.PagedKVStore(self.playout, self.pages_per_rank)
                for _ in range(n_decode)
            ]
        else:
            self.layout = kv_lib.KVLayout.from_struct(
                model.kv_block_struct(ctx, prompt_len=4, cache_len=cache_len)
            )
            self.block_elems = self.layout.total + self.HEADER
            self.block_bytes = self.block_elems * 4
            self.seg_elems = self.n_slots * self.block_elems
            self.plan = sched.plan_p2p(
                nbytes=self.block_bytes,
                engine=self.gas.make_engine(),
                costs=costs,
            )

        # ---- AM control plane ------------------------------------------
        handlers = self.gas.handlers

        def kv_ack(state, payload, args):
            del payload
            out = dict(state)
            out["acks"] = state["acks"].at[args[1]].set(args[0] + 1)
            return out

        ack_id = handlers.register("kv_ack", kv_ack)

        def kv_ready(state, payload, args):
            rid, slot, origin = args[0], args[1], args[2]
            row = jnp.stack([jnp.ones((), jnp.int32), rid, origin])
            out = dict(state)
            out["inbox"] = state["inbox"].at[slot].set(row)
            return out, am.reply_short(ack_id, args=(rid, slot), like=payload)

        handlers.register("kv_ready", kv_ready, replies=True)

        def req_done(state, payload, args):
            del payload, args
            out = dict(state)
            out["done"] = state["done"] + 1
            return out

        handlers.register("req_done", req_done)

        # ---- device-side cluster state (host-managed between ticks) ----
        self.kvseg = np.zeros((self.n, self.seg_elems), np.float32)
        self.inbox = np.zeros((self.n, n_slots, 3), np.int32)
        self.acks = np.zeros((self.n, n_slots), np.int32)
        self.done = np.zeros((self.n, 1), np.int32)
        if paged:
            self._alias_store_mem()

        # ---- pools ------------------------------------------------------
        self.decode_servers = [
            Server(model, ctx, params, decode_batch, cache_len, eos_id=eos_id)
            for _ in range(n_decode)
        ]
        self._prefill_fn = jax.jit(
            lambda p, b: model.prefill(p, ctx, b, cache_len=cache_len)
        )

        # ---- host scheduler state --------------------------------------
        self.queue: List[Any] = []
        self.by_rid: Dict[int, Any] = {}
        self.finished: List[Any] = []
        # one in-flight push per prefill worker: (request, pool, slot, block)
        self.pending_push: List[Optional[Tuple]] = [None] * n_prefill
        self.staged: List[Dict[int, int]] = [dict() for _ in range(n_decode)]
        self._done_queue: List[Tuple[int, int, int]] = []  # (d, rid+1, origin)
        self._finished_seen = [0] * n_decode
        self._rr_decode = 0
        self._transfer_fns: Dict[Tuple[int, ...], Any] = {}
        self.kv_transfers = 0
        self.kv_acked = 0
        self.kv_pages_sent = 0
        self.kv_pages_shared = 0
        self.decoded_tokens = 0
        self.dropped_am = 0

    # ------------------------------------------------------------------ #
    # role views
    # ------------------------------------------------------------------ #
    def decode_rank(self, d: int) -> int:
        return self.n_prefill + d

    def _alias_store_mem(self) -> None:
        """Point each decode store's physical page array at its rank's
        partition of the (freshly consumed) pool segment — the host
        mirror of the PGAS shard.  Stores never write in disaggregated
        mode; pages arrive only over the wire."""
        for d, store in enumerate(self.stores):
            store.mem = self.kvseg[self.decode_rank(d)].reshape(
                self.pages_per_rank, self.playout.page_elems
            )

    # ------------------------------------------------------------------ #
    # request intake
    # ------------------------------------------------------------------ #
    def submit(self, req: Any) -> None:
        req.t_enqueue = time.monotonic()
        self.queue.append(req)
        self.by_rid[req.rid] = req

    # ------------------------------------------------------------------ #
    # SPMD transfer program (data plane + control plane, one launch)
    # ------------------------------------------------------------------ #
    def _transfer_fn(self, perm: Tuple[int, ...]) -> Any:
        cached = self._transfer_fns.get(perm)
        if cached is not None:
            return cached
        jax = self.jax
        gasnet = self.gasnet
        from jax.sharding import PartitionSpec as P

        spec = P(self.node_axis)
        block = self.block_elems

        def data_plane_dense(node, kvseg, outflat, meta):
            has = meta[0, 0] > 0
            slot = meta[0, 2]
            handles, _ = kv_lib.push_block(
                node,
                kvseg,
                outflat[0],
                to=gasnet.Perm(perm),
                base_index=slot * block,
                pred=has,
                plan=self.plan,
            )
            return handles

        def data_plane_paged(node, kvseg, outflat, meta, page_meta):
            # one pred-gated put per page, landing at the allocator's slot
            # (page_meta[j] = flat pool offset, send flag); prefix-shared
            # pages trace with pred=False and ship nothing.
            has = meta[0, 0] > 0
            handles = []
            for j in range(self.playout.n_pages):
                hs, _ = kv_lib.push_block(
                    node,
                    kvseg,
                    outflat[0, j],
                    to=gasnet.Perm(perm),
                    base_index=page_meta[0, j, 0],
                    pred=has & (page_meta[0, j, 1] > 0),
                    plan=self.plan,
                )
                handles.extend(hs)
            return handles

        def body(kvseg, inbox, acks, done, outflat, meta, page_meta, done_meta):
            node = self.gas.make_node()
            has = meta[0, 0] > 0
            rid, slot, dst = meta[0, 1], meta[0, 2], meta[0, 3]
            # data plane: planned segmented split-phase puts
            if self.paged:
                handles = data_plane_paged(node, kvseg, outflat, meta, page_meta)
            else:
                handles = data_plane_dense(node, kvseg, outflat, meta)
            # control plane rides while the puts are in flight
            ackh = node.am_call(
                dst,
                "kv_ready",
                args=(rid, slot, node.my_id),
                pred=has,
                ack=lambda st: st["acks"],
            )
            for j in range(self.max_done):
                node.am_short(
                    done_meta[0, j, 1],
                    "req_done",
                    args=(done_meta[0, j, 0],),
                    pred=done_meta[0, j, 0] > 0,
                )
            kvseg = kv_lib.sync_push(node, kvseg, handles)
            state = {"inbox": inbox[0], "acks": acks[0], "done": done[0]}
            state = node.am_flush(state)
            acked = node.sync(ackh)
            return (
                kvseg,
                state["inbox"][None],
                acked[None],
                state["done"][None],
                node.dropped[None],
            )

        fn = jax.jit(
            self.shard_map(
                body,
                mesh=self.mesh,
                in_specs=(spec,) * 8,
                out_specs=(spec,) * 5,
                check_vma=False,
            )
        )
        self._transfer_fns[perm] = fn
        return fn

    # ------------------------------------------------------------------ #
    # host scheduler
    # ------------------------------------------------------------------ #
    def _pick_target(
        self, taken: set, prompt: Optional[Any] = None
    ) -> Optional[Tuple[int, int]]:
        """(decode pool index, staging slot) with capacity, round-robin.

        Paged mode adds two rules: the target rank must hold enough free
        pool pages for a worst-case (unshared) admission, and ranks are
        tried in order of *prefix affinity* — the rank whose pool already
        holds the longest leading run of the prompt's pages wins, so the
        shared pages are mapped instead of moved."""
        order = [(self._rr_decode + i) % self.n_decode for i in range(self.n_decode)]
        if self.paged and prompt is not None:
            matches = {d: self.stores[d].prefix_match(prompt) for d in order}
            best = max(matches.values())
            if best > 0:
                # hard affinity: only ranks holding the longest resident
                # prefix qualify — admitting elsewhere would re-ship pages
                # that already exist.  If they are busy this tick the
                # request waits one tick (head-of-line, bounded: slots and
                # pages free as decodes finish).
                order = [d for d in order if matches[d] == best]
        for d in order:
            if d in taken:
                continue
            if self.paged and self.stores[d].n_free < self.playout.n_pages:
                continue
            for slot in range(self.n_slots):
                if slot not in self.staged[d]:
                    self._rr_decode = (d + 1) % self.n_decode
                    return d, slot
        return None

    def _run_prefills(self) -> None:
        """Assign queued requests to idle prefill workers (host compute)."""
        taken = {push[1] for push in self.pending_push if push is not None}
        for p in range(self.n_prefill):
            if self.pending_push[p] is not None or not self.queue:
                continue
            target = self._pick_target(taken, prompt=self.queue[0].prompt)
            if target is None:
                return
            d, slot = target
            req = self.queue.pop(0)
            jnp = self.jnp
            toks = jnp.asarray(req.prompt, jnp.int32)[None]
            logits, caches_one = self._prefill_fn(self.params, {"inputs": toks})
            tok = int(np.argmax(np.asarray(logits)[0]))
            req.out.append(tok)
            req.t_first = time.monotonic()
            if self.paged:
                # the pool's allocator assigns the pages NOW (host control
                # plane); the page payloads go one-sided into those exact
                # slots of the decode rank's pool shard — no staging copy,
                # and prefix-shared pages ship nothing at all.
                pages = np.asarray(self.playout.flatten(caches_one))
                plan = self.stores[d].plan_admit(req.prompt)
                self.stores[d].commit(req.rid, plan)
                self.pending_push[p] = (req, d, slot, pages, plan)
            else:
                header = np.asarray([tok, len(req.prompt)], np.int32).view(np.float32)
                flat = np.concatenate(
                    [header, np.asarray(self.layout.flatten(caches_one))]
                )
                self.pending_push[p] = (req, d, slot, flat, None)
            self.staged[d][slot] = req.rid
            taken.add(d)

    def _launch_transfer(self) -> Optional[Tuple[Any, ...]]:
        """Build this tick's transfer inputs and dispatch the SPMD program
        (asynchronously — the caller overlaps decode before consuming)."""
        pushes = [
            (p, push)
            for p, push in enumerate(self.pending_push)
            if push is not None
        ]
        if not pushes and not self._done_queue:
            return None
        edges = {p: self.decode_rank(d) for p, (_, d, _, _, _) in pushes}
        perm = kv_lib.handoff_permutation(self.n, edges)
        if self.paged:
            outflat = np.zeros(
                (self.n, self.playout.n_pages, self.playout.page_elems),
                np.float32,
            )
            page_meta = np.zeros((self.n, self.playout.n_pages, 2), np.int32)
        else:
            outflat = np.zeros((self.n, self.block_elems), np.float32)
            page_meta = np.zeros((self.n, 1, 2), np.int32)
        meta = np.zeros((self.n, 4), np.int32)
        for p, (req, d, slot, flat, aplan) in pushes:
            outflat[p] = flat
            meta[p] = (1, req.rid, slot, self.decode_rank(d))
            if self.paged:
                for j, (page_id, fresh) in enumerate(zip(aplan.table, aplan.fresh)):
                    page_meta[p, j] = (
                        page_id * self.playout.page_elems,
                        1 if fresh else 0,
                    )
            if not getattr(req, "_push_counted", False):
                req._push_counted = True
                self.kv_transfers += 1
                if self.paged:
                    n_fresh = sum(aplan.fresh)
                    self.kv_pages_sent += n_fresh
                    self.kv_pages_shared += self.playout.n_pages - n_fresh
        done_meta = np.zeros((self.n, self.max_done, 2), np.int32)
        per_rank_counts = [0] * self.n
        leftover: List[Tuple[int, int, int]] = []
        for d, rid_plus1, origin in self._done_queue:
            rank = self.decode_rank(d)
            j = per_rank_counts[rank]
            if j < self.max_done:
                done_meta[rank, j] = (rid_plus1, origin)
                per_rank_counts[rank] = j + 1
            else:
                leftover.append((d, rid_plus1, origin))
        self._done_queue = leftover
        fn = self._transfer_fn(perm)
        return fn(
            self.kvseg,
            self.inbox,
            self.acks,
            self.done,
            outflat,
            meta,
            page_meta,
            done_meta,
        )

    def _decode_step(self) -> None:
        """One continuous-batching tick on every decode server; collect
        newly finished requests as completion reports for the next
        transfer launch."""
        for d, server in enumerate(self.decode_servers):
            self.decoded_tokens += server.step()
            fresh = server.finished[self._finished_seen[d] :]
            self._finished_seen[d] = len(server.finished)
            for req in fresh:
                self.finished.append(req)
                if self.paged:
                    # drop the request's page references; prefix pages
                    # shared with live requests stay resident
                    self.stores[d].release(req.rid)
                origin = getattr(req, "origin_rank", 0)
                self._done_queue.append((d, req.rid + 1, origin))

    def _consume_transfer(self, results: Tuple[Any, ...]) -> None:
        # np.array (not asarray): host copies must stay writable — the
        # scheduler clears inbox flags after installs
        kvseg, inbox, acks, done, dropped = (np.array(r) for r in results)
        self.kvseg, self.inbox, self.acks, self.done = kvseg, inbox, acks, done
        if self.paged:
            self._alias_store_mem()  # fresh host mirror of the pool shards
        self.dropped_am += int(dropped.sum())
        # prefill side: retire acknowledged pushes
        for p, push in enumerate(self.pending_push):
            if push is None:
                continue
            req, d, slot, _, _ = push
            if int(self.acks[p, slot]) == req.rid + 1:
                self.kv_acked += 1
                req.origin_rank = p
                self.pending_push[p] = None
        # decode side: install staged blocks into servers with free rows
        for d, server in enumerate(self.decode_servers):
            rank = self.decode_rank(d)
            for slot in range(self.n_slots):
                occupied = int(self.inbox[rank, slot, 0])
                rid = int(self.inbox[rank, slot, 1])
                if not occupied:
                    continue
                req = self.by_rid.get(int(rid))
                if req is None or self.staged[d].get(slot) != int(rid):
                    continue
                if self._install(server, rank, slot, req):
                    self.inbox[rank, slot, 0] = 0
                    del self.staged[d][slot]

    def _install(self, server, rank: int, slot: int, req) -> bool:
        if self.paged:
            # read the request's cache back THROUGH its page table: the
            # pool shard (not any staging copy) is the source of truth
            d = rank - self.n_prefill
            caches_one = self.stores[d].gather(req.rid)
            return server.admit_prefilled(
                req,
                caches_one,
                first_token=req.out[0],
                position=len(req.prompt),
            )
        block = self.kvseg[
            rank, slot * self.block_elems : (slot + 1) * self.block_elems
        ]
        header = block[: self.HEADER].view(np.int32)
        tok, position = int(header[0]), int(header[1])
        caches_one = self.layout.unflatten(self.jnp.asarray(block[self.HEADER :]))
        return server.admit_prefilled(
            req, caches_one, first_token=tok, position=position
        )

    # ------------------------------------------------------------------ #
    def tick(self) -> None:
        """One cluster tick: prefill, launch the KV transfer, overlap a
        decode step with it, then consume the transfer results."""
        self._run_prefills()
        results = self._launch_transfer()
        self._decode_step()  # overlaps the in-flight transfer
        if results is not None:
            self._consume_transfer(results)

    def idle(self) -> bool:
        return (
            not self.queue
            and all(p is None for p in self.pending_push)
            and not any(self.staged[d] for d in range(self.n_decode))
            and not any(any(s.active) or s.queue for s in self.decode_servers)
        )

    def run_until_drained(self, max_ticks: int = 10000) -> Dict[str, Any]:
        t0 = time.monotonic()
        ticks = 0
        while not self.idle() and ticks < max_ticks:
            self.tick()
            ticks += 1
        # final flushes so the last completions reach their origin ranks
        # (bounded: an unacknowledged push must not spin forever)
        for _ in range(2 * self.n + 2):
            results = self._launch_transfer()
            if results is None:
                break
            self._consume_transfer(results)
        dt = time.monotonic() - t0
        lat = [r.t_done - r.t_enqueue for r in self.finished]
        ttft = [r.t_first - r.t_enqueue for r in self.finished]
        if self.paged:
            kv_bytes = self.kv_pages_sent * self.playout.page_bytes
        else:
            kv_bytes = self.kv_transfers * self.block_bytes
        stats = {
            "requests": len(self.finished),
            "decoded_tokens": self.decoded_tokens,
            "wall_s": dt,
            "ticks": ticks,
            "tok_per_s": self.decoded_tokens / dt if dt else 0.0,
            "p50_latency_s": float(np.median(lat)) if lat else 0.0,
            "p99_latency_s": float(np.percentile(lat, 99)) if lat else 0.0,
            "p50_ttft_s": float(np.median(ttft)) if ttft else 0.0,
            "kv_transfers": self.kv_transfers,
            "kv_acked": self.kv_acked,
            "kv_bytes": kv_bytes,
            "kv_bytes_per_s": kv_bytes / dt if dt else 0.0,
            "kv_block_bytes": self.block_bytes,
            "kv_plan": self.plan.describe(),
            "completions_notified": int(self.done[: self.n_prefill].sum()),
            "am_dropped": self.dropped_am,
        }
        if self.paged:
            # hit rate over SHAREABLE pages only (full prompt pages — the
            # store's counters); tail pages can never be shared and would
            # dilute the number
            hits = sum(s.prefix_hits for s in self.stores)
            misses = sum(s.prefix_misses for s in self.stores)
            stats.update({
                "paged": True,
                "page_tokens": self.playout.page_tokens,
                "page_bytes": self.playout.page_bytes,
                "pages_per_rank": self.pages_per_rank,
                "kv_pages_sent": self.kv_pages_sent,
                "kv_pages_shared": self.kv_pages_shared,
                "prefix_hit_rate": (hits / (hits + misses) if hits + misses else 0.0),
                "pool_free_pages": sum(s.n_free for s in self.stores),
            })
        return stats

"""KV-cache blocks over the GAS layer: the disaggregated-serving data plane.

A prefill node finishes a request holding a KV-cache pytree; a decode node
needs that cache installed in one of its staging slots.  The paper's split
applies directly: the *bulk* bytes move as one-sided remote writes (the
GAScore command path — here ``Node.put_nb`` segmented per
``sched.plan_p2p``), while the *control* packet announcing the block rides
the Active Message request/reply plane (``repro.serving.disagg``).

Three pieces:

1. :class:`KVLayout` — a bit-transparent mapping between a cache pytree and
   one flat float32 *carrier* vector (int leaves are bitcast, half-precision
   floats are widened exactly), so a block is a contiguous GASNet segment
   range and the transfer is engine-agnostic.
2. :func:`push_block` — ship a block with ``plan_p2p``-planned segmented
   split-phase puts: all segments are initiated before any completion is
   consumed, so the wire overlaps the receiver epilogue (and any decode
   compute issued between initiation and :func:`sync_push`).
3. :func:`handoff_permutation` — complete a set of prefill→decode edges
   into a full bijection (hardware transports signal every recv semaphore
   exactly once, so only bijections are legal); the filler edges carry
   ``pred=False`` puts that the receiver discards.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import sched

__all__ = [
    "KVLayout",
    "LeafSpec",
    "carrier_cast",
    "carrier_uncast",
    "segment_bounds",
    "push_block",
    "sync_push",
    "handoff_permutation",
]


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    """One cache leaf's slice of the flat carrier block."""

    shape: Tuple[int, ...]
    dtype: Any
    offset: int
    size: int


def carrier_cast(x: jax.Array) -> jax.Array:
    """Bit-transparent elementwise cast of one leaf into the float32
    carrier (shape-preserving; the paged layout slices before flattening)."""
    if x.dtype == jnp.float32:
        return x
    if x.dtype in (jnp.int32, jnp.uint32):
        return lax.bitcast_convert_type(x, jnp.float32)
    if x.dtype in (jnp.int8, jnp.int16, jnp.uint8, jnp.uint16):
        return lax.bitcast_convert_type(x.astype(jnp.int32), jnp.float32)
    if x.dtype == jnp.bool_:
        return x.astype(jnp.float32)
    if jnp.issubdtype(x.dtype, jnp.floating):
        return x.astype(jnp.float32)  # bf16/f16 widen exactly
    raise TypeError(f"unsupported KV leaf dtype {x.dtype}")


def carrier_uncast(flat: jax.Array, dtype: Any) -> jax.Array:
    """Inverse of :func:`carrier_cast` (shape-preserving)."""
    dtype = jnp.dtype(dtype)
    if dtype == jnp.float32:
        return flat
    if dtype in (jnp.int8, jnp.int16, jnp.int32, jnp.uint8, jnp.uint16, jnp.uint32):
        return lax.bitcast_convert_type(flat, jnp.int32).astype(dtype)
    if dtype == jnp.bool_:
        return flat != 0.0
    if jnp.issubdtype(dtype, jnp.floating):
        return flat.astype(dtype)
    raise TypeError(f"unsupported KV leaf dtype {dtype}")


def _to_carrier(x: jax.Array) -> jax.Array:
    """Flatten one leaf into the float32 carrier, bit-transparently."""
    return carrier_cast(x).reshape(-1)


def _from_carrier(flat: jax.Array, spec: LeafSpec) -> jax.Array:
    return carrier_uncast(flat, spec.dtype).reshape(spec.shape)


class KVLayout:
    """Static block layout of one request's KV cache.

    Built once from an abstract cache pytree (``Model.kv_block_struct``);
    :meth:`flatten` / :meth:`unflatten` round-trip any concrete cache of
    that structure through a single ``(total,)`` float32 carrier vector,
    bit-exactly.
    """

    def __init__(self, treedef: Any, leaves: List[LeafSpec]):
        self.treedef = treedef
        self.leaves = leaves
        self.total = sum(leaf.size for leaf in leaves)

    @classmethod
    def from_struct(cls, struct: Any) -> "KVLayout":
        leaf_structs, treedef = jax.tree_util.tree_flatten(struct)
        leaves: List[LeafSpec] = []
        offset = 0
        for s in leaf_structs:
            size = 1
            for d in s.shape:
                size *= int(d)
            leaves.append(
                LeafSpec(
                    shape=tuple(s.shape),
                    dtype=jnp.dtype(s.dtype),
                    offset=offset,
                    size=size,
                )
            )
            offset += size
        return cls(treedef, leaves)

    @property
    def nbytes(self) -> int:
        return self.total * 4  # float32 carrier

    def flatten(self, caches: Any) -> jax.Array:
        vals = jax.tree_util.tree_leaves(caches)
        if len(vals) != len(self.leaves):
            raise ValueError(
                f"cache has {len(vals)} leaves, layout expects "
                f"{len(self.leaves)}"
            )
        return jnp.concatenate([_to_carrier(v) for v in vals])

    def unflatten(self, flat: jax.Array) -> Any:
        flat = flat.reshape(-1)
        if flat.shape[0] != self.total:
            raise ValueError(
                f"flat block has {flat.shape[0]} elems, layout expects "
                f"{self.total}"
            )
        vals = [
            _from_carrier(flat[leaf.offset : leaf.offset + leaf.size], leaf)
            for leaf in self.leaves
        ]
        return jax.tree_util.tree_unflatten(self.treedef, vals)


def segment_bounds(total: int, n_segments: int) -> List[Tuple[int, int]]:
    """Static ``(offset, size)`` list cutting ``total`` elements into at
    most ``n_segments`` contiguous near-equal segments (never empty)."""
    g = max(1, min(int(n_segments), int(total)))
    base, rem = divmod(int(total), g)
    bounds: List[Tuple[int, int]] = []
    offset = 0
    for i in range(g):
        size = base + (1 if i < rem else 0)
        bounds.append((offset, size))
        offset += size
    return bounds


def push_block(
    node: Any,
    seg: jax.Array,
    flat: jax.Array,
    *,
    to: Any,
    base_index: jax.Array | int = 0,
    pred: jax.Array | bool | None = None,
    plan: Optional[sched.CollectivePlan] = None,
    n_segments: Optional[int] = None,
    costs: Optional[Dict[str, sched.EngineCost]] = None,
) -> Tuple[List[Any], sched.CollectivePlan]:
    """Initiate one KV-block transfer as planned segmented non-blocking puts.

    The segment count comes from ``sched.plan_p2p`` (the stage-boundary
    planner: chunk so wire time overlaps the receiver epilogue) unless
    pinned via ``n_segments``.  Every segment's ``put_nb`` is initiated
    here — all in flight at once — and the caller drains them with
    :func:`sync_push` after issuing any compute it wants overlapped.

    Returns ``(handles, plan)``.
    """
    if plan is None:
        nbytes = int(flat.size) * flat.dtype.itemsize
        plan = sched.plan_p2p(nbytes=nbytes, engine=node.engine, costs=costs)
    g = int(plan.n_segments if n_segments is None else n_segments)
    handles = []
    for offset, size in segment_bounds(int(flat.size), g):
        handles.append(
            node.put_nb(
                seg,
                flat[offset : offset + size],
                to=to,
                index=base_index + offset,
                pred=pred,
            )
        )
    return handles, plan


def sync_push(node: Any, seg: jax.Array, handles: Sequence[Any]) -> jax.Array:
    """Drain one block's put handles in issue order; returns the updated
    segment (outstanding puts on the same segment compose, see
    ``Node.sync``)."""
    for h in handles:
        seg = node.sync(h)
    return seg


def handoff_permutation(n_nodes: int, edges: Dict[int, int]) -> Tuple[int, ...]:
    """Complete prefill→decode ``edges`` (src rank -> dst rank) into a full
    bijection over ``n_nodes`` ranks.

    Hardware (GAScore) transports are bijection-only — every receive
    semaphore fires exactly once — so ranks without a real edge get filler
    destinations in stable order; their puts ship ``pred=False`` and the
    receivers keep their memory untouched.
    """
    dst: List[Optional[int]] = [None] * n_nodes
    used = set()
    for s, d in edges.items():
        if not (0 <= s < n_nodes and 0 <= d < n_nodes):
            raise ValueError(f"edge {s}->{d} outside {n_nodes} ranks")
        if dst[s] is not None:
            raise ValueError(f"duplicate source rank {s}")
        if d in used:
            raise ValueError(f"duplicate destination rank {d}")
        dst[s] = d
        used.add(d)
    remaining = [r for r in range(n_nodes) if r not in used]
    for s in range(n_nodes):
        if dst[s] is None:
            dst[s] = remaining.pop(0)
    assert not remaining
    return tuple(dst)  # type: ignore[arg-type]

"""Global paged KV pool: a PGAS page allocator for prefix-shared serving.

PR 3 shipped KV caches between prefill and decode ranks as opaque dense
blocks.  This module applies the paper's addressing model — every node
reads and writes one partitioned global address space with one-sided
operations — to the hottest serving data structure: the KV cache becomes
a pool of fixed-size token *pages* living in a GASNet segment sharded
across the decode ranks, and requests hold *page tables* instead of
memory.

Four layers, host-side state functional throughout:

1. :class:`PagedLayout` — the carrier format: cut a request cache's token
   axis (``cache_len``) into ``n_pages`` pages of ``page_tokens`` each;
   every page is one contiguous float32 carrier vector (``page_elems``),
   bit-transparent like :class:`~repro.serving.kv.KVLayout` (int leaves
   bitcast, half floats widened exactly).
2. The **functional free-list allocator** — :class:`PoolState` is an
   immutable value; :func:`alloc` / :func:`free` / :func:`fork` /
   :func:`writable` return new states.  Pages are refcounted:
   :func:`fork` shares a page between requests (prefix sharing),
   :func:`free` returns it to the free list only when the last reference
   drops, and :func:`writable` is copy-on-write — a shared page is never
   mutated in place.
3. :class:`PagedKVStore` — one rank's pool shard: the physical page
   memory (``mem`` aliases the rank's GASNet segment in the
   disaggregated cluster), the allocator state, per-request page tables,
   and the prompt-prefix index that maps a full-page token chain to the
   resident physical page, so two requests with a common prompt prefix
   resolve to the *same physical pages* and only the divergent tail is
   ever transferred or stored.
4. :class:`PoolMap` + :func:`fetch_pages` — the global address space:
   global page ``g`` lives at flat offset ``local(g) * page_elems`` of
   rank ``owner(g)``'s segment, and a decode rank prefetches remote
   pages with the vectored split-phase get (``Node.get_nbv`` — one
   request/reply pair per planned batch, batch count from
   ``sched.plan_p2p``), overlapping the fetch with its attention step.

The compute side is ``repro.kernels.paged_attention``: decode attention
reading K/V directly through the page table.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sched
from repro.serving import kv as kv_lib

__all__ = [
    "PagedLayout",
    "PageLeafSpec",
    "token_axis",
    "PoolState",
    "PoolError",
    "OutOfPagesError",
    "DoubleFreeError",
    "UNMATERIALIZED",
    "make_pool",
    "alloc",
    "free",
    "fork",
    "writable",
    "check_pool",
    "AdmitPlan",
    "PREFIX_CACHE_RID",
    "PIN_RID",
    "PagedKVStore",
    "PoolMap",
    "fetch_pages",
    "sync_fetch",
]

#: Page-table sentinel for a slot whose physical page does not exist yet
#: (lazy allocation) — it materialises when the first position inside it
#: is written, and :meth:`PagedKVStore.gather` synthesises the absent page
#: from :meth:`PagedLayout.empty_page_row`.
UNMATERIALIZED = -1

#: Pseudo-table rid owning pages a rank ADOPTED into its prefix index from
#: a migration donor (elastic scale-out): the pages are live and prefix-
#: shareable but belong to no request, so they hold their refcount through
#: a reserved table entry — ``check_pool``'s refcount==table-multiplicity
#: invariant covers them unchanged.
PREFIX_CACHE_RID = -1

#: Pseudo-table rid pinning a migration DONOR's pages for the duration of
#: an in-flight page transfer: the extra reference keeps the physical
#: pages (and their bytes) alive even if every owning request retires
#: mid-transfer.  Dropped by :meth:`PagedKVStore.unpin_pages`.
PIN_RID = -2


# --------------------------------------------------------------------------- #
# 1. Page-granular carrier layout
# --------------------------------------------------------------------------- #
def token_axis(shape: Sequence[int], cache_len: int) -> int:
    """Index of the token (cache) axis in one cache-leaf shape: the unique
    axis of size ``cache_len``.  Raises when the leaf has no such axis or
    the size is ambiguous — paging needs an unambiguous cut."""
    hits = [i for i, d in enumerate(shape) if int(d) == int(cache_len)]
    if len(hits) != 1:
        raise ValueError(
            f"cannot locate the token axis of cache leaf {tuple(shape)}: "
            f"{len(hits)} axes of size {cache_len}"
        )
    return hits[0]


@dataclasses.dataclass(frozen=True)
class PageLeafSpec:
    """One cache leaf's per-page slice of the carrier page."""

    shape: Tuple[int, ...]  # full leaf shape
    dtype: Any
    axis: int  # token axis
    offset: int  # start column inside the carrier page
    size: int  # carrier elements per page for this leaf
    fill: int = 0  # init value of an unwritten slot (-1 for "pos" leaves)


class PagedLayout:
    """Static page layout of one request's KV cache.

    Built once from an abstract cache pytree (``Model.kv_block_struct``);
    :meth:`flatten` / :meth:`unflatten` round-trip any concrete cache of
    that structure through an ``(n_pages, page_elems)`` float32 carrier
    array, bit-exactly.  Page ``p`` carries token positions
    ``[p * page_tokens, (p + 1) * page_tokens)`` of every leaf.
    """

    def __init__(
        self,
        treedef: Any,
        leaves: List[PageLeafSpec],
        cache_len: int,
        page_tokens: int,
    ):
        self.treedef = treedef
        self.leaves = leaves
        self.cache_len = int(cache_len)
        self.page_tokens = int(page_tokens)
        self.n_pages = self.cache_len // self.page_tokens
        self.page_elems = sum(leaf.size for leaf in leaves)
        self._empty_row: Optional[np.ndarray] = None

    @classmethod
    def from_struct(
        cls, struct: Any, *, cache_len: int, page_tokens: int
    ) -> "PagedLayout":
        if cache_len % page_tokens:
            raise ValueError(
                f"cache_len={cache_len} not a multiple of "
                f"page_tokens={page_tokens}"
            )
        with_path, treedef = jax.tree_util.tree_flatten_with_path(struct)
        leaves: List[PageLeafSpec] = []
        offset = 0
        for path, s in with_path:
            ax = token_axis(s.shape, cache_len)
            size = 1
            for i, d in enumerate(s.shape):
                size *= int(page_tokens) if i == ax else int(d)
            name = getattr(path[-1], "key", None) if path else None
            leaves.append(
                PageLeafSpec(
                    shape=tuple(int(d) for d in s.shape),
                    dtype=jnp.dtype(s.dtype),
                    axis=ax,
                    offset=offset,
                    size=size,
                    # unwritten cache slots are NOT zeros: position leaves
                    # init to -1 (the empty-slot sentinel attention masks
                    # on); payload leaves init to 0 — same rule as the
                    # model's prefill cache construction.
                    fill=-1 if name == "pos" else 0,
                )
            )
            offset += size
        return cls(treedef, leaves, cache_len, page_tokens)

    def pages_for(self, n_tokens: int) -> int:
        """Number of leading pages covering ``n_tokens`` positions."""
        return -(-max(0, int(n_tokens)) // self.page_tokens)

    def empty_page_row(self) -> np.ndarray:
        """Carrier row of one ABSENT page: the exact bytes a freshly
        initialised cache holds at unwritten positions (payloads zero,
        ``pos`` = -1).  Lazy tables synthesise this row at :meth:`gather`
        for unmaterialised slots, so a recycled physical page's stale
        bytes never leak into attention (the ``pos=-1`` init means an
        absent page is not zeros)."""
        if self._empty_row is None:
            cols = []
            for leaf in self.leaves:
                shape = tuple(
                    self.page_tokens if i == leaf.axis else d
                    for i, d in enumerate(leaf.shape)
                )
                v = jnp.full(shape, leaf.fill, leaf.dtype)
                c = jnp.moveaxis(kv_lib.carrier_cast(v), leaf.axis, 0)
                cols.append(c.reshape(leaf.size))
            self._empty_row = np.asarray(jnp.concatenate(cols), np.float32)
        return self._empty_row

    @property
    def page_bytes(self) -> int:
        return self.page_elems * 4  # float32 carrier

    def flatten(self, caches: Any) -> jax.Array:
        """Cache pytree -> (n_pages, page_elems) float32 carrier pages."""
        vals = jax.tree_util.tree_leaves(caches)
        if len(vals) != len(self.leaves):
            raise ValueError(
                f"cache has {len(vals)} leaves, layout expects "
                f"{len(self.leaves)}"
            )
        cols = []
        for v, leaf in zip(vals, self.leaves):
            if tuple(v.shape) != leaf.shape:
                raise ValueError(f"cache leaf {tuple(v.shape)} != layout {leaf.shape}")
            c = jnp.moveaxis(kv_lib.carrier_cast(v), leaf.axis, 0)
            cols.append(c.reshape(self.n_pages, leaf.size))
        return jnp.concatenate(cols, axis=1)

    def flatten_page(self, caches: Any, page: int) -> jax.Array:
        """One page's carrier row (``(page_elems,)``) without flattening
        the rest of the cache — the per-decode-step writeback path only
        touches the page holding the new token."""
        if not (0 <= page < self.n_pages):
            raise ValueError(f"page {page} outside [0, {self.n_pages})")
        vals = jax.tree_util.tree_leaves(caches)
        lo = page * self.page_tokens
        cols = []
        for v, leaf in zip(vals, self.leaves):
            if tuple(v.shape) != leaf.shape:
                raise ValueError(f"cache leaf {tuple(v.shape)} != layout {leaf.shape}")
            window = jax.lax.slice_in_dim(v, lo, lo + self.page_tokens, axis=leaf.axis)
            c = jnp.moveaxis(kv_lib.carrier_cast(window), leaf.axis, 0)
            cols.append(c.reshape(leaf.size))
        return jnp.concatenate(cols)

    def page_struct(self) -> Any:
        """Abstract pytree of ONE page: every leaf's token axis cut from
        ``cache_len`` to ``page_tokens`` (the unit the pool allocates)."""
        vals = [
            jax.ShapeDtypeStruct(
                tuple(
                    self.page_tokens if i == leaf.axis else d
                    for i, d in enumerate(leaf.shape)
                ),
                leaf.dtype,
            )
            for leaf in self.leaves
        ]
        return jax.tree_util.tree_unflatten(self.treedef, vals)

    def decode_views(self, mem: Any) -> Any:
        """Per-layer page-pool views of a physical pool for the paged
        decode step: each serving-cache leaf ``(L, 1, cache_len, *tail)``
        (the ``Model.kv_block_struct`` convention: batch 1, token axis 2)
        becomes ``(L, n_phys_pages, page_tokens, *tail)`` — the
        ``k_pages``/``v_pages`` shape ``kernels.paged_attention`` reads
        through a page table.  ``mem`` is any ``(P, page_elems)`` carrier
        pool (the rank's shard, possibly with extra scratch rows); the
        transform is a pure reshape, bit-transparent per leaf dtype."""
        mem = jnp.asarray(mem)
        n_phys = mem.shape[0]
        vals = []
        for leaf in self.leaves:
            if len(leaf.shape) < 3 or leaf.axis != 2 or leaf.shape[1] != 1:
                raise ValueError(
                    f"decode_views needs (L, 1, cache_len, ...) serving "
                    f"leaves, got {leaf.shape} (token axis {leaf.axis})"
                )
            tail = leaf.shape[3:]
            col = mem[:, leaf.offset : leaf.offset + leaf.size]
            x = col.reshape(
                (n_phys, self.page_tokens, leaf.shape[0], 1) + tail
            )
            x = jnp.moveaxis(x, 2, 0)[:, :, :, 0]  # (L, P, T, *tail)
            vals.append(kv_lib.carrier_uncast(x, leaf.dtype))
        return jax.tree_util.tree_unflatten(self.treedef, vals)

    def views_to_pool(self, views: Any) -> jax.Array:
        """Inverse of :meth:`decode_views`: per-layer page pools back into
        the ``(P, page_elems)`` carrier array (bit-exact round trip)."""
        vals = jax.tree_util.tree_leaves(views)
        if len(vals) != len(self.leaves):
            raise ValueError(
                f"views have {len(vals)} leaves, layout expects "
                f"{len(self.leaves)}"
            )
        cols = []
        for v, leaf in zip(vals, self.leaves):
            x = kv_lib.carrier_cast(v)  # (L, P, T, *tail)
            x = jnp.moveaxis(x[:, :, :, None], 0, 2)  # (P, T, L, 1, *tail)
            cols.append(x.reshape(x.shape[0], leaf.size))
        return jnp.concatenate(cols, axis=1)

    def shard_heads(
        self, tp: int, n_kv_heads: int
    ) -> Tuple["PagedLayout", np.ndarray]:
        """Head-shard axis for tensor-parallel decode groups.

        Returns ``(shard_layout, cols)``: the :class:`PagedLayout` of ONE
        rank's pool shard (``k``/``v`` leaves keep only ``KH/tp`` heads;
        ``pos`` and other head-free leaves replicated) plus an
        ``(tp, shard_page_elems)`` int array of full-page carrier columns
        such that shard ``s`` of a page row is ``row[cols[s]]`` — and the
        full row is rebuilt by scattering every shard back through its
        columns (``k``/``v`` columns partition; replicated columns agree
        bit-for-bit on every shard, so reassembly order is immaterial).

        Page ids, page tables, the allocator and the prefix index are all
        shard-invariant: every rank of a group holds the same table and
        the same page count, just ``1/tp``-th of each page's bytes.
        """
        if tp <= 1:
            return self, np.arange(self.page_elems)[None]
        if n_kv_heads % tp:
            raise ValueError(
                f"tp={tp} must divide n_kv_heads={n_kv_heads}"
            )
        kh_l = n_kv_heads // tp
        with_path, _ = jax.tree_util.tree_flatten_with_path(
            self.page_struct()
        )
        cols: List[List[np.ndarray]] = [[] for _ in range(tp)]
        shard_vals = []
        for (path, _), leaf in zip(with_path, self.leaves):
            name = getattr(path[-1], "key", None) if path else None
            inner = (
                (self.page_tokens,)
                + leaf.shape[: leaf.axis]
                + leaf.shape[leaf.axis + 1 :]
            )
            idx = np.arange(leaf.size).reshape(inner) + leaf.offset
            if name in ("k", "v"):
                if (
                    len(leaf.shape) < 4
                    or leaf.axis != 2
                    or leaf.shape[3] != n_kv_heads
                ):
                    raise ValueError(
                        f"cannot head-shard {name!r} leaf {leaf.shape}: "
                        f"expected (L, 1, cache_len, {n_kv_heads}, ...)"
                    )
                # inner layout is (T, L, 1, KH, *rest): head axis 3
                for s in range(tp):
                    sel = idx[:, :, :, s * kh_l : (s + 1) * kh_l]
                    cols[s].append(sel.reshape(-1))
                shape = (
                    leaf.shape[:3] + (kh_l,) + leaf.shape[4:]
                )
            else:
                for s in range(tp):
                    cols[s].append(idx.reshape(-1))
                shape = leaf.shape
            shard_vals.append(jax.ShapeDtypeStruct(shape, leaf.dtype))
        shard_struct = jax.tree_util.tree_unflatten(self.treedef, shard_vals)
        shard_layout = PagedLayout.from_struct(
            shard_struct, cache_len=self.cache_len,
            page_tokens=self.page_tokens,
        )
        return shard_layout, np.stack(
            [np.concatenate(c) for c in cols]
        )

    def unflatten(self, pages: jax.Array) -> Any:
        """(n_pages, page_elems) carrier pages -> cache pytree."""
        pages = jnp.asarray(pages)
        if pages.shape != (self.n_pages, self.page_elems):
            raise ValueError(
                f"pages {pages.shape} != layout "
                f"({self.n_pages}, {self.page_elems})"
            )
        vals = []
        for leaf in self.leaves:
            col = pages[:, leaf.offset : leaf.offset + leaf.size]
            moved = (
                (self.cache_len,)
                + leaf.shape[: leaf.axis]
                + leaf.shape[leaf.axis + 1 :]
            )
            x = jnp.moveaxis(col.reshape(moved), 0, leaf.axis)
            vals.append(kv_lib.carrier_uncast(x, leaf.dtype))
        return jax.tree_util.tree_unflatten(self.treedef, vals)


# --------------------------------------------------------------------------- #
# 2. Functional page allocator (refcounted free list)
# --------------------------------------------------------------------------- #
class PoolError(RuntimeError):
    """Base allocator error."""


class OutOfPagesError(PoolError):
    """The free list is empty (pool oversubscribed)."""


class DoubleFreeError(PoolError):
    """A page with no live references was freed again."""


@dataclasses.dataclass(frozen=True)
class PoolState:
    """Immutable allocator state: LIFO free list + per-page refcounts.

    A page is either *free* (refcount 0, on the free list exactly once)
    or *live* (refcount >= 1, not on the free list) — the invariant
    :func:`check_pool` asserts and the hypothesis suite hammers.
    """

    free: Tuple[int, ...]
    refcnt: Tuple[int, ...]

    @property
    def n_pages(self) -> int:
        return len(self.refcnt)

    @property
    def n_free(self) -> int:
        return len(self.free)

    @property
    def n_live(self) -> int:
        return self.n_pages - self.n_free


def make_pool(n_pages: int) -> PoolState:
    if n_pages < 1:
        raise ValueError(f"need at least one page, got {n_pages}")
    return PoolState(free=tuple(range(n_pages - 1, -1, -1)), refcnt=(0,) * n_pages)


def alloc(state: PoolState, n: int = 1) -> Tuple[PoolState, Tuple[int, ...]]:
    """Pop ``n`` pages off the free list (refcount 1 each)."""
    if n > state.n_free:
        raise OutOfPagesError(
            f"alloc({n}) with {state.n_free}/{state.n_pages} pages free"
        )
    pages = state.free[-n:][::-1] if n else ()
    refcnt = list(state.refcnt)
    for p in pages:
        refcnt[p] = 1
    return PoolState(state.free[: len(state.free) - n], tuple(refcnt)), pages


def fork(state: PoolState, pages: Sequence[int]) -> PoolState:
    """Add one reference to every page in ``pages`` (prefix sharing: a new
    request maps the same physical pages)."""
    refcnt = list(state.refcnt)
    for p in pages:
        if refcnt[p] < 1:
            raise PoolError(f"fork of free page {p}")
        refcnt[p] += 1
    return PoolState(state.free, tuple(refcnt))


def free(state: PoolState, pages: Sequence[int]) -> PoolState:
    """Drop one reference per page; pages reaching refcount 0 return to
    the free list.  Freeing an already-free page raises
    :class:`DoubleFreeError` (never silently corrupts the list)."""
    refcnt = list(state.refcnt)
    free_list = list(state.free)
    for p in pages:
        if not (0 <= p < len(refcnt)):
            raise PoolError(f"free of page {p} outside pool")
        if refcnt[p] < 1:
            raise DoubleFreeError(f"double free of page {p}")
        refcnt[p] -= 1
        if refcnt[p] == 0:
            free_list.append(p)
    return PoolState(tuple(free_list), tuple(refcnt))


def writable(state: PoolState, page: int) -> Tuple[PoolState, int, bool]:
    """Copy-on-write resolve: return ``(state, page', copied)`` where
    ``page'`` is safe to mutate for one owner.  A privately held page
    (refcount 1) is returned as-is; a shared page allocates a fresh page
    and drops one reference on the original — the caller copies the
    payload ``mem[page] -> mem[page']``."""
    if state.refcnt[page] < 1:
        raise PoolError(f"writable() on free page {page}")
    if state.refcnt[page] == 1:
        return state, page, False
    state, (fresh,) = alloc(state, 1)
    state = free(state, (page,))
    return state, fresh, True


def check_pool(
    state: PoolState,
    tables: Optional[Sequence[Sequence[int]]] = None,
    evicted: Optional[Sequence[Sequence[int]]] = None,
) -> None:
    """Assert the allocator invariant (used by the property tests).

    With ``tables`` (the resident page tables, possibly holding
    :data:`UNMATERIALIZED` slots) the check extends to the
    oversubscription seam: every materialised entry must be live and
    every reference must be table-borne — ``refcnt[p]`` equals the
    entry's multiplicity across tables, so unmaterialised slots carry no
    refcount and no page is referenced off the books.  With ``evicted``
    (the page tables of swapped-out requests, as snapshotted at
    preemption) the check asserts those requests hold NO pool reference:
    an evicted-but-referenced page lives in the memory tier, and its old
    physical page is either recycled or owned by surviving sharers —
    never still pinned by the preempted request."""
    if len(set(state.free)) != len(state.free):
        raise AssertionError(f"duplicate pages on free list: {state.free}")
    for p in state.free:
        if state.refcnt[p] != 0:
            raise AssertionError(f"page {p} free with refcount {state.refcnt[p]}")
    live = sum(1 for c in state.refcnt if c > 0)
    if live + state.n_free != state.n_pages:
        raise AssertionError(
            f"{live} live + {state.n_free} free != {state.n_pages} pages"
        )
    if tables is not None:
        counts = [0] * state.n_pages
        for t in tables:
            for p in t:
                if p == UNMATERIALIZED:
                    continue
                if not (0 <= p < state.n_pages):
                    raise AssertionError(f"table entry {p} outside pool")
                counts[p] += 1
        for p, (want, got) in enumerate(zip(counts, state.refcnt)):
            if want != got:
                raise AssertionError(
                    f"page {p}: {want} table reference(s) vs refcount {got}"
                )
    if evicted is not None:
        resident = (
            {p for t in tables for p in t if p != UNMATERIALIZED}
            if tables is not None
            else None
        )
        for t in evicted:
            for p in t:
                if p == UNMATERIALIZED:
                    continue
                if resident is not None and p in resident:
                    continue  # recycled to (or shared with) a live request
                if 0 <= p < state.n_pages and state.refcnt[p] != 0:
                    raise AssertionError(
                        f"evicted page {p} still holds refcount "
                        f"{state.refcnt[p]} with no table referencing it"
                    )


# --------------------------------------------------------------------------- #
# 3. One rank's pool shard: memory + tables + prefix index
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class AdmitPlan:
    """Placement decision for one request: its page table, which pages are
    fresh (must be written/transferred) vs prefix-shared (already
    resident — the transfer ships them ``pred=False``).  Lazy admissions
    leave the tail :data:`UNMATERIALIZED` (no physical page yet): those
    slots are neither fresh nor shared."""

    table: Tuple[int, ...]
    fresh: Tuple[bool, ...]

    @property
    def shared(self) -> Tuple[int, ...]:
        return tuple(
            p for p, f in zip(self.table, self.fresh)
            if not f and p != UNMATERIALIZED
        )

    @property
    def n_materialized(self) -> int:
        return sum(1 for p in self.table if p != UNMATERIALIZED)


class PagedKVStore:
    """One decode rank's shard of the global KV pool.

    ``mem`` is the rank's physical page array ``(n_pages, page_elems)``
    float32 — the host mirror of the rank's GASNet segment (the
    disaggregated cluster transfers pages into the segment one-sided and
    refreshes ``mem`` from it each tick; the colocated server writes it
    directly).  All bookkeeping (allocator state, page tables, prefix
    index) is host-side and functional at the allocator layer.

    Prefix sharing: a *full* prompt page (every one of its
    ``page_tokens`` positions covered by the prompt) is keyed by the
    token chain from position 0 through its last token.  ``admit`` of a
    prompt whose leading chain matches resident keys maps those physical
    pages into the new request's table (``fork``) instead of allocating;
    only the tail is fresh.  Decode never mutates a shared page — the
    first write past the prompt lands in the request's own tail page, and
    :func:`writable` copy-on-write protects the boundary page when the
    prompt length is not page-aligned.
    """

    def __init__(self, layout: PagedLayout, n_pages: int):
        self.layout = layout
        self.state = make_pool(n_pages)
        self.mem = np.zeros((n_pages, layout.page_elems), np.float32)
        self.tables: Dict[int, Tuple[int, ...]] = {}
        # full-page token chain -> resident physical page
        self._prefix: Dict[Tuple[int, ...], int] = {}
        self._page_key: Dict[int, Tuple[int, ...]] = {}
        self.prefix_hits = 0
        self.prefix_misses = 0
        # replica-aware swap bookkeeping (fault tolerance): how many pages
        # left this shard under each durability level, and which evicted
        # requests still have replicated tier copies
        self.swap_out_replica_pages = 0
        self.swapped_replicated: Dict[int, int] = {}

    # ------------------------------------------------------------------ #
    def plan_admit(self, prompt: Sequence[int], lazy: bool = False) -> AdmitPlan:
        """Allocate a page table for one request, prefix-sharing resident
        full prompt pages.  Pure allocator mutation; the payload write (or
        one-sided transfer) of the fresh pages happens separately.

        ``lazy=True`` materialises only the pages the prompt covers; the
        generation tail stays :data:`UNMATERIALIZED` and pages appear as
        positions are written (:meth:`prepare_write`) — so the pool can
        admit an aggregate logical demand larger than its physical
        capacity (oversubscription)."""
        pt = self.layout.page_tokens
        n_shareable = len(prompt) // pt  # only fully-covered prompt pages
        n_backed = (
            self.layout.pages_for(len(prompt)) if lazy else self.layout.n_pages
        )
        table: List[int] = []
        fresh: List[bool] = []
        prompt = tuple(int(t) for t in prompt)
        chain_live = True
        for p in range(self.layout.n_pages):
            if p >= n_backed:
                table.append(UNMATERIALIZED)
                fresh.append(False)
                continue
            page_id = None
            if chain_live and p < n_shareable:
                page_id = self._prefix.get(prompt[: (p + 1) * pt])
            if page_id is not None:
                self.state = fork(self.state, (page_id,))
                table.append(page_id)
                fresh.append(False)
                self.prefix_hits += 1
            else:
                chain_live = False  # sharing must be a leading run
                self.state, (new_page,) = alloc(self.state, 1)
                table.append(new_page)
                fresh.append(True)
                if p < n_shareable:
                    key = prompt[: (p + 1) * pt]
                    self._prefix[key] = new_page
                    self._page_key[new_page] = key
                    self.prefix_misses += 1
        return AdmitPlan(table=tuple(table), fresh=tuple(fresh))

    def commit(self, rid: int, plan: AdmitPlan) -> None:
        self.tables[rid] = plan.table

    def write_pages(self, plan: AdmitPlan, pages: Any) -> None:
        """Host write of the fresh pages (the colocated path; the
        disaggregated path lands them one-sided into the segment)."""
        pages = np.asarray(pages, np.float32)
        for p, (page_id, is_fresh) in enumerate(zip(plan.table, plan.fresh)):
            if is_fresh:
                self.mem[page_id] = pages[p]

    def admit(self, rid: int, prompt: Sequence[int], pages: Any) -> AdmitPlan:
        """plan + write + commit in one call (colocated server path)."""
        plan = self.plan_admit(prompt)
        self.write_pages(plan, pages)
        self.commit(rid, plan)
        return plan

    def prefix_match(self, prompt: Sequence[int]) -> int:
        """Number of leading full prompt pages already resident (the
        prefix-affinity routing signal: admit where the match is longest
        and those pages ship nothing)."""
        pt = self.layout.page_tokens
        prompt = tuple(int(t) for t in prompt)
        n = 0
        for p in range(len(prompt) // pt):
            if self._prefix.get(prompt[: (p + 1) * pt]) is None:
                break
            n += 1
        return n

    # ------------------------------------------------------------------ #
    def gather(self, rid: int) -> Any:
        """Read one request's cache back through its page table.
        Unmaterialised slots synthesise the absent page
        (:meth:`PagedLayout.empty_page_row`): a recycled physical page's
        stale bytes can never reach attention through a lazy table."""
        table = self.tables[rid]
        if all(p != UNMATERIALIZED for p in table):
            return self.layout.unflatten(self.mem[list(table)])
        empty = self.layout.empty_page_row()
        rows = np.stack(
            [self.mem[p] if p != UNMATERIALIZED else empty for p in table]
        )
        return self.layout.unflatten(rows)

    def page_table(self, rid: int) -> Tuple[int, ...]:
        return self.tables[rid]

    def freeable(self, rid: int) -> int:
        """Pages that would return to the free list if ``rid`` were
        evicted — refcount-aware: prefix-shared physical pages stay with
        their sharers, unmaterialised slots hold nothing.  The victim
        *value* signal the preemption scheduler sums."""
        table = self.tables.get(rid, ())
        return sum(
            1 for p in table
            if p != UNMATERIALIZED and self.state.refcnt[p] == 1
        )

    def device_table(self, rid: int, absent: int) -> Tuple[int, ...]:
        """The table with unmaterialised slots replaced by ``absent`` (a
        scratch physical page) — the form the paged-attention kernel
        consumes: every entry must be a valid physical id, and absent
        slots are masked by ``lengths`` anyway."""
        return tuple(
            absent if p == UNMATERIALIZED else p for p in self.tables[rid]
        )

    def prepare_write(self, rid: int, position: int) -> int:
        """Make the page holding ``position`` writable for ``rid`` and
        return its physical id: a lazy slot materialises (alloc), a
        shared page copy-on-write splits, and the written page leaves the
        prefix index (its chain no longer matches).  This is the
        bookkeeping half of a decode-step write; the payload lands either
        host-side (:meth:`write_token_page`) or on-device (the paged
        decode step scattering straight into the pool)."""
        table = list(self.tables[rid])
        p = position // self.layout.page_tokens
        page_id = table[p]
        if page_id == UNMATERIALIZED:
            self.state, (dst,) = alloc(self.state, 1)
            table[p] = dst
            self.tables[rid] = tuple(table)
            # a materialising page starts absent: synthesise its init row
            # so the bytes of whoever held it before never resurface
            self.mem[dst] = self.layout.empty_page_row()
        else:
            self.state, dst, copied = writable(self.state, page_id)
            if copied:
                table[p] = dst
                self.tables[rid] = tuple(table)
                # COW payload copy: the fresh page starts as a bit-exact
                # copy of the shared original
                self.mem[dst] = self.mem[page_id]
        # a mutated page no longer matches its prompt chain: drop the key
        key = self._page_key.pop(dst, None)
        if key is not None and self._prefix.get(key) == dst:
            del self._prefix[key]
        return dst

    def write_token_page(self, rid: int, position: int, page_row: Any) -> int:
        """Install the page holding ``position`` after a decode step wrote
        that token.  ``page_row`` must be the page's FULL carrier row
        (``PagedLayout.flatten_page``).  Copy-on-write and lazy
        materialisation via :meth:`prepare_write`.  Returns the physical
        page written."""
        dst = self.prepare_write(rid, position)
        self.mem[dst] = np.asarray(page_row, np.float32)
        return dst

    def materialize_through(self, rid: int, n_pages: int) -> Tuple[int, ...]:
        """Allocate physical pages for every unmaterialised slot among the
        first ``n_pages`` logical pages (the pre-swap staging step: a
        victim's decode-written positions must have pool pages to ship
        from).  Returns the freshly allocated physical ids; the caller
        stages their payloads."""
        table = list(self.tables[rid])
        fresh: List[int] = []
        try:
            for p in range(min(int(n_pages), len(table))):
                if table[p] == UNMATERIALIZED:
                    self.state, (pp,) = alloc(self.state, 1)
                    table[p] = pp
                    fresh.append(pp)
        except OutOfPagesError:
            # transactional: a partial materialisation must not leak the
            # pages it already took (the caller falls back to recompute)
            if fresh:
                self.state = free(self.state, fresh)
            raise
        self.tables[rid] = tuple(table)
        return tuple(fresh)

    def _drop_refs(self, table: Sequence[int]) -> None:
        live = [p for p in table if p != UNMATERIALIZED]
        self.state = free(self.state, live)
        for page_id in live:
            if self.state.refcnt[page_id] == 0:
                key = self._page_key.pop(page_id, None)
                if key is not None and self._prefix.get(key) == page_id:
                    del self._prefix[key]

    def release(self, rid: int) -> None:
        """Drop one request's references; pages whose last reference drops
        leave the prefix index with them.  Unmaterialised slots hold no
        reference."""
        self._drop_refs(self.tables.pop(rid))

    def evict_request(self, rid: int) -> Tuple[Tuple[int, int], ...]:
        """Preempt ``rid``: return its materialised ``(logical, physical)``
        page pairs, then drop every reference exactly like
        :meth:`release`.  Refcount-aware by construction: a physical page
        still referenced by a running request (prefix-shared) merely loses
        this request's reference — its bytes stay resident for the
        sharers and are never invalidated.  The caller must have captured
        (or swapped out) the payloads *before* evicting, since a fully
        dropped page may be recycled immediately."""
        table = self.tables[rid]
        pairs = tuple(
            (lp, pp) for lp, pp in enumerate(table) if pp != UNMATERIALIZED
        )
        self._drop_refs(self.tables.pop(rid))
        return pairs

    def admit_resume(self, rid: int, logical_pages: Sequence[int]) -> Tuple[int, ...]:
        """Re-admit a preempted request: allocate fresh physical pages for
        its previously materialised logical pages (the swap-in
        destination); the rest of the table stays unmaterialised.
        Resumed tables do not re-enter the prefix index — their chains
        may have diverged from the resident prompts."""
        logical = sorted(int(p) for p in logical_pages)
        self.state, phys = alloc(self.state, len(logical))
        table = [UNMATERIALIZED] * self.layout.n_pages
        for lp, pp in zip(logical, phys):
            table[lp] = pp
        self.tables[rid] = tuple(table)
        return phys

    # ---- replica-aware swap bookkeeping (fault tolerance) ------------- #
    def shared_page_count(self, rid: int) -> int:
        """Materialised pages of ``rid`` referenced by MORE than one table
        — the hot/prefix-shared pages whose tier swap-outs are worth
        replicating (losing them loses every sharer's prefix)."""
        table = self.tables.get(rid, ())
        return sum(
            1
            for p in table
            if p != UNMATERIALIZED and self.state.refcnt[p] > 1
        )

    def note_swap_out(self, rid: int, n_pages: int, replicas: int = 0) -> None:
        """Record that ``rid``'s swap-out left this shard with
        ``replicas`` EXTRA tier copies (0 = unreplicated).  Purely
        bookkeeping — the tier owns the placements; the pool remembers
        the durability so recovery can tell swap-resume from recompute."""
        if replicas > 0:
            self.swap_out_replica_pages += int(n_pages) * int(replicas)
            self.swapped_replicated[rid] = int(replicas)

    def note_swap_in(self, rid: int) -> None:
        """Forget a swapped request's replica record (resume or abort)."""
        self.swapped_replicated.pop(rid, None)

    # ---- prefix-index migration (elastic scale-out) ------------------- #
    def prefix_entries(self) -> List[Tuple[Tuple[int, ...], int]]:
        """The resident prefix index as ``(chain_key, physical_page)``
        rows, shortest chains first — adoption order must follow chain
        order so a capped migration still transfers usable leading runs
        (``prefix_match`` walks keys from the front)."""
        return sorted(self._prefix.items(), key=lambda kv: len(kv[0]))

    def adopt_prefix(
        self, entries: Sequence[Tuple[Tuple[int, ...], int]]
    ) -> List[Tuple[int, int]]:
        """Adopt a donor's prefix index: allocate one local physical page
        per new chain key and index it, owned by the
        :data:`PREFIX_CACHE_RID` pseudo-table (live, shareable, owned by
        no request).  Returns ``(donor_physical, local_physical)`` pairs —
        the vectored-RMA transfer list; the PAYLOAD bytes must land at
        the local pages (over the wire) before any sharer decodes.
        Already-present keys are skipped; stops early when the pool
        cannot fit another page."""
        adopted: List[Tuple[int, int]] = []
        cache = list(self.tables.get(PREFIX_CACHE_RID, ()))
        for key, donor_pp in entries:
            key = tuple(int(t) for t in key)
            if key in self._prefix:
                continue
            try:
                self.state, (pp,) = alloc(self.state, 1)
            except OutOfPagesError:
                break
            self._prefix[key] = pp
            self._page_key[pp] = key
            cache.append(pp)
            adopted.append((int(donor_pp), pp))
        if cache:
            self.tables[PREFIX_CACHE_RID] = tuple(cache)
        return adopted

    def release_prefix_cache(self) -> int:
        """Drop every adopted-but-unowned prefix page (pressure relief or
        shutdown); pages shared with live requests stay with them."""
        table = self.tables.pop(PREFIX_CACHE_RID, ())
        self._drop_refs(table)
        return len(table)

    def pin_pages(self, pages: Sequence[int]) -> None:
        """Hold an extra reference on ``pages`` (a migration donor's
        transfer set) under the :data:`PIN_RID` pseudo-table so retiring
        owners cannot recycle them while the bytes are on the wire."""
        pages = tuple(int(p) for p in pages)
        self.state = fork(self.state, pages)
        self.tables[PIN_RID] = self.tables.get(PIN_RID, ()) + pages

    def unpin_pages(self) -> None:
        """Drop every migration pin (the transfer landed or aborted)."""
        self._drop_refs(self.tables.pop(PIN_RID, ()))

    # ------------------------------------------------------------------ #
    @property
    def n_free(self) -> int:
        return self.state.n_free

    def stats(self) -> Dict[str, int]:
        return {
            "n_pages": self.state.n_pages,
            "n_free": self.state.n_free,
            "prefix_hits": self.prefix_hits,
            "prefix_misses": self.prefix_misses,
            "swap_out_replica_pages": self.swap_out_replica_pages,
            "prefix_cache_pages": len(self.tables.get(PREFIX_CACHE_RID, ())),
        }


# --------------------------------------------------------------------------- #
# 4. The global address space + split-phase vectored page fetch
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class PoolMap:
    """Global page addressing over the sharded pool segment: decode rank
    ``r`` owns local pages ``[0, pages_per_rank)``; global page ``g``
    lives at flat carrier offset ``local(g) * page_elems`` of rank
    ``owner(g)``'s partition — a (node, index) global address exactly as
    in ``core.addrspace``."""

    n_ranks: int
    pages_per_rank: int
    page_elems: int

    @property
    def n_pages(self) -> int:
        return self.n_ranks * self.pages_per_rank

    def owner(self, g: int) -> int:
        return int(g) // self.pages_per_rank

    def local(self, g: int) -> int:
        return int(g) % self.pages_per_rank

    def global_id(self, rank: int, local: int) -> int:
        return int(rank) * self.pages_per_rank + int(local)

    def offset(self, g) -> Any:
        """Flat carrier offset of a (possibly traced) global page id in
        its owner's partition."""
        return (jnp.asarray(g, jnp.int32) % self.pages_per_rank) * self.page_elems


def fetch_pages(
    node: Any,
    seg: jax.Array,
    page_offsets: jax.Array,
    *,
    frm: Any,
    page_elems: int,
    plan: Optional[sched.CollectivePlan] = None,
    n_batches: Optional[int] = None,
    costs: Optional[Dict[str, sched.EngineCost]] = None,
    pred: jax.Array | bool | None = None,
) -> Tuple[List[Any], sched.CollectivePlan]:
    """Initiate the split-phase prefetch of remote KV pages.

    ``page_offsets`` are flat carrier offsets in the source partition
    (``PoolMap.offset`` of each global page id).  The fetch is issued as
    vectored gets (``node.get_nbv`` — m offsets per request/reply pair);
    ``sched.plan_p2p`` on the total byte count picks how many batches to
    keep in flight, so the wire overlaps the attention step the caller
    runs before :func:`sync_fetch`.

    Returns ``(handles, plan)``.
    """
    offs = jnp.asarray(page_offsets, jnp.int32).reshape(-1)
    m = int(offs.shape[0])
    if plan is None:
        plan = sched.plan_p2p(
            nbytes=m * page_elems * 4, engine=node.engine, costs=costs
        )
    g = int(plan.n_segments if n_batches is None else n_batches)
    handles = []
    for start, count in kv_lib.segment_bounds(m, g):
        handles.append(
            node.get_nbv(
                seg,
                frm=frm,
                indices=offs[start : start + count],
                size=page_elems,
                pred=pred,
            )
        )
    return handles, plan


def sync_fetch(node: Any, handles: Sequence[Any]) -> jax.Array:
    """Drain one prefetch's handles in issue order; returns the
    ``(n_pages, page_elems)`` carrier stack."""
    return jnp.concatenate([node.sync(h) for h in handles], axis=0)

"""Global paged KV pool: a PGAS page allocator for prefix-shared serving.

PR 3 shipped KV caches between prefill and decode ranks as opaque dense
blocks.  This module applies the paper's addressing model — every node
reads and writes one partitioned global address space with one-sided
operations — to the hottest serving data structure: the KV cache becomes
a pool of fixed-size token *pages* living in a GASNet segment sharded
across the decode ranks, and requests hold *page tables* instead of
memory.

Four layers, host-side state functional throughout:

1. :class:`PagedLayout` — the carrier format: cut a request cache's token
   axis (``cache_len``) into ``n_pages`` pages of ``page_tokens`` each;
   every page is one contiguous float32 carrier vector (``page_elems``),
   bit-transparent like :class:`~repro.serving.kv.KVLayout` (int leaves
   bitcast, half floats widened exactly).
2. The **functional free-list allocator** — :class:`PoolState` is an
   immutable value; :func:`alloc` / :func:`free` / :func:`fork` /
   :func:`writable` return new states.  Pages are refcounted:
   :func:`fork` shares a page between requests (prefix sharing),
   :func:`free` returns it to the free list only when the last reference
   drops, and :func:`writable` is copy-on-write — a shared page is never
   mutated in place.
3. :class:`PagedKVStore` — one rank's pool shard: the physical page
   memory (``mem`` aliases the rank's GASNet segment in the
   disaggregated cluster), the allocator state, per-request page tables,
   and the prompt-prefix index that maps a full-page token chain to the
   resident physical page, so two requests with a common prompt prefix
   resolve to the *same physical pages* and only the divergent tail is
   ever transferred or stored.
4. :class:`PoolMap` + :func:`fetch_pages` — the global address space:
   global page ``g`` lives at flat offset ``local(g) * page_elems`` of
   rank ``owner(g)``'s segment, and a decode rank prefetches remote
   pages with the vectored split-phase get (``Node.get_nbv`` — one
   request/reply pair per planned batch, batch count from
   ``sched.plan_p2p``), overlapping the fetch with its attention step.

The compute side is ``repro.kernels.paged_attention``: decode attention
reading K/V directly through the page table.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sched
from repro.serving import kv as kv_lib

__all__ = [
    "PagedLayout",
    "PageLeafSpec",
    "token_axis",
    "PoolState",
    "PoolError",
    "OutOfPagesError",
    "DoubleFreeError",
    "make_pool",
    "alloc",
    "free",
    "fork",
    "writable",
    "check_pool",
    "AdmitPlan",
    "PagedKVStore",
    "PoolMap",
    "fetch_pages",
    "sync_fetch",
]


# --------------------------------------------------------------------------- #
# 1. Page-granular carrier layout
# --------------------------------------------------------------------------- #
def token_axis(shape: Sequence[int], cache_len: int) -> int:
    """Index of the token (cache) axis in one cache-leaf shape: the unique
    axis of size ``cache_len``.  Raises when the leaf has no such axis or
    the size is ambiguous — paging needs an unambiguous cut."""
    hits = [i for i, d in enumerate(shape) if int(d) == int(cache_len)]
    if len(hits) != 1:
        raise ValueError(
            f"cannot locate the token axis of cache leaf {tuple(shape)}: "
            f"{len(hits)} axes of size {cache_len}"
        )
    return hits[0]


@dataclasses.dataclass(frozen=True)
class PageLeafSpec:
    """One cache leaf's per-page slice of the carrier page."""

    shape: Tuple[int, ...]  # full leaf shape
    dtype: Any
    axis: int  # token axis
    offset: int  # start column inside the carrier page
    size: int  # carrier elements per page for this leaf


class PagedLayout:
    """Static page layout of one request's KV cache.

    Built once from an abstract cache pytree (``Model.kv_block_struct``);
    :meth:`flatten` / :meth:`unflatten` round-trip any concrete cache of
    that structure through an ``(n_pages, page_elems)`` float32 carrier
    array, bit-exactly.  Page ``p`` carries token positions
    ``[p * page_tokens, (p + 1) * page_tokens)`` of every leaf.
    """

    def __init__(
        self,
        treedef: Any,
        leaves: List[PageLeafSpec],
        cache_len: int,
        page_tokens: int,
    ):
        self.treedef = treedef
        self.leaves = leaves
        self.cache_len = int(cache_len)
        self.page_tokens = int(page_tokens)
        self.n_pages = self.cache_len // self.page_tokens
        self.page_elems = sum(leaf.size for leaf in leaves)

    @classmethod
    def from_struct(
        cls, struct: Any, *, cache_len: int, page_tokens: int
    ) -> "PagedLayout":
        if cache_len % page_tokens:
            raise ValueError(
                f"cache_len={cache_len} not a multiple of "
                f"page_tokens={page_tokens}"
            )
        leaf_structs, treedef = jax.tree_util.tree_flatten(struct)
        leaves: List[PageLeafSpec] = []
        offset = 0
        for s in leaf_structs:
            ax = token_axis(s.shape, cache_len)
            size = 1
            for i, d in enumerate(s.shape):
                size *= int(page_tokens) if i == ax else int(d)
            leaves.append(
                PageLeafSpec(
                    shape=tuple(int(d) for d in s.shape),
                    dtype=jnp.dtype(s.dtype),
                    axis=ax,
                    offset=offset,
                    size=size,
                )
            )
            offset += size
        return cls(treedef, leaves, cache_len, page_tokens)

    @property
    def page_bytes(self) -> int:
        return self.page_elems * 4  # float32 carrier

    def flatten(self, caches: Any) -> jax.Array:
        """Cache pytree -> (n_pages, page_elems) float32 carrier pages."""
        vals = jax.tree_util.tree_leaves(caches)
        if len(vals) != len(self.leaves):
            raise ValueError(
                f"cache has {len(vals)} leaves, layout expects "
                f"{len(self.leaves)}"
            )
        cols = []
        for v, leaf in zip(vals, self.leaves):
            if tuple(v.shape) != leaf.shape:
                raise ValueError(f"cache leaf {tuple(v.shape)} != layout {leaf.shape}")
            c = jnp.moveaxis(kv_lib.carrier_cast(v), leaf.axis, 0)
            cols.append(c.reshape(self.n_pages, leaf.size))
        return jnp.concatenate(cols, axis=1)

    def flatten_page(self, caches: Any, page: int) -> jax.Array:
        """One page's carrier row (``(page_elems,)``) without flattening
        the rest of the cache — the per-decode-step writeback path only
        touches the page holding the new token."""
        if not (0 <= page < self.n_pages):
            raise ValueError(f"page {page} outside [0, {self.n_pages})")
        vals = jax.tree_util.tree_leaves(caches)
        lo = page * self.page_tokens
        cols = []
        for v, leaf in zip(vals, self.leaves):
            if tuple(v.shape) != leaf.shape:
                raise ValueError(f"cache leaf {tuple(v.shape)} != layout {leaf.shape}")
            window = jax.lax.slice_in_dim(v, lo, lo + self.page_tokens, axis=leaf.axis)
            c = jnp.moveaxis(kv_lib.carrier_cast(window), leaf.axis, 0)
            cols.append(c.reshape(leaf.size))
        return jnp.concatenate(cols)

    def page_struct(self) -> Any:
        """Abstract pytree of ONE page: every leaf's token axis cut from
        ``cache_len`` to ``page_tokens`` (the unit the pool allocates)."""
        vals = [
            jax.ShapeDtypeStruct(
                tuple(
                    self.page_tokens if i == leaf.axis else d
                    for i, d in enumerate(leaf.shape)
                ),
                leaf.dtype,
            )
            for leaf in self.leaves
        ]
        return jax.tree_util.tree_unflatten(self.treedef, vals)

    def unflatten(self, pages: jax.Array) -> Any:
        """(n_pages, page_elems) carrier pages -> cache pytree."""
        pages = jnp.asarray(pages)
        if pages.shape != (self.n_pages, self.page_elems):
            raise ValueError(
                f"pages {pages.shape} != layout "
                f"({self.n_pages}, {self.page_elems})"
            )
        vals = []
        for leaf in self.leaves:
            col = pages[:, leaf.offset : leaf.offset + leaf.size]
            moved = (
                (self.cache_len,)
                + leaf.shape[: leaf.axis]
                + leaf.shape[leaf.axis + 1 :]
            )
            x = jnp.moveaxis(col.reshape(moved), 0, leaf.axis)
            vals.append(kv_lib.carrier_uncast(x, leaf.dtype))
        return jax.tree_util.tree_unflatten(self.treedef, vals)


# --------------------------------------------------------------------------- #
# 2. Functional page allocator (refcounted free list)
# --------------------------------------------------------------------------- #
class PoolError(RuntimeError):
    """Base allocator error."""


class OutOfPagesError(PoolError):
    """The free list is empty (pool oversubscribed)."""


class DoubleFreeError(PoolError):
    """A page with no live references was freed again."""


@dataclasses.dataclass(frozen=True)
class PoolState:
    """Immutable allocator state: LIFO free list + per-page refcounts.

    A page is either *free* (refcount 0, on the free list exactly once)
    or *live* (refcount >= 1, not on the free list) — the invariant
    :func:`check_pool` asserts and the hypothesis suite hammers.
    """

    free: Tuple[int, ...]
    refcnt: Tuple[int, ...]

    @property
    def n_pages(self) -> int:
        return len(self.refcnt)

    @property
    def n_free(self) -> int:
        return len(self.free)

    @property
    def n_live(self) -> int:
        return self.n_pages - self.n_free


def make_pool(n_pages: int) -> PoolState:
    if n_pages < 1:
        raise ValueError(f"need at least one page, got {n_pages}")
    return PoolState(free=tuple(range(n_pages - 1, -1, -1)), refcnt=(0,) * n_pages)


def alloc(state: PoolState, n: int = 1) -> Tuple[PoolState, Tuple[int, ...]]:
    """Pop ``n`` pages off the free list (refcount 1 each)."""
    if n > state.n_free:
        raise OutOfPagesError(
            f"alloc({n}) with {state.n_free}/{state.n_pages} pages free"
        )
    pages = state.free[-n:][::-1] if n else ()
    refcnt = list(state.refcnt)
    for p in pages:
        refcnt[p] = 1
    return PoolState(state.free[: len(state.free) - n], tuple(refcnt)), pages


def fork(state: PoolState, pages: Sequence[int]) -> PoolState:
    """Add one reference to every page in ``pages`` (prefix sharing: a new
    request maps the same physical pages)."""
    refcnt = list(state.refcnt)
    for p in pages:
        if refcnt[p] < 1:
            raise PoolError(f"fork of free page {p}")
        refcnt[p] += 1
    return PoolState(state.free, tuple(refcnt))


def free(state: PoolState, pages: Sequence[int]) -> PoolState:
    """Drop one reference per page; pages reaching refcount 0 return to
    the free list.  Freeing an already-free page raises
    :class:`DoubleFreeError` (never silently corrupts the list)."""
    refcnt = list(state.refcnt)
    free_list = list(state.free)
    for p in pages:
        if not (0 <= p < len(refcnt)):
            raise PoolError(f"free of page {p} outside pool")
        if refcnt[p] < 1:
            raise DoubleFreeError(f"double free of page {p}")
        refcnt[p] -= 1
        if refcnt[p] == 0:
            free_list.append(p)
    return PoolState(tuple(free_list), tuple(refcnt))


def writable(state: PoolState, page: int) -> Tuple[PoolState, int, bool]:
    """Copy-on-write resolve: return ``(state, page', copied)`` where
    ``page'`` is safe to mutate for one owner.  A privately held page
    (refcount 1) is returned as-is; a shared page allocates a fresh page
    and drops one reference on the original — the caller copies the
    payload ``mem[page] -> mem[page']``."""
    if state.refcnt[page] < 1:
        raise PoolError(f"writable() on free page {page}")
    if state.refcnt[page] == 1:
        return state, page, False
    state, (fresh,) = alloc(state, 1)
    state = free(state, (page,))
    return state, fresh, True


def check_pool(state: PoolState) -> None:
    """Assert the allocator invariant (used by the property tests)."""
    if len(set(state.free)) != len(state.free):
        raise AssertionError(f"duplicate pages on free list: {state.free}")
    for p in state.free:
        if state.refcnt[p] != 0:
            raise AssertionError(f"page {p} free with refcount {state.refcnt[p]}")
    live = sum(1 for c in state.refcnt if c > 0)
    if live + state.n_free != state.n_pages:
        raise AssertionError(
            f"{live} live + {state.n_free} free != {state.n_pages} pages"
        )


# --------------------------------------------------------------------------- #
# 3. One rank's pool shard: memory + tables + prefix index
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class AdmitPlan:
    """Placement decision for one request: its page table, which pages are
    fresh (must be written/transferred) vs prefix-shared (already
    resident — the transfer ships them ``pred=False``)."""

    table: Tuple[int, ...]
    fresh: Tuple[bool, ...]

    @property
    def shared(self) -> Tuple[int, ...]:
        return tuple(p for p, f in zip(self.table, self.fresh) if not f)


class PagedKVStore:
    """One decode rank's shard of the global KV pool.

    ``mem`` is the rank's physical page array ``(n_pages, page_elems)``
    float32 — the host mirror of the rank's GASNet segment (the
    disaggregated cluster transfers pages into the segment one-sided and
    refreshes ``mem`` from it each tick; the colocated server writes it
    directly).  All bookkeeping (allocator state, page tables, prefix
    index) is host-side and functional at the allocator layer.

    Prefix sharing: a *full* prompt page (every one of its
    ``page_tokens`` positions covered by the prompt) is keyed by the
    token chain from position 0 through its last token.  ``admit`` of a
    prompt whose leading chain matches resident keys maps those physical
    pages into the new request's table (``fork``) instead of allocating;
    only the tail is fresh.  Decode never mutates a shared page — the
    first write past the prompt lands in the request's own tail page, and
    :func:`writable` copy-on-write protects the boundary page when the
    prompt length is not page-aligned.
    """

    def __init__(self, layout: PagedLayout, n_pages: int):
        self.layout = layout
        self.state = make_pool(n_pages)
        self.mem = np.zeros((n_pages, layout.page_elems), np.float32)
        self.tables: Dict[int, Tuple[int, ...]] = {}
        # full-page token chain -> resident physical page
        self._prefix: Dict[Tuple[int, ...], int] = {}
        self._page_key: Dict[int, Tuple[int, ...]] = {}
        self.prefix_hits = 0
        self.prefix_misses = 0

    # ------------------------------------------------------------------ #
    def plan_admit(self, prompt: Sequence[int]) -> AdmitPlan:
        """Allocate a page table for one request, prefix-sharing resident
        full prompt pages.  Pure allocator mutation; the payload write (or
        one-sided transfer) of the fresh pages happens separately."""
        pt = self.layout.page_tokens
        n_shareable = len(prompt) // pt  # only fully-covered prompt pages
        table: List[int] = []
        fresh: List[bool] = []
        prompt = tuple(int(t) for t in prompt)
        chain_live = True
        for p in range(self.layout.n_pages):
            page_id = None
            if chain_live and p < n_shareable:
                page_id = self._prefix.get(prompt[: (p + 1) * pt])
            if page_id is not None:
                self.state = fork(self.state, (page_id,))
                table.append(page_id)
                fresh.append(False)
                self.prefix_hits += 1
            else:
                chain_live = False  # sharing must be a leading run
                self.state, (new_page,) = alloc(self.state, 1)
                table.append(new_page)
                fresh.append(True)
                if p < n_shareable:
                    key = prompt[: (p + 1) * pt]
                    self._prefix[key] = new_page
                    self._page_key[new_page] = key
                    self.prefix_misses += 1
        return AdmitPlan(table=tuple(table), fresh=tuple(fresh))

    def commit(self, rid: int, plan: AdmitPlan) -> None:
        self.tables[rid] = plan.table

    def write_pages(self, plan: AdmitPlan, pages: Any) -> None:
        """Host write of the fresh pages (the colocated path; the
        disaggregated path lands them one-sided into the segment)."""
        pages = np.asarray(pages, np.float32)
        for p, (page_id, is_fresh) in enumerate(zip(plan.table, plan.fresh)):
            if is_fresh:
                self.mem[page_id] = pages[p]

    def admit(self, rid: int, prompt: Sequence[int], pages: Any) -> AdmitPlan:
        """plan + write + commit in one call (colocated server path)."""
        plan = self.plan_admit(prompt)
        self.write_pages(plan, pages)
        self.commit(rid, plan)
        return plan

    def prefix_match(self, prompt: Sequence[int]) -> int:
        """Number of leading full prompt pages already resident (the
        prefix-affinity routing signal: admit where the match is longest
        and those pages ship nothing)."""
        pt = self.layout.page_tokens
        prompt = tuple(int(t) for t in prompt)
        n = 0
        for p in range(len(prompt) // pt):
            if self._prefix.get(prompt[: (p + 1) * pt]) is None:
                break
            n += 1
        return n

    # ------------------------------------------------------------------ #
    def gather(self, rid: int) -> Any:
        """Read one request's cache back through its page table."""
        return self.layout.unflatten(self.mem[list(self.tables[rid])])

    def page_table(self, rid: int) -> Tuple[int, ...]:
        return self.tables[rid]

    def write_token_page(self, rid: int, position: int, page_row: Any) -> int:
        """Install the page holding ``position`` after a decode step wrote
        that token.  ``page_row`` must be the page's FULL carrier row
        (``PagedLayout.flatten_page``).  Copy-on-write: if the page is
        still shared with another request, the request's table is
        repointed at a fresh page first (no payload copy needed — the
        full row lands below).  Returns the physical page written."""
        table = list(self.tables[rid])
        p = position // self.layout.page_tokens
        page_id = table[p]
        self.state, dst, copied = writable(self.state, page_id)
        if copied:
            table[p] = dst
            self.tables[rid] = tuple(table)
        # a mutated page no longer matches its prompt chain: drop the key
        key = self._page_key.pop(dst, None)
        if key is not None and self._prefix.get(key) == dst:
            del self._prefix[key]
        self.mem[dst] = np.asarray(page_row, np.float32)
        return dst

    def release(self, rid: int) -> None:
        """Drop one request's references; pages whose last reference drops
        leave the prefix index with them."""
        table = self.tables.pop(rid)
        self.state = free(self.state, table)
        for page_id in table:
            if self.state.refcnt[page_id] == 0:
                key = self._page_key.pop(page_id, None)
                if key is not None and self._prefix.get(key) == page_id:
                    del self._prefix[key]

    # ------------------------------------------------------------------ #
    @property
    def n_free(self) -> int:
        return self.state.n_free

    def stats(self) -> Dict[str, int]:
        return {
            "n_pages": self.state.n_pages,
            "n_free": self.state.n_free,
            "prefix_hits": self.prefix_hits,
            "prefix_misses": self.prefix_misses,
        }


# --------------------------------------------------------------------------- #
# 4. The global address space + split-phase vectored page fetch
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class PoolMap:
    """Global page addressing over the sharded pool segment: decode rank
    ``r`` owns local pages ``[0, pages_per_rank)``; global page ``g``
    lives at flat carrier offset ``local(g) * page_elems`` of rank
    ``owner(g)``'s partition — a (node, index) global address exactly as
    in ``core.addrspace``."""

    n_ranks: int
    pages_per_rank: int
    page_elems: int

    @property
    def n_pages(self) -> int:
        return self.n_ranks * self.pages_per_rank

    def owner(self, g: int) -> int:
        return int(g) // self.pages_per_rank

    def local(self, g: int) -> int:
        return int(g) % self.pages_per_rank

    def global_id(self, rank: int, local: int) -> int:
        return int(rank) * self.pages_per_rank + int(local)

    def offset(self, g) -> Any:
        """Flat carrier offset of a (possibly traced) global page id in
        its owner's partition."""
        return (jnp.asarray(g, jnp.int32) % self.pages_per_rank) * self.page_elems


def fetch_pages(
    node: Any,
    seg: jax.Array,
    page_offsets: jax.Array,
    *,
    frm: Any,
    page_elems: int,
    plan: Optional[sched.CollectivePlan] = None,
    n_batches: Optional[int] = None,
    costs: Optional[Dict[str, sched.EngineCost]] = None,
    pred: jax.Array | bool | None = None,
) -> Tuple[List[Any], sched.CollectivePlan]:
    """Initiate the split-phase prefetch of remote KV pages.

    ``page_offsets`` are flat carrier offsets in the source partition
    (``PoolMap.offset`` of each global page id).  The fetch is issued as
    vectored gets (``node.get_nbv`` — m offsets per request/reply pair);
    ``sched.plan_p2p`` on the total byte count picks how many batches to
    keep in flight, so the wire overlaps the attention step the caller
    runs before :func:`sync_fetch`.

    Returns ``(handles, plan)``.
    """
    offs = jnp.asarray(page_offsets, jnp.int32).reshape(-1)
    m = int(offs.shape[0])
    if plan is None:
        plan = sched.plan_p2p(
            nbytes=m * page_elems * 4, engine=node.engine, costs=costs
        )
    g = int(plan.n_segments if n_batches is None else n_batches)
    handles = []
    for start, count in kv_lib.segment_bounds(m, g):
        handles.append(
            node.get_nbv(
                seg,
                frm=frm,
                indices=offs[start : start + count],
                size=page_elems,
                pred=pred,
            )
        )
    return handles, plan


def sync_fetch(node: Any, handles: Sequence[Any]) -> jax.Array:
    """Drain one prefetch's handles in issue order; returns the
    ``(n_pages, page_elems)`` carrier stack."""
    return jnp.concatenate([node.sync(h) for h in handles], axis=0)

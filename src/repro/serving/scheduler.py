"""SLO-aware preemptive admission scheduling over the paged KV pool.

PR 4's pool rejected work it could not place: ``plan_admit`` booked a full
page table up front, so the pool could never oversubscribe and a burst
larger than physical memory simply waited.  With lazy allocation
(:func:`repro.serving.pool.PagedKVStore.plan_admit` ``lazy=True``) the
pool *does* oversubscribe — aggregate logical demand may exceed physical
pages — and something must arbitrate when the free list runs dry.  This
module is that arbiter:

- **Admission** is a priority queue with deadlines: each request carries
  an :class:`SLO` (priority, TTFT deadline, TPOT deadline) and admission
  order is priority-major, earliest-deadline-first within a priority.
  Preempted requests outrank new admissions of the same priority
  (resume-first), so a victim is never starved by a stream of fresh
  arrivals it keeps paying for.
- **Preemption** picks victims when free pages run out: lowest priority
  first, most-recently-admitted within a priority (least progress lost),
  and never a victim whose priority exceeds the beneficiary's — a
  preemption chain therefore strictly descends and cannot cycle.  Victim
  *value* is refcount-aware: the freeable-page count the caller supplies
  should count only pages whose last reference the victim holds
  (prefix-shared physical pages stay resident for their sharers and are
  never invalidated — see ``PagedKVStore.evict_request``).
- **Swap vs recompute** is priced per victim with the measured
  :class:`~repro.core.sched.EngineCost` β model (``BENCH_gas.json``):
  swapping costs two vectored transfers of the victim's resident bytes
  (out now, in at resume); recomputing costs one prefill plus replaying
  every generated token through the decode step.  Short-lived requests
  with few generated tokens recompute; page-heavy long decodes swap.

The scheduler is pure host-side bookkeeping over opaque request ids —
the colocated :class:`~repro.launch.serve.PagedServer`, the
disaggregated cluster, and the hypothesis property tests all drive the
same object.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core import sched
from repro.obs.metrics import Registry, counter_property

__all__ = [
    "SLO",
    "swap_or_recompute",
    "AdmissionScheduler",
]


@dataclasses.dataclass(frozen=True)
class SLO:
    """Service-level objective of one request.

    ``priority`` is strict (higher preempts lower); the deadlines are
    *soft* ordering signals — TTFT orders admission within a priority,
    TPOT breaks ties among preemption victims (the request with the most
    deadline slack is evicted first)."""

    priority: int = 0
    ttft_deadline_s: float = math.inf
    tpot_deadline_s: float = math.inf


def swap_or_recompute(
    n_pages: int,
    page_bytes: int,
    generated_tokens: int,
    cost: sched.EngineCost,
    *,
    decode_step_us: float = 2000.0,
    prefill_us: float = 4000.0,
) -> Tuple[str, float, float]:
    """Price the two ways to preempt one victim and pick the cheaper.

    Swap = one vectored put now + one vectored get at resume, both
    carrying the victim's resident pages (α + β·KiB each way, the
    measured transport constants).  Recompute = drop the pages, then at
    resume one prefill plus one decode step per already-generated token
    (the replay that rebuilds the cache bit-identically).  Returns
    ``(mode, swap_us, recompute_us)``.
    """
    kib = n_pages * page_bytes / 1024.0
    swap_us = 2.0 * (cost.alpha_us + cost.beta_us_per_kib * kib)
    recompute_us = prefill_us + generated_tokens * decode_step_us
    mode = "swap" if swap_us <= recompute_us else "recompute"
    return mode, swap_us, recompute_us


@dataclasses.dataclass
class _Entry:
    rid: int
    slo: SLO
    t_submit: float
    prompt_len: int
    state: str = "queued"  # queued | running | preempted | done
    generated: int = 0
    t_admitted: float = 0.0
    admit_seq: int = 0
    preempt_mode: Optional[str] = None
    preempts: int = 0


class AdmissionScheduler:
    """The host-side arbiter (see module docstring).

    ``cost`` defaults to the software-node constants; pass
    ``sched.load_costs("BENCH_gas.json")[engine]`` (or any
    :class:`~repro.core.sched.EngineCost`) to plan against measured wire
    speed.  ``page_bytes`` prices swap transfers; ``decode_step_us`` /
    ``prefill_us`` price recompute replay.

    The cumulative counters (evictions/swaps/recomputes/resumes) live on
    a typed :class:`~repro.obs.metrics.Registry` — pass ``registry`` to
    share the owning cluster's registry (one ``reset()`` clears the
    whole cluster's counters); stats keys are unchanged.
    """

    # cumulative counters, registry-backed (explicit Counter kind)
    evictions = counter_property("sched_evictions")
    swaps = counter_property("sched_swaps")
    recomputes = counter_property("sched_recomputes")
    resumes = counter_property("sched_resumes")
    deferrals = counter_property("sched_deferrals")

    def __init__(
        self,
        *,
        page_bytes: int,
        cost: Optional[sched.EngineCost] = None,
        costs: Optional[Dict[str, sched.EngineCost]] = None,
        engine_name: str = "xla",
        decode_step_us: float = 2000.0,
        prefill_us: float = 4000.0,
        registry: Optional[Registry] = None,
    ):
        table = costs or sched.DEFAULT_COSTS
        self.cost = cost or table.get(engine_name) or next(iter(table.values()))
        self.page_bytes = page_bytes
        self.decode_step_us = decode_step_us
        self.prefill_us = prefill_us
        self._entries: Dict[int, _Entry] = {}
        self._seq = 0
        self.metrics = registry if registry is not None else Registry()
        self.health = None  # Optional[repro.obs.health.HealthMonitor]
        self.evictions = 0
        self.swaps = 0
        self.recomputes = 0
        self.resumes = 0
        self.deferrals = 0

    # ------------------------------------------------------------------ #
    def submit(
        self,
        rid: int,
        slo: Optional[SLO] = None,
        prompt_len: int = 0,
        now: float = 0.0,
    ) -> None:
        if rid in self._entries:
            raise ValueError(f"request {rid} already submitted")
        self._entries[rid] = _Entry(
            rid=rid, slo=slo or SLO(), t_submit=now, prompt_len=prompt_len
        )

    def entry(self, rid: int) -> _Entry:
        return self._entries[rid]

    def slo(self, rid: int) -> SLO:
        return self._entries[rid].slo

    def _key(self, e: _Entry) -> Tuple:
        # priority-major; resume-first within a priority (anti-starvation:
        # a victim outranks every later same-priority arrival); then EDF
        # on the absolute TTFT deadline; then FIFO.
        return (
            -e.slo.priority,
            0 if e.state == "preempted" else 1,
            e.t_submit + e.slo.ttft_deadline_s,
            e.t_submit,
            e.rid,
        )

    def attach_health(self, monitor) -> None:
        """Wire a :class:`~repro.obs.health.HealthMonitor` in: while its
        at-risk set is non-empty, :meth:`admission_order` defers every
        waiting request *below* the monitor's backpressure floor (the
        highest at-risk priority) — deadline-critical work stops
        competing with bulk admissions for pool pages.  The floor clears
        the moment the at-risk set drains, so nothing starves."""
        self.health = monitor

    def admission_order(self) -> List[int]:
        """Waiting requests (queued + preempted) in admission order.

        With an attached health monitor signalling backpressure,
        below-floor requests are deferred (dropped from this tick's
        order, counted on ``sched_deferrals``)."""
        waiting = [
            e for e in self._entries.values()
            if e.state in ("queued", "preempted")
        ]
        floor = (
            self.health.backpressure_floor()
            if self.health is not None else None
        )
        if floor is not None:
            eligible = [e for e in waiting if e.slo.priority >= floor]
            self.deferrals += len(waiting) - len(eligible)
            waiting = eligible
        return [e.rid for e in sorted(waiting, key=self._key)]

    # ------------------------------------------------------------------ #
    def on_admitted(self, rid: int, now: float = 0.0) -> None:
        e = self._entries[rid]
        if e.state == "preempted":
            self.resumes += 1
        e.state = "running"
        e.t_admitted = now
        self._seq += 1
        e.admit_seq = self._seq

    def on_step(self, rid: int) -> None:
        self._entries[rid].generated += 1

    def on_done(self, rid: int) -> None:
        self._entries[rid].state = "done"

    def on_preempted(self, rid: int, mode: str) -> None:
        e = self._entries[rid]
        e.state = "preempted"
        e.preempt_mode = mode
        e.preempts += 1
        self.evictions += 1
        if mode == "swap":
            self.swaps += 1
        else:
            self.recomputes += 1

    # ------------------------------------------------------------------ #
    def choose_mode(self, rid: int, n_pages: int) -> Tuple[str, float, float]:
        """Swap vs recompute for one prospective victim (β-model priced)."""
        e = self._entries[rid]
        return swap_or_recompute(
            n_pages,
            self.page_bytes,
            e.generated,
            self.cost,
            decode_step_us=self.decode_step_us,
            prefill_us=self.prefill_us,
        )

    def pick_victims(
        self,
        running: Sequence[int],
        need_pages: int,
        freeable: Callable[[int], int],
        beneficiary: Optional[int] = None,
        strict: bool = False,
    ) -> List[int]:
        """Choose preemption victims freeing at least ``need_pages``.

        ``freeable(rid)`` must count only pages whose LAST reference the
        victim holds (refcount-aware: evicting a request never invalidates
        a physical page a running sharer still maps).  Victims are taken
        lowest-priority first, and never above the beneficiary's priority
        — so preemption strictly descends and cannot starve or cycle.
        ``strict=True`` additionally requires victims strictly BELOW the
        beneficiary (the admission-triggered rule: a fresh arrival never
        displaces an equal-priority running request; a running request
        that must write its next page may, since its victims resume from
        pages freed by retirement, not by counter-preemption).  Returns
        ``[]`` when the reachable victims cannot free enough.
        """
        if need_pages <= 0:
            return []
        cap = (
            self._entries[beneficiary].slo.priority
            if beneficiary is not None and beneficiary in self._entries
            else None
        )
        cands = []
        for rid in running:
            if rid == beneficiary:
                continue
            e = self._entries.get(rid)
            if e is None or e.state != "running":
                continue
            if cap is not None and (
                e.slo.priority > cap or (strict and e.slo.priority >= cap)
            ):
                continue
            cands.append(e)
        # lowest priority first; most slack, then most recently admitted
        # (least progress lost) within a priority
        cands.sort(
            key=lambda e: (
                e.slo.priority,
                -(e.t_admitted + e.slo.tpot_deadline_s),
                -e.admit_seq,
            )
        )
        victims: List[int] = []
        freed = 0
        for e in cands:
            gain = freeable(e.rid)
            if gain <= 0:
                continue
            victims.append(e.rid)
            freed += gain
            if freed >= need_pages:
                return victims
        return []

    # ------------------------------------------------------------------ #
    def stats(self) -> Dict[str, Any]:
        return {
            "sched_evictions": self.evictions,
            "sched_swaps": self.swaps,
            "sched_recomputes": self.recomputes,
            "sched_resumes": self.resumes,
            "sched_deferrals": self.deferrals,
        }

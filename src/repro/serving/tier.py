"""Remote KV memory tier: memory-only GAS ranks + page swap over RMA.

The paper's hardware engine exists so a node can export *memory* into the
global address space independent of where compute runs — FPGA memory
nodes and CPUs share one PGAS.  This module is that archetype,
serving-shaped: a new GAS role ``memory`` (``launch.mesh.serve_roles``)
whose ranks contribute segment capacity but run **no model compute**.
Their segments form the second tier of the KV hierarchy: when the decode
pool oversubscribes, preemption victims' pages *swap out* to a memory
rank and *swap in* again at resume, bit-exactly.

Three pieces:

1. :class:`MemoryTier` — host-side bookkeeping of the tier: a slot
   allocator per memory rank (LIFO free lists, mirroring the pool
   allocator) plus per-request holdings mapping each swapped request's
   logical pages to ``(memory_rank, slot)`` addresses.  One request's
   pages always land on ONE memory rank, so the whole swap-out is a
   single vectored put and the swap-in a single vectored get.  For the
   colocated server the tier also carries host-side slot arrays
   (``host_mem``); in the disaggregated cluster the bytes live in the
   memory ranks' GASNet segments and move only over the wire.
2. :func:`swap_out_pages` — the device half of eviction: read m victim
   pages out of the local pool shard and land them at their assigned
   slot offsets of the memory rank's partition with the **vectored put**
   (``Node.put_nbv`` — m pages + their target offsets + per-page flags in
   one command block), batched by ``sched.plan_p2p`` like every bulk
   transfer in the stack.
3. :func:`install_pages` — the device half of resume: a vectored get
   (``pool.fetch_pages`` over the memory rank's partition) brings the
   slots back; ``install_pages`` lands the fetched carrier rows at the
   freshly allocated pool offsets of the local shard, per-page gated.

:func:`check_tier` extends the pool invariant across the hierarchy: a
request is resident in exactly one tier, tier slots are never leaked or
double-freed, and a drained tier holds nothing.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax
import numpy as np

from repro.core import sched
from repro.serving import kv as kv_lib

__all__ = [
    "TierError",
    "OutOfSlotsError",
    "Holding",
    "MemoryTier",
    "swap_out_pages",
    "install_pages",
    "check_tier",
]


class TierError(RuntimeError):
    """Base memory-tier bookkeeping error."""


class OutOfSlotsError(TierError):
    """No memory rank has enough free slots for a swap-out."""


@dataclasses.dataclass(frozen=True)
class Holding:
    """One swapped-out request's tier residency: logical page ``i`` of the
    request lives in slot ``slots[i]`` of memory rank ``rank``."""

    rank: int  # memory pool index (0-based over the memory ranks)
    logical: Tuple[int, ...]  # logical page ids, ascending
    slots: Tuple[int, ...]  # tier slot per logical page


class MemoryTier:
    """Host bookkeeping of the memory ranks' page slots.

    ``n_ranks`` memory ranks export ``slots_per_rank`` page slots of
    ``page_elems`` carrier elements each.  ``host_backed=True`` (the
    colocated server) additionally materialises the slot arrays host-side
    so swap bytes can move without a wire; the disaggregated cluster
    leaves ``host_mem`` empty and moves bytes one-sided between GASNet
    segments.
    """

    def __init__(
        self,
        n_ranks: int,
        slots_per_rank: int,
        page_elems: int,
        host_backed: bool = False,
    ):
        if n_ranks < 1 or slots_per_rank < 1:
            raise ValueError(
                f"memory tier needs >= 1 rank and slot, got "
                f"{n_ranks}x{slots_per_rank}"
            )
        self.n_ranks = n_ranks
        self.slots_per_rank = slots_per_rank
        self.page_elems = page_elems
        self._free: List[List[int]] = [
            list(range(slots_per_rank - 1, -1, -1)) for _ in range(n_ranks)
        ]
        self.holdings: Dict[int, Holding] = {}
        self.host_mem: Optional[np.ndarray] = (
            np.zeros((n_ranks, slots_per_rank, page_elems), np.float32)
            if host_backed
            else None
        )
        self.swapped_out_pages = 0
        self.swapped_in_pages = 0

    # ------------------------------------------------------------------ #
    @property
    def n_free(self) -> int:
        return sum(len(f) for f in self._free)

    def free_slots(self, rank: int) -> int:
        return len(self._free[rank])

    def slot_offset(self, rank: int, slot: int) -> int:
        """Flat carrier offset of a tier slot in memory rank ``rank``'s
        segment partition (the tier analogue of ``PoolMap.offset``)."""
        del rank  # each rank's partition is self-addressed
        return int(slot) * self.page_elems

    # ------------------------------------------------------------------ #
    def plan_swap_out(self, rid: int, logical_pages: Sequence[int]) -> Holding:
        """Assign tier slots for one request's materialised pages, all on
        the single memory rank with the most free slots (one vectored put
        carries the whole request out; one vectored get brings it back).
        Raises :class:`OutOfSlotsError` when no rank fits."""
        if rid in self.holdings:
            raise TierError(f"request {rid} already swapped out")
        logical = tuple(sorted(int(p) for p in logical_pages))
        if not logical:
            raise TierError(f"request {rid} has no materialised pages")
        rank = max(range(self.n_ranks), key=lambda r: len(self._free[r]))
        if len(self._free[rank]) < len(logical):
            raise OutOfSlotsError(
                f"swap-out of {len(logical)} pages: best memory rank has "
                f"{len(self._free[rank])}/{self.slots_per_rank} slots free"
            )
        slots = tuple(self._free[rank].pop() for _ in logical)
        h = Holding(rank=rank, logical=logical, slots=slots)
        self.holdings[rid] = h
        self.swapped_out_pages += len(logical)
        return h

    def release(self, rid: int) -> Holding:
        """Drop one request's tier residency (at swap-in completion, or at
        abort) and return the slots to their rank's free list."""
        h = self.holdings.pop(rid, None)
        if h is None:
            raise TierError(f"request {rid} holds no tier slots")
        for s in h.slots:
            if s in self._free[h.rank]:
                raise TierError(f"double free of tier slot {h.rank}:{s}")
            self._free[h.rank].append(s)
        self.swapped_in_pages += len(h.slots)
        return h

    # ---- host-backed byte path (colocated server) --------------------- #
    def host_store(self, rid: int, rows: Any) -> Holding:
        """Swap-out without a wire: assign slots and copy the page rows
        into the host-side tier arrays (rows follow ``plan_swap_out``'s
        ascending logical order)."""
        if self.host_mem is None:
            raise TierError("tier is not host-backed")
        rows = np.asarray(rows, np.float32)
        h = self.holdings.get(rid)
        if h is None:
            raise TierError(f"plan_swap_out({rid}) first")
        if rows.shape != (len(h.slots), self.page_elems):
            raise TierError(
                f"swap rows {rows.shape} != ({len(h.slots)}, {self.page_elems})"
            )
        for row, s in zip(rows, h.slots):
            self.host_mem[h.rank, s] = row
        return h

    def host_load(self, rid: int) -> np.ndarray:
        """Swap-in without a wire: the stored rows, ascending logical
        order (the caller releases the holding after installing them)."""
        if self.host_mem is None:
            raise TierError("tier is not host-backed")
        h = self.holdings[rid]
        return np.stack([self.host_mem[h.rank, s] for s in h.slots])

    # ------------------------------------------------------------------ #
    def stats(self) -> Dict[str, int]:
        return {
            "tier_ranks": self.n_ranks,
            "tier_slots": self.n_ranks * self.slots_per_rank,
            "tier_free_slots": self.n_free,
            "tier_resident_requests": len(self.holdings),
            "tier_swapped_out_pages": self.swapped_out_pages,
            "tier_swapped_in_pages": self.swapped_in_pages,
        }


def check_tier(tier: MemoryTier, resident_rids: Sequence[int] = ()) -> None:
    """Assert the tier invariant: free lists are duplicate-free, holdings
    and free lists partition every rank's slots exactly, and no request is
    resident in both tiers (``resident_rids`` = requests holding pool
    pages)."""
    used: Dict[int, set] = {r: set() for r in range(tier.n_ranks)}
    for rid, h in tier.holdings.items():
        if len(h.slots) != len(h.logical):
            raise AssertionError(f"holding {rid}: slots != logical pages")
        for s in h.slots:
            if s in used[h.rank]:
                raise AssertionError(
                    f"tier slot {h.rank}:{s} held by two requests"
                )
            used[h.rank].add(s)
    for r in range(tier.n_ranks):
        free = tier._free[r]
        if len(set(free)) != len(free):
            raise AssertionError(f"duplicate slots on rank {r} free list")
        if used[r] & set(free):
            raise AssertionError(f"rank {r}: held slot also on free list")
        if len(used[r]) + len(free) != tier.slots_per_rank:
            raise AssertionError(
                f"rank {r}: {len(used[r])} held + {len(free)} free != "
                f"{tier.slots_per_rank}"
            )
    both = set(tier.holdings) & set(int(r) for r in resident_rids)
    if both:
        raise AssertionError(
            f"request(s) {sorted(both)} resident in pool AND tier"
        )


# --------------------------------------------------------------------------- #
# device plane: swap bytes over the GAS layer
# --------------------------------------------------------------------------- #
def swap_out_pages(
    node: Any,
    seg: jax.Array,
    src_offsets: jax.Array,
    dst_offsets: jax.Array,
    *,
    to: Any,
    page_elems: int,
    flags: jax.Array | Sequence[Any] | None = None,
    plan: Optional[sched.CollectivePlan] = None,
    n_batches: Optional[int] = None,
    costs: Optional[Dict[str, sched.EngineCost]] = None,
) -> Tuple[List[Any], sched.CollectivePlan]:
    """Initiate the split-phase swap-out of m pool pages to a memory rank.

    Reads each page at flat offset ``src_offsets[j]`` of the local pool
    shard and lands it at ``dst_offsets[j]`` of node ``pattern(me)``'s
    partition via the vectored put (``node.put_nbv`` — payloads + command
    block per batch, batch count from ``sched.plan_p2p`` on the total
    byte count).  ``flags`` gates per page (a rank swapping fewer than m
    pages this tick clears the tail).  Returns ``(handles, plan)``; drain
    with ``kv.sync_push``-style ``node.sync`` per handle.
    """
    src = jnp.asarray(src_offsets, jnp.int32).reshape(-1)
    dst = jnp.asarray(dst_offsets, jnp.int32).reshape(-1)
    m = int(src.shape[0])
    if int(dst.shape[0]) != m:
        raise ValueError(f"swap_out_pages: {m} sources vs {dst.shape[0]} dests")
    if flags is None:
        flags = jnp.ones((m,), jnp.int32)
    else:
        flags = jnp.asarray(flags).astype(jnp.int32).reshape(-1)
    local = node.local(seg).reshape(-1)
    pages = [
        lax.dynamic_slice(local, (src[j],), (page_elems,)) for j in range(m)
    ]
    if plan is None:
        plan = sched.plan_p2p(
            nbytes=m * page_elems * 4, engine=node.engine, costs=costs
        )
    g = int(plan.n_segments if n_batches is None else n_batches)
    handles = []
    for start, count in kv_lib.segment_bounds(m, g):
        handles.append(
            node.put_nbv(
                seg,
                pages[start : start + count],
                to=to,
                indices=dst[start : start + count],
                pred=flags[start : start + count],
            )
        )
    return handles, plan


def install_pages(
    node: Any,
    seg: jax.Array,
    fetched: jax.Array,
    dst_offsets: jax.Array,
    flags: jax.Array | Sequence[Any] | None = None,
) -> jax.Array:
    """Land swap-in pages (the ``(m, page_elems)`` stack a vectored get of
    tier slots returned) at ``dst_offsets`` of the local pool shard,
    per-page gated — the receive epilogue of a resume.  Returns the
    updated segment."""
    fetched = jnp.asarray(fetched)
    m, page_elems = int(fetched.shape[0]), int(fetched.shape[1])
    dst = jnp.asarray(dst_offsets, jnp.int32).reshape(-1)
    if flags is None:
        flags = jnp.ones((m,), jnp.int32)
    else:
        flags = jnp.asarray(flags).astype(jnp.int32).reshape(-1)
    local = node.local(seg)
    flat = local.reshape(-1)
    for j in range(m):
        cur = lax.dynamic_slice(flat, (dst[j],), (page_elems,))
        flat = lax.dynamic_update_slice(
            flat, jnp.where(flags[j] > 0, fetched[j], cur), (dst[j],)
        )
    return node._restore(seg, flat.reshape(local.shape))

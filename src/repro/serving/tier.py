"""Remote KV memory tier: memory-only GAS ranks + page swap over RMA.

The paper's hardware engine exists so a node can export *memory* into the
global address space independent of where compute runs — FPGA memory
nodes and CPUs share one PGAS.  This module is that archetype,
serving-shaped: a new GAS role ``memory`` (``launch.mesh.serve_roles``)
whose ranks contribute segment capacity but run **no model compute**.
Their segments form the second tier of the KV hierarchy: when the decode
pool oversubscribes, preemption victims' pages *swap out* to a memory
rank and *swap in* again at resume, bit-exactly.

Three pieces:

1. :class:`MemoryTier` — host-side bookkeeping of the tier: a slot
   allocator per memory rank (LIFO free lists, mirroring the pool
   allocator) plus per-request holdings mapping each swapped request's
   logical pages to ``(memory_rank, slot)`` addresses.  One request's
   pages always land on ONE memory rank per *placement*, so each
   swap-out leg is a single vectored put and the swap-in a single
   vectored get.  With ``replicas >= 2`` a holding carries extra
   :class:`Placement` legs on distinct memory ranks: the swap-out put is
   fanned to every leg, and :meth:`restore_placement` restores from any
   live one — the quorum read that survives a memory-rank loss.  For the
   colocated server the tier also carries host-side slot arrays
   (``host_mem``); in the disaggregated cluster the bytes live in the
   memory ranks' GASNet segments and move only over the wire.
2. :func:`swap_out_pages` — the device half of eviction: read m victim
   pages out of the local pool shard and land them at their assigned
   slot offsets of the memory rank's partition with the **vectored put**
   (``Node.put_nbv`` — m pages + their target offsets + per-page flags in
   one command block), batched by ``sched.plan_p2p`` like every bulk
   transfer in the stack.
3. :func:`install_pages` — the device half of resume: a vectored get
   (``pool.fetch_pages`` over the memory rank's partition) brings the
   slots back; ``install_pages`` lands the fetched carrier rows at the
   freshly allocated pool offsets of the local shard, per-page gated.

Failure handling: :meth:`MemoryTier.mark_failed` removes a dead rank
from the allocator, scrubs its placements, and reports the requests
whose LAST live placement died (those fall back to recompute-resume);
:meth:`MemoryTier.admit_rank` re-admits a recovered rank with a fresh
slot map (its old bytes are gone).

:func:`check_tier` extends the pool invariant across the hierarchy: a
request is resident in exactly one tier, tier slots are never leaked or
double-freed on any LIVE rank, and a drained tier holds nothing.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax
import numpy as np

from repro.core import sched
from repro.obs import trace as obs_trace
from repro.obs.metrics import Registry, counter_property
from repro.serving import kv as kv_lib

__all__ = [
    "TierError",
    "OutOfSlotsError",
    "Placement",
    "Holding",
    "MemoryTier",
    "swap_out_pages",
    "install_pages",
    "check_tier",
]


class TierError(RuntimeError):
    """Base memory-tier bookkeeping error."""


class OutOfSlotsError(TierError):
    """No memory rank has enough free slots for a swap-out."""


@dataclasses.dataclass(frozen=True)
class Placement:
    """One replica leg of a holding: logical page ``i`` of the request
    lives in slot ``slots[i]`` of memory rank ``rank``."""

    rank: int  # memory pool index (0-based over the memory ranks)
    slots: Tuple[int, ...]  # tier slot per logical page

    @property
    def n_pages(self) -> int:
        return len(self.slots)


@dataclasses.dataclass(frozen=True)
class Holding:
    """One swapped-out request's tier residency.  ``rank``/``slots`` name
    the primary placement (kept flat for the single-replica fast path);
    ``replicas`` carries the extra legs the fanned swap-out also fed."""

    rank: int  # primary memory pool index
    logical: Tuple[int, ...]  # logical page ids, ascending
    slots: Tuple[int, ...]  # tier slot per logical page (primary)
    replicas: Tuple[Placement, ...] = ()

    @property
    def placements(self) -> Tuple[Placement, ...]:
        """Every live-or-dead leg, primary first."""
        return (Placement(self.rank, self.slots),) + self.replicas


class MemoryTier:
    """Host bookkeeping of the memory ranks' page slots.

    ``n_ranks`` memory ranks export ``slots_per_rank`` page slots of
    ``page_elems`` carrier elements each.  ``host_backed=True`` (the
    colocated server) additionally materialises the slot arrays host-side
    so swap bytes can move without a wire; the disaggregated cluster
    leaves ``host_mem`` empty and moves bytes one-sided between GASNet
    segments.  ``replicas`` is the default placement fan-out of
    :meth:`plan_swap_out`: each swap-out allocates slots on up to that
    many distinct live ranks, and restores survive ``replicas - 1``
    memory-rank losses.

    Cumulative counters live on a typed
    :class:`~repro.obs.metrics.Registry` (pass ``registry`` to share the
    owning cluster's); ``stats()`` keys are unchanged.
    """

    # cumulative counters, registry-backed (explicit Counter kind)
    swapped_out_pages = counter_property("tier_swapped_out_pages")
    swapped_in_pages = counter_property("tier_swapped_in_pages")
    replica_pages = counter_property("tier_replica_pages")
    quorum_restores = counter_property("tier_quorum_restores")
    degraded_placements = counter_property("tier_degraded_placements")

    def __init__(
        self,
        n_ranks: int,
        slots_per_rank: int,
        page_elems: int,
        host_backed: bool = False,
        replicas: int = 1,
        registry: Optional[Registry] = None,
    ):
        if n_ranks < 1 or slots_per_rank < 1:
            raise ValueError(
                f"memory tier needs >= 1 rank and slot, got "
                f"{n_ranks}x{slots_per_rank}"
            )
        if not (1 <= replicas <= n_ranks):
            raise ValueError(
                f"replicas={replicas} outside [1, n_ranks={n_ranks}]"
            )
        self.n_ranks = n_ranks
        self.slots_per_rank = slots_per_rank
        self.page_elems = page_elems
        self.replicas = replicas
        self._free: List[List[int]] = [
            list(range(slots_per_rank - 1, -1, -1)) for _ in range(n_ranks)
        ]
        self.holdings: Dict[int, Holding] = {}
        self.failed: set = set()
        self._promoted: set = set()  # rids whose primary leg died
        self.host_mem: Optional[np.ndarray] = (
            np.zeros((n_ranks, slots_per_rank, page_elems), np.float32)
            if host_backed
            else None
        )
        self.metrics = registry if registry is not None else Registry()
        self.swapped_out_pages = 0
        self.swapped_in_pages = 0
        self.replica_pages = 0
        self.quorum_restores = 0
        self.degraded_placements = 0

    # ------------------------------------------------------------------ #
    @property
    def n_free(self) -> int:
        return sum(len(f) for f in self._free)

    @property
    def live_ranks(self) -> List[int]:
        return [r for r in range(self.n_ranks) if r not in self.failed]

    def free_slots(self, rank: int) -> int:
        return len(self._free[rank])

    def slot_offset(self, rank: int, slot: int) -> int:
        """Flat carrier offset of a tier slot in memory rank ``rank``'s
        segment partition (the tier analogue of ``PoolMap.offset``)."""
        del rank  # each rank's partition is self-addressed
        return int(slot) * self.page_elems

    # ------------------------------------------------------------------ #
    def plan_swap_out(
        self,
        rid: int,
        logical_pages: Sequence[int],
        replicas: Optional[int] = None,
    ) -> Holding:
        """Assign tier slots for one request's materialised pages on up to
        ``replicas`` distinct LIVE memory ranks, most-free first (one
        vectored put per leg carries the whole request out; one vectored
        get from any surviving leg brings it back).  The primary leg must
        fit or :class:`OutOfSlotsError` raises; missing extra legs only
        degrade (counted, not fatal — a tier under slot pressure keeps
        accepting swaps at reduced durability)."""
        if rid in self.holdings:
            raise TierError(f"request {rid} already swapped out")
        logical = tuple(sorted(int(p) for p in logical_pages))
        if not logical:
            raise TierError(f"request {rid} has no materialised pages")
        want = self.replicas if replicas is None else int(replicas)
        want = max(1, min(want, len(self.live_ranks)))
        order = sorted(
            self.live_ranks, key=lambda r: len(self._free[r]), reverse=True
        )
        chosen = [r for r in order if len(self._free[r]) >= len(logical)]
        chosen = chosen[:want]
        if not chosen:
            best = max((len(self._free[r]) for r in order), default=0)
            raise OutOfSlotsError(
                f"swap-out of {len(logical)} pages: best live memory rank "
                f"has {best}/{self.slots_per_rank} slots free"
            )
        if len(chosen) < want:
            self.degraded_placements += 1
        legs = [
            Placement(
                rank=r,
                slots=tuple(self._free[r].pop() for _ in logical),
            )
            for r in chosen
        ]
        h = Holding(
            rank=legs[0].rank,
            logical=logical,
            slots=legs[0].slots,
            replicas=tuple(legs[1:]),
        )
        self.holdings[rid] = h
        self.swapped_out_pages += len(logical)
        self.replica_pages += len(logical) * (len(legs) - 1)
        return h

    def restore_placement(self, rid: int) -> Placement:
        """The placement a swap-in should read: the primary when its rank
        is live, else the first surviving replica (the quorum read —
        also counted when :meth:`mark_failed` already promoted a replica
        into the primary seat).  Raises :class:`TierError` when every
        leg is on a failed rank."""
        h = self.holdings.get(rid)
        if h is None:
            raise TierError(f"request {rid} holds no tier slots")
        for i, pl in enumerate(h.placements):
            if pl.rank not in self.failed:
                if i > 0 or rid in self._promoted:
                    self.quorum_restores += 1
                    self._promoted.discard(rid)
                    tr = obs_trace.active()
                    if tr.enabled:
                        tr.instant(
                            "quorum_restore", cat="ft", rid=rid,
                            leg=i, rank=pl.rank,
                        )
                return pl
        raise TierError(f"request {rid}: no live replica (all legs failed)")

    def release(self, rid: int) -> Holding:
        """Drop one request's tier residency (at swap-in completion, or at
        abort) and return every live leg's slots to its rank's free list
        (a failed rank's slots died with it)."""
        h = self.holdings.pop(rid, None)
        if h is None:
            raise TierError(f"request {rid} holds no tier slots")
        self._promoted.discard(rid)
        for pl in h.placements:
            if pl.rank in self.failed:
                continue
            for s in pl.slots:
                if s in self._free[pl.rank]:
                    raise TierError(
                        f"double free of tier slot {pl.rank}:{s}"
                    )
                self._free[pl.rank].append(s)
        self.swapped_in_pages += len(h.slots)
        return h

    # ---- membership ---------------------------------------------------- #
    def mark_failed(self, rank: int) -> List[int]:
        """A memory rank died: drop it from the allocator, scrub its
        placements, and return the requests whose LAST live placement it
        held — their tier bytes are unrecoverable and the caller must
        fall back to recompute-resume.  Idempotent."""
        if not (0 <= rank < self.n_ranks):
            raise TierError(f"memory rank {rank} outside tier")
        if rank in self.failed:
            return []
        self.failed.add(rank)
        self._free[rank] = []
        lost: List[int] = []
        for rid, h in list(self.holdings.items()):
            legs = [pl for pl in h.placements if pl.rank != rank]
            if len(legs) == len(h.placements):
                continue
            if not legs:
                lost.append(rid)
                del self.holdings[rid]
                self._promoted.discard(rid)
                continue
            if h.rank == rank:
                self._promoted.add(rid)
            self.holdings[rid] = Holding(
                rank=legs[0].rank,
                logical=h.logical,
                slots=legs[0].slots,
                replicas=tuple(legs[1:]),
            )
        return lost

    def admit_rank(self, rank: int) -> None:
        """Re-admit a recovered (or replacement) memory rank with a fresh
        slot map — its previous bytes are gone, so it rejoins empty."""
        if rank not in self.failed:
            raise TierError(f"memory rank {rank} is not failed")
        self.failed.discard(rank)
        self._free[rank] = list(range(self.slots_per_rank - 1, -1, -1))

    # ---- host-backed byte path (colocated server) --------------------- #
    def host_store(self, rid: int, rows: Any) -> Holding:
        """Swap-out without a wire: copy the page rows into the host-side
        tier arrays at EVERY live placement (rows follow
        ``plan_swap_out``'s ascending logical order) — the host analogue
        of the fanned vectored put."""
        if self.host_mem is None:
            raise TierError("tier is not host-backed")
        rows = np.asarray(rows, np.float32)
        h = self.holdings.get(rid)
        if h is None:
            raise TierError(f"plan_swap_out({rid}) first")
        if rows.shape != (len(h.slots), self.page_elems):
            raise TierError(
                f"swap rows {rows.shape} != ({len(h.slots)}, {self.page_elems})"
            )
        for pl in h.placements:
            if pl.rank in self.failed:
                continue
            for row, s in zip(rows, pl.slots):
                self.host_mem[pl.rank, s] = row
        return h

    def host_load(self, rid: int) -> np.ndarray:
        """Swap-in without a wire: the stored rows from the first live
        placement, ascending logical order (the caller releases the
        holding after installing them)."""
        if self.host_mem is None:
            raise TierError("tier is not host-backed")
        pl = self.restore_placement(rid)
        return np.stack([self.host_mem[pl.rank, s] for s in pl.slots])

    # ------------------------------------------------------------------ #
    def stats(self) -> Dict[str, int]:
        # point-in-time values land in the registry as explicit Gauges
        # (they survive reset(); the counters above are what reset clears)
        g = self.metrics.gauge
        g("tier_free_slots").set(self.n_free)
        g("tier_resident_requests").set(len(self.holdings))
        g("tier_failed_ranks").set(len(self.failed))
        return {
            "tier_ranks": self.n_ranks,
            "tier_slots": self.n_ranks * self.slots_per_rank,
            "tier_free_slots": self.n_free,
            "tier_resident_requests": len(self.holdings),
            "tier_swapped_out_pages": self.swapped_out_pages,
            "tier_swapped_in_pages": self.swapped_in_pages,
            "tier_replicas": self.replicas,
            "tier_replica_pages": self.replica_pages,
            "tier_quorum_restores": self.quorum_restores,
            "tier_degraded_placements": self.degraded_placements,
            "tier_failed_ranks": len(self.failed),
        }


def check_tier(tier: MemoryTier, resident_rids: Sequence[int] = ()) -> None:
    """Assert the tier invariant: free lists are duplicate-free, holdings
    (every live placement leg) and free lists partition every LIVE rank's
    slots exactly, no placement references a failed rank, and no request
    is resident in both tiers (``resident_rids`` = requests holding pool
    pages)."""
    used: Dict[int, set] = {r: set() for r in range(tier.n_ranks)}
    for rid, h in tier.holdings.items():
        for pl in h.placements:
            if pl.rank in tier.failed:
                raise AssertionError(
                    f"holding {rid}: placement on failed rank {pl.rank}"
                )
            if len(pl.slots) != len(h.logical):
                raise AssertionError(f"holding {rid}: slots != logical pages")
            for s in pl.slots:
                if s in used[pl.rank]:
                    raise AssertionError(
                        f"tier slot {pl.rank}:{s} held by two placements"
                    )
                used[pl.rank].add(s)
        ranks = [pl.rank for pl in h.placements]
        if len(set(ranks)) != len(ranks):
            raise AssertionError(
                f"holding {rid}: two placements on one rank {ranks}"
            )
    for r in range(tier.n_ranks):
        free = tier._free[r]
        if r in tier.failed:
            if free:
                raise AssertionError(f"failed rank {r} has free slots")
            continue
        if len(set(free)) != len(free):
            raise AssertionError(f"duplicate slots on rank {r} free list")
        if used[r] & set(free):
            raise AssertionError(f"rank {r}: held slot also on free list")
        if len(used[r]) + len(free) != tier.slots_per_rank:
            raise AssertionError(
                f"rank {r}: {len(used[r])} held + {len(free)} free != "
                f"{tier.slots_per_rank}"
            )
    both = set(tier.holdings) & set(int(r) for r in resident_rids)
    if both:
        raise AssertionError(
            f"request(s) {sorted(both)} resident in pool AND tier"
        )


# --------------------------------------------------------------------------- #
# device plane: swap bytes over the GAS layer
# --------------------------------------------------------------------------- #
def swap_out_pages(
    node: Any,
    seg: jax.Array,
    src_offsets: jax.Array,
    dst_offsets: jax.Array,
    *,
    to: Any,
    page_elems: int,
    flags: jax.Array | Sequence[Any] | None = None,
    plan: Optional[sched.CollectivePlan] = None,
    n_batches: Optional[int] = None,
    costs: Optional[Dict[str, sched.EngineCost]] = None,
) -> Tuple[List[Any], sched.CollectivePlan]:
    """Initiate the split-phase swap-out of m pool pages to a memory rank.

    Reads each page at flat offset ``src_offsets[j]`` of the local pool
    shard and lands it at ``dst_offsets[j]`` of node ``pattern(me)``'s
    partition via the vectored put (``node.put_nbv`` — payloads + command
    block per batch, batch count from ``sched.plan_p2p`` on the total
    byte count).  ``flags`` gates per page (a rank swapping fewer than m
    pages this tick clears the tail).  Replication is the caller fanning
    this call once per placement leg — same sources, each leg's offsets
    and permutation.  Returns ``(handles, plan)``; drain with
    ``kv.sync_push``-style ``node.sync`` per handle.
    """
    src = jnp.asarray(src_offsets, jnp.int32).reshape(-1)
    dst = jnp.asarray(dst_offsets, jnp.int32).reshape(-1)
    m = int(src.shape[0])
    if int(dst.shape[0]) != m:
        raise ValueError(f"swap_out_pages: {m} sources vs {dst.shape[0]} dests")
    if flags is None:
        flags = jnp.ones((m,), jnp.int32)
    else:
        flags = jnp.asarray(flags).astype(jnp.int32).reshape(-1)
    local = node.local(seg).reshape(-1)
    pages = [
        lax.dynamic_slice(local, (src[j],), (page_elems,)) for j in range(m)
    ]
    if plan is None:
        plan = sched.plan_p2p(
            nbytes=m * page_elems * 4, engine=node.engine, costs=costs
        )
    g = int(plan.n_segments if n_batches is None else n_batches)
    handles = []
    for start, count in kv_lib.segment_bounds(m, g):
        handles.append(
            node.put_nbv(
                seg,
                pages[start : start + count],
                to=to,
                indices=dst[start : start + count],
                pred=flags[start : start + count],
            )
        )
    return handles, plan


def install_pages(
    node: Any,
    seg: jax.Array,
    fetched: jax.Array,
    dst_offsets: jax.Array,
    flags: jax.Array | Sequence[Any] | None = None,
) -> jax.Array:
    """Land swap-in pages (the ``(m, page_elems)`` stack a vectored get of
    tier slots returned) at ``dst_offsets`` of the local pool shard,
    per-page gated — the receive epilogue of a resume.  Returns the
    updated segment."""
    fetched = jnp.asarray(fetched)
    m, page_elems = int(fetched.shape[0]), int(fetched.shape[1])
    dst = jnp.asarray(dst_offsets, jnp.int32).reshape(-1)
    if flags is None:
        flags = jnp.ones((m,), jnp.int32)
    else:
        flags = jnp.asarray(flags).astype(jnp.int32).reshape(-1)
    local = node.local(seg)
    flat = local.reshape(-1)
    for j in range(m):
        cur = lax.dynamic_slice(flat, (dst[j],), (page_elems,))
        flat = lax.dynamic_update_slice(
            flat, jnp.where(flags[j] > 0, fetched[j], cur), (dst[j],)
        )
    return node._restore(seg, flat.reshape(local.shape))

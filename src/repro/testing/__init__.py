"""Multi-device test suites, run as subprocesses by pytest.

Each module's __main__ sets XLA_FLAGS for N host CPU devices BEFORE
importing jax (which is why these are separate processes: the main pytest
process must keep seeing 1 device, per the dry-run isolation rule).
"""

"""Distributed substrate suite: compressed grad rings, GPipe, EP MoE,
elastic checkpoint restart across meshes (8 devices)."""
import os

if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map


def main() -> None:
    from repro.configs.registry import SMOKE
    from repro.core.engine import make_engine
    from repro.data.synthetic import ShardedLoader, SyntheticLM
    from repro.models.build import build_model
    from repro.optim import adamw, compression
    from repro.parallel.ctx import RunCtx
    from repro.parallel.pipeline import gpipe
    from repro.runtime.trainer import Trainer, TrainerConfig

    mesh8 = jax.make_mesh((8,), ("node",))

    # ---- int8 EF compressed all-reduce ------------------------------------
    x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 1024)), jnp.float32)

    def prog(xl):
        eng = make_engine("xla", "node", 8)
        err = jnp.zeros((1024,), jnp.float32)
        red, _ = compression.compressed_ring_all_reduce(eng, xl[0], err)
        return red[None]

    red = jax.jit(
        shard_map(prog, mesh=mesh8, in_specs=(P("node"),),
                      out_specs=P("node"), check_vma=False)
    )(x)
    want = np.asarray(x).sum(0)
    rel = np.abs(np.asarray(red)[0] - want).max() / np.abs(want).max()
    assert rel < 0.05, rel
    print(f"compressed all-reduce OK (rel {rel:.4f})")

    # ---- GPipe 8-stage forward parity --------------------------------------
    M, mb, D = 8, 4, 16
    xm = jnp.asarray(np.random.default_rng(1).normal(size=(M, mb, D)), jnp.float32)
    w = jnp.asarray(np.random.default_rng(2).normal(size=(8, D, D)) * 0.1,
                    jnp.float32)

    def stage(wl, xx):
        return jnp.tanh(xx @ wl[0])

    out = jax.jit(
        shard_map(
            lambda wl, xs: gpipe(stage, wl, xs, axis="node", n_stages=8),
            mesh=mesh8, in_specs=(P("node"), P(None)), out_specs=P(None),
            check_vma=False,
        )
    )(w, xm)
    ref = xm
    for i in range(8):
        ref = jnp.tanh(ref @ w[i])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)

    # GPipe backward: grads of sum(out) wrt w match sequential reference
    def pipe_loss(wl, xs):
        o = shard_map(
            lambda wl, xs: gpipe(stage, wl, xs, axis="node", n_stages=8),
            mesh=mesh8, in_specs=(P("node"), P(None)), out_specs=P(None),
            check_vma=False,
        )(wl, xs)
        return (o ** 2).sum()

    def seq_loss(wl, xs):
        o = xs
        for i in range(8):
            o = jnp.tanh(o @ wl[i])
        return (o ** 2).sum()

    g_pipe = jax.jit(jax.grad(pipe_loss))(w, xm)
    g_seq = jax.jit(jax.grad(seq_loss))(w, xm)
    np.testing.assert_allclose(np.asarray(g_pipe), np.asarray(g_seq),
                               atol=2e-4, rtol=2e-4)
    print("gpipe fwd+bwd parity OK")

    # ---- EP MoE (shard_map path) == local MoE ------------------------------
    import dataclasses

    from repro.models import layers as L

    # generous capacity so local-vs-EP drop boundaries rarely differ
    cfg = dataclasses.replace(SMOKE["arctic-480b"], capacity_factor=4.0)
    mesh2d = jax.make_mesh((2, 4), ("data", "model"))
    ctx_ep = RunCtx(mesh=mesh2d, dp=("data",), tp="model",
                    moe_mode="ep_shardmap", remat="none")
    ctx_lo = RunCtx(mesh=None, moe_mode="local", remat="none")
    mp, _ = L.moe_init(cfg, ctx_ep, jax.random.PRNGKey(1))
    xx = jnp.asarray(
        np.random.default_rng(3).normal(size=(8, 16, cfg.d_model)) * 0.1,
        jnp.float32,
    )
    y_ep = jax.jit(lambda p, a: L.apply_moe(p, cfg, ctx_ep, a))(mp, xx)
    y_lo = jax.jit(lambda p, a: L.apply_moe(p, cfg, ctx_lo, a))(mp, xx)
    # EP shards tokens before routing: capacity boundaries differ from the
    # single-queue local path, so only near-equality is expected (dropped
    # tokens differ at the margin). Most rows must match closely.
    diff = np.abs(np.asarray(y_ep) - np.asarray(y_lo)).max(-1).reshape(-1)
    frac_same = float((diff < 1e-4).mean())
    assert frac_same > 0.97, frac_same
    print(f"EP MoE vs local OK ({frac_same:.2%} token rows identical)")

    # ---- elastic restart: (4,2) mesh -> (2,2) mesh -------------------------
    cfg = SMOKE["qwen3-4b"]
    model = build_model(cfg)
    opt = adamw.AdamWConfig(lr=1e-3, weight_decay=0.0)
    meshA = jax.make_mesh((4, 2), ("data", "model"))
    ctxA = RunCtx(mesh=meshA, dp=("data",), tp="model", remat="none")
    with tempfile.TemporaryDirectory() as td:
        trA = Trainer(model, ctxA, opt,
                      TrainerConfig(steps=6, ckpt_every=3, ckpt_dir=td,
                                    log_every=1))
        pA, sA = trA.init(jax.random.PRNGKey(0))
        src = SyntheticLM(cfg, batch=8, seq_len=32, seed=7)
        loader = ShardedLoader(src, mesh=meshA, dp_axes=("data",))
        pA, sA, histA = trA.run(pA, sA, loader)
        loader.close()

        # "pod loss": restart on a smaller mesh from step 3's snapshot
        meshB = jax.make_mesh((2, 2), ("data", "model"))
        ctxB = RunCtx(mesh=meshB, dp=("data",), tp="model", remat="none")
        trB = Trainer(model, ctxB, opt,
                      TrainerConfig(steps=6, ckpt_every=0, ckpt_dir=td,
                                    log_every=1))
        pB, sB, start, extra = trB.recover(jax.random.PRNGKey(9))
        assert start == 6  # latest snapshot
        loaderB = ShardedLoader(src, mesh=meshB, dp_axes=("data",),
                                start_step=int(extra["data_step"]))
        # params restored onto the smaller mesh must equal the originals
        for a, b in zip(jax.tree.leaves(pA), jax.tree.leaves(pB)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # and training continues
        trB.tcfg.steps = 8
        pB, sB, histB = trB.run(pB, sB, loaderB, start_step=6)
        loaderB.close()
        assert np.isfinite(histB[-1]["loss"])
    print("elastic restart OK")

    # ---- fsdp_gather / remat=names numerical parity ------------------------
    import dataclasses as _dc

    cfgq = SMOKE["qwen3-4b"]
    modelq = build_model(cfgq)
    meshQ = jax.make_mesh((4, 2), ("data", "model"))
    base_ctx = RunCtx(mesh=meshQ, dp=("data",), tp="model", remat="full")
    opt_ctx = _dc.replace(base_ctx, fsdp_gather=True, remat="names")
    pq, _ = modelq.init(RunCtx(mesh=None), jax.random.PRNGKey(2))
    srcq = SyntheticLM(cfgq, batch=8, seq_len=32, seed=5)
    bq = {k: jnp.asarray(v) for k, v in srcq.batch_at(0).items()}
    l_base = float(jax.jit(lambda p, b: modelq.train_loss(p, base_ctx, b))(pq, bq))
    l_opt = float(jax.jit(lambda p, b: modelq.train_loss(p, opt_ctx, b))(pq, bq))
    assert abs(l_base - l_opt) < 1e-4, (l_base, l_opt)
    g_base = jax.jit(jax.grad(lambda p: modelq.train_loss(p, base_ctx, bq)))(pq)
    g_opt = jax.jit(jax.grad(lambda p: modelq.train_loss(p, opt_ctx, bq)))(pq)
    for a, b in zip(jax.tree.leaves(g_base), jax.tree.leaves(g_opt)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=2e-4, rtol=2e-3)
    print("fsdp_gather/remat=names parity OK")

    print("DIST_SUITE_PASS")


if __name__ == "__main__":
    main()

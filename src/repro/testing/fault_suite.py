"""Fault-injection suite: ranks die, join, and recover mid-flight.

Deterministic rank-kill / heartbeat-delay injectors over the lockstep
single-process cluster (6 host devices).  Every scenario compares a
faulted run against its no-failure twin and requires BIT-IDENTICAL
tokens — recovery that silently corrupts output cannot pass, because a
killed rank's segment mirror is poisoned with NaN the instant it dies.

Scenarios (``--fast`` runs the first, third, and fourth — the fixed-seed
PR subset; the full run adds the chaos scenario, seeded for nightly
randomisation via ``--seed``):

1. kill-a-decode-rank: 1P+2D+2M, one decode rank killed in the
   mid-KV-handoff window (after its admission put launched, before the
   ``kv_ready`` ack is consumed) — every request completes bit-exactly,
   pool/tier invariants hold on all survivors.
2. quorum restore: ``tier_replicas=2`` under pressure, the PRIMARY leg's
   memory rank killed while requests sit swapped out — restores read the
   surviving replica (``get_nbv`` quorum), zero recompute fallbacks.
3. elastic join: a spare rank promotes into a new decode group, the
   prefix index migrates over one vectored RMA get, and the joined rank
   serves requests with token parity.
4. heartbeat delay: beats delayed for fewer ticks than the timeout must
   NOT trip failure detection (no false positives).
5. chaos(seed): a randomised kill (role, tick, phase drawn from the
   seed) over the standard workload — parity + invariants, any seed.
"""

import argparse
import os

if __name__ == "__main__":
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=6"
    )

import numpy as np

PAGE_TOKENS = 8


class FaultInjector:
    """Deterministic fault plan driven by the cluster's fault hook.

    Each event is ``{"tick": T, "phase": p, "kill": rank_or_fn}`` — at
    the first hook firing with phase ``p`` and tick >= ``T`` the rank (or
    ``fn(cluster) -> rank | None``; None retries next firing) is killed.
    """

    def __init__(self, events):
        self.events = list(events)
        self.log = []

    def __call__(self, cluster, phase, tick):
        for ev in list(self.events):
            if ev["phase"] != phase or tick < ev["tick"]:
                continue
            rank = ev["kill"]
            if callable(rank):
                rank = rank(cluster)
            if rank is None:
                continue  # condition not met yet — retry on later ticks
            cluster.kill_rank(rank)
            self.log.append((tick, phase, rank))
            self.events.remove(ev)


def build_model_once():
    import jax

    from repro.configs.registry import SMOKE
    from repro.models.build import build_model
    from repro.parallel.ctx import RunCtx

    cfg = SMOKE["qwen3-4b"]
    model = build_model(cfg)
    ctx = RunCtx(mesh=None, remat="none")
    params, _ = model.init(ctx, jax.random.PRNGKey(0))
    return cfg, model, ctx, params


def make_requests(cfg, rng, n=6):
    """Mixed workload: even rids share a two-page prompt prefix (the
    hot pages replication protects), odd rids are private."""
    from repro.launch.serve import Request

    shared = rng.integers(0, cfg.vocab, size=2 * PAGE_TOKENS).tolist()
    reqs = []
    for rid in range(n):
        if rid % 2 == 0:
            prompt = shared + rng.integers(0, cfg.vocab, size=rid + 1).tolist()
        else:
            plen = int(rng.integers(6, 20))
            prompt = rng.integers(0, cfg.vocab, size=plen).tolist()
        reqs.append(
            Request(rid=rid, prompt=prompt, max_new=int(rng.integers(5, 10)))
        )
    return reqs


def run_cluster(model, ctx, params, reqs, hook=None, ticks_before=0,
                late_reqs=(), max_ticks=800, **kw):
    from repro.serving.disagg import DisaggCluster

    cl = DisaggCluster(
        model, ctx, params, paged=True, page_tokens=PAGE_TOKENS, **kw
    )
    cl.fault_hook = hook
    for r in reqs:
        cl.submit(r)
    for _ in range(ticks_before):
        cl.tick()
    for r in late_reqs:
        cl.submit(r)
    stats = cl.run_until_drained(max_ticks=max_ticks)
    toks = {r.rid: list(r.out) for r in cl.finished}
    return cl, stats, toks


def check_survivors(cl):
    """Pool + tier invariants on every surviving rank after drain."""
    from repro.serving import pool, tier as tier_lib

    for g in range(cl.n_groups):
        if cl._group_down(g):
            continue
        store = cl.stores[g]
        pool.check_pool(store.state, tables=list(store.tables.values()))
    if cl.tier is not None:
        tier_lib.check_tier(cl.tier)
        assert not cl.tier.holdings, "tier not drained"


def assert_parity(base, got, what):
    assert set(got) == set(base), (
        f"{what}: finished rids {sorted(got)} != {sorted(base)}"
    )
    for rid, want in base.items():
        assert got[rid] == want, (
            f"{what}: rid {rid} tokens diverged\n  want {want}\n  got  "
            f"{got[rid]}"
        )


# --------------------------------------------------------------------------- #
def scenario_kill_decode(cfg, model, ctx, params):
    """1P+2D+2M(+1 spare idle): kill one decode rank in the
    mid-KV-handoff window; every request completes bit-identically."""
    shape = dict(n_prefill=1, n_decode=2, n_memory=2, n_spare=1,
                 decode_batch=2, cache_len=48)
    reqs = make_requests(cfg, np.random.default_rng(3))
    _, _, base = run_cluster(model, ctx, params, reqs, **shape)

    def mid_handoff_target(cl):
        # a push whose put launched THIS tick and whose ack is about to
        # be consumed: killing its target now is the mid-handoff death
        for push in cl.pending_push:
            if push is not None and not cl._group_down(push[1]):
                return cl.decode_rank(push[1])
        return None

    inj = FaultInjector(
        [{"tick": 2, "phase": "pre_consume", "kill": mid_handoff_target}]
    )
    reqs = make_requests(cfg, np.random.default_rng(3))
    cl, stats, toks = run_cluster(
        model, ctx, params, reqs, hook=inj, **shape
    )
    assert inj.log, "injector never fired (no mid-flight push found)"
    assert stats["rank_failures"] == 1, stats["rank_failures"]
    assert stats["recovered_reroutes"] + stats["recovered_recompute"] >= 1
    assert_parity(base, toks, "kill-decode")
    check_survivors(cl)
    dead = inj.log[0][2]
    assert np.isnan(cl.kvseg[dead]).all(), "dead rank's mirror unpoisoned"
    print(f"kill-decode OK: rank {dead} died mid-handoff at tick "
          f"{inj.log[0][0]}, {stats['recovered_reroutes']} rerouted / "
          f"{stats['recovered_recompute']} recomputed, tokens bit-exact")


def scenario_quorum_restore(cfg, model, ctx, params):
    """Replicated swap-outs survive a memory-rank loss: the example's
    pressure burst with ``tier_replicas=2``, primary leg killed while
    holdings are out — restores read the surviving replica."""
    from repro.launch.serve import Request
    from repro.serving.scheduler import SLO

    def burst():
        rng = np.random.default_rng(11)
        reqs = []
        for rid in range(5):
            plen = int(rng.integers(18, 28))
            reqs.append(Request(
                rid=rid,
                prompt=rng.integers(0, cfg.vocab, size=plen).tolist(),
                max_new=14 if rid < 3 else 8,
            ))
        for r in reqs[:3]:
            r.slo = SLO(priority=0)
        for r in reqs[3:]:
            r.slo = SLO(priority=2)
        return reqs

    shape = dict(n_prefill=1, n_decode=1, n_memory=2, decode_batch=2,
                 cache_len=48, pages_per_rank=8, tier_replicas=2,
                 replicate_all_swaps=True)

    def run(hook):
        reqs = burst()
        return run_cluster(model, ctx, params, reqs[:3], hook=hook,
                           ticks_before=8, late_reqs=reqs[3:], **shape)

    _, bstats, base = run(None)
    assert bstats["sched_swaps"] >= 1, "pressure burst produced no swap"
    assert bstats["tier_replica_pages"] >= 1, "no replicated swap pages"

    def primary_leg(cl):
        if cl.tier is None or not cl.tier.holdings:
            return None
        h = next(iter(cl.tier.holdings.values()))
        return cl.memory_rank(h.rank)

    inj = FaultInjector([{"tick": 9, "phase": "tick", "kill": primary_leg}])
    cl, stats, toks = run(inj)
    assert inj.log, "no holding was resident to kill under"
    assert stats["rank_failures"] == 1
    assert stats["tier_quorum_restores"] >= 1, stats
    assert stats["recovered_recompute"] == 0, (
        "replicated pages should never fall back to recompute", stats,
    )
    assert_parity(base, toks, "quorum-restore")
    check_survivors(cl)
    print(f"quorum-restore OK: memory rank {inj.log[0][2]} died with "
          f"{stats['tier_quorum_restores']} quorum restore(s), "
          f"0 recompute fallbacks, tokens bit-exact")


def scenario_elastic_join(cfg, model, ctx, params):
    """A spare promotes into a new decode group mid-run; the prefix
    index migrates over vectored RMA and the joined rank serves."""
    shape = dict(n_prefill=1, n_decode=1, n_spare=1, decode_batch=2,
                 cache_len=48)
    allreqs = make_requests(cfg, np.random.default_rng(5))
    first, second = allreqs[:4], allreqs[4:]

    # no-join twin for parity
    base_first = make_requests(cfg, np.random.default_rng(5))
    _, _, base = run_cluster(
        model, ctx, params, base_first[:4], ticks_before=6,
        late_reqs=base_first[4:], **shape,
    )

    from repro.serving.disagg import DisaggCluster

    cl = DisaggCluster(model, ctx, params, paged=True,
                       page_tokens=PAGE_TOKENS, **shape)
    for r in first:
        cl.submit(r)
    for _ in range(6):
        cl.tick()
    joined = cl.join_decode_rank()
    assert cl.roles[joined] == "decode" and cl.n_groups == 2
    for r in second:
        cl.submit(r)
    stats = cl.run_until_drained(max_ticks=800)
    toks = {r.rid: list(r.out) for r in cl.finished}
    assert stats["elastic_joins"] == 1
    assert stats["migrated_prefix_pages"] >= 1, (
        "prefix index did not migrate", stats,
    )
    served = len(cl.decode_servers[-1].finished)
    assert served >= 1, "joined rank served nothing"
    assert_parity(base, toks, "elastic-join")
    # drop the adopted prefix cache and require a fully drained pool
    cl.stores[-1].release_prefix_cache()
    check_survivors(cl)
    print(f"elastic-join OK: rank {joined} promoted, "
          f"{stats['migrated_prefix_pages']} prefix page(s) migrated, "
          f"{served} request(s) served on the joined rank, tokens "
          f"bit-exact")


def scenario_heartbeat_delay(cfg, model, ctx, params):
    """Beats delayed for fewer ticks than the timeout are NOT failures."""
    shape = dict(n_prefill=1, n_decode=1, decode_batch=2, cache_len=48,
                 heartbeat_timeout=3)
    reqs = make_requests(cfg, np.random.default_rng(7), n=4)
    _, _, base = run_cluster(model, ctx, params, reqs, **shape)

    from repro.serving.disagg import DisaggCluster

    cl = DisaggCluster(model, ctx, params, paged=True,
                       page_tokens=PAGE_TOKENS, **shape)
    # rank 1 goes silent for ticks 3..5 (3 missed beats == timeout, the
    # detector requires STRICTLY more) then recovers
    cl.beat_filter = lambda rank, tick: not (rank == 1 and 3 <= tick <= 5)
    reqs = make_requests(cfg, np.random.default_rng(7), n=4)
    for r in reqs:
        cl.submit(r)
    stats = cl.run_until_drained(max_ticks=800)
    toks = {r.rid: list(r.out) for r in cl.finished}
    assert stats["rank_failures"] == 0, (
        "delay below the timeout tripped failure detection", stats,
    )
    assert not cl.monitor.failed
    assert_parity(base, toks, "heartbeat-delay")
    print("heartbeat-delay OK: 3 missed beats < timeout declared nothing "
          "dead, tokens bit-exact")


def scenario_chaos(cfg, model, ctx, params, seed):
    """Randomised kill drawn from ``seed``: victim role (decode, memory,
    spare), tick, and phase vary; parity + invariants must hold."""
    rng = np.random.default_rng(seed)
    shape = dict(n_prefill=1, n_decode=2, n_memory=2, n_spare=1,
                 decode_batch=2, cache_len=48, tier_replicas=2,
                 replicate_all_swaps=True)
    reqs = make_requests(cfg, np.random.default_rng(seed + 1))
    _, _, base = run_cluster(model, ctx, params, reqs, **shape)

    victim = int(rng.choice([1, 2, 3, 4, 5]))  # decode, memory, or spare
    tick = int(rng.integers(2, 12))
    phase = str(rng.choice(["tick", "pre_consume"]))
    inj = FaultInjector([{"tick": tick, "phase": phase, "kill": victim}])
    reqs = make_requests(cfg, np.random.default_rng(seed + 1))
    cl, stats, toks = run_cluster(
        model, ctx, params, reqs, hook=inj, **shape
    )
    assert inj.log, "chaos kill never fired"
    assert stats["rank_failures"] == 1
    assert_parity(base, toks, f"chaos(seed={seed})")
    check_survivors(cl)
    print(f"chaos OK: seed={seed} killed rank {victim} "
          f"({cl.roles[victim] if victim < len(cl.roles) else '?'}) at "
          f"tick {tick}/{phase}, tokens bit-exact")


def main(argv=None) -> None:
    from repro.obs import export as obs_export
    from repro.obs import trace as obs_trace

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int, default=0,
                    help="chaos scenario seed (echo into CI summaries)")
    ap.add_argument("--fast", action="store_true",
                    help="fixed-seed PR subset (skips quorum + chaos)")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="write the suite's chrome trace here on success "
                         "(export.validate-checked) so the nightly chaos "
                         "run leaves an inspectable artifact, not just a "
                         "pass line")
    args = ap.parse_args(argv)

    print(f"fault_suite: seed={args.seed} fast={args.fast}")
    cfg, model, ctx, params = build_model_once()

    # the whole suite runs under the tracer so a failing scenario leaves
    # a flight-recorder window: the last ticks of spans/instants (rank
    # deaths, heartbeat misses, handoffs) land in the CI step summary
    # with the replay seed — the post-mortem a nightly chaos failure
    # otherwise wouldn't have
    tracer = obs_trace.enable(capacity=1 << 16)
    try:
        scenario_kill_decode(cfg, model, ctx, params)
        scenario_elastic_join(cfg, model, ctx, params)
        scenario_heartbeat_delay(cfg, model, ctx, params)
        if not args.fast:
            scenario_quorum_restore(cfg, model, ctx, params)
            scenario_chaos(cfg, model, ctx, params, args.seed)
    except BaseException:
        dump = obs_export.flight_dump(
            tracer, 64,
            reason=f"fault_suite scenario failed (seed {args.seed})",
            seed=args.seed,
        )
        summary = obs_export.render_flight_summary(dump)
        print(summary)
        step_summary = os.environ.get("GITHUB_STEP_SUMMARY")
        if step_summary:
            with open(step_summary, "a") as f:
                f.write(summary + "\n")
        raise
    else:
        if args.trace:
            # Dedicated single-cluster replay for the artifact: the
            # scenarios above interleave twin clusters (undisturbed base
            # + injected) on one tracer, and each cluster restarts the
            # tick clock — two streams at the same virtual timestamps
            # can never merge into one valid timeline.  The export
            # records one injected run alone: a decode rank dies
            # mid-run, recovery re-routes, and the whole thing lands as
            # a clean validated trace.
            obs_trace.disable()
            replay = obs_trace.enable(capacity=1 << 16)
            try:
                inj = FaultInjector(
                    [{"tick": 2, "phase": "tick", "kill": 1}])
                run_cluster(
                    model, ctx, params,
                    make_requests(cfg, np.random.default_rng(3)),
                    hook=inj,
                    n_prefill=1, n_decode=2, n_memory=2, n_spare=1,
                    decode_batch=2, cache_len=48,
                    metrics=replay.registry,
                )
                assert inj.log, "traced replay: kill never fired"
            finally:
                obs_trace.disable()
            trace = obs_export.chrome_trace(replay, labels=["chaos_replay"])
            problems = obs_export.validate(trace, replay.registry)
            if problems:
                for p in problems:
                    print(f"trace INVALID: {p}")
                raise SystemExit(
                    f"fault_suite trace failed export.validate with "
                    f"{len(problems)} problem(s) — the artifact a "
                    f"post-mortem would load is malformed"
                )
            obs_export.write_trace(trace, args.trace)
            print(f"trace OK: {args.trace} "
                  f"({len(trace['traceEvents'])} events, validated: "
                  f"spans nest, every RMA synced, bytes == counters)")
    finally:
        obs_trace.disable()

    print("FAULT_SUITE_PASS")


if __name__ == "__main__":
    main()

"""GAS layer suite: segments, put/get, AMs, ring collectives (8 devices)."""
import os

if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map


def main() -> None:
    from repro.core import am, collectives, gasnet
    from repro.core.engine import make_engine

    mesh = jax.make_mesh((8,), ("node",))
    ctx = gasnet.Context(mesh, node_axis="node", backend="xla",
                         am_payload_width=4)

    aspace = ctx.address_space()
    aspace.register("buf", (32,), jnp.float32)
    seg = aspace.alloc("buf")
    assert seg.shape == (8, 32)

    # ---- one-sided put (Shift pattern, sender-chosen offset) ------------
    def prog(node, seg):
        data = jnp.full((4,), node.my_id, jnp.float32)
        seg = node.put(seg, data, to=gasnet.Shift(1), index=2)
        node.barrier()
        return seg

    got = np.asarray(ctx.spmd(prog, seg))
    for n in range(8):
        np.testing.assert_allclose(got[n, 2:6], (n - 1) % 8)
        np.testing.assert_allclose(got[n, :2], 0)
    print("put OK")

    # ---- put with Perm pattern ------------------------------------------
    perm = (3, 0, 7, 1, 2, 6, 5, 4)

    def prog_perm(node, seg):
        data = jnp.full((4,), node.my_id, jnp.float32)
        return node.put(seg, data, to=gasnet.Perm(perm), index=0)

    got = np.asarray(ctx.spmd(prog_perm, seg))
    for s, d in enumerate(perm):
        np.testing.assert_allclose(got[d, :4], s)
    print("perm put OK")

    # ---- one-sided get ----------------------------------------------------
    seg2 = ctx.spmd(prog, seg)

    def prog_get(node, seg):
        return node.get(seg, frm=gasnet.Shift(3), index=2, size=4)[None]

    out = np.asarray(ctx.spmd(prog_get, seg2, out_specs=P("node")))
    for n in range(8):
        np.testing.assert_allclose(out[n], (n + 3 - 1) % 8)
    print("get OK")

    # ---- ring collectives vs natives --------------------------------------
    x = jnp.arange(8.0 * 16).reshape(8, 16)

    def prog_coll(node, x):
        e = node.engine
        ag = collectives.ring_all_gather(e, node.local(x))
        rs = collectives.ring_reduce_scatter(e, ag)
        ar = collectives.ring_all_reduce(e, node.local(x) * 1.0)
        return ag[None], rs[None], ar[None]

    ag, rs, ar = ctx.spmd(
        prog_coll, x, out_specs=(P("node"), P("node"), P("node"))
    )
    ag, rs, ar = map(np.asarray, (ag, rs, ar))
    xg = np.asarray(x)
    for n in range(8):
        np.testing.assert_allclose(ag[n], xg.reshape(-1))
        np.testing.assert_allclose(rs[n], 8 * xg.reshape(8, 16)[n])
    np.testing.assert_allclose(ar, np.tile(xg.sum(0), (8, 1)))
    print("ring collectives OK")

    # ---- hierarchical all-reduce (2 pods x 4) -----------------------------
    mesh2 = jax.make_mesh((2, 4), ("pod", "data"))
    xx = jnp.arange(8.0 * 16).reshape(2, 4, 16) / 7.0

    def prog_hier(x_l):
        inner = make_engine("xla", "data", 4)
        outer = make_engine("xla", "pod", 2)
        return collectives.hierarchical_all_reduce(inner, outer, x_l[0, 0])[
            None, None
        ]

    hier = jax.jit(
        shard_map(
            prog_hier, mesh=mesh2, in_specs=(P("pod", "data"),),
            out_specs=P("pod", "data"), check_vma=False,
        )
    )(xx)
    np.testing.assert_allclose(
        np.asarray(hier), np.tile(np.asarray(xx).sum((0, 1)), (2, 4, 1)),
        rtol=1e-6,
    )
    print("hierarchical all-reduce OK")

    # ---- active messages: counters + AMLong writes -------------------------
    handlers = ctx.handlers

    @handlers.handler("count")
    def h_count(state, payload, args):
        out = dict(state)
        out["cnt"] = state["cnt"] + args[0]
        return out

    handlers.register("write", am.long_write_handler("buf"))

    def prog_am(node, seg):
        state = {"cnt": jnp.zeros((), jnp.int32), "buf": node.local(seg)}
        d1 = jnp.asarray((node.my_id + 2) % 8, jnp.int32)
        node.am_short(d1, "count", args=(3,))
        node.am_short(d1, "count", args=(4,))
        node.am_long(
            jnp.asarray((node.my_id + 1) % 8, jnp.int32), "write",
            payload=jnp.full((4,), 100 + node.my_id, jnp.float32),
            dst_index=8,
        )
        state = node.am_flush(state)
        return state["cnt"][None], state["buf"][None]

    cnt, buf = ctx.spmd(prog_am, seg, out_specs=(P("node"), P("node")))
    cnt, buf = np.asarray(cnt), np.asarray(buf)
    np.testing.assert_array_equal(cnt, 7)
    for n in range(8):
        np.testing.assert_allclose(buf[n, 8:12], 100 + (n - 1) % 8)
    print("active messages OK")

    # ---- AM overflow accounting -------------------------------------------
    ctx2 = gasnet.Context(mesh, node_axis="node", backend="xla",
                          am_payload_width=4, am_capacity=4,
                          am_per_peer_capacity=1)
    ctx2.handlers.register("count", h_count)

    def prog_over(node, seg):
        state = {"cnt": jnp.zeros((), jnp.int32)}
        d = jnp.asarray((node.my_id + 1) % 8, jnp.int32)
        for _ in range(3):  # 3 messages to the same peer, capacity 1
            node.am_short(d, "count", args=(1,))
        state = node.am_flush(state)
        return state["cnt"][None], node.dropped[None]

    cnt, dropped = ctx2.spmd(prog_over, seg, out_specs=(P("node"), P("node")))
    np.testing.assert_array_equal(np.asarray(cnt), 1)
    np.testing.assert_array_equal(np.asarray(dropped), 2)
    print("AM overflow accounting OK")

    # ---- AM request/reply: round trip + ack handles, xla vs mixed map ------
    def run_request_reply(backend):
        ctx_rr = gasnet.Context(mesh, node_axis="node", backend=backend,
                                am_payload_width=4)
        table = ctx_rr.handlers

        def pong(state, payload, args):
            out = dict(state)
            out["ack_payload"] = payload
            out["ack_arg"] = state["ack_arg"] + args[0]
            return out

        pong_id = table.register("pong", pong)

        def ping(state, payload, args):
            out = dict(state)
            out["got"] = state["got"] + args[0]
            reply = am.reply_medium(
                pong_id, payload + 1.0, args=(args[0] + 1,)
            )
            return out, reply

        table.register("ping", ping, replies=True)

        def prog_rr(node, seg):
            me = node.my_id
            state = {
                "got": jnp.zeros((), jnp.int32),
                "ack_arg": jnp.zeros((), jnp.int32),
                "ack_payload": jnp.zeros((4,), jnp.float32),
            }
            h = node.am_call(
                (me + 3) % 8, "ping",
                payload=jnp.full((4,), me, jnp.float32),
                args=(me * 10,), ack=lambda st: st["ack_payload"],
            )
            state = node.am_flush(state)
            acked = node.sync(h)
            return (state["got"][None], state["ack_arg"][None],
                    acked[None])

        return tuple(
            np.asarray(o) for o in ctx_rr.spmd(
                prog_rr, seg, out_specs=(P("node"),) * 3
            )
        )

    rr_sw = run_request_reply("xla")
    got, ack_arg, acked = rr_sw
    for n in range(8):
        assert int(got[n]) == ((n - 3) % 8) * 10          # request landed
        assert int(ack_arg[n]) == n * 10 + 1              # reply came back
        np.testing.assert_allclose(acked[n], n + 1.0)     # ack handle value
    rr_mix = run_request_reply("xla,gascore")
    for name, a, b in zip(("got", "ack_arg", "ack_payload"), rr_sw, rr_mix):
        np.testing.assert_allclose(a, b, err_msg=f"request/reply: {name}")
    print("AM request/reply round trip OK (xla + mixed map)")

    # ---- Extended API: split-phase non-blocking put/get --------------------
    def prog_nb(node, seg):
        # initiate, overlap independent compute, then sync
        h = node.put_nb(seg, jnp.full((4,), node.my_id, jnp.float32),
                        to=gasnet.Shift(1), index=2)
        overlapped = jnp.sum(node.local(seg) * 2.0)  # no dep on the transfer
        seg = node.sync(h)
        g = node.get_nb(seg, frm=gasnet.Shift(3), index=2, size=4)
        done, got = node.try_sync(g)
        assert done
        return seg, got[None] + 0.0 * overlapped

    seg_nb, got = ctx.spmd(prog_nb, seg, out_specs=(P("node"), P("node")))
    seg_blk = ctx.spmd(prog, seg)  # the blocking version of the same put
    np.testing.assert_allclose(np.asarray(seg_nb), np.asarray(seg_blk))
    for n in range(8):
        np.testing.assert_allclose(np.asarray(got)[n], (n + 3 - 1) % 8)
    print("nb put/get OK")

    # ---- vectored get (get_nbv): one request/reply pair for m slices -------
    def prog_nbv(node, seg):
        # gated fetch: odd ranks trace the same transfers but get zeros
        h = node.get_nbv(seg, frm=gasnet.Shift(1), indices=[2, 6, 0],
                         size=2, pred=(node.my_id % 2) == 0)
        gated = node.sync(h)
        # ungated fetch via the blocking wrapper
        allv = node.get_v(seg, frm=gasnet.Shift(3), indices=[4, 0], size=3)
        return gated[None], allv[None]

    seg_src = ctx.spmd(prog, seg)  # deterministic contents (put suite above)
    gated, allv = map(
        np.asarray,
        ctx.spmd(prog_nbv, seg_src, out_specs=(P("node"), P("node"))),
    )
    src_seg = np.asarray(seg_src)
    for n in range(8):
        want = np.stack([src_seg[(n + 1) % 8, i : i + 2] for i in (2, 6, 0)])
        if n % 2 == 0:
            np.testing.assert_allclose(gated[n], want)
        else:
            np.testing.assert_allclose(gated[n], 0.0)
        want3 = np.stack([src_seg[(n + 3) % 8, i : i + 3] for i in (4, 0)])
        np.testing.assert_allclose(allv[n], want3)
    print("vectored get (incl. pred-gated) OK")

    # ---- vectored put (put_nbv): m writes + offsets in one command block ---
    def prog_pnbv(node, seg):
        datas = jnp.stack(
            [jnp.full((3,), 1.0 + node.my_id * 10 + j) for j in range(2)]
        )
        # per-payload flags: payload 1 ships gated-off from odd ranks
        h = node.put_nbv(seg, datas, to=gasnet.Shift(1), indices=[2, 20],
                         pred=[True, (node.my_id % 2) == 0])
        overlapped = jnp.sum(node.local(seg))  # no dep on the transfer
        seg = node.sync(h)
        # blocking wrapper, Perm pattern
        seg = node.put_v(seg, jnp.full((1, 4), 70.0 + node.my_id),
                         to=gasnet.Perm(perm), indices=[10])
        return seg + 0.0 * overlapped

    zseg = aspace.alloc("buf")
    got = np.asarray(ctx.spmd(prog_pnbv, zseg))
    for n in range(8):
        src = (n - 1) % 8
        np.testing.assert_allclose(got[n, 2:5], 1.0 + src * 10)
        if src % 2 == 0:
            np.testing.assert_allclose(got[n, 20:23], 2.0 + src * 10)
        else:
            np.testing.assert_allclose(got[n, 20:23], 0.0)
        np.testing.assert_allclose(got[n, 10:14], 70.0 + perm.index(n))
    print("vectored put (incl. per-page pred) OK")

    def prog_nb_all(node, seg):
        node.put_nb(seg, jnp.full((2,), 1.0, jnp.float32),
                    to=gasnet.Shift(1), index=0)
        node.get_nb(seg, frm=gasnet.Shift(1), index=2, size=2)
        seg2, got = node.sync_all()  # FIFO completion
        return seg2, got[None]

    seg_all, _ = ctx.spmd(prog_nb_all, seg, out_specs=(P("node"), P("node")))
    np.testing.assert_allclose(np.asarray(seg_all)[:, :2], 1.0)
    print("sync_all OK")

    # ---- new collectives: broadcast + exchange (all-to-all) ----------------
    def prog_bcex(node, x):
        e = node.engine
        bc = collectives.broadcast(e, node.local(x), root=5)
        ex = collectives.exchange(e, node.local(x))
        return bc[None], ex[None]

    bc, ex = ctx.spmd(prog_bcex, x, out_specs=(P("node"), P("node")))
    bc, ex = np.asarray(bc), np.asarray(ex)
    for n in range(8):
        np.testing.assert_allclose(bc[n], xg[5])
    np.testing.assert_allclose(
        ex.reshape(8, 8, 2), xg.reshape(8, 8, 2).transpose(1, 0, 2)
    )
    print("broadcast/exchange OK")

    # ---- engine parity (xla vs gascore) for every Extended op --------------
    ctx_hw = gasnet.Context(mesh, node_axis="node", backend="gascore")
    xk = jnp.arange(8.0 * 8 * 128).reshape(8, 8, 128)
    aspace_hw = ctx_hw.address_space()
    aspace_hw.register("kbuf", (8, 128), jnp.float32)
    segk = aspace_hw.alloc("kbuf")

    def prog_ext(node, seg, x):
        h = node.put_nb(seg, jnp.full((128,), 1.0 + node.my_id, jnp.float32),
                        to=gasnet.Shift(1), index=128)
        seg = node.sync(h)
        g = node.get_nb(seg, frm=gasnet.Shift(1), index=128, size=128)
        got = node.sync(g)
        # vectored multi-get, gated on even ranks: both engines must agree
        gv = node.get_nbv(seg, frm=gasnet.Shift(2), indices=[128, 0, 192],
                          size=64, pred=(node.my_id % 2) == 0)
        gotv = node.sync(gv)
        # vectored multi-put (per-payload flags): the write-side mirror
        pv = node.put_nbv(
            seg,
            [jnp.full((32,), 5.0 + node.my_id), jnp.full((32,), 9.0)],
            to=gasnet.Shift(3), indices=[256, 640],
            pred=[True, (node.my_id % 2) == 0],
        )
        seg = node.sync(pv)
        e = node.engine
        bc = collectives.broadcast(e, node.local(x), root=2)
        ex = collectives.exchange(e, node.local(x))
        return seg, got[None], gotv[None], bc[None], ex[None]

    specs = (P("node"),) * 5
    sw = ctx.spmd(prog_ext, segk, xk, out_specs=specs)
    hw = ctx_hw.spmd(prog_ext, segk, xk, out_specs=specs)
    for name, a, b in zip(("put_nb/put_nbv/sync", "get_nb", "get_nbv(pred)",
                           "broadcast", "exchange"), sw, hw):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-6,
            err_msg=f"engine parity: {name}",
        )
    print("extended engine parity OK (incl. vectored get + put)")

    # ---- heterogeneous EngineMap: mixed sw/hw nodes, same parity suite -----
    # Alternating software (XLA) and hardware (GAScore) ranks in ONE mesh:
    # the paper's mixed cluster.  The identical Extended-API program must
    # produce identical results.
    ctx_mix = gasnet.Context(mesh, node_axis="node", backend="xla,gascore")
    mix = ctx_mix.spmd(prog_ext, segk, xk, out_specs=specs)
    for name, a, b in zip(("put_nb/put_nbv/sync", "get_nb", "get_nbv(pred)",
                           "broadcast", "exchange"), sw, mix):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-6,
            err_msg=f"mixed-map parity: {name}",
        )
    print("heterogeneous EngineMap parity OK")

    # ---- scheduler: segmented rings match monolithic; plans dispatch -------
    from repro.core import sched

    xi = jnp.arange(8 * 16, dtype=jnp.int32).reshape(8, 16) * 7 - 300

    def prog_seg(node, x):
        e = node.engine
        xl = node.local(x)
        mono = collectives.ring_all_reduce(e, xl)
        seg = collectives.segmented_ring_all_reduce(
            e, xl, n_segments=3, depth=2
        )
        agm = collectives.ring_all_gather(e, xl)
        ags = collectives.segmented_ring_all_gather(
            e, xl, n_segments=4, depth=3
        )
        planned = sched.all_reduce(e, xl)
        return mono[None], seg[None], agm[None], ags[None], planned[None]

    for c in (ctx, ctx_mix):
        mono, seg, agm, ags, planned = map(
            np.asarray, c.spmd(prog_seg, xi, out_specs=(P("node"),) * 5)
        )
        np.testing.assert_array_equal(mono, seg)
        np.testing.assert_array_equal(agm, ags)
        np.testing.assert_array_equal(
            planned, np.tile(np.asarray(xi).sum(0), (8, 1))
        )
    print("segmented + planned collectives OK")

    # ---- TP-group all-reduce at decode-step payloads -----------------------
    # the per-sub-block partial sum of a tensor-parallel decode group:
    # (B, 1, D)-shaped activations, f32 and bf16, must agree across pure
    # software, pure hardware, and mixed engine maps (the ISSUE's
    # heterogeneous TP groups) and match the numpy sum
    def prog_tp(node, x):
        return sched.all_reduce(node.engine, node.local(x))[None]

    for dt, tol in ((jnp.float32, 1e-6), (jnp.bfloat16, 0.05)):
        xa = (jnp.arange(8.0 * 4 * 1 * 128).reshape(8, 4, 1, 128) / 37.0
              - 5.0).astype(dt)
        want = np.tile(
            np.asarray(xa.astype(jnp.float32)).sum(0), (8, 1, 1, 1)
        )
        outs = {
            name: np.asarray(
                c.spmd(prog_tp, xa, out_specs=P("node"))
            ).astype(np.float32)
            for name, c in (("xla", ctx), ("gascore", ctx_hw),
                            ("mixed", ctx_mix))
        }
        for name, o in outs.items():
            np.testing.assert_allclose(
                o, want, rtol=tol,
                err_msg=f"TP all-reduce vs numpy on {name} ({dt.__name__})",
            )
            np.testing.assert_allclose(
                o, outs["xla"], rtol=tol,
                err_msg=f"TP all-reduce engine parity: {name}",
            )
    print("TP-group all-reduce parity OK (decode payloads, f32+bf16)")

    print("GAS_SUITE_PASS")


if __name__ == "__main__":
    main()
